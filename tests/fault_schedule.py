"""Deterministic fault injection for the storage stack.

The gc-vs-push race (and every other concurrency contract in the sync
layer) is only testable if interleavings can be *scheduled*, not hoped
for.  This module provides that instrument:

``Schedule``
    Maps named **sync points** to actions.  A sync point is
    ``"<op>:before"`` / ``"<op>:after"`` (store wrapper) or
    ``"wire:<op>:before"`` / ``"wire:<op>:after"`` (transport wrapper).
    Actions: **gate** (block the arriving thread until the test releases
    it — how a push is frozen between its uploads and its ``cas_refs``),
    **kill** (raise :class:`InjectedFault`, a ``RemoteError`` subclass —
    at ``:before`` the request was never delivered, at ``:after`` it was:
    the ambiguous case), **delay** (sleep — reorders concurrent ops).

``SeededSchedule``
    Randomized fuzzing with *positional determinism*: the decision for
    the N-th arrival at a sync point is drawn from
    ``Random(f"{seed}:{point}:{n}")`` — independent of thread timing, so
    a seed names a reproducible fault pattern even under a racy
    interleaving.  Every decision is logged; :meth:`SeededSchedule.to_json`
    dumps the pattern for the CI failure artifact.

``FaultyStore`` / ``FaultyTransport``
    Transparent wrappers over any ``StoreBackend`` / transport that fire
    the schedule around each intercepted operation.

Used by tests/test_gc_race.py (deterministic gc-vs-push interleavings)
and the seeded-fuzz leg of tests/sync_conformance.py.
"""

from __future__ import annotations

import json
import random
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import msgpack

from repro.core.errors import RemoteError


class InjectedFault(RemoteError):
    """A scheduled fault — distinguishable from real transport errors."""


class Gate:
    """A pause point: the arriving thread sets ``reached`` and blocks on
    ``release``.  Tests wait for ``reached`` (the op is now frozen at the
    sync point), interleave whatever they want, then ``release.set()``."""

    def __init__(self, point: str):
        self.point = point
        self.reached = threading.Event()
        self.release = threading.Event()

    def wait_reached(self, timeout: float = 30.0) -> None:
        if not self.reached.wait(timeout):
            raise AssertionError(
                f"no thread arrived at sync point {self.point!r} "
                f"within {timeout}s")

    def open(self) -> None:
        self.release.set()


class Schedule:
    """Explicit, programmable fault schedule (deterministic tests).

    Rules are registered per sync point with an optional 1-based
    ``occurrence`` (None = every arrival).  Thread-safe; arrival counts
    are per point.
    """

    _GATE_TIMEOUT = 60.0

    def __init__(self):
        self._rules: Dict[str, List[Tuple[Optional[int], Tuple]] ] = {}
        self._counts: Dict[str, int] = {}
        self._lock = threading.Lock()
        #: every arrival that triggered an action: (point, n, action)
        self.log: List[Tuple[str, int, str]] = []

    # ------------------------------------------------------------- rules
    def _add(self, point: str, action: Tuple,
             occurrence: Optional[int]) -> None:
        with self._lock:
            self._rules.setdefault(point, []).append((occurrence, action))

    def gate(self, point: str, *, occurrence: Optional[int] = 1) -> Gate:
        """Freeze the ``occurrence``-th arrival at ``point`` until the
        returned :class:`Gate` is opened."""
        g = Gate(point)
        self._add(point, ("gate", g), occurrence)
        return g

    def kill(self, point: str, *, occurrence: Optional[int] = 1,
             times: int = 1) -> "Schedule":
        """Raise :class:`InjectedFault` at ``point`` (``times`` arrivals
        starting from ``occurrence``; with ``occurrence=None`` every
        arrival dies)."""
        if occurrence is None:
            self._add(point, ("kill",), None)
        else:
            for i in range(times):
                self._add(point, ("kill",), occurrence + i)
        return self

    def delay(self, point: str, seconds: float, *,
              occurrence: Optional[int] = None) -> "Schedule":
        self._add(point, ("delay", seconds), occurrence)
        return self

    # ----------------------------------------------------------- firing
    def _actions_for(self, point: str) -> Tuple[int, List[Tuple]]:
        with self._lock:
            n = self._counts[point] = self._counts.get(point, 0) + 1
            actions = [a for occ, a in self._rules.get(point, ())
                       if occ is None or occ == n]
            for a in actions:
                self.log.append((point, n, a[0]))
            return n, actions

    def fire(self, point: str) -> None:
        """Called by the wrappers at every sync point.  Applies matching
        actions in registration order; ``kill`` raises."""
        _n, actions = self._actions_for(point)
        for action in actions:
            if action[0] == "gate":
                g: Gate = action[1]
                g.reached.set()
                if not g.release.wait(self._GATE_TIMEOUT):
                    raise AssertionError(
                        f"gate at {point!r} never released "
                        f"({self._GATE_TIMEOUT}s)")
            elif action[0] == "delay":
                time.sleep(action[1])
            elif action[0] == "kill":
                raise InjectedFault(f"injected fault at {point!r}")


class SeededSchedule(Schedule):
    """Randomized schedule with positionally deterministic decisions.

    The N-th arrival at sync point P draws from
    ``Random(f"{seed}:{P}:{N}")`` — thread timing cannot change what a
    given (point, arrival) does, so ``seed`` fully names the fault
    pattern.  ``kill_points``/``delay_points`` are substring filters over
    sync-point names (e.g. ``"wire:"`` faults only the transport layer;
    ``"cas_refs"`` only ref updates).
    """

    def __init__(self, seed: int, *, p_kill: float = 0.04,
                 p_delay: float = 0.35, max_delay: float = 0.002,
                 kill_points: Tuple[str, ...] = (":before",),
                 delay_points: Tuple[str, ...] = ("",),
                 max_kills_per_point: int = 2):
        super().__init__()
        self.seed = seed
        self.p_kill = p_kill
        self.p_delay = p_delay
        self.max_delay = max_delay
        self.kill_points = kill_points
        self.delay_points = delay_points
        # cap consecutive kills so a retrying client (retries=2) always
        # gets through eventually: fuzzing probes interleavings, it must
        # not starve every operation into permanent failure
        self.max_kills_per_point = max_kills_per_point
        self._kills: Dict[str, int] = {}
        self.decisions: List[Dict[str, Any]] = []

    def fire(self, point: str) -> None:
        with self._lock:
            n = self._counts[point] = self._counts.get(point, 0) + 1
        rng = random.Random(f"{self.seed}:{point}:{n}")
        roll = rng.random()
        may_kill = (any(k in point for k in self.kill_points)
                    and self._kills.get(point, 0)
                    < self.max_kills_per_point)
        if may_kill and roll < self.p_kill:
            with self._lock:
                self._kills[point] = self._kills.get(point, 0) + 1
                self.decisions.append(
                    {"point": point, "n": n, "action": "kill"})
            raise InjectedFault(
                f"injected fault at {point!r} (seed {self.seed}, "
                f"arrival {n})")
        if (any(d in point for d in self.delay_points)
                and roll < self.p_kill + self.p_delay):
            delay = rng.random() * self.max_delay
            with self._lock:
                self.decisions.append(
                    {"point": point, "n": n, "action": "delay",
                     "seconds": delay})
            time.sleep(delay)

    def to_json(self) -> str:
        """The decision log as a replay artifact (uploaded by the CI
        gc-race job on failure: the seed reproduces the run, the log
        shows what it did)."""
        with self._lock:
            return json.dumps({"seed": self.seed,
                               "p_kill": self.p_kill,
                               "p_delay": self.p_delay,
                               "max_delay": self.max_delay,
                               "decisions": list(self.decisions)},
                              indent=2)


# ------------------------------------------------------------------ wrappers
#: StoreBackend methods wrapped with sync points.  Anything not listed
#: (root, _supports_encoded, gc_mark, ...) passes through untouched.
INTERCEPTED_OPS = (
    "put", "put_many", "put_encoded", "put_many_encoded",
    "get", "get_many", "get_encoded", "get_many_encoded",
    "has", "has_many", "size", "mtime", "delete_object",
    "set_ref", "get_ref", "cas_ref", "cas_refs", "delete_ref",
    "list_refs", "list_objects",
)


class FaultyStore:
    """A ``StoreBackend`` whose intercepted operations fire
    ``"<op>:before"`` / ``"<op>:after"`` on a :class:`Schedule`.

    Wraps *any* backend (filesystem, ``RemoteStore``, ``S3Backend``), so
    the same schedule drives races through every transport the
    conformance matrix covers.
    """

    def __init__(self, inner, schedule: Schedule):
        object.__setattr__(self, "inner", inner)
        object.__setattr__(self, "schedule", schedule)

    def __getattr__(self, name: str):
        attr = getattr(self.inner, name)
        if name in INTERCEPTED_OPS and callable(attr):
            schedule = self.schedule

            def wrapped(*args, _attr=attr, _name=name, **kwargs):
                schedule.fire(f"{_name}:before")
                out = _attr(*args, **kwargs)
                schedule.fire(f"{_name}:after")
                return out

            return wrapped
        return attr


class FaultyTransport:
    """A transport wrapper firing ``"wire:<op>:before"`` / ``":after"``.

    A kill at ``:before`` drops the request un-delivered (clean retryable
    failure); a kill at ``:after`` drops the *reply* after the server
    applied the request — the ambiguous case the sync layer resolves by
    re-reading refs."""

    def __init__(self, inner, schedule: Schedule):
        self.inner = inner
        self.schedule = schedule

    def request(self, payload: bytes) -> bytes:
        try:
            op = msgpack.unpackb(payload, raw=False).get("op", "?")
        except Exception:  # noqa: BLE001 - never block on a weird frame
            op = "?"
        self.schedule.fire(f"wire:{op}:before")
        reply = self.inner.request(payload)
        self.schedule.fire(f"wire:{op}:after")
        return reply

    def close(self) -> None:
        self.inner.close()
