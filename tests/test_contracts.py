"""Data contracts: catalog-enforced expectations (ROADMAP item 4).

WAP expectations are opt-in — a cooperating caller runs them before
publishing.  Contracts are attached to tables IN the catalog and enforced
at the ref update itself, so every path that can move a branch head
(commit, merge fast-forward, merge 3-way, publish) is gated, including
writers that bypass the write-audit-publish ceremony entirely.
"""

import numpy as np
import pytest

from repro.core import (CONTRACTS_TABLE, Catalog, Commit, ContractViolation,
                        ExpectationFailed, Lake, ObjectStore,
                        PermissionDenied, ReproError, Rule, TableIO,
                        parse_rule_spec, publish, rule)

GOOD = {"p": np.linspace(0.0, 1.0, 8).astype(np.float32)}
NANS = {"p": np.array([0.1, np.nan], np.float32)}
OUT_OF_RANGE = {"p": np.array([0.5, 1.5], np.float32)}

PROB_RULES = [rule("not_empty"), rule("no_nans"),
              rule("column_range", column="p", lo=0.0, hi=1.0)]


@pytest.fixture()
def open_lake(tmp_path):
    """protect_main=False: models an untrusted writer with direct commit
    access — exactly who contracts must stop."""
    return Lake(tmp_path / "open", protect_main=False)


def _contracted(lake):
    snap = lake.io.write_snapshot(GOOD)
    lake.catalog.commit("main", {"probs": snap}, "seed", _wap_token=True)
    lake.catalog.add_contract("probs", PROB_RULES, _wap_token=True)
    return snap


# ------------------------------------------------------------- enforcement
def test_direct_commit_of_violating_data_rejected(open_lake):
    """The untrusted-writer path: no WAP, no audit, straight commit —
    still rejected at the ref update."""
    lake = open_lake
    _contracted(lake)
    head = lake.catalog.head("main")
    bad = lake.io.write_snapshot(NANS)
    with pytest.raises(ContractViolation) as ei:
        lake.catalog.commit("main", {"probs": bad}, "sneaky")
    assert ei.value.table == "probs"
    assert any("no_nans" in name for name in ei.value.failures)
    assert lake.catalog.head("main") == head  # no ref moved


def test_contracts_are_inherited_by_branches(lake):
    _contracted(lake)
    lake.catalog.create_branch("u.dev", "main", author="u")
    bad = lake.io.write_snapshot(OUT_OF_RANGE)
    with pytest.raises(ContractViolation):
        lake.catalog.commit("u.dev", {"probs": bad}, "bad", author="u")
    good2 = lake.io.write_snapshot(GOOD)
    lake.catalog.commit("u.dev", {"probs": good2}, "fine", author="u")


def test_merge_3way_enforces_dst_contracts(lake):
    """Bad data committed on a branch that forked BEFORE the contract
    existed (so its own commits were unguarded) is caught when merged
    into the contracted destination."""
    snap = lake.io.write_snapshot(GOOD)
    lake.catalog.commit("main", {"probs": snap}, "seed", _wap_token=True)
    lake.catalog.create_branch("u.old", "main", author="u")
    bad = lake.io.write_snapshot(NANS)
    lake.catalog.commit("u.old", {"probs": bad}, "pre-contract", author="u")
    lake.catalog.add_contract("probs", PROB_RULES, _wap_token=True)
    with pytest.raises(ContractViolation):
        lake.catalog.merge("u.old", "main", _wap_token=True)
    assert lake.catalog.tables("main")["probs"] == snap


def test_merge_ff_enforces_contracts_against_raw_store_writer(open_lake):
    """A writer with raw store access handcrafts a commit (bypassing
    Catalog.commit entirely) and points its branch at it.  The merge —
    even a pure fast-forward — still runs the contracts before main's
    ref moves: enforcement is a property of the catalog, not of writer
    cooperation."""
    lake = open_lake
    _contracted(lake)
    head = lake.catalog.head("main")
    head_tables = lake.catalog.tables("main")
    bad = lake.io.write_snapshot(NANS)
    forged = lake.catalog._store_commit(Commit(
        (head,), {**head_tables, "probs": bad}, "forged", "rogue", 0.0))
    lake.catalog.store.set_ref("branch=rogue.b", forged)
    with pytest.raises(ContractViolation):
        lake.catalog.merge("rogue.b", "main")
    assert lake.catalog.head("main") == head


def test_publish_path_enforces_contracts(lake):
    """Passing the WAP audit is not enough: publish funnels through
    merge, where the catalog's contracts still gate the data.
    ContractViolation subclasses ExpectationFailed, so publish callers
    handle both uniformly."""
    snap = lake.io.write_snapshot(GOOD)
    lake.catalog.commit("main", {"probs": snap}, "seed", _wap_token=True)
    lake.catalog.create_branch("u.dev", "main", author="u")
    bad = lake.io.write_snapshot(NANS)
    lake.catalog.commit("u.dev", {"probs": bad}, "pre-contract", author="u")
    lake.catalog.add_contract("probs", PROB_RULES, _wap_token=True)
    with pytest.raises(ContractViolation) as ei:
        publish(lake.catalog, lake.io, "u.dev", [], author="u")
    assert isinstance(ei.value, ExpectationFailed)
    assert lake.catalog.tables("main")["probs"] == snap


def test_add_contract_over_violating_data_rejected(lake):
    """Attach-time validation: a contract can never be in force over
    data that already fails it."""
    bad = lake.io.write_snapshot(NANS)
    lake.catalog.commit("main", {"probs": bad}, "legacy", _wap_token=True)
    with pytest.raises(ContractViolation):
        lake.catalog.add_contract("probs", PROB_RULES, _wap_token=True)
    assert lake.catalog.contracts("main") == {}


def test_drop_contract_releases_the_gate(open_lake):
    lake = open_lake
    _contracted(lake)
    lake.catalog.drop_contract("probs")
    bad = lake.io.write_snapshot(NANS)
    lake.catalog.commit("main", {"probs": bad}, "now allowed")
    with pytest.raises(ReproError):
        lake.catalog.drop_contract("probs")  # nothing left to drop


def test_contracts_are_versioned_per_branch(lake):
    """A contract added on a branch gates that branch only — and rides a
    merge into main like any other table change."""
    snap = lake.io.write_snapshot(GOOD)
    lake.catalog.commit("main", {"probs": snap}, "seed", _wap_token=True)
    lake.catalog.create_branch("u.dev", "main", author="u")
    lake.catalog.add_contract("probs", PROB_RULES, branch="u.dev",
                              author="u")
    assert "probs" in lake.catalog.contracts("u.dev")
    assert lake.catalog.contracts("main") == {}
    lake.catalog.merge("u.dev", "main", _wap_token=True)
    assert "probs" in lake.catalog.contracts("main")
    bad = lake.io.write_snapshot(NANS)
    with pytest.raises(ContractViolation):
        lake.catalog.commit("main", {"probs": bad}, "bad", _wap_token=True)


def test_unknown_rule_kind_fails_closed(lake):
    """A rule kind this host doesn't have registered rejects the commit —
    enforcement never silently waves data through."""
    snap = lake.io.write_snapshot(GOOD)
    lake.catalog.commit("main", {"probs": snap}, "seed", _wap_token=True)
    with pytest.raises(ContractViolation) as ei:
        # Rule() directly: rule() would refuse the unknown kind eagerly
        lake.catalog.add_contract("probs", [Rule("from_the_future", {})],
                                  _wap_token=True)
    assert "unknown rule kind" in str(ei.value)


def test_cannot_contract_the_contracts_table(lake):
    with pytest.raises(PermissionDenied):
        lake.catalog.add_contract(CONTRACTS_TABLE, [rule("not_empty")],
                                  _wap_token=True)


def test_contracts_table_hidden_from_normal_writes(lake):
    """The reserved entry is catalog metadata: direct writes are refused
    (only add_contract/drop_contract may move it)."""
    with pytest.raises(PermissionDenied):
        lake.catalog.commit("main", {CONTRACTS_TABLE: "deadbeef"}, "sneak",
                            _wap_token=True)


def test_unchanged_tables_are_not_revalidated(lake, monkeypatch):
    """Enforcement only reads tables whose snapshot or contract moved —
    a commit to table B never pays a data read for contracted table A."""
    _contracted(lake)
    calls = []
    real_read = lake.catalog._table_io().read

    def counting_read(digest, columns=None):
        calls.append(digest)
        return real_read(digest, columns)

    monkeypatch.setattr(lake.catalog._table_io(), "read", counting_read)
    other = lake.io.write_snapshot({"v": np.ones(3, np.float32)})
    lake.catalog.commit("main", {"other": other}, "disjoint",
                        _wap_token=True)
    assert calls == []


# ------------------------------------------------------------ CLI rule specs
def test_parse_rule_spec_round_trip():
    assert parse_rule_spec("not_empty") == rule("not_empty")
    assert parse_rule_spec("no_nans") == rule("no_nans")
    assert parse_rule_spec("no_nans:p,q") == rule("no_nans",
                                                  columns=["p", "q"])
    assert parse_rule_spec("column_range:p,0,1") == rule(
        "column_range", column="p", lo=0.0, hi=1.0)
    assert parse_rule_spec("columns_required:a,b") == rule(
        "columns_required", columns=["a", "b"])


@pytest.mark.parametrize("spec", ["bogus", "column_range:p,0",
                                  "columns_required"])
def test_parse_rule_spec_rejects_malformed(spec):
    with pytest.raises(ReproError):
        parse_rule_spec(spec)
