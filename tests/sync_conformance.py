"""Sync conformance harness: one contract suite, every configuration.

The push/pull/clone layer promises the same semantics no matter how a store
is reached or how many transfer workers move the closure.  This module
states that contract ONCE as a list of checks and runs it against every

    backend   ×  transport  ×  concurrency
    (fs, tiered) (direct, loopback, http, s3, s3+sigv4)  (--jobs 1, --jobs N)

combination — ``s3`` reaches the remote through the S3-compatible REST
dialect (:class:`repro.core.s3.S3Backend` against the in-process stub
server; the ``s3+sigv4`` flavor additionally arms the stub's
signature verification, proving the canonical-request math on every
request), with the SAME directory read directly as the oracle: the stub's
bucket layout is byte-compatible with the filesystem store — "correct-by-design" sync treated as a testable interface
rather than an emergent property of one happy path:

* **round-trip**: push → pull reproduces heads, closures and table bytes
  bit-identically;
* **accounting**: ``SyncReport``/``MultiSyncReport`` byte/object counts are
  exact and dedup-aware, including when the remote already holds part of
  the closure;
* **atomicity**: a multi-ref push with one failing fast-forward leaves
  every ref on both sides unchanged, and the ``cas_refs`` primitive is
  all-or-nothing through every transport;
* **tags**: tag refs round-trip (push/pull/resolve) and root their closure
  against gc on both tiers;
* **concurrency safety**: two overlapping pushes never corrupt refs or
  lose blobs.

Run standalone (the CI leg) or through the pytest wrapper
(``tests/test_sync_conformance.py``):

    PYTHONPATH=src python -m tests.sync_conformance --jobs 1
    PYTHONPATH=src python -m tests.sync_conformance --jobs 8
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))  # fault_schedule under -m

import argparse
import shutil
import tempfile
import threading
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, List, Optional

import numpy as np

from fault_schedule import FaultyStore, FaultyTransport, SeededSchedule
from repro.core import (Lake, LoopbackTransport, ObjectStore, RemoteServer,
                        RemoteStore, SyncError, commit_closure, connect,
                        pull, pull_refs, push, push_refs, serve_http,
                        serve_s3)
from repro.core.errors import (ObjectNotFound, RefConflict, RefNotFound,
                               ReproError)
from repro.core.gc import collect

BACKENDS = ("fs", "tiered")
TRANSPORTS = ("direct", "loopback", "http", "s3", "s3+sigv4")


@dataclass(frozen=True)
class Combo:
    backend: str    # local lake flavor: plain ObjectStore or TieredStore
    transport: str  # how the remote is reached
    jobs: int       # transfer concurrency (1 = sequential)

    @property
    def ident(self) -> str:
        return f"{self.backend}/{self.transport}/jobs={self.jobs}"


class SyncContext:
    """One check's world: a fresh remote store plus lake/remote factories
    wired for one combo.  ``remote_store`` is the ground-truth filesystem
    tree behind every transport — checks use it as the oracle."""

    def __init__(self, combo: Combo, root: Path):
        self.combo = combo
        self.root = Path(root)
        self.remote_store = ObjectStore(self.root / "remote")
        self._server = RemoteServer(self.remote_store)
        self._httpd = None
        self._url: Optional[str] = None

    def remote(self):
        """A client handle onto the shared remote — one per call, so
        concurrent pushers never share a transport."""
        if self.combo.transport == "direct":
            return self.remote_store
        if self.combo.transport == "loopback":
            return RemoteStore(LoopbackTransport(self._server))
        if self._httpd is None:
            if self.combo.transport.startswith("s3"):
                # the stub serves the SAME tree remote_store reads — the
                # oracle stays a direct filesystem view of the bucket.
                # The sigv4 flavor arms signature verification: every
                # request of every check must carry a signature the stub
                # re-derives identically (creds ride the returned URL, so
                # connect() signs transparently)
                creds = None
                if self.combo.transport == "s3+sigv4":
                    from repro.core.sigv4 import Credentials
                    creds = Credentials("CONFORMANCEKEY",
                                        "conformance/secret+key")
                self._httpd, self._url = serve_s3(self.root / "remote",
                                                  credentials=creds)
            else:
                self._httpd, self._url = serve_http(self.remote_store)
        return connect(self._url)

    def lake(self, name: str) -> Lake:
        if self.combo.backend == "tiered":
            return Lake(self.root / name, protect_main=False,
                        remote=self.remote())
        return Lake(self.root / name, protect_main=False)

    def close(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()


def _seed(lake: Lake, branch: str, tables: int = 3, scale: float = 1.0,
          n: int = 96) -> None:
    for i in range(tables):
        lake.write_table(branch, f"t{i}",
                         {"v": np.arange(n, dtype=np.float32) * scale + i},
                         author=branch.split(".")[0])


def _closure_on_remote(ctx: SyncContext, store, head: str) -> None:
    for digest in commit_closure(store, head):
        assert ctx.remote_store.has(digest), \
            f"closure digest {digest[:12]} missing on remote"


# ------------------------------------------------------------------- checks
def check_round_trip(ctx: SyncContext) -> None:
    """push → pull: heads, closures and table bytes are bit-identical."""
    a = ctx.lake("a")
    _seed(a, "main")
    a.catalog.create_branch("u.exp", "main", author="u")
    _seed(a, "u.exp", tables=2, scale=3.0)
    rep = push(a.store, ctx.remote(), "u.exp", jobs=ctx.combo.jobs)
    assert rep.ref_updated and rep.objects_sent > 0
    head = a.catalog.head("u.exp")
    _closure_on_remote(ctx, a.store, head)

    b = ctx.lake("b")
    prep = pull(b.store, ctx.remote(), "u.exp", jobs=ctx.combo.jobs)
    if ctx.combo.backend == "fs":
        assert prep.ref_updated
    # a tiered lake already sees the remote head through the tier, so the
    # pull is legitimately a ref-noop there — head equality is the contract
    assert b.catalog.head("u.exp") == head
    for table in ("t0", "t1"):
        av, bv = a.read_table("u.exp", table), b.read_table("u.exp", table)
        np.testing.assert_array_equal(av["v"], bv["v"])


def check_accounting_exact(ctx: SyncContext) -> None:
    """Counts are exact and dedup-aware, also when the remote already has
    part of the closure (objects land once, bytes match blob sizes)."""
    a = ctx.lake("a")
    _seed(a, "main")
    a.catalog.create_branch("u.exp", "main", author="u")
    _seed(a, "u.exp", tables=2, scale=2.0)

    before = set(ctx.remote_store.iter_objects())
    first = push(a.store, ctx.remote(), "main", jobs=ctx.combo.jobs)
    after_main = set(ctx.remote_store.iter_objects())
    new = after_main - before
    assert first.objects_sent == len(new)
    assert first.bytes_sent == sum(len(a.store.get(d)) for d in new)

    # second push of a branch sharing all of main's history: only the delta
    second = push(a.store, ctx.remote(), "u.exp", jobs=ctx.combo.jobs)
    after_exp = set(ctx.remote_store.iter_objects())
    delta = after_exp - after_main
    assert second.objects_sent == len(delta)
    assert second.bytes_sent == sum(len(a.store.get(d)) for d in delta)

    # identical re-push: nothing sent, dedup visible, counts stay exact
    third = push(a.store, ctx.remote(), "u.exp", jobs=ctx.combo.jobs)
    assert third.objects_sent == 0 and third.bytes_sent == 0
    assert third.objects_skipped > 0
    assert set(ctx.remote_store.iter_objects()) == after_exp


def check_multi_ref_atomic(ctx: SyncContext) -> None:
    """One stale branch fails the preflight / CAS → every ref on both
    sides stays exactly where it was."""
    a = ctx.lake("a")
    _seed(a, "main")
    a.catalog.create_branch("u.one", "main", author="u")
    a.catalog.create_branch("u.two", "main", author="u")
    _seed(a, "u.one", tables=1, scale=5.0)
    _seed(a, "u.two", tables=1, scale=7.0)
    multi = push_refs(a.store, ctx.remote(), ["u.one", "u.two"],
                      jobs=ctx.combo.jobs)
    assert set(multi.updated_refs) == {"branch=u.one", "branch=u.two"}

    # another host advances u.one on the remote → A is now stale on u.one
    b = ctx.lake("b")
    pull(b.store, ctx.remote(), "u.one", jobs=ctx.combo.jobs)
    _seed(b, "u.one", tables=1, scale=9.0)
    push(b.store, ctx.remote(), "u.one", jobs=ctx.combo.jobs)

    _seed(a, "u.one", tables=1, scale=11.0)  # diverges from B's push
    _seed(a, "u.two", tables=1, scale=13.0)
    remote_before = {r: d for r, d in
                     ctx.remote_store.list_refs("branch=")[0]}
    local_before = {r: a.store.get_ref(r)
                    for r in a.store.iter_refs("remote/")}
    try:
        push_refs(a.store, ctx.remote(), ["u.one", "u.two"],
                  jobs=ctx.combo.jobs)
        raise AssertionError("non-fast-forward push did not fail")
    except SyncError:
        pass
    remote_after = {r: d for r, d in
                    ctx.remote_store.list_refs("branch=")[0]}
    assert remote_after == remote_before, "a remote ref moved despite fail"
    local_after = {r: a.store.get_ref(r)
                   for r in a.store.iter_refs("remote/")}
    assert local_after == local_before, "a tracking ref moved despite fail"

    # the CAS primitive itself is all-or-nothing through the transport:
    # one good update + one stale expectation → neither applies
    remote = ctx.remote()
    good_new = a.catalog.head("u.two")
    try:
        remote.cas_refs([("branch=u.two", remote_before["branch=u.two"],
                          good_new),
                         ("branch=u.one", "0" * 64, good_new)])
        raise AssertionError("cas_refs with a stale expectation succeeded")
    except RefConflict:
        pass
    assert {r: d for r, d in ctx.remote_store.list_refs("branch=")[0]} \
        == remote_before


def check_tags_round_trip(ctx: SyncContext) -> None:
    """Tags travel with push/pull, resolve by every spelling, and root
    their closures against gc on both tiers."""
    a = ctx.lake("a")
    _seed(a, "main")
    a.catalog.create_branch("u.rel", "main", author="u")
    _seed(a, "u.rel", tables=1, scale=4.0)
    tagged = a.catalog.create_tag("v1.0", "u.rel")
    push(a.store, ctx.remote(), "u.rel", tags=["v1.0"], jobs=ctx.combo.jobs)
    assert ctx.remote_store.get_ref("tag=v1.0") == tagged

    b = ctx.lake("b")
    pull(b.store, ctx.remote(), "u.rel", tags=["v*"], jobs=ctx.combo.jobs)
    assert b.catalog.resolve("v1.0") == tagged
    assert b.catalog.resolve("tag=v1.0") == tagged
    assert b.catalog.resolve("origin/v1.0") == tagged
    np.testing.assert_array_equal(b.read_table("v1.0", "t0")["v"],
                                  a.read_table("u.rel", "t0")["v"])

    # local tier: branch gone, tag is the only root → closure survives gc
    # (on a tiered lake the branch ref may only ever have existed remotely)
    for ref in ("branch=u.rel", "remote/origin/branch=u.rel"):
        try:
            b.store.delete_ref(ref)
        except RefNotFound:
            pass
    collect(b.store)
    assert b.read_table("v1.0", "t0")["v"][0] == a.read_table(
        "u.rel", "t0")["v"][0]
    # remote tier: same story on the server's own store
    ctx.remote_store.delete_ref("branch=u.rel")
    collect(ctx.remote_store)
    for digest in commit_closure(b.store, tagged):
        assert ctx.remote_store.has(digest)


def check_concurrent_pushes(ctx: SyncContext) -> None:
    """Two overlapping pushes (shared base history, distinct branches) run
    concurrently: no lost blobs, no corrupted refs, both heads land."""
    a = ctx.lake("a")
    _seed(a, "main")
    a.catalog.create_branch("u.one", "main", author="u")
    a.catalog.create_branch("u.two", "main", author="u")
    _seed(a, "u.one", tables=2, scale=5.0)
    _seed(a, "u.two", tables=2, scale=7.0)

    errors: List[BaseException] = []

    def pusher(branch: str) -> None:
        try:
            push(a.store, ctx.remote(), branch, jobs=ctx.combo.jobs)
        except BaseException as e:  # noqa: BLE001 - collected for the assert
            errors.append(e)

    threads = [threading.Thread(target=pusher, args=(b,))
               for b in ("u.one", "u.two")]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, f"concurrent pushes failed: {errors!r}"
    for branch in ("u.one", "u.two"):
        head = a.catalog.head(branch)
        assert ctx.remote_store.get_ref(f"branch={branch}") == head
        _closure_on_remote(ctx, a.store, head)


CHECKS: List[Callable[[SyncContext], None]] = [
    check_round_trip,
    check_accounting_exact,
    check_multi_ref_atomic,
    check_tags_round_trip,
    check_concurrent_pushes,
]


# ----------------------------------------------------------- seeded fuzzing
FUZZ_BACKENDS = ("fs", "s3")


def _fuzz_invariants(remote_store: ObjectStore, context: str) -> List[str]:
    """The quiesced-state contract: every branch/tag ref on the remote
    resolves to a FULLY present, bit-identical closure.  ``commit_closure``
    reads every blob through digest verification, so completing without
    ``ObjectNotFound`` is exactly "nothing torn, nothing corrupt"."""
    violations: List[str] = []
    refs = (remote_store.list_refs("branch=")[0]
            + remote_store.list_refs("tag=")[0])
    for name, digest in refs:
        try:
            commit_closure(remote_store, digest)
        except ObjectNotFound as e:
            violations.append(
                f"{context}: ref {name} -> {digest[:12]} has a torn or "
                f"corrupt closure ({e})")
    return violations


#: the fuzz sweeps with this grace window — comfortably longer than any
#: in-test sync, which is the documented operating envelope (the window
#: must cover an in-flight sync that started AFTER the sweep's token
#: bump; the token covers the ones that started before)
FUZZ_PRUNE_AGE = 30.0
_FUZZ_AGE = 7200.0  # how far pre-seeded objects are aged into the past


def _age_remote_objects(remote_root: Path, seconds: float) -> None:
    """Rewind every object file's mtime (the bucket tree doubles as the
    store tree, so this ages the fs and s3 views identically) — making
    pre-seeded data OLD relative to the grace window while everything
    the storm uploads stays young."""
    obj_dir = remote_root / "objects"
    for sub in obj_dir.iterdir() if obj_dir.is_dir() else ():
        if not sub.is_dir():
            continue
        for path in sub.iterdir():
            stat = path.stat()
            os.utime(path, (stat.st_atime, stat.st_mtime - seconds))


def fuzz_once(backend: str, seed: int, root: Path, *,
              jobs: int = 4) -> List[str]:
    """One randomized fault schedule over concurrent push/pull/gc.

    Three sync threads (two overlapping pushes, one pull) run through
    fault-injected handles (``SeededSchedule``: positionally deterministic
    kills/delays named by ``seed``) while a GC thread repeatedly sweeps
    the remote under the documented safety contract — generation token +
    a grace window longer than any in-flight sync.  The remote is
    pre-seeded with OLD data (aged past the window): old garbage, which
    the sweeps must actually delete mid-storm, and an old live closure,
    which reachability must protect no matter its age.  Individual ops
    may fail cleanly (clean failures are part of the contract); after
    quiesce, every surviving ref must resolve to a complete bit-identical
    closure — including after one final sweep — and the old garbage must
    be gone."""
    schedule = SeededSchedule(seed)
    remote_store = ObjectStore(root / "remote")
    server = RemoteServer(remote_store)
    httpd = None
    url = None
    if backend == "s3":
        httpd, url = serve_s3(root / "remote")

    def sync_remote():
        """A fault-injected client handle, one per thread."""
        if backend == "s3":
            return FaultyStore(connect(url), schedule)
        return RemoteStore(FaultyTransport(LoopbackTransport(server),
                                           schedule))

    def gc_handle():
        """GC runs through clean handles: the contract under test is the
        race with syncs, not gc's own fault tolerance (tests/test_gc_race
        covers the wire downgrades)."""
        if backend == "s3":
            return connect(url)
        return RemoteStore(LoopbackTransport(server), allow_delete=True)

    lake_a = Lake(root / "a", protect_main=False)
    _seed(lake_a, "main")
    for i, branch in enumerate(("u.one", "u.two")):
        lake_a.catalog.create_branch(branch, "main", author="u")
        _seed(lake_a, branch, tables=2, scale=3.0 + i)
    # seed the remote faultlessly (old live data) + unreachable garbage
    # the storm's sweeps must collect, then age it all past the window
    push(lake_a.store, RemoteStore(LoopbackTransport(server)), "main",
         jobs=jobs)
    garbage = [remote_store.put(f"fuzz garbage {seed}:{i}".encode() * 64)
               for i in range(5)]
    _age_remote_objects(root / "remote", _FUZZ_AGE)
    lake_b = Lake(root / "b", protect_main=False)

    errors: List[str] = []
    push_ok = {}

    def tolerated(e: BaseException, what: str) -> None:
        if isinstance(e, ReproError):
            return  # clean failure — allowed under injected faults
        errors.append(f"{what}: non-clean failure {e!r}")

    def pusher(branch: str) -> None:
        try:
            push(lake_a.store, sync_remote(), branch, jobs=jobs)
            push_ok[branch] = True
        except BaseException as e:  # noqa: BLE001 - classified above
            tolerated(e, f"push {branch}")

    def puller() -> None:
        for _ in range(3):
            try:
                pull(lake_b.store, sync_remote(), "u.one", jobs=jobs)
                return
            except ReproError:
                time.sleep(0.003)  # branch not pushed yet / raced a sweep
            except BaseException as e:  # noqa: BLE001
                tolerated(e, "pull u.one")
                return

    def collector() -> None:
        for _ in range(3):
            try:
                collect(gc_handle(), prune_age=FUZZ_PRUNE_AGE)
            except ReproError:
                pass  # e.g. raced ref deletions — clean by contract
            except BaseException as e:  # noqa: BLE001
                tolerated(e, "gc")
            time.sleep(0.002)

    try:
        threads = {name: threading.Thread(target=fn, daemon=True)
                   for name, fn in (("push u.one", lambda: pusher("u.one")),
                                    ("push u.two", lambda: pusher("u.two")),
                                    ("pull", puller), ("gc", collector))}
        for t in threads.values():
            t.start()
        for name, t in threads.items():
            t.join(120)
            if t.is_alive():
                # quiesce failed: the invariant checks below would race a
                # still-mutating remote — report the hang itself instead
                errors.append(f"{name}: thread still running after 120s "
                              "(hang — invariants not checkable)")
        violations = list(errors)
        if not any("hang" in v for v in violations):
            violations += _fuzz_invariants(remote_store, "post-quiesce")
            # one final clean sweep: gc must never delete live data
            try:
                collect(gc_handle(), prune_age=FUZZ_PRUNE_AGE)
            except ReproError as e:
                violations.append(f"quiesced gc failed: {e!r}")
            violations += _fuzz_invariants(remote_store, "post-quiesce-gc")
            # the sweeps had teeth: the old unreachable garbage is gone
            for digest in garbage:
                if remote_store.has(digest):
                    violations.append(
                        f"old garbage {digest[:12]} survived every sweep")
            # a push that REPORTED success must have fully published; any
            # other remote head must be a value some completed operation
            # legitimately left (covered by the closure walk above)
            for branch in ("u.one", "u.two", "main"):
                try:
                    head = remote_store.get_ref(f"branch={branch}")
                except RefNotFound:
                    head = None
                if push_ok.get(branch) or branch == "main":
                    if head != lake_a.catalog.head(branch):
                        violations.append(
                            f"branch={branch}: push reported success but "
                            "the remote head is "
                            f"{head[:12] if head else 'absent'}")
    finally:
        if httpd is not None:
            httpd.shutdown()
    if violations:
        violations.append(f"fault schedule: {schedule.to_json()}")
    return violations


def run_fuzz(seeds, *, backends=FUZZ_BACKENDS, jobs: int = 4,
             verbose: bool = True,
             artifact_dir: Optional[str] = None) -> List[str]:
    """The fuzz leg: every seed × backend, fresh world each.  On a
    violation the decision log is written to ``artifact_dir`` (the CI
    gc-race job uploads it for replay: re-run with the same ``--seed``)."""
    failures: List[str] = []
    for backend in backends:
        for seed in seeds:
            tmp = tempfile.mkdtemp(prefix="sync-fuzz-")
            try:
                violations = fuzz_once(backend, seed, Path(tmp), jobs=jobs)
            except BaseException as e:  # noqa: BLE001 - harness report
                violations = [f"harness crash: {e!r}"]
            finally:
                shutil.rmtree(tmp, ignore_errors=True)
            if violations:
                failures.append(f"{backend}/seed={seed}: "
                                + "; ".join(violations[:3]))
                if artifact_dir:
                    artifact = (Path(artifact_dir)
                                / f"fault-schedule-{backend}-{seed}.json")
                    artifact.parent.mkdir(parents=True, exist_ok=True)
                    artifact.write_text("\n".join(violations))
                if verbose:
                    print(f"FAIL fuzz {backend:3s} seed={seed}")
                    for v in violations:
                        print(f"     {v}")
            elif verbose:
                print(f"PASS fuzz {backend:3s} seed={seed}")
    return failures


# ------------------------------------------------------------------- runner
def run_check(check: Callable[[SyncContext], None], combo: Combo,
              root: Path) -> None:
    """One check in a fresh world; raises on contract violation."""
    ctx = SyncContext(combo, root)
    try:
        check(ctx)
    finally:
        ctx.close()


def run_matrix(jobs: int, *, backends=BACKENDS, transports=TRANSPORTS,
               verbose: bool = True) -> List[str]:
    failures: List[str] = []
    for backend in backends:
        for transport in transports:
            combo = Combo(backend, transport, jobs)
            for check in CHECKS:
                tmp = tempfile.mkdtemp(prefix="sync-conf-")
                try:
                    run_check(check, combo, Path(tmp))
                    if verbose:
                        print(f"PASS {combo.ident:28s} {check.__name__}")
                except BaseException as e:  # noqa: BLE001 - harness report
                    failures.append(f"{combo.ident} {check.__name__}: {e!r}")
                    if verbose:
                        print(f"FAIL {combo.ident:28s} {check.__name__}: "
                              f"{e!r}")
                finally:
                    shutil.rmtree(tmp, ignore_errors=True)
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="sync conformance matrix (backend × transport × jobs) "
                    "+ the seeded gc-race fuzz leg (--fuzz N --seed S)")
    ap.add_argument("--jobs", type=int, default=8,
                    help="transfer concurrency (1 = sequential path)")
    ap.add_argument("--backends", default=",".join(BACKENDS))
    ap.add_argument("--transports", default=",".join(TRANSPORTS))
    ap.add_argument("--fuzz", type=int, default=0, metavar="N",
                    help="run N seeded fault schedules of concurrent "
                         "push/pull/gc per fuzz backend INSTEAD of the "
                         "matrix (schedules use seeds SEED..SEED+N-1)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base seed for --fuzz (a failing seed replays "
                         "the same fault pattern)")
    ap.add_argument("--fuzz-backends", default=",".join(FUZZ_BACKENDS),
                    help="comma list of fs,s3 for the fuzz leg")
    ap.add_argument("--artifact-dir", default=None, metavar="DIR",
                    help="write fault-schedule replay artifacts for "
                         "failed fuzz runs into DIR (the CI gc-race job "
                         "uploads them)")
    args = ap.parse_args(argv)
    if args.fuzz > 0:
        seeds = range(args.seed, args.seed + args.fuzz)
        failures = run_fuzz(seeds,
                            backends=tuple(args.fuzz_backends.split(",")),
                            jobs=args.jobs,
                            artifact_dir=args.artifact_dir)
        total = args.fuzz * len(args.fuzz_backends.split(","))
        print(f"\ngc-race fuzz: {total - len(failures)}/{total} schedules "
              f"clean (base seed {args.seed}, jobs={args.jobs})")
        for f in failures:
            print(f"  FAILED: {f}")
        return 1 if failures else 0
    failures = run_matrix(args.jobs,
                          backends=tuple(args.backends.split(",")),
                          transports=tuple(args.transports.split(",")))
    n_combos = (len(args.backends.split(","))
                * len(args.transports.split(",")))
    total = n_combos * len(CHECKS)
    print(f"\nsync conformance: {total - len(failures)}/{total} passed "
          f"(jobs={args.jobs})")
    for f in failures:
        print(f"  FAILED: {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
