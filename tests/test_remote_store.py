"""Property + unit tests for the remote object-store backend protocol.

Everything here runs against :class:`RemoteStore` — i.e. through the wire
contract (msgpack frames over loopback or HTTP), never against the
filesystem store directly — so these tests pin the *protocol* semantics any
real S3/GCS backend must reproduce: content-addressed immutable PUT/GET,
linearizable CAS refs, complete paged listing, batched exists.
"""

import threading
from concurrent.futures import ThreadPoolExecutor

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dep — fall back to the seeded mini-sampler
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from repro.core import (LoopbackTransport, ObjectStore, RemoteServer,
                        RemoteStore, TieredStore, serve_http, sha256_hex)
from repro.core import store as store_mod
from repro.core.errors import (ObjectNotFound, RefConflict, RefNotFound,
                               RemoteError)

CODECS = ["raw", "zlib"] + (["zstd"] if "zstd" in store_mod.WRITE_CODECS
                            else [])


def loopback_remote(path, **store_kw) -> RemoteStore:
    return RemoteStore(LoopbackTransport(RemoteServer(
        ObjectStore(path, **store_kw))))


# ----------------------------------------------------------------- roundtrip
@settings(max_examples=30, deadline=None)
@given(data=st.binary(min_size=0, max_size=4096),
       codec=st.sampled_from(CODECS))
def test_property_remote_roundtrip_across_codecs(tmp_path_factory, data,
                                                 codec):
    """put/get through the wire is the identity whatever codec the server
    stores with, and the digest is the sha-256 of the uncompressed bytes
    (content addressing is codec- and transport-independent)."""
    remote = loopback_remote(tmp_path_factory.mktemp("r"), codec=codec)
    digest = remote.put(data)
    assert digest == sha256_hex(data)
    assert remote.get(digest) == data
    assert remote.has(digest)


def test_remote_put_idempotent_reput(tmp_path):
    """Re-PUT of an existing digest is a no-op returning the same digest —
    the dedup contract push relies on."""
    remote = loopback_remote(tmp_path)
    data = b"same bytes" * 200
    d1 = remote.put(data)
    d2 = remote.put(data)
    assert d1 == d2
    assert list(remote.iter_objects()) == [d1]
    assert remote.get(d1) == data


def test_remote_get_missing_raises(tmp_path):
    remote = loopback_remote(tmp_path)
    with pytest.raises(ObjectNotFound):
        remote.get("0" * 64)


def test_remote_rejects_mislabeled_content(tmp_path):
    """The server verifies content hashes to the claimed digest — a
    corrupted or malicious PUT cannot poison a content address."""
    remote = loopback_remote(tmp_path)
    with pytest.raises(RemoteError):
        remote._call("put_object", digest="f" * 64, data=b"not that")


def test_remote_size_and_has_many(tmp_path):
    remote = loopback_remote(tmp_path)
    blobs = [bytes([i]) * (100 * (i + 1)) for i in range(5)]
    digests = [remote.put(b) for b in blobs]
    assert remote.size(digests[0]) > 0
    present = remote.has_many(digests + ["0" * 64, "f" * 64])
    assert present == set(digests)
    assert remote.has_many([]) == set()


# --------------------------------------------------------------------- paging
@settings(max_examples=8, deadline=None)
@given(n=st.integers(1, 40), limit=st.integers(1, 7))
def test_property_paged_object_listing_complete(tmp_path_factory, n, limit):
    """Paged listing with any page size enumerates every object exactly
    once, in sorted order, and terminates."""
    remote = loopback_remote(tmp_path_factory.mktemp("r"))
    digests = {remote.put(f"obj-{i}".encode()) for i in range(n)}
    seen, token, pages = [], None, 0
    while True:
        page, token = remote.list_objects(page_token=token, limit=limit)
        seen.extend(page)
        pages += 1
        assert pages <= n + 2, "listing did not terminate"
        if token is None:
            break
    assert seen == sorted(digests)
    assert len(seen) == len(set(seen))


def test_paged_ref_listing_complete_with_values(tmp_path):
    remote = loopback_remote(tmp_path)
    expect = {}
    for i in range(23):
        name = f"cache/{i % 4:02d}/entry{i:03d}"
        remote.set_ref(name, f"digest{i}")
        expect[name] = f"digest{i}"
    remote.set_ref("branch=main", "head")  # outside the prefix
    seen, token = {}, None
    while True:
        page, token = remote.list_refs("cache/", page_token=token, limit=5)
        seen.update(dict(page))
        if token is None:
            break
    assert seen == expect


# ----------------------------------------------------------------------- refs
def test_remote_ref_lifecycle(tmp_path):
    remote = loopback_remote(tmp_path)
    with pytest.raises(RefNotFound):
        remote.get_ref("branch=nope")
    remote.set_ref("branch=main", "aaa")
    assert remote.get_ref("branch=main") == "aaa"
    remote.cas_ref("branch=main", "aaa", "bbb")
    assert remote.get_ref("branch=main") == "bbb"
    with pytest.raises(RefConflict):
        remote.cas_ref("branch=main", "aaa", "ccc")
    with pytest.raises(RefConflict):
        remote.cas_ref("branch=new", "stale", "x")  # expected-missing CAS
    remote.cas_ref("branch=new", None, "x")
    remote.delete_ref("branch=new")
    with pytest.raises(RefNotFound):
        remote.get_ref("branch=new")


def test_remote_cas_linearizable_under_concurrent_writers(tmp_path):
    """N threads × K CAS-retry increments through the wire lose no update —
    the linearizability push's ref handoff depends on."""
    remote = loopback_remote(tmp_path)
    remote.set_ref("ctr", "0")
    n_threads, n_incr = 8, 20

    def worker(_tid):
        client = loopback_remote(tmp_path)  # own client, same server store
        for _ in range(n_incr):
            while True:
                cur = client.get_ref("ctr")
                try:
                    client.cas_ref("ctr", cur, str(int(cur) + 1))
                    break
                except RefConflict:
                    continue

    with ThreadPoolExecutor(max_workers=n_threads) as pool:
        list(pool.map(worker, range(n_threads)))
    assert remote.get_ref("ctr") == str(n_threads * n_incr)


def test_remote_concurrent_puts_one_object(tmp_path):
    remote = loopback_remote(tmp_path)
    data = b"contended" * 300
    with ThreadPoolExecutor(max_workers=8) as pool:
        digests = list(pool.map(lambda _i: remote.put(data), range(16)))
    assert set(digests) == {sha256_hex(data)}
    assert list(remote.iter_objects()) == [sha256_hex(data)]


# ----------------------------------------------------------------------- HTTP
@pytest.fixture()
def http_remote(tmp_path):
    store = ObjectStore(tmp_path / "served")
    httpd, url = serve_http(store)
    try:
        from repro.core import connect

        yield connect(url), store
    finally:
        httpd.shutdown()


def test_http_loopback_roundtrip(http_remote):
    remote, served = http_remote
    data = b"over actual sockets" * 128
    digest = remote.put(data)
    assert remote.get(digest) == data
    assert served.has(digest)  # landed in the served directory
    remote.set_ref("branch=main", digest)
    assert remote.get_ref("branch=main") == digest
    with pytest.raises(RefConflict):
        remote.cas_ref("branch=main", "stale", "x")
    with pytest.raises(ObjectNotFound):
        remote.get("0" * 64)


def test_http_transport_fault_is_remote_error_after_retries():
    """Socket-level failures (connection refused/reset) surface as
    RemoteError after the idempotent-op retry budget — never as a raw
    OSError that would bypass both retries and the CLI's error handling."""
    from repro.core import connect

    remote = connect("http://127.0.0.1:1")  # nothing listens on port 1
    with pytest.raises(RemoteError):
        remote.get_ref("branch=main")


def test_http_concurrent_clients(http_remote):
    remote, _served = http_remote
    blobs = [f"blob-{i}".encode() * 50 for i in range(24)]
    with ThreadPoolExecutor(max_workers=6) as pool:
        digests = list(pool.map(remote.put, blobs))
    assert remote.has_many(digests) == set(digests)
    for d, b in zip(digests, blobs):
        assert remote.get(d) == b


# --------------------------------------------------------------------- tiered
def test_tiered_read_through_with_write_back(tmp_path):
    remote = loopback_remote(tmp_path / "remote")
    local = ObjectStore(tmp_path / "local")
    tiered = TieredStore(local, remote)

    data = b"published elsewhere" * 64
    digest = remote.put(data)
    assert not local.has(digest)
    assert tiered.has(digest)            # visible through the tier
    assert tiered.get(digest) == data    # faults through...
    assert local.has(digest)             # ...and writes back locally

    own = tiered.put(b"local write")
    assert local.has(own)
    assert not remote.has(own)           # publishing requires an explicit push


def test_tiered_refs_local_first_remote_fallback(tmp_path):
    remote = loopback_remote(tmp_path / "remote")
    local = ObjectStore(tmp_path / "local")
    tiered = TieredStore(local, remote)

    remote.set_ref("branch=shared", "remote-head")
    remote.set_ref("cache/ab/cdef", "remote-entry")
    assert tiered.get_ref("branch=shared") == "remote-head"
    assert tiered.get_ref("cache/ab/cdef") == "remote-entry"

    tiered.set_ref("branch=shared", "local-head")  # local shadows remote
    assert tiered.get_ref("branch=shared") == "local-head"
    assert remote.get_ref("branch=shared") == "remote-head"  # untouched

    # CAS against the tiered view: a remote-only ref can be adopted locally
    tiered.cas_ref("cache/ab/cdef", "remote-entry", "new-entry")
    assert local.get_ref("cache/ab/cdef") == "new-entry"
    with pytest.raises(RefConflict):
        tiered.cas_ref("cache/ab/cdef", "remote-entry", "x")

    names = list(tiered.iter_refs())
    assert "branch=shared" in names and "cache/ab/cdef" in names


def test_tiered_enumeration_is_local_only(tmp_path):
    """GC sweeps must never reach the shared remote through a tier."""
    remote = loopback_remote(tmp_path / "remote")
    local = ObjectStore(tmp_path / "local")
    tiered = TieredStore(local, remote)
    d_remote = remote.put(b"remote only")
    d_local = tiered.put(b"local only")
    assert list(tiered.iter_objects()) == [d_local]
    assert tiered.delete_object(d_remote) is False  # no-op: not local
    assert remote.has(d_remote)
