"""Write-Audit-Publish gate (paper §5.5) + expectations."""

import numpy as np
import pytest

from repro.core import (ExpectationFailed, Model, Pipeline, audit,
                        column_range, expectation, model, no_nans, not_empty,
                        publish)


def _dev_branch_with_table(lake, cols, author="r", branch="r.dev"):
    lake.catalog.create_branch(branch, "main", author=author)
    lake.write_table(branch, "training_data", cols, author=author)
    return branch


def test_audit_pass(lake):
    b = _dev_branch_with_table(lake, {"x": np.ones(5, np.float32)})
    rep = audit(lake.catalog, lake.io, b, [not_empty("training_data"),
                                           no_nans("training_data")])
    assert rep.passed and all(rep.results.values())


def test_audit_fail_on_nans(lake):
    b = _dev_branch_with_table(
        lake, {"x": np.array([1.0, np.nan], np.float32)})
    rep = audit(lake.catalog, lake.io, b, [no_nans("training_data")])
    assert not rep.passed


def test_audit_fail_on_missing_table(lake):
    lake.catalog.create_branch("r.dev", "main", author="r")
    rep = audit(lake.catalog, lake.io, "r.dev", [not_empty("ghost")])
    assert not rep.passed
    assert "ghost_not_empty" in rep.errors


def test_publish_gates_main(lake):
    """The paper's empty-table bug: publish must refuse an empty table."""
    b = _dev_branch_with_table(lake, {"x": np.ones(5, np.float32)})

    @expectation("training_data")
    def has_enough_rows(f):
        return f["x"].shape[0] >= 100  # fails: only 5 rows

    with pytest.raises(ExpectationFailed):
        publish(lake.catalog, lake.io, b, [has_enough_rows], author="r")
    assert "training_data" not in lake.catalog.tables("main")

    # relax the gate → publish lands on main with audit metadata
    head = publish(lake.catalog, lake.io, b, [not_empty("training_data")],
                   author="r")
    assert "training_data" in lake.catalog.tables("main")
    # the audit trail is recorded in the history of the merged branch
    log = lake.catalog.log(head, first_parent=False)
    audits = [lake.catalog.commit_info(d).meta.get("audit")
              for d in log]
    assert any(a for a in audits if a)


def test_column_range_expectation(lake):
    b = _dev_branch_with_table(lake, {"p": np.linspace(0, 1, 11)})
    ok = audit(lake.catalog, lake.io, b, [column_range("training_data",
                                                       "p", 0.0, 1.0)])
    assert ok.passed
    bad = audit(lake.catalog, lake.io, b, [column_range("training_data",
                                                        "p", 0.0, 0.5)])
    assert not bad.passed


def test_full_wap_cycle_with_pipeline(seeded_lake):
    """End-to-end: branch → run DAG → audit → publish (the CI/CD pattern)."""
    from repro.core import col, lit, sql_model

    final_table = sql_model("final_table", select=["c1"],
                            frm="source_table",
                            where=col("transaction_ts") >= lit(0))

    @model()
    def training_data(data=Model("final_table")):
        return {"x": data["c1"]}

    pipe = Pipeline([final_table, training_data])
    seeded_lake.catalog.create_branch("ci.run", "main", author="ci")
    seeded_lake.run(pipe, branch="ci.run", author="ci")
    publish(seeded_lake.catalog, seeded_lake.io, "ci.run",
            [not_empty("training_data"), no_nans("training_data")],
            author="ci")
    assert "training_data" in seeded_lake.catalog.tables("main")
