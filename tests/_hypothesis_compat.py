"""Minimal stand-in for ``hypothesis`` when the package is not installed.

The tier-1 suite must collect and run on a bare interpreter (see
.github/workflows/ci.yml: one matrix leg has no optional deps).  When real
hypothesis is importable the test modules use it; otherwise they fall back to
this shim, which expands each ``@given`` into a deterministic, seeded
``pytest.mark.parametrize`` over a fixed number of random examples.  That
keeps the property tests meaningful (many concrete cases, reproducible
failures) without the shrinking/coverage machinery.

Only the strategy surface the suite actually uses is implemented:
``binary, integers, lists, sampled_from, tuples``.
"""

from __future__ import annotations

import inspect
import random
from typing import Any, Callable, List

import pytest

_N_EXAMPLES = 12  # per property; hypothesis legs run the real 25-50


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (``st.*``)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def binary(*, min_size: int = 0, max_size: int = 64) -> _Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return rng.getrandbits(8 * n).to_bytes(n, "little") if n else b""
        return _Strategy(draw)

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        pool = list(elements)
        return _Strategy(lambda rng: pool[rng.randrange(len(pool))])

    @staticmethod
    def lists(elem: _Strategy, *, min_size: int = 0, max_size: int = 8,
              unique: bool = False) -> _Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            out: List[Any] = []
            attempts = 0
            while len(out) < n and attempts < 100 * (n + 1):
                v = elem.draw(rng)
                attempts += 1
                if unique and v in out:
                    continue
                out.append(v)
            return out
        return _Strategy(draw)

    @staticmethod
    def tuples(*elems: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))


def settings(**_kw):
    """No-op: example count is fixed at ``_N_EXAMPLES`` in the fallback."""
    def deco(fn):
        return fn
    return deco


def given(*pos: _Strategy, **named: _Strategy):
    """Expand to ``parametrize`` — positional strategies bind to the
    rightmost test parameters (hypothesis semantics), named to their names;
    remaining leading parameters stay pytest fixtures."""
    def deco(fn):
        sig_params = list(inspect.signature(fn).parameters)
        argnames = list(named)
        if pos:
            argnames = sig_params[len(sig_params) - len(pos):] + argnames
        strategies_in_order = list(pos) + [named[k] for k in named]
        rng = random.Random(f"repro:{fn.__name__}")
        cases = []
        for _ in range(_N_EXAMPLES):
            vals = tuple(s.draw(rng) for s in strategies_in_order)
            cases.append(vals[0] if len(vals) == 1 else vals)
        return pytest.mark.parametrize(",".join(argnames), cases)(fn)
    return deco
