"""Checkpoint-as-commit: save/restore roundtrip, async manager, digests,
elastic reshard, fault-tolerant trainer resume."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, columns_to_tree,
                              latest_checkpoint, leaves_to_columns, restore,
                              restore_into, save)
from repro.configs import smoke_config
from repro.models import init_params
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)


def _params():
    return init_params(smoke_config("paper-demo"), KEY)


def test_leaves_columns_roundtrip():
    tree = {"a": np.arange(6).reshape(2, 3), "b": {"c": np.ones(4)}}
    cols = leaves_to_columns(tree)
    back = columns_to_tree(cols)
    np.testing.assert_array_equal(back["a"], tree["a"])
    np.testing.assert_array_equal(back["b"]["c"], tree["b"]["c"])


def test_save_restore_roundtrip(lake):
    params = _params()
    opt = adamw.init(params, adamw.AdamWConfig())
    commit = save(lake, "main", step=7, params=params, opt_state=opt,
                  _wap_token=True) if False else None
    # main is protected; use a user branch like the trainer does
    lake.catalog.create_branch("t.run", "main", author="t")
    commit = save(lake, "t.run", step=7, params=params, opt_state=opt,
                  author="t")
    p2, opt_cols, meta = restore(lake, commit)
    assert meta["step"] == 7
    flat1 = jax.tree.leaves(params)
    flat2 = jax.tree.leaves(p2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # typed opt-state restore
    template = adamw.init(p2, adamw.AdamWConfig())
    cols = lake.io.read(lake.catalog.tables(commit)["ckpt_opt"])
    opt2 = restore_into(template, cols)
    assert isinstance(opt2, adamw.AdamWState)
    assert int(opt2.step) == int(opt.step)


def test_restore_any_historical_commit(lake):
    params = _params()
    lake.catalog.create_branch("t.run", "main", author="t")
    c1 = save(lake, "t.run", step=1, params=params, author="t")
    p_later = jax.tree.map(lambda x: x + 1 if x.dtype != bool else x, params)
    save(lake, "t.run", step=2, params=p_later, author="t")
    p1, _, meta1 = restore(lake, c1)  # time travel to step 1
    assert meta1["step"] == 1
    np.testing.assert_array_equal(np.asarray(p1["embed"]),
                                  np.asarray(params["embed"]))


def test_digest_verification(lake):
    params = _params()
    lake.catalog.create_branch("t.run", "main", author="t")
    commit = save(lake, "t.run", step=1, params=params, author="t")
    p, _, _ = restore(lake, commit, verify=True)  # digest matches
    meta = lake.catalog.commit_info(commit).meta["checkpoint"]
    assert len(meta["params_digest"]) == 64  # 8 × uint32 hex


def test_async_manager(lake):
    params = _params()
    lake.catalog.create_branch("t.run", "main", author="t")
    mgr = CheckpointManager(lake, "t.run", author="t")
    for s in (1, 2, 3):
        mgr.submit(step=s, params=params)
    commits = mgr.wait()
    assert [s for s, _ in commits] == [1, 2, 3]
    assert latest_checkpoint(lake, "t.run") == commits[-1][1]
    mgr.close()


def test_unchanged_leaves_dedup(lake):
    """Content addressing: identical leaves across checkpoints are stored
    once (the CoW story for model state)."""
    params = _params()
    lake.catalog.create_branch("t.run", "main", author="t")
    save(lake, "t.run", step=1, params=params, author="t")
    n1 = len(list(lake.store.iter_objects()))
    save(lake, "t.run", step=2, params=params, author="t")  # same params
    n2 = len(list(lake.store.iter_objects()))
    assert n2 - n1 <= 2  # only the new snapshot + commit metadata objects


def test_trainer_fault_tolerant_resume_bitexact(lake):
    """Crash at step k, resume, and land on the same final loss as an
    uninterrupted run — proves checkpoint + stateless loader determinism."""
    from repro.data import build_data_pipeline, seed_corpus
    from repro.runtime import Trainer, TrainerConfig

    cfg = smoke_config("paper-demo")
    lake.catalog.create_branch("data.main", "main", author="data")
    seed_corpus(lake, "data.main", n_docs=64, seed=3,
                vocab_size=cfg.vocab_size, mean_len=80, author="data")
    lake.run(build_data_pipeline(32), branch="data.main", author="data")

    def make(run_name, failure_at=None):
        tcfg = TrainerConfig(arch=cfg.name, seq_len=32, global_batch=4,
                             n_steps=8, ckpt_every=4, author="t",
                             schedule="constant",
                             schedule_kw={"peak_lr": 1e-3})
        return Trainer(lake, cfg, tcfg, data_branch="data.main",
                       run_name=run_name, failure_at=failure_at)

    t_clean = make("clean")
    clean = t_clean.run()

    t_faulty = make("faulty", failure_at=6)
    with pytest.raises(RuntimeError):
        t_faulty.run()
    resumed = t_faulty.run(resume=True)
    # resume restarts from the step-4 checkpoint → same final state
    assert resumed["losses"][-1] == pytest.approx(clean["losses"][-1],
                                                  rel=1e-6)
