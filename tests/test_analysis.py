"""While-aware HLO analyzer: trip-count weighting, slice-aware bytes,
tuple collectives — on synthetic HLO with known ground truth."""

import textwrap

import pytest

from repro.distributed import analysis


def _prog(text):
    return analysis.HloProgram(textwrap.dedent(text))


def test_dot_inside_while_weighted_by_trip_count():
    prog = _prog("""\
    %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p0 = f32[8,8]{1,0} parameter(0)
      %w = f32[8,8]{1,0} parameter(1)
      %d = f32[8,8]{1,0} dot(%p0, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      ROOT %t = (s32[], f32[8,8]) tuple(%i, %d)
    }

    %cond (p: (s32[], f32[8,8])) -> pred[] {
      %c = s32[] constant(24)
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }

    ENTRY %main (a: f32[8,8]) -> f32[8,8] {
      %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
    }
    """)
    flops, _ = prog.flops_bytes()
    assert flops == 24 * 2 * 8 * 8 * 8


def test_fusion_called_from_while_inherits_weight():
    prog = _prog("""\
    %fused_computation (p: f32[4,4]) -> f32[4,4] {
      %p0 = f32[4,4]{1,0} parameter(0)
      %q0 = f32[4,4]{1,0} parameter(1)
      %d = f32[4,4]{1,0} dot(%p0, %q0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
    }

    %body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
      %f = f32[4,4]{1,0} fusion(%x, %y), kind=kOutput, calls=%fused_computation
      ROOT %t = (s32[], f32[4,4]) tuple(%i, %f)
    }

    %cond (p: (s32[], f32[4,4])) -> pred[] {
      %c = s32[] constant(10)
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }

    ENTRY %main (a: f32[4,4]) -> f32[4,4] {
      %w = (s32[], f32[4,4]) while(%init), condition=%cond, body=%body
    }
    """)
    flops, _ = prog.flops_bytes()
    assert flops == 10 * 2 * 4 * 4 * 4


def test_slice_aware_bytes_for_stacked_buffers():
    """A DUS into a (trip, …) stack must be charged one slice per iter."""
    prog = _prog("""\
    %body (p: (s32[], f32[12,8,8])) -> (s32[], f32[12,8,8]) {
      %stack = f32[12,8,8]{2,1,0} parameter(1)
      %upd = f32[1,8,8]{2,1,0} parameter(2)
      %dus = f32[12,8,8]{2,1,0} dynamic-update-slice(%stack, %upd, %i)
      ROOT %t = (s32[], f32[12,8,8]) tuple(%i, %dus)
    }

    %cond (p: (s32[], f32[12,8,8])) -> pred[] {
      %c = s32[] constant(12)
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }

    ENTRY %main (a: f32[12,8,8]) -> f32[12,8,8] {
      %w = (s32[], f32[12,8,8]) while(%init), condition=%cond, body=%body
    }
    """)
    _, nbytes = prog.flops_bytes()
    # DUS: result (12,8,8)/12 + operand stack (12,8,8)/12 + update (1,8,8),
    # ×12 iterations = 3 slices/iter × 12 × 256 bytes
    slice_bytes = 8 * 8 * 4
    assert nbytes == pytest.approx(12 * 3 * slice_bytes)


def test_tuple_all_reduce_counts_all_elements():
    hlo = textwrap.dedent("""\
    ENTRY %main (a: f32[8]) -> f32[8] {
      %z = (f32[128]{0}, f32[64]{0}, f32[32]{0}) all-reduce(%p, %q, %r), replica_groups={{0,1}}
    }
    """)
    stats = analysis.parse_collectives(hlo, n_devices=2)
    assert stats.result_bytes["all-reduce"] == (128 + 64 + 32) * 4


def test_nested_while_multiplies():
    prog = _prog("""\
    %inner_body (p: (s32[], f32[2,2])) -> (s32[], f32[2,2]) {
      %p0 = f32[2,2]{1,0} parameter(0)
      %q0 = f32[2,2]{1,0} parameter(1)
      %d = f32[2,2]{1,0} dot(%p0, %q0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      ROOT %t = (s32[], f32[2,2]) tuple(%i, %d)
    }

    %inner_cond (p: (s32[], f32[2,2])) -> pred[] {
      %c = s32[] constant(5)
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }

    %outer_body (p: (s32[], f32[2,2])) -> (s32[], f32[2,2]) {
      %w2 = (s32[], f32[2,2]) while(%init2), condition=%inner_cond, body=%inner_body
      ROOT %t = (s32[], f32[2,2]) tuple(%i, %g)
    }

    %outer_cond (p: (s32[], f32[2,2])) -> pred[] {
      %c = s32[] constant(7)
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }

    ENTRY %main (a: f32[2,2]) -> f32[2,2] {
      %w = (s32[], f32[2,2]) while(%init), condition=%outer_cond, body=%outer_body
    }
    """)
    flops, _ = prog.flops_bytes()
    assert flops == 7 * 5 * 2 * 2 * 2 * 2  # nested trips multiply


def test_roofline_mfu_bound_sane():
    r = analysis.Roofline(flops_per_device=1e12, bytes_per_device=1e9,
                          collective_link_bytes=0, n_devices=2,
                          model_flops_total=1.5e12)
    assert 0 < r.mfu_bound <= 1.0
    assert r.useful_flops_ratio == pytest.approx(0.75)
