"""The gc-vs-push race, pinned under deterministic interleavings.

A push uploads its closure first and moves refs last, so a remote GC
sweep racing the window between ``put_objects`` and ``cas_refs`` used to
delete the uploads and let the push publish refs to missing blobs (the
documented "quiet-window limitation").  This suite drives that exact
interleaving with the fault-injection layer (tests/fault_schedule.py):

* a **control** test reproduces the legacy sweep's data loss, proving the
  interleaving is the dangerous one (and keeping the harness honest);
* the **regression** test runs the same interleaving against the real
  ``collect`` — pre-PR it fails (refs over deleted blobs), post-PR the
  generation token fails the push's ref update cleanly and the retry
  re-uploads: zero missing blobs;
* **grace window** tests: boundary properties (never sweep a
  reachable-or-young object, always sweep old garbage) on the fs store
  and through the S3 ``Last-Modified`` path;
* **server-side mark**: ``gc_mark``/``gc_sweep`` do the whole collection
  in two wire requests — no per-object reads;
* **downgrade contract**: a server predating the new ops falls back to a
  client-side mark with a loud warning, never a crash.
"""

import os
import threading
import warnings
from collections import Counter

import msgpack
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dep — fall back to the seeded mini-sampler
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from fault_schedule import FaultyStore, FaultyTransport, Schedule
from repro.core import (GC_GENERATION_REF, Lake, LoopbackTransport,
                        ObjectStore, RemoteServer, RemoteStore,
                        commit_closure, connect, ensure_generation, push,
                        read_generation, serve_s3)
from repro.core.gc import collect, mark_live, sweep


def _lake_with_branch(root, n: int = 2048) -> Lake:
    lake = Lake(root, protect_main=False)
    lake.write_table("main", "base",
                     {"v": np.arange(n, dtype=np.float32)})
    lake.catalog.create_branch("u.exp", "main", author="u")
    lake.write_table("u.exp", "t",
                     {"v": np.full(n, 7.0, np.float32)}, author="u")
    return lake


def _missing_on(remote_store: ObjectStore, lake: Lake, branch: str):
    head = lake.catalog.head(branch)
    return [d for d in commit_closure(lake.store, head)
            if not remote_store.has(d)]


def _push_in_thread(lake: Lake, remote, branch: str = "u.exp"):
    result = {}

    def pusher():
        try:
            result["report"] = push(lake.store, remote, branch)
        except BaseException as e:  # noqa: BLE001 - surfaced by the test
            result["error"] = e

    t = threading.Thread(target=pusher)
    t.start()
    return t, result


# ------------------------------------------------------ the race, pinned
def test_control_legacy_sweep_loses_inflight_push_blobs(tmp_path):
    """CONTROL: the pre-PR sweep algorithm (mark + delete-unmarked, no
    generation bump, no grace window) interleaved between a push's uploads
    and its ref update really does destroy the push's blobs while the ref
    lands — the data loss the tentpole closes.  If this stops
    reproducing, the harness (not the fix) broke."""
    lake = _lake_with_branch(tmp_path / "lake")
    remote_store = ObjectStore(tmp_path / "remote")
    ensure_generation(remote_store)

    schedule = Schedule()
    gate = schedule.gate("cas_refs:before")
    thread, result = _push_in_thread(lake, FaultyStore(remote_store,
                                                       schedule))
    gate.wait_reached()  # uploads done, ref update frozen

    # the PR-4 sweep, verbatim: no token bump, no upload-age check
    live = mark_live(remote_store)
    legacy_swept = 0
    for digest in list(remote_store.iter_objects()):
        if digest not in live:
            remote_store.delete_object(digest)
            legacy_swept += 1
    assert legacy_swept > 0, "the sweep found nothing — race not staged"

    gate.open()
    thread.join(30)
    # the push saw no error (the legacy sweep never touched the token) …
    assert "error" not in result, f"push failed: {result.get('error')!r}"
    # … yet published a branch whose closure is GONE: the data loss
    assert _missing_on(remote_store, lake, "u.exp"), \
        "legacy sweep no longer loses data — is the control stale?"


def test_gc_race_push_retries_and_no_blob_is_lost(tmp_path):
    """REGRESSION (fails on the pre-PR sweep logic): the real ``collect``
    interleaved in the same window — even with NO grace window — must not
    let the push publish refs to deleted blobs.  The generation token
    fails the frozen push's cas_refs; the push re-uploads and succeeds
    with its full closure present."""
    lake = _lake_with_branch(tmp_path / "lake")
    remote_store = ObjectStore(tmp_path / "remote")
    ensure_generation(remote_store)

    schedule = Schedule()
    gate = schedule.gate("cas_refs:before")
    thread, result = _push_in_thread(lake, FaultyStore(remote_store,
                                                       schedule))
    gate.wait_reached()

    rep = collect(remote_store, prune_age=0.0)  # harshest setting
    assert rep.swept > 0, "the sweep found nothing — race not staged"
    assert rep.generation is not None

    gate.open()
    thread.join(30)
    assert "error" not in result, f"push failed: {result.get('error')!r}"
    assert result["report"].gc_retries == 1
    assert result["report"].ref_updated
    assert _missing_on(remote_store, lake, "u.exp") == []
    # bit-identical closure on the remote, digest-verified reads
    head = lake.catalog.head("u.exp")
    assert remote_store.get_ref("branch=u.exp") == head
    for digest in commit_closure(lake.store, head):
        assert remote_store.get(digest) == lake.store.get(digest)


def test_gc_race_grace_window_protects_uploads_without_deleting(tmp_path):
    """With a real grace window the racing sweep deletes nothing at all —
    the frozen push's uploads are young — and the push still completes
    with its closure intact (the token bump forces one clean retry)."""
    lake = _lake_with_branch(tmp_path / "lake")
    remote_store = ObjectStore(tmp_path / "remote")
    ensure_generation(remote_store)

    schedule = Schedule()
    gate = schedule.gate("cas_refs:before")
    thread, result = _push_in_thread(lake, FaultyStore(remote_store,
                                                       schedule))
    gate.wait_reached()

    rep = collect(remote_store, prune_age=3600.0)
    assert rep.swept == 0
    assert rep.skipped_young > 0  # the uploads were seen — and spared

    gate.open()
    thread.join(30)
    assert "error" not in result, f"push failed: {result.get('error')!r}"
    assert _missing_on(remote_store, lake, "u.exp") == []


def test_gc_race_through_wire_with_server_side_mark(tmp_path):
    """The same race through the msgpack wire: the push hangs at its
    ``cas_refs`` request, the GC runs via the server-side
    ``gc_mark``/``gc_sweep`` ops, and the wire-level generation conflict
    still forces the clean retry + re-upload."""
    lake = _lake_with_branch(tmp_path / "lake")
    remote_store = ObjectStore(tmp_path / "remote")
    ensure_generation(remote_store)
    server = RemoteServer(remote_store)

    schedule = Schedule()
    gate = schedule.gate("wire:cas_refs:before")
    pusher_remote = RemoteStore(FaultyTransport(LoopbackTransport(server),
                                                schedule))
    thread, result = _push_in_thread(lake, pusher_remote)
    gate.wait_reached()

    gc_client = RemoteStore(LoopbackTransport(server), allow_delete=True)
    rep = collect(gc_client, prune_age=0.0)
    assert rep.mode == "server"
    assert rep.swept > 0

    gate.open()
    thread.join(30)
    assert "error" not in result, f"push failed: {result.get('error')!r}"
    assert result["report"].gc_retries >= 1
    assert _missing_on(remote_store, lake, "u.exp") == []


# --------------------------------------------------- server-side mark
class CountingTransport:
    def __init__(self, inner):
        self.inner = inner
        self.ops = Counter()

    def request(self, payload: bytes) -> bytes:
        self.ops[msgpack.unpackb(payload, raw=False).get("op", "?")] += 1
        return self.inner.request(payload)

    def close(self) -> None:
        self.inner.close()


def test_server_side_mark_does_no_per_object_wire_reads(tmp_path):
    """`repro gc --remote` against a current server is exactly two wire
    requests — gc_mark + gc_sweep — regardless of how many objects the
    remote holds.  (The PR-4 client-side mark paid one get/has per
    commit/snapshot.)"""
    lake = _lake_with_branch(tmp_path / "lake")
    remote_store = ObjectStore(tmp_path / "remote")
    server = RemoteServer(remote_store)
    push(lake.store, RemoteStore(LoopbackTransport(server)), "u.exp")
    remote_store.delete_ref("branch=u.exp")  # make the closure garbage

    counting = CountingTransport(LoopbackTransport(server))
    rep = collect(RemoteStore(counting, allow_delete=True), prune_age=0.0)
    assert rep.mode == "server"
    assert rep.swept > 0
    assert set(counting.ops) == {"gc_mark", "gc_sweep"}
    assert counting.ops["gc_mark"] == 1 and counting.ops["gc_sweep"] == 1
    # and the sweep really happened server-side
    assert _missing_on(remote_store, lake, "u.exp")


def test_remote_gc_generation_visible_to_clients(tmp_path):
    """A server-side sweep bumps the shared token in the refs keyspace —
    the same ref a push validates — and dry runs bump nothing."""
    remote_store = ObjectStore(tmp_path / "remote")
    server = RemoteServer(remote_store)
    client = RemoteStore(LoopbackTransport(server), allow_delete=True)
    before = read_generation(remote_store)
    rep_dry = collect(client, dry_run=True)
    assert rep_dry.generation is None
    assert read_generation(remote_store) == before
    rep = collect(client)
    assert rep.generation is not None
    assert remote_store.get_ref(GC_GENERATION_REF) == rep.generation


# ------------------------------------------------- downgrade contract
class LegacyGcServer(RemoteServer):
    """A PR-4-era server: no gc_mark/gc_sweep/stat_object ops."""
    _op_gc_mark = None    # getattr finds None -> "unknown op" reply
    _op_gc_sweep = None
    _op_stat_object = None


def test_gc_remote_falls_back_on_legacy_server_with_loud_warning(tmp_path):
    """`repro gc --remote` against a server that predates gc_mark must
    degrade to the client-side mark — correct results, loud warning,
    never a crash (the same downgrade posture as the cas_refs fallback
    in tests/test_sync_conformance.py)."""
    lake = _lake_with_branch(tmp_path / "lake")
    remote_store = ObjectStore(tmp_path / "remote")
    server = LegacyGcServer(remote_store)
    push(lake.store, RemoteStore(LoopbackTransport(server)), "u.exp")
    head = lake.catalog.head("u.exp")

    client = RemoteStore(LoopbackTransport(server), allow_delete=True)
    with pytest.warns(RuntimeWarning, match="predates the gc_mark"):
        rep = collect(client, prune_age=0.0)
    assert rep.mode == "client-fallback"
    assert rep.swept == 0  # branch=u.exp still roots everything
    for digest in commit_closure(lake.store, head):
        assert remote_store.has(digest)

    # drop the root: the fallback sweep must actually collect, and the
    # generation token still advances (cas_ref exists on old servers)
    gen_before = read_generation(remote_store)
    remote_store.delete_ref("branch=u.exp")
    with pytest.warns(RuntimeWarning, match="predates the gc_mark"):
        rep2 = collect(client, prune_age=0.0)
    assert rep2.swept > 0
    assert not list(remote_store.iter_objects())
    assert read_generation(remote_store) != gen_before


def test_legacy_server_grace_window_degrades_loudly_not_silently(tmp_path):
    """Against a server with no stat_object there is no age data: the
    sweep proceeds (legacy quiet-window behavior) but says so — silence
    here would read as 'the window held' when it could not."""
    remote_store = ObjectStore(tmp_path / "remote")
    server = LegacyGcServer(remote_store)
    remote_store.put(b"garbage " * 64)  # unreachable, just uploaded
    client = RemoteStore(LoopbackTransport(server), allow_delete=True)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        rep = collect(client, prune_age=3600.0)
    messages = [str(w.message) for w in caught]
    assert any("predates the gc_mark" in m for m in messages)
    assert any("grace window is DISABLED" in m for m in messages)
    assert rep.swept == 1  # swept despite being young — loudly


# ----------------------------------------------- grace window properties
def test_sweep_boundary_is_exact_under_pinned_clock(tmp_path):
    """Deterministic boundary: with ``now`` pinned, age >= prune_age
    sweeps and age < prune_age is spared — no wall-clock jitter."""
    store = ObjectStore(tmp_path / "store")
    digest = store.put(b"boundary garbage " * 8)
    t0 = store.mtime(digest)

    swept, _freed, young = sweep(store, set(), prune_age=100.0,
                                 dry_run=True, now=t0 + 99.9)
    assert (swept, young) == (0, 1)
    swept, _freed, young = sweep(store, set(), prune_age=100.0,
                                 now=t0 + 100.0)
    assert (swept, young) == (1, 0)
    assert not store.has(digest)

    # a LIVE object is never swept, no matter how old
    live_digest = store.put(b"precious " * 8)
    swept, _freed, _young = sweep(store, {live_digest}, prune_age=0.0,
                                  now=t0 + 10_000.0)
    assert swept == 0 and store.has(live_digest)


@settings(max_examples=12, deadline=None)
@given(st.lists(st.integers(min_value=-1500, max_value=1500),
                min_size=1, max_size=5),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_grace_window_property_fs(tmp_path_factory, offsets, seed):
    """Property (fs backend): for garbage ages scattered around the
    prune-age boundary, ``collect`` never sweeps a reachable-or-young
    object and always sweeps old garbage.  Reachable objects are aged
    too — age must never override reachability."""
    prune_age = 600.0
    root = tmp_path_factory.mktemp("grace")
    lake = Lake(root / "lake", protect_main=False)
    lake.write_table("main", "t",
                     {"v": np.arange(64, dtype=np.float32)})
    store = lake.store

    # age every reachable object far beyond the window: still protected
    for digest in list(store.iter_objects()):
        path = store._path(digest)
        os.utime(path, (path.stat().st_atime,
                        path.stat().st_mtime - 10 * prune_age))

    garbage = {}
    rng = np.random.default_rng(seed)
    for i, offset in enumerate(offsets):
        # keep a safety margin around the boundary: the sweep's clock
        # runs a beat after utime, so exact-boundary ages are untestable
        # with a live clock (pinned-clock exactness is tested above)
        if abs(offset) < 30:
            offset = 30 if offset >= 0 else -30
        blob = b"garbage" + bytes(rng.integers(0, 256, 32).tolist()) \
            + bytes([i])
        digest = store.put(blob)
        path = store._path(digest)
        age = prune_age + offset
        os.utime(path, (path.stat().st_atime,
                        path.stat().st_mtime - age))
        garbage[digest] = age

    report = collect(store, prune_age=prune_age)
    for digest, age in garbage.items():
        if age >= prune_age:
            assert not store.has(digest), \
                f"old garbage (age {age}s) survived the sweep"
        else:
            assert store.has(digest), \
                f"young object (age {age}s) was swept inside the window"
    assert report.skipped_young == sum(1 for a in garbage.values()
                                       if a < prune_age)
    # reachability always wins: the table is intact
    assert lake.read_table("main", "t")["v"][0] == 0.0


def test_grace_window_over_s3_last_modified(tmp_path):
    """The same window through the S3 dialect: ages come from the
    ``Last-Modified`` header (stub: backing-file mtime, like real S3)."""
    httpd, url = serve_s3(tmp_path / "bucket")
    try:
        backend = connect(url)
        old = backend.put(b"old garbage " * 16)
        young = backend.put(b"young garbage " * 16)
        # age `old` beyond the window by rewinding its backing file
        bucket = tmp_path / "bucket"
        path = bucket / "objects" / old[:2] / old[2:]
        os.utime(path, (path.stat().st_atime,
                        path.stat().st_mtime - 7200))
        assert backend.mtime(old) < backend.mtime(young)

        report = collect(backend, prune_age=3600.0)
        assert not backend.has(old)
        assert backend.has(young)
        assert report.skipped_young == 1
        # second pass after the window expires (simulated): sweeps it
        path2 = bucket / "objects" / young[:2] / young[2:]
        os.utime(path2, (path2.stat().st_atime,
                         path2.stat().st_mtime - 7200))
        report2 = collect(backend, prune_age=3600.0)
        assert report2.swept == 1 and not backend.has(young)
    finally:
        httpd.shutdown()
