"""The Iceberg-style table metadata hierarchy (snapshot -> manifest-list ->
manifest -> tensorfiles): versioned encoding with legacy-v0 decode, O(delta)
appends that reuse parent manifests verbatim, zone-map predicate pushdown
provably equivalent to the unpruned scan, column pruning down to the
tensorfile decode, and the manifest-diff append/append merge in the
transaction layer.
"""

import msgpack
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from repro.core import Lake, ObjectStore, TableIO, col
from repro.core import gc as gc_mod
from repro.core import sync as sync_mod
from repro.core.errors import SchemaError, TransactionConflict
from repro.core.table import (ManifestEntry, inline_manifest, unpack_manifest,
                              zone_may_match, zone_of)


def _unpack(blob):
    return msgpack.unpackb(blob, raw=False)


@pytest.fixture()
def store(tmp_path):
    return ObjectStore(tmp_path / "store")


@pytest.fixture()
def io(store):
    return TableIO(store, target_rows_per_file=8)


# ---------------------------------------------------------- format hierarchy
def test_snapshot_is_three_level_hierarchy(io, store):
    digest = io.write_snapshot({"a": np.arange(20, dtype=np.int64)})
    obj = _unpack(store.get(digest))
    assert obj["v"] == 1
    assert "manifest" not in obj  # flat v0 list is gone
    mlist = _unpack(store.get(obj["manifest_list"]))
    assert mlist["kind"] == "manifest_list"
    [row] = mlist["manifests"]
    entries = unpack_manifest(store.get(row[0]))
    assert len(entries) == 3  # 20 rows / 8 per file
    assert sum(e.nrows for e in entries) == 20 == obj["nrows"]
    # manifest-list rows carry the zone rollup next to the counts
    assert row[4]["a"]["min"] == 0 and row[4]["a"]["max"] == 19


def test_append_is_o_delta_and_reuses_parent_manifests(io, store):
    head = io.write_snapshot({"a": np.arange(64, dtype=np.int64)})
    base_manifests = [m.digest for m in io.load_snapshot(head).manifests]
    before = set(store.iter_objects())
    head2 = io.append(head, {"a": np.arange(64, 70, dtype=np.int64)})
    new_objects = set(store.iter_objects()) - before
    # O(delta): 1 tensorfile + 1 manifest + 1 manifest-list + 1 snapshot,
    # regardless of how many files the parent already had
    assert len(new_objects) == 4
    manifests = io.load_snapshot(head2).manifests
    assert [m.digest for m in manifests[:-1]] == base_manifests  # verbatim
    assert manifests[-1].nrows == 6


def test_append_cost_flat_as_table_grows(io, store):
    head = io.write_snapshot({"a": np.arange(80, dtype=np.int64)})
    costs = []
    for i in range(12):
        before = len(set(store.iter_objects()))
        head = io.append(head, {"a": np.arange(i * 5, i * 5 + 5,
                                               dtype=np.int64)})
        costs.append(len(set(store.iter_objects())) - before)
    assert len(set(costs)) == 1  # identical metadata cost every time


def test_history_and_row_order_preserved(io):
    h1 = io.write_snapshot({"a": np.arange(10, dtype=np.int64)})
    h2 = io.append(h1, {"a": np.arange(10, 14, dtype=np.int64)})
    h3 = io.append(h2, {"a": np.arange(14, 30, dtype=np.int64)})
    assert io.history(h3) == [h3, h2, h1]
    np.testing.assert_array_equal(io.read(h3)["a"], np.arange(30))
    np.testing.assert_array_equal(io.read(h2)["a"], np.arange(14))


# ------------------------------------------------------------------ legacy v0
def _write_legacy_v0(store, cols, *, parent=None, op="overwrite", seq=0,
                     rows_per_file=8):
    """Hand-pack a pre-hierarchy snapshot: flat entry list inline, no
    ``v`` key — byte-compatible with what old lakes hold on disk."""
    from repro.core import tensorfile

    arrays = {k: np.asarray(v) for k, v in cols.items()}
    n = next(iter(arrays.values())).shape[0]
    entries, schema = [], None
    for start in range(0, n, rows_per_file):
        chunk = {k: v[start:start + rows_per_file] for k, v in arrays.items()}
        blob, meta = tensorfile.encode(chunk)
        schema = meta["schema"]
        entries.append([store.put(blob), meta["nrows"], meta["nbytes"],
                        meta["stats"]])
    return store.put(msgpack.packb(
        {"schema": schema, "manifest": entries, "parent": parent, "op": op,
         "seq": seq}, use_bin_type=True))


def test_legacy_v0_snapshot_still_readable(io, store):
    cols = {"a": np.arange(20, dtype=np.int64),
            "b": np.linspace(0, 1, 20).astype(np.float32)}
    legacy = _write_legacy_v0(store, cols)
    snap = io.load_snapshot(legacy)
    assert snap.nrows == 20 and snap.nfiles == 3
    np.testing.assert_array_equal(io.read(legacy)["a"], cols["a"])
    # pushdown works over the inline manifest's rolled-up zone too
    out = io.read(legacy, columns=["a"], where=col("a") >= 18)
    np.testing.assert_array_equal(out["a"], [18, 19])


def test_append_on_legacy_parent_migrates_to_hierarchy(io, store):
    legacy = _write_legacy_v0(store, {"a": np.arange(20, dtype=np.int64)})
    head = io.append(legacy, {"a": np.arange(20, 25, dtype=np.int64)})
    obj = _unpack(store.get(head))
    assert obj["v"] == 1 and "manifest_list" in obj  # migrated on touch
    np.testing.assert_array_equal(io.read(head)["a"], np.arange(25))
    # the legacy parent's entries were materialized as a real manifest blob
    first = io.load_snapshot(head).manifests[0]
    assert first.digest is not None
    assert len(unpack_manifest(store.get(first.digest))) == 3


def test_walkers_traverse_both_formats(io, store):
    legacy = _write_legacy_v0(store, {"a": np.arange(20, dtype=np.int64)})
    head = io.append(legacy, {"a": np.arange(20, 25, dtype=np.int64)})
    live = set()
    gc_mod._mark_snapshot(store, head, live)
    # every reachable object of both formats is marked: data files of the
    # legacy parent AND the v1 snapshot/mlist/manifest blobs
    for frame_digest in [e.digest
                         for m in io.load_snapshot(head).manifests
                         for e in io.manifest_entries(m)]:
        assert frame_digest in live
    assert legacy in live and head in live
    # commit_closure agrees with the mark walk on snapshot subtrees
    # (modulo the commit objects it is rooted at)
    lake = Lake(store.root, protect_main=False)
    lake.catalog.commit("main", {"t": head}, "seed")
    closure = sync_mod.commit_closure(store, lake.catalog.head("main"))
    assert live <= closure


def test_sync_ships_hierarchy_and_dedups_manifests(tmp_path):
    from repro.core import (LoopbackTransport, RemoteServer, RemoteStore,
                            push, pull)

    lake = Lake(tmp_path / "a", protect_main=False)
    io = TableIO(lake.store, target_rows_per_file=8)
    head = io.write_snapshot({"a": np.arange(40, dtype=np.int64)})
    lake.catalog.commit("main", {"t": head}, "seed")
    remote = RemoteStore(LoopbackTransport(RemoteServer(
        ObjectStore(tmp_path / "remote"))))
    push(lake.store, remote, "main")

    head2 = io.append(head, {"a": np.arange(40, 45, dtype=np.int64)})
    lake.catalog.commit("main", {"t": head2}, "append")
    rep = push(lake.store, remote, "main")
    # checkpoint-to-checkpoint: the parent's manifests dedup — only the
    # delta (tensorfile, manifest, mlist, snapshot, commit) crosses
    assert 0 < rep.objects_sent <= 5

    lake_b = Lake(tmp_path / "b", protect_main=False)
    pull(lake_b.store, remote, "main")
    np.testing.assert_array_equal(
        lake_b.read_table("main", "t")["a"], np.arange(45))


# ------------------------------------------------------------ column pruning
def test_projected_read_never_materializes_untouched_columns(io, monkeypatch):
    """Failing-first regression for the column-pruning bug: with
    ``columns=``, the other columns' raw bytes must never reach
    ``np.frombuffer`` (the materialization point in tensorfile.decode)."""
    cols = {"a": np.arange(32, dtype=np.int64),
            "b": np.arange(32, dtype=np.float32),
            "c": np.arange(32, dtype=np.int32)}
    digest = io.write_snapshot(cols)
    nfiles = io.load_snapshot(digest).nfiles

    calls = []
    real = np.frombuffer

    def counting(buf, *a, **kw):
        calls.append(len(buf))
        return real(buf, *a, **kw)

    monkeypatch.setattr(np, "frombuffer", counting)
    out = io.read(digest, columns=["a"])
    assert list(out) == ["a"]
    assert len(calls) == nfiles  # one decode per file for ONE column, not 3
    total = sum(calls)
    assert total == 32 * 8  # int64 bytes only; b and c never materialized


def test_predicate_columns_are_decoded_but_not_returned(io):
    digest = io.write_snapshot({"a": np.arange(32, dtype=np.int64),
                                "b": np.arange(32, dtype=np.int64)})
    out = io.read(digest, columns=["a"], where=col("b") > 29)
    assert list(out) == ["a"]
    np.testing.assert_array_equal(out["a"], [30, 31])


def test_unknown_columns_raise(io):
    digest = io.write_snapshot({"a": np.arange(8, dtype=np.int64)})
    with pytest.raises(SchemaError):
        io.read(digest, columns=["nope"])
    with pytest.raises(SchemaError):
        io.read(digest, columns=["a"], where=col("nope") > 0)


# --------------------------------------------------------- zone-map pushdown
def test_zone_pruned_scan_skips_manifest_blobs(io, store):
    head = io.write_snapshot({"a": np.arange(64, dtype=np.int64)})
    head = io.append(head, {"a": np.arange(1000, 1064, dtype=np.int64)})
    reads = []
    orig_get = store.get

    def tracking_get(d):
        reads.append(d)
        return orig_get(d)

    store.get = tracking_get
    try:
        out = io.read(head, where=col("a") >= 1000)
    finally:
        del store.get
    np.testing.assert_array_equal(out["a"], np.arange(1000, 1064))
    snap = io.load_snapshot(head)
    pruned_manifest = snap.manifests[0].digest
    assert pruned_manifest not in reads  # whole manifest skipped unread
    # and none of its data files were fetched either
    for e in unpack_manifest(orig_get(pruned_manifest)):
        assert e.digest not in reads


def _build_predicate(spec):
    """(kind, op_a, lit_a, op_b, lit_b) -> an Expr over columns a and b."""
    kind, op_a, lit_a, op_b, lit_b = spec
    ops = {"gt": lambda c, v: c > v, "ge": lambda c, v: c >= v,
           "lt": lambda c, v: c < v, "le": lambda c, v: c <= v,
           "eq": lambda c, v: c == v, "ne": lambda c, v: c != v}
    pa, pb = ops[op_a](col("a"), lit_a), ops[op_b](col("b"), lit_b)
    if kind == "a":
        return pa
    if kind == "and":
        return pa & pb
    if kind == "or":
        return pa | pb
    return ~pa  # "not"


_CMP = st.sampled_from(["gt", "ge", "lt", "le", "eq", "ne"])
_PRED = st.tuples(st.sampled_from(["a", "and", "or", "not"]),
                  _CMP, st.integers(min_value=-50, max_value=150),
                  _CMP, st.integers(min_value=-50, max_value=150))
_BATCHES = st.lists(
    st.lists(st.integers(min_value=-40, max_value=140), min_size=1,
             max_size=20),
    min_size=1, max_size=6)


@settings(max_examples=40, deadline=None)
@given(batches=_BATCHES, spec=_PRED)
def test_pruned_scan_equals_full_scan(tmp_path, batches, spec):
    """THE pushdown soundness property: for arbitrary data distributions
    (so arbitrary zone maps) and arbitrary predicates, the zone-pruned
    filtered read returns exactly the rows a full-scan filter would."""
    suffix = abs(hash((tuple(map(tuple, batches)), spec))) % (1 << 30)
    store = ObjectStore(tmp_path / f"s{suffix}")
    io = TableIO(store, target_rows_per_file=4)
    head = None
    for batch in batches:
        a = np.asarray(batch, dtype=np.int64)
        cols = {"a": a, "b": (a * 3 - 7).astype(np.int64)}
        head = io.write_snapshot(cols) if head is None else io.append(head,
                                                                      cols)
    pred = _build_predicate(spec)
    full = io.read(head)
    mask = pred.evaluate(full)
    pruned = io.read(head, where=pred)
    np.testing.assert_array_equal(pruned["a"], full["a"][mask])
    np.testing.assert_array_equal(pruned["b"], full["b"][mask])


@settings(max_examples=40, deadline=None)
@given(values=st.lists(st.integers(min_value=-100, max_value=100),
                       min_size=0, max_size=12),
       op=_CMP, literal=st.integers(min_value=-110, max_value=110))
def test_zone_may_match_is_sound(values, op, literal):
    """zone_may_match(e, zone, n) is False ONLY when no row matches —
    checked against brute-force evaluation on the actual rows."""
    arr = np.asarray(values, dtype=np.int64).reshape(len(values))
    if len(values):
        entry = ManifestEntry("x", len(values), arr.nbytes,
                              {"v": {"min": int(arr.min()),
                                     "max": int(arr.max())}})
    else:
        entry = ManifestEntry("x", 0, 0, {})
    zone = zone_of((entry,))
    ops = {"gt": lambda c, v: c > v, "ge": lambda c, v: c >= v,
           "lt": lambda c, v: c < v, "le": lambda c, v: c <= v,
           "eq": lambda c, v: c == v, "ne": lambda c, v: c != v}
    pred = ops[op](col("v"), literal)
    any_match = bool(pred.evaluate({"v": arr}).any()) if len(values) else False
    if any_match:
        assert zone_may_match(pred, zone, len(values))


def test_nan_semantics_in_zone_pruning(io):
    """NaN rows compare False under every operator except ``!=`` — the
    zone evaluator must keep files containing NaN alive for ``!=`` and
    must never prune a mixed file unsoundly."""
    vals = np.array([1.0, np.nan, 3.0, np.nan], dtype=np.float64)
    digest = io.write_snapshot({"v": vals})
    out = io.read(digest, where=col("v") != 2.0)
    # != matches the NaN rows as numpy does
    assert out["v"].shape[0] == 4
    out = io.read(digest, where=col("v") > 2.0)
    np.testing.assert_array_equal(out["v"], [3.0])
    # all-NaN file: only != can match
    digest = io.write_snapshot({"v": np.full(4, np.nan)})
    assert io.read(digest, where=col("v") == 1.0)["v"].shape[0] == 0
    assert io.read(digest, where=col("v") != 1.0)["v"].shape[0] == 4


def test_zone_rollup_omits_unstatted_columns():
    entries = (ManifestEntry("x", 4, 32, {"a": {"min": 0, "max": 3}}),
               ManifestEntry("y", 4, 32, {"a": {}}))  # empty stats
    assert "a" not in zone_of(entries)  # pruning would be unsound
    mf = inline_manifest(entries)
    assert mf.nrows == 8 and mf.nfiles == 2


def test_empty_filtered_read_returns_typed_empty_columns(io):
    digest = io.write_snapshot({"a": np.arange(8, dtype=np.int64),
                                "b": np.ones((8, 3), dtype=np.float32)})
    out = io.read(digest, where=col("a") > 99)
    assert out["a"].dtype == np.int64 and out["a"].shape == (0,)
    assert out["b"].dtype == np.float32 and out["b"].shape == (0, 3)


# --------------------------------------------- append/append manifest merge
def test_same_table_disjoint_appends_both_land_without_conflict(tmp_path):
    lake = Lake(tmp_path / "lake", protect_main=False)
    base = lake.io.write_snapshot({"v": np.arange(10, dtype=np.int64)})
    lake.catalog.commit("main", {"events": base}, "seed")

    t1 = lake.transaction("main", author="w1")
    t2 = lake.transaction("main", author="w2")
    t1.write("events", {"v": np.arange(100, 110, dtype=np.int64)},
             append=True)
    t2.write("events", {"v": np.arange(200, 210, dtype=np.int64)},
             append=True)
    t1.commit("w1 append")
    t2.commit("w2 append")  # rebases via manifest diff, no conflict

    assert lake.catalog.txn_stats["conflicts"] == 0
    assert lake.catalog.txn_stats["append_merges"] == 1
    out = lake.read_table("main", "events")["v"]
    assert out.shape[0] == 30
    assert set(out.tolist()) == (set(range(10)) | set(range(100, 110))
                                 | set(range(200, 210)))
    # first-committer's rows precede the rebased writer's (their + ours)
    np.testing.assert_array_equal(out[:20],
                                  np.concatenate([np.arange(10),
                                                  np.arange(100, 110)]))


def test_append_overwrite_race_is_still_a_conflict(tmp_path):
    lake = Lake(tmp_path / "lake", protect_main=False)
    base = lake.io.write_snapshot({"v": np.arange(10, dtype=np.int64)})
    lake.catalog.commit("main", {"events": base}, "seed")

    t1 = lake.transaction("main", author="w1")
    t2 = lake.transaction("main", author="w2")
    t1.write("events", {"v": np.arange(5, dtype=np.int64)})  # overwrite
    t2.write("events", {"v": np.arange(50, 60, dtype=np.int64)}, append=True)
    t1.commit("w1 overwrite")
    with pytest.raises(TransactionConflict):
        t2.commit("w2 append")
    # and the mirror image: append lands, overwrite conflicts
    t3 = lake.transaction("main", author="w3")
    t4 = lake.transaction("main", author="w4")
    t3.write("events", {"v": np.arange(3, dtype=np.int64)}, append=True)
    t4.write("events", {"v": np.arange(3, dtype=np.int64)})
    t3.commit("w3 append")
    with pytest.raises(TransactionConflict):
        t4.commit("w4 overwrite")


def test_declared_read_of_moved_table_still_conflicts(tmp_path):
    """The append merge must not weaken repeatable-read semantics: a
    transaction that READ a table another writer appended to is stale."""
    lake = Lake(tmp_path / "lake", protect_main=False)
    base = lake.io.write_snapshot({"v": np.arange(4, dtype=np.int64)})
    lake.catalog.commit("main", {"events": base}, "seed")

    t1 = lake.transaction("main", author="reader")
    t1.read("events")
    t1.write("summary", {"n": np.array([4], dtype=np.int64)})
    t2 = lake.transaction("main", author="writer")
    t2.write("events", {"v": np.arange(9, dtype=np.int64)}, append=True)
    t2.commit("concurrent append")
    with pytest.raises(TransactionConflict):
        t1.commit("stale summary")


def test_many_writers_same_table_all_land(tmp_path):
    import threading

    lake = Lake(tmp_path / "lake", protect_main=False)
    base = lake.io.write_snapshot({"v": np.arange(4, dtype=np.int64)})
    lake.catalog.commit("main", {"events": base}, "seed")
    errors = []

    def writer(i):
        try:
            txn = lake.transaction("main", author=f"w{i}")
            txn.write("events",
                      {"v": np.arange(i * 100, i * 100 + 10,
                                      dtype=np.int64)}, append=True)
            txn.commit(f"w{i}")
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []
    assert lake.catalog.txn_stats["conflicts"] == 0
    out = lake.read_table("main", "events")["v"]
    assert out.shape[0] == 4 + 6 * 10  # zero lost updates
