"""Distributed DAG execution: leases in the refs keyspace, worker backends,
crash recovery, and the cross-executor bit-identity contract.

The deterministic worker-crash tests reuse tests/fault_schedule.py: the
:class:`~repro.core.exec.WorkerService` ``trace`` hook fires the schedule's
sync points (``worker:claim``, ``worker:execute``,
``worker:complete:before``), so "a worker dies right before reporting
completion" is a scheduled event, not a hoped-for race.
"""

import threading
import time
import warnings

import numpy as np
import pytest

from fault_schedule import InjectedFault, Schedule
from repro.core import (CacheDemotionWarning, Lake, Model,
                        NodeExecutionError, Pipeline, ReproError,
                        WorkerService, execute, model, run_status)
from repro.core.exec import DONE, FAILED, LEASED, PENDING, Lease, LeaseBoard
from repro.core.exec.coordinator import _reset_demotion_warnings
from repro.core.gc import collect

# ---------------------------------------------------------------------------
# Module-level node functions.  The process executor pickles functions by
# reference, so everything a process-pool test runs must be a module-level
# *function* — ``model()`` returns a Node, which would shadow the function's
# name, so the raw fns keep their own names and are wrapped explicitly.

_MUTABLE_STATE = {"tag": "unstable"}  # mutable global -> cache_safe False


def _doubled_fn(data=Model("source_table")):
    return {"v": data["c1"] * 2.0}


def _unstable_fn(data=Model("doubled")):
    _ = _MUTABLE_STATE  # unstable capture: uncacheable, and unmaterialized
    return {"v": data["v"] + 1.0}


def _final_fn(data=Model("unstable_mid")):
    return {"v": data["v"] * 3.0}


def pipe3() -> Pipeline:
    """doubled -> unstable_mid (uncacheable, materialize=False) -> final."""
    return Pipeline([
        model(name="doubled")(_doubled_fn),
        model(name="unstable_mid", materialize=False)(_unstable_fn),
        model(name="final")(_final_fn),
    ])


def mk_lake(tmp_path, name, source_cols):
    """A fresh lake with the SAME deterministic clock as every sibling —
    identical operation sequences produce identical commit timestamps,
    which is what makes commit digests comparable across executors."""
    t = [1_700_000_000.0]

    def clock():
        t[0] += 1.0
        return t[0]

    lake = Lake(tmp_path / name, clock=clock)
    snap = lake.io.write_snapshot(source_cols)
    lake.catalog.commit("main", {"source_table": snap}, "seed",
                        _wap_token=True)
    lake.catalog.create_branch("u.run", "main", author="u")
    return lake


def the_exec_id(lake) -> str:
    (run_id,) = list(LeaseBoard.list_runs(lake.store))
    return run_id


# =============================================================== lease board
def test_lease_encode_decode_roundtrip():
    lease = Lease(node="n", state=LEASED, owner="w1", attempt=3,
                  deadline=1234.5, payload="ab" * 32)
    assert Lease.decode("n", lease.encode()) == lease
    empty = Lease(node="m", state=PENDING, owner="", attempt=0,
                  deadline=0.0, payload="")
    assert Lease.decode("m", empty.encode()) == empty
    assert lease.expired(now=1235.0)
    assert not lease.expired(now=1234.0)
    assert not empty.expired(now=1e12)  # pending never "expires"
    with pytest.raises(ReproError, match="corrupt lease"):
        Lease.decode("n", "not-a-lease")


def test_lease_transitions_and_attempt_counter(lake):
    t = [0.0]
    board = LeaseBoard(lake.store, "run1", clock=lambda: t[0])
    board.publish("n", "")
    assert board.read("n").state == PENDING

    l1 = board.claim("n", "w1", ttl=100.0)
    assert l1.state == LEASED and l1.owner == "w1" and l1.attempt == 1
    # a second claimer loses: the node is no longer pending
    assert board.claim("n", "w2", ttl=100.0) is None

    t[0] = 50.0
    hb = board.heartbeat(l1, ttl=100.0)
    assert hb is not None and hb.deadline == 150.0

    # requeue preserves the attempt counter; the next claim increments it
    assert board.requeue(hb)
    assert board.read("n").state == PENDING
    assert board.read("n").attempt == 1
    l2 = board.claim("n", "w2", ttl=100.0)
    assert l2.attempt == 2
    # the old owner's heartbeat and completion are now dead letters
    assert board.heartbeat(hb, ttl=100.0) is None
    assert board.complete(hb, "feed" * 16) is False
    # the new owner completes
    assert board.complete(l2, "feed" * 16)
    assert board.read("n").state == DONE
    # done is terminal
    assert board.claim("n", "w3", ttl=100.0) is None
    assert board.poison(board.read("n"), "dead" * 16) is False


def test_lease_claim_race_exactly_one_winner(lake):
    board = LeaseBoard(lake.store, "race")
    board.publish("n", "")
    wins = []
    barrier = threading.Barrier(8)

    def claimer(i):
        barrier.wait()
        got = board.claim("n", f"w{i}", ttl=100.0)
        if got is not None:
            wins.append(got.owner)

    threads = [threading.Thread(target=claimer, args=(i,)) for i in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert len(wins) == 1  # CAS: exactly one claim lands


# ============================================== cross-executor bit identity
def test_executors_commit_bit_identical(tmp_path, source_cols):
    """jobs=1, jobs=8 and the process pool must produce bit-identical
    commit digests on a DAG whose middle node is uncacheable AND
    unmaterialized — the shape that forces the executor to persist an
    internal snapshot purely so descendants can key off it."""
    runs = {
        "jobs1": dict(jobs=1),
        "jobs8": dict(jobs=8),
        "procpool": dict(jobs=4, executor="process"),
    }
    digests = {}
    for label, kw in runs.items():
        lk = mk_lake(tmp_path, label, source_cols)
        rep = execute(pipe3(), lk.catalog, lk.io, branch="u.run",
                      author="u", **kw)
        assert rep.commit is not None
        assert rep.node_stats["unstable_mid"].cache_skip_reason \
            == "unstable-capture"
        assert rep.node_stats["unstable_mid"].cache_key is None
        digests[label] = rep.commit
    assert len(set(digests.values())) == 1, digests


def test_process_pool_shares_run_cache_across_processes(tmp_path,
                                                        source_cols):
    """The shared fs run cache is the cross-process memo table: a second
    process-pool run hits it for every cacheable node."""
    lk = mk_lake(tmp_path, "memo", source_cols)
    cold = execute(pipe3(), lk.catalog, lk.io, branch="u.run",
                   author="u", jobs=2, executor="process")
    warm = execute(pipe3(), lk.catalog, lk.io, branch="u.run",
                   author="u", jobs=2, executor="process")
    assert cold.cache_hits == 0
    assert warm.cache_hits == 2  # doubled + final (unstable_mid never caches)
    assert warm.commit is None  # nothing changed on the branch
    np.testing.assert_allclose(
        lk.read_table("u.run", "final")["v"],
        (lk.read_table("main", "source_table")["c1"] * 2.0 + 1.0) * 3.0,
        rtol=1e-6)


def test_process_pool_falls_back_to_thread_for_closures(seeded_lake):
    """Nodes defined inside a function can't be pickled — they must run
    (correctly) on the in-process fallback instead of failing the run."""
    captured = 7.0

    @model()
    def closure_node(data=Model("source_table")):
        return {"v": data["c1"] + captured}

    seeded_lake.catalog.create_branch("u.fb", "main", author="u")
    rep = execute(Pipeline([closure_node]), seeded_lake.catalog,
                  seeded_lake.io, branch="u.fb", author="u",
                  jobs=2, executor="process")
    assert "closure_node" in rep.outputs
    np.testing.assert_allclose(
        seeded_lake.read_table("u.fb", "closure_node")["v"],
        seeded_lake.read_table("main", "source_table")["c1"] + 7.0)


def test_unknown_executor_rejected(seeded_lake):
    with pytest.raises(ReproError, match="unknown executor"):
        execute(pipe3(), seeded_lake.catalog, seeded_lake.io,
                branch="main", author="u", executor="carrier-pigeon")


# ===================================================== remote worker service
def test_remote_worker_end_to_end(tmp_path, source_cols):
    """Coordinator publishes leases; a WorkerService (same store, separate
    poll loop) claims, heartbeats, executes and completes them.  The commit
    is bit-identical to a thread-executor run on a sibling lake."""
    lk = mk_lake(tmp_path, "remote", source_cols)
    pipe = pipe3()
    svc = WorkerService(lk.store, [pipe], name="w1", ttl=5.0, poll=0.01)
    stop = threading.Event()
    th = threading.Thread(target=svc.serve_forever, args=(stop,),
                          daemon=True)
    th.start()
    try:
        rep = execute(pipe, lk.catalog, lk.io, branch="u.run",
                      author="u", executor="remote", lease_ttl=5.0,
                      poll=0.01, wait_timeout=30.0)
    finally:
        stop.set()
        th.join(timeout=10.0)
    assert svc.nodes_done == 3
    assert rep.executor == "remote"
    assert all(s.attempts == 1 for s in rep.node_stats.values())

    ref = mk_lake(tmp_path, "ref", source_cols)
    ref_rep = execute(pipe3(), ref.catalog, ref.io, branch="u.run",
                      author="u", jobs=1)
    assert rep.commit == ref_rep.commit


def test_remote_worker_ignores_unknown_pipeline(tmp_path, source_cols):
    """Code is never shipped: a worker that doesn't hold a pipeline with
    the run's exact code hash must not touch its leases (the same pinning
    that makes replay refuse drifted code)."""
    lk = mk_lake(tmp_path, "drift", source_cols)

    @model()
    def other(data=Model("source_table")):
        return {"v": data["c1"]}

    svc = WorkerService(lk.store, [Pipeline([other])], name="wx",
                        ttl=1.0, poll=0.01)
    with pytest.raises(ReproError, match="stalled"):
        execute(pipe3(), lk.catalog, lk.io, branch="u.run",
                author="u", executor="remote", poll=0.01,
                wait_timeout=0.5)
    assert svc.run_once() is False  # nothing it can (or may) claim
    assert svc.nodes_done == 0


def test_killed_worker_node_is_released_and_run_completes(tmp_path,
                                                          source_cols):
    """Fault schedule: worker 1 dies AFTER executing a node (snapshot +
    cache entry written) but BEFORE completing the lease.  The coordinator
    detects the expired lease, requeues the node, and worker 2 finishes
    the run — hitting the run cache for the dead worker's work."""
    lk = mk_lake(tmp_path, "crash", source_cols)
    pipe = pipe3()
    sched = Schedule()
    sched.kill("worker:complete:before", occurrence=1)

    w1 = WorkerService(lk.store, [pipe], name="doomed", ttl=0.4,
                       poll=0.01, trace=sched.fire)
    w2 = WorkerService(lk.store, [pipe], name="survivor", ttl=0.4,
                       poll=0.01)
    stop = threading.Event()

    def worker_host():
        # worker 1 claims one node and crashes mid-completion; worker 2
        # then serves the rest of the run (and the re-leased node)
        with pytest.raises(InjectedFault):
            while not w1.run_once():
                time.sleep(0.005)
        w2.serve_forever(stop)

    th = threading.Thread(target=worker_host, daemon=True)
    th.start()
    try:
        rep = execute(pipe, lk.catalog, lk.io, branch="u.run",
                      author="u", executor="remote", lease_ttl=0.4,
                      poll=0.02, max_attempts=5, wait_timeout=30.0)
    finally:
        stop.set()
        th.join(timeout=10.0)

    assert rep.commit is not None
    # exactly one node needed a second lease, and the survivor served it
    # from the cache entry the dead worker had already written
    releases = [s for s in rep.node_stats.values() if s.attempts == 2]
    assert len(releases) == 1
    assert releases[0].cache_hit
    assert w1.nodes_done == 0 and w2.nodes_done == 3


def test_poison_pill_after_max_attempts(tmp_path, source_cols):
    """A node that kills every worker that claims it must not retry
    forever: after ``max_attempts`` lease claims the coordinator poisons
    it and the run fails with the attempt count attached."""
    lk = mk_lake(tmp_path, "poison", source_cols)
    pipe = pipe3()
    sched = Schedule()
    sched.kill("worker:complete:before", occurrence=None)  # every claim dies
    svc = WorkerService(lk.store, [pipe], name="mayfly", ttl=0.3,
                        poll=0.01, trace=sched.fire)
    stop = threading.Event()

    def respawning_host():
        while not stop.is_set():
            try:
                if not svc.run_once():
                    time.sleep(0.005)
            except InjectedFault:
                continue  # the "crashed" worker process, respawned

    th = threading.Thread(target=respawning_host, daemon=True)
    th.start()
    try:
        with pytest.raises(NodeExecutionError, match="poison pill") as ei:
            execute(pipe, lk.catalog, lk.io, branch="u.run",
                    author="u", executor="remote", lease_ttl=0.3,
                    poll=0.02, max_attempts=2, wait_timeout=30.0)
    finally:
        stop.set()
        th.join(timeout=10.0)
    assert ei.value.node == "doubled"  # the only root: first node claimed
    assert ei.value.attempts == 2
    status = run_status(lk.store, the_exec_id(lk))
    assert status["state"] == "failed"
    assert status["nodes"]["doubled"]["state"] == FAILED


# =================================================== cache demotion warning
def test_unhashable_param_demotion_warns_once_and_is_recorded(seeded_lake):
    """The silent ``except TypeError`` demotion is now loud and auditable:
    one CacheDemotionWarning per node, with the skip reason on the
    NodeStat."""
    _reset_demotion_warnings()

    class Opaque:  # no stable cache encoding
        pass

    @model()
    def tuned(data=Model("source_table"), knob=None):
        return {"v": data["c1"]}

    pipe = Pipeline([tuned])
    seeded_lake.catalog.create_branch("u.warn", "main", author="u")
    with pytest.warns(CacheDemotionWarning, match="tuned"):
        rep = execute(pipe, seeded_lake.catalog, seeded_lake.io,
                      branch="u.warn", author="u",
                      params={"knob": Opaque()})
    stat = rep.node_stats["tuned"]
    assert stat.cache_skip_reason == "unhashable-param"
    assert stat.cache_key is None

    # once per node: the second run is silent (but still demoted)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        rep2 = execute(pipe, seeded_lake.catalog, seeded_lake.io,
                       branch="u.warn", author="u",
                       params={"knob": Opaque()})
    assert not [w for w in rec
                if issubclass(w.category, CacheDemotionWarning)]
    assert rep2.node_stats["tuned"].cache_skip_reason == "unhashable-param"


def test_cache_skip_reason_lands_in_ledger_manifest(seeded_lake):
    seeded_lake.catalog.create_branch("u.led2", "main", author="u")
    res = seeded_lake.run(pipe3(), branch="u.led2", author="u")
    manifest = seeded_lake.ledger.get(res.run_id)
    assert manifest["nodes"]["unstable_mid"]["cache_skip_reason"] \
        == "unstable-capture"
    assert manifest["nodes"]["doubled"]["cache_skip_reason"] is None
    assert manifest["nodes"]["doubled"]["attempts"] == 1


def test_unstable_capture_reason_recorded(seeded_lake):
    seeded_lake.catalog.create_branch("u.cap", "main", author="u")
    rep = execute(pipe3(), seeded_lake.catalog, seeded_lake.io,
                  branch="u.cap", author="u")
    assert rep.node_stats["unstable_mid"].cache_skip_reason \
        == "unstable-capture"
    assert rep.node_stats["doubled"].cache_skip_reason is None
    # with the cache off entirely there is nothing to skip
    seeded_lake.catalog.create_branch("u.nocache", "main", author="u")
    rep2 = execute(pipe3(), seeded_lake.catalog, seeded_lake.io,
                   branch="u.nocache", author="u", use_cache=False)
    assert rep2.node_stats["unstable_mid"].cache_skip_reason is None


# ============================================================== repro status
def test_run_status_live_and_final(seeded_lake):
    """While a node executes, ``repro status`` shows its lease (owner,
    attempt, heartbeat headroom); after the run, the record's final
    summary — and the lease refs are gone, so the keyspace stays bounded."""
    started = threading.Event()
    release = threading.Event()

    @model()
    def gated(data=Model("source_table")):
        started.set()
        assert release.wait(10.0)
        return {"v": data["c1"]}

    seeded_lake.catalog.create_branch("u.live", "main", author="u")
    out = {}

    def runner():
        out["rep"] = execute(Pipeline([gated]), seeded_lake.catalog,
                             seeded_lake.io, branch="u.live", author="u",
                             exec_id="statusrun01", lease_ttl=60.0)

    th = threading.Thread(target=runner, daemon=True)
    th.start()
    try:
        assert started.wait(10.0)
        live = run_status(seeded_lake.store, "statusr")  # prefix resolves
        assert live["state"] == "running"
        assert live["nodes"]["gated"]["state"] == LEASED
        assert live["nodes"]["gated"]["attempt"] == 1
        assert live["nodes"]["gated"]["heartbeat_in"] > 0
        assert not live["nodes"]["gated"]["expired"]
    finally:
        release.set()
        th.join(timeout=10.0)
    assert out["rep"].exec_id == "statusrun01"

    done = run_status(seeded_lake.store, "statusrun01")
    assert done["state"] == "done"
    assert done["commit"] == out["rep"].commit
    assert done["nodes"]["gated"]["state"] == "done"
    assert done["nodes"]["gated"]["snapshot"] is not None
    # lease refs deleted after completion
    assert LeaseBoard(seeded_lake.store, "statusrun01").board() == {}


def test_run_status_resolves_ledger_run_id(seeded_lake):
    seeded_lake.catalog.create_branch("u.led", "main", author="u")
    res = seeded_lake.run(pipe3(), branch="u.led", author="u")
    status = seeded_lake.run_status(res.run_id)
    assert status["ledger_run_id"] == res.run_id
    assert status["state"] == "done"
    assert set(status["nodes"]) == {"doubled", "unstable_mid", "final"}
    manifest = seeded_lake.ledger.get(res.run_id)
    assert manifest["executor"]["kind"] == "thread"
    assert manifest["executor"]["exec_id"] == status["exec_id"]


def test_run_status_unknown_run_raises(seeded_lake):
    with pytest.raises(ReproError, match="no execution state"):
        run_status(seeded_lake.store, "nope")


# ======================================================================= gc
def test_gc_keeps_inflight_exec_state(tmp_path, source_cols):
    """A published-but-unclaimed task blob (remote run waiting for a
    worker) must survive gc — sweeping it would strand the run."""
    lk = mk_lake(tmp_path, "gcrun", source_cols)
    pipe = pipe3()
    err = {}

    def runner():
        try:
            execute(pipe, lk.catalog, lk.io, branch="u.run",
                    author="u", executor="remote", poll=0.02,
                    lease_ttl=5.0, wait_timeout=30.0)
        except Exception as e:  # noqa: BLE001 - surfaced via err below
            err["e"] = e

    th = threading.Thread(target=runner, daemon=True)
    th.start()

    def pending_published() -> bool:
        try:
            board = LeaseBoard(lk.store, the_exec_id(lk)).board()
        except ValueError:  # run record not created yet
            return False
        return any(l.state == PENDING for l in board.values())

    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline and not pending_published():
        time.sleep(0.01)
    assert pending_published()
    collect(lk.store)  # mid-run sweep: must not eat exec blobs

    svc = WorkerService(lk.store, [pipe], name="late", ttl=5.0, poll=0.01)
    stop = threading.Event()
    wt = threading.Thread(target=svc.serve_forever, args=(stop,),
                          daemon=True)
    wt.start()
    th.join(timeout=30.0)
    stop.set()
    wt.join(timeout=10.0)
    assert "e" not in err, f"run failed after gc: {err.get('e')}"
    assert svc.nodes_done == 3
