import os
import sys

# Tests run on the single real CPU device (the dry-run, and only the dry-run,
# forces 512 host devices in its own process — see launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.core import Lake


@pytest.fixture()
def lake(tmp_path):
    """A throwaway lake with a deterministic clock (monotone, test-stable)."""
    t = [1_700_000_000.0]

    def clock():
        t[0] += 1.0
        return t[0]

    return Lake(tmp_path / "lake", clock=clock)


@pytest.fixture()
def source_cols():
    rng = np.random.default_rng(0)
    n = 257  # intentionally not a multiple of any chunk size
    return {
        "c1": rng.normal(size=n).astype(np.float32),
        "c2": rng.integers(0, 1000, size=n).astype(np.int64),
        "c3": (np.arange(n) % 7).astype(np.int32),
        "transaction_ts": np.arange(n, dtype=np.int64),
    }


@pytest.fixture()
def seeded_lake(lake, source_cols):
    snap = lake.io.write_snapshot(source_cols)
    lake.catalog.commit("main", {"source_table": snap}, "seed",
                        _wap_token=True)
    return lake
