"""Serving conformance harness: one contract suite, every configuration.

The serving tier promises the same semantics no matter how requests are
batched or how many replicas sit behind the front-end.  This module states
that contract ONCE as a list of checks and runs it against every

    batching mode   ×   replicas
    (continuous, fixed)  (1, 2)

combination — the same treatment ``sync_conformance.py`` gave the remote
tier, now for the serving fleet (docs/serving.md):

* **equivalence**: a continuously-batched request's token stream is
  bit-identical to generating it alone, for any arrival order /
  ``n_tokens`` mix (the fixed baseline documents completion + commit
  pinning only — left-pad contamination is exactly why it is the
  baseline);
* **rollout**: flipping ``serving/prod`` mid-load rolls replicas one at a
  time onto the new commit with ZERO failed requests, and every response
  cites one of the two deployed commits — never a torn state;
* **rollback**: the reverse flip converges the fleet back, twice in a row
  returns to the start;
* **canary**: a candidate failing its WAP gate leaves ``serving/prod``
  (and ``serving/prev``) untouched — no partial flip;
* **crash**: a replica killed mid-rollout (``tests/fault_schedule.py``
  kills at the ``replica:*:swap:before`` sync point) takes no requests
  with it — survivors re-serve its work from the old tag;
* **head-of-line**: short requests submitted after a long one overtake it
  under continuous batching (and demonstrably do NOT under the fixed
  baseline);
* **warm pool**: on a tiered lake a replica prefetches its checkpoint
  closure through the read-through BEFORE taking traffic.

Run standalone (the CI leg) or through the pytest wrapper
(``tests/test_serving_conformance.py``):

    PYTHONPATH=src python -m tests.serving_conformance
    PYTHONPATH=src python -m tests.serving_conformance --soak 40 --seed 7
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))  # fault_schedule under -m

import argparse
import shutil
import tempfile
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from fault_schedule import Schedule
from repro.checkpoint import save
from repro.configs import smoke_config
from repro.core import Lake, ObjectStore
from repro.core.errors import ReproError
from repro.core.sync import commit_closure
from repro.core.wap import column_range
from repro.models import init_params
from repro.serving import (PREV_TAG, PROD_TAG, ContinuousBatcher,
                           FixedBatchedServer, Replica, ServeEngine,
                           ServingFleet, canary_rollout,
                           default_canary_expectations, flip_tag,
                           prefetch_weights, read_tag, rollback)

MODES = ("continuous", "fixed")
REPLICAS = (1, 2)
MAX_LEN = 64
SLOTS = 2


@dataclass(frozen=True)
class Combo:
    mode: str       # request scheduler: continuous batching or fixed buckets
    replicas: int   # fleet width behind the front-end

    @property
    def ident(self) -> str:
        return f"{self.mode}/replicas={self.replicas}"


class ServeContext:
    """One check's world: a fresh lake holding two checkpoint commits
    (A = seed 0, B = seed 1) with ``serving/prod`` tagged onto A."""

    def __init__(self, combo: Combo, root: Path, *, tiered: bool = False):
        self.combo = combo
        self.root = Path(root)
        t = [1_700_000_000.0]

        def clock():
            t[0] += 1.0
            return t[0]

        if tiered:
            # checkpoints live on the REMOTE; the serving lake reads them
            # through the tier (the warm-pool prefetch scenario)
            origin = Lake(self.root / "origin", protect_main=False,
                          clock=clock)
            self._make_checkpoints(origin)
            self.remote_store = origin.store
            self.lake = Lake(self.root / "lake", protect_main=False,
                             clock=clock, remote=origin.store)
            # the serving lake resolves commits/tags through the tier
            self.commit_a, self.commit_b = self._commits
        else:
            self.lake = Lake(self.root / "lake", protect_main=False,
                             clock=clock)
            self._make_checkpoints(self.lake)
            self.commit_a, self.commit_b = self._commits
        self.cfg = smoke_config("paper-demo")

    def _make_checkpoints(self, lake: Lake) -> None:
        cfg = smoke_config("paper-demo")
        lake.catalog.create_branch("t.run", "main", author="t")
        a = save(lake, "t.run", step=1,
                 params=init_params(cfg, jax.random.PRNGKey(0)), author="t")
        b = save(lake, "t.run", step=2,
                 params=init_params(cfg, jax.random.PRNGKey(1)), author="t")
        flip_tag(lake, a)
        self._commits = (a, b)

    # ------------------------------------------------------------ fixtures
    def fleet(self, *, replicas: Optional[int] = None,
              on_event=None, poll_every: int = 2) -> ServingFleet:
        return ServingFleet(self.lake, self.cfg,
                            replicas=replicas or self.combo.replicas,
                            slots=SLOTS, max_len=MAX_LEN,
                            mode=self.combo.mode, poll_every=poll_every,
                            on_event=on_event)

    def requests(self, n: int, *, seed: int = 0, max_gen: int = 6
                 ) -> List[Tuple[int, np.ndarray, int]]:
        rng = np.random.default_rng(seed)
        return [(rid,
                 rng.integers(3, self.cfg.vocab_size,
                              size=int(rng.integers(4, 11))
                              ).astype(np.int32),
                 int(rng.integers(1, max_gen + 1)))
                for rid in range(n)]

    def oracle(self, commit: str,
               reqs: List[Tuple[int, np.ndarray, int]]
               ) -> Dict[int, np.ndarray]:
        """Sequential per-request generation at B=1 — the ground truth the
        continuous batcher must match token for token."""
        eng = ServeEngine.from_catalog(self.lake, commit, self.cfg,
                                       max_len=MAX_LEN, batch_size=1)
        return {rid: eng.generate(p[None], n_tokens=n).tokens[0]
                for rid, p, n in reqs}


def _assert_served(fleet: ServingFleet, reqs, *, commits) -> None:
    """Every request completed, at full length, citing a deployed commit."""
    assert set(fleet.completed) == {rid for rid, _, _ in reqs}, \
        f"lost requests: {set(r for r, _, _ in reqs) - set(fleet.completed)}"
    for rid, _p, n in reqs:
        res = fleet.completed[rid]
        assert res.tokens.shape[1] == n, (rid, res.tokens.shape, n)
        assert res.model_commit in commits, (rid, res.model_commit)


# ------------------------------------------------------------------- checks
def check_equivalence(ctx: ServeContext) -> None:
    """Batched serving completes everything, pinned to the tag's commit;
    under continuous batching the streams equal the sequential oracle."""
    reqs = ctx.requests(8)
    fleet = ctx.fleet()
    # staggered arrival: half up front, the rest injected mid-generation
    for rid, p, n in reqs[:4]:
        fleet.submit(rid, p, n)
    fleet.step()
    for rid, p, n in reqs[4:]:
        fleet.submit(rid, p, n)
    fleet.drain()
    _assert_served(fleet, reqs, commits={ctx.commit_a})
    if ctx.combo.mode == "continuous":
        oracle = ctx.oracle(ctx.commit_a, reqs)
        for rid, _p, _n in reqs:
            np.testing.assert_array_equal(
                fleet.completed[rid].tokens[0], oracle[rid],
                err_msg=f"req {rid} diverged from the sequential oracle")


def check_rollout_under_load(ctx: ServeContext) -> None:
    """Tag flip mid-load: zero failed requests, replicas converge to the
    new commit one at a time, every response cites A or B (no torn state),
    and the flip records A under ``serving/prev``."""
    reqs = ctx.requests(12, seed=1)
    fleet = ctx.fleet()
    for rid, p, n in reqs[:6]:
        fleet.submit(rid, p, n)
    fleet.step()
    rep = flip_tag(ctx.lake, ctx.commit_b)
    assert rep.flipped and rep.old == ctx.commit_a
    for rid, p, n in reqs[6:]:
        fleet.submit(rid, p, n)
        fleet.step()
    fleet.drain()
    for _ in range(3 * fleet.poll_every):  # let the rolling update finish
        fleet.step()
    _assert_served(fleet, reqs, commits={ctx.commit_a, ctx.commit_b})
    assert fleet.rollouts == 1
    assert all(r.commit == ctx.commit_b for r in fleet.replicas)
    assert all(r.swaps == 2 for r in fleet.replicas)
    assert read_tag(ctx.lake, PREV_TAG) == ctx.commit_a
    # late traffic is served from B
    fleet.submit(999, reqs[0][1], 2)
    fleet.drain()
    assert fleet.completed[999].model_commit == ctx.commit_b


def check_rollback(ctx: ServeContext) -> None:
    """Rollback is the reverse flip; two in a row return to the start."""
    fleet = ctx.fleet()
    flip_tag(ctx.lake, ctx.commit_b)
    for _ in range(4 * fleet.poll_every):
        fleet.step()
    assert all(r.commit == ctx.commit_b for r in fleet.replicas)

    rb = rollback(ctx.lake)
    assert rb.flipped and rb.new == ctx.commit_a
    assert read_tag(ctx.lake, PROD_TAG) == ctx.commit_a
    assert read_tag(ctx.lake, PREV_TAG) == ctx.commit_b
    for _ in range(4 * fleet.poll_every):
        fleet.step()
    assert all(r.commit == ctx.commit_a for r in fleet.replicas)
    reqs = ctx.requests(3, seed=2)
    for rid, p, n in reqs:
        fleet.submit(rid, p, n)
    fleet.drain()
    _assert_served(fleet, reqs, commits={ctx.commit_a})
    assert rollback(ctx.lake).new == ctx.commit_b  # flip-flop works


def check_canary_gate(ctx: ServeContext) -> None:
    """A canary failing its WAP gate leaves the serving tags untouched —
    no partial flip; a passing canary flips and records the audit."""
    reqs = ctx.requests(4, seed=3, max_gen=4)
    prev_before = read_tag(ctx.lake, PREV_TAG)
    impossible = default_canary_expectations() + [
        column_range("serve_metrics", "n_tokens", 1000, 2000)]
    rep = canary_rollout(ctx.lake, ctx.cfg, ctx.commit_b, reqs, impossible,
                         slots=SLOTS, max_len=MAX_LEN)
    assert not rep.flipped and rep.reason == "canary audit failed"
    assert rep.audit is not None and not rep.audit.passed
    assert read_tag(ctx.lake, PROD_TAG) == ctx.commit_a, "tag moved on fail"
    assert read_tag(ctx.lake, PREV_TAG) == prev_before

    rep = canary_rollout(ctx.lake, ctx.cfg, ctx.commit_b, reqs,
                         slots=SLOTS, max_len=MAX_LEN)
    assert rep.flipped and rep.audit.passed
    assert read_tag(ctx.lake, PROD_TAG) == ctx.commit_b
    assert read_tag(ctx.lake, PREV_TAG) == ctx.commit_a
    # the gate's evidence is committed — the verdict is replayable
    metrics = ctx.lake.read_table("canary.rollout", "serve_metrics")
    assert metrics["ok"].shape[0] == len(reqs) and (metrics["ok"] == 1).all()


def check_replica_crash_mid_rollout(ctx: ServeContext) -> None:
    """Kill r0 exactly at its rollout swap sync point: the fleet serves
    every request throughout (survivors re-serve r0's work), finishes the
    rollout on the survivors, and loses nothing."""
    schedule = Schedule()
    # occurrence 2: the first arrival is r0's initial load, the second is
    # its rollout swap — the mid-rollout kill
    schedule.kill("replica:r0:swap:before", occurrence=2)
    fleet = ctx.fleet(replicas=max(2, ctx.combo.replicas),
                      on_event=schedule.fire)
    reqs = ctx.requests(10, seed=4)
    for rid, p, n in reqs[:5]:
        fleet.submit(rid, p, n)
    fleet.step()
    flip_tag(ctx.lake, ctx.commit_b)
    for rid, p, n in reqs[5:]:
        fleet.submit(rid, p, n)
        fleet.step()
    fleet.drain()
    for _ in range(4 * fleet.poll_every):
        fleet.step()
    _assert_served(fleet, reqs, commits={ctx.commit_a, ctx.commit_b})
    dead = [r for r in fleet.replicas if not r.alive]
    assert [r.name for r in dead] == ["r0"], "r0 should have died mid-swap"
    assert any("crash" in e for _, e in fleet.events)
    survivors = [r for r in fleet.replicas if r.alive]
    assert survivors and all(r.commit == ctx.commit_b for r in survivors)
    # traffic keeps flowing after the crash
    fleet.submit(999, reqs[0][1], 2)
    fleet.drain()
    assert fleet.completed[999].model_commit == ctx.commit_b


def check_head_of_line(ctx: ServeContext) -> None:
    """Continuous batching: a short request submitted AFTER a long one
    completes first.  The fixed baseline demonstrably blocks it — the
    regression the continuous batcher exists to fix."""
    engine = ServeEngine.from_catalog(ctx.lake, ctx.commit_a, ctx.cfg,
                                      max_len=MAX_LEN, batch_size=SLOTS)
    long_n, short_n = 24, 2
    prompt = ctx.requests(1, seed=5)[0][1]
    if ctx.combo.mode == "continuous":
        srv = ContinuousBatcher(engine, slots=SLOTS)
        srv.submit(0, prompt, long_n)
        srv.step()
        srv.submit(1, prompt, short_n)    # arrives while 0 is in flight
        steps_to_short = 0
        while 1 not in srv.completed:
            srv.step()
            steps_to_short += 1
        assert 0 not in srv.completed, \
            "short request waited for the long one (head-of-line blocking)"
        assert steps_to_short <= short_n + 1
        while srv.pending:
            srv.step()
        assert srv.completed[0].tokens.shape[1] == long_n
    else:
        srv = FixedBatchedServer(engine)
        srv.submit(0, prompt, long_n)
        srv.submit(1, prompt, short_n)
        srv.step()                        # one bucket serves both
        assert 0 in srv.completed and 1 in srv.completed
        # the documented cost: the short rider decoded long_n steps anyway
        assert srv.completed[1].tokens.shape[1] == short_n


def check_warm_prefetch(ctx: ServeContext) -> None:
    """Tiered lake: loading a replica pulls the checkpoint closure local
    BEFORE traffic; a second prefetch finds nothing left to fetch."""
    tiered = ServeContext(ctx.combo, ctx.root / "tiered", tiered=True)
    local = tiered.lake.store.local
    closure = set(commit_closure(tiered.remote_store, tiered.commit_a))
    assert any(not local.has(d) for d in closure), \
        "closure already local — the tiered scenario is vacuous"
    fetched = prefetch_weights(tiered.lake, tiered.commit_a)
    assert fetched > 0
    assert all(local.has(d) for d in closure), "prefetch left cold objects"
    assert prefetch_weights(tiered.lake, tiered.commit_a) == 0

    fleet = tiered.fleet(replicas=1)
    assert fleet.replicas[0].prefetched == 0  # warm pool: nothing to pull
    reqs = tiered.requests(3, seed=6)
    for rid, p, n in reqs:
        fleet.submit(rid, p, n)
    fleet.drain()
    _assert_served(fleet, reqs, commits={tiered.commit_a})
    # a replica loading the NEVER-prefetched commit B pulls its delta
    r = Replica("cold", tiered.lake, tiered.cfg, max_len=MAX_LEN,
                slots=SLOTS)
    r.load(tiered.commit_b)
    assert r.prefetched > 0
    assert all(local.has(d)
               for d in commit_closure(tiered.remote_store,
                                       tiered.commit_b))


CHECKS: List[Callable[[ServeContext], None]] = [
    check_equivalence,
    check_rollout_under_load,
    check_rollback,
    check_canary_gate,
    check_replica_crash_mid_rollout,
    check_head_of_line,
    check_warm_prefetch,
]


# --------------------------------------------------------------------- soak
def soak(combo: Combo, root: Path, *, seed: int, requests: int = 40) -> None:
    """Pinned-seed soak: a sustained randomized workload with a rollout,
    a rollback and a replica kill injected mid-stream.  Invariants: zero
    failed requests, every response full-length and citing a deployed
    commit, and (continuous mode) bit-identical to the sequential oracle.
    """
    ctx = ServeContext(combo, root)
    rng = np.random.default_rng(seed)
    reqs = ctx.requests(requests, seed=seed)
    fleet = ctx.fleet(replicas=max(2, combo.replicas))
    pending = list(reqs)
    flip_at, back_at = requests // 3, (2 * requests) // 3
    kill_at = requests // 2
    submitted = 0
    while pending or fleet.pending:
        if pending and rng.random() < 0.7:
            rid, p, n = pending.pop(0)
            fleet.submit(rid, p, n)
            submitted += 1
            if submitted == flip_at:
                flip_tag(ctx.lake, ctx.commit_b)
            if submitted == kill_at and fleet.alive_count > 1:
                fleet.kill(fleet.replicas[0].name)
            if submitted == back_at:
                rollback(ctx.lake)
        fleet.step()
    for _ in range(4 * fleet.poll_every):
        fleet.step()
    _assert_served(fleet, reqs, commits={ctx.commit_a, ctx.commit_b})
    assert fleet.rollouts == 2
    if combo.mode == "continuous":
        oracles = {c: ctx.oracle(c, reqs)
                   for c in (ctx.commit_a, ctx.commit_b)}
        for rid, _p, _n in reqs:
            res = fleet.completed[rid]
            np.testing.assert_array_equal(
                res.tokens[0], oracles[res.model_commit][rid],
                err_msg=f"req {rid} diverged (commit "
                        f"{res.model_commit[:12]})")


# --------------------------------------------------------------------- main
def run_check(check: Callable[[ServeContext], None], combo: Combo,
              root: Path) -> None:
    """One check in a fresh world; raises on contract violation."""
    check(ServeContext(combo, Path(root)))


def run_matrix(*, modes=MODES, replicas=REPLICAS,
               verbose: bool = True) -> List[str]:
    failures: List[str] = []
    for mode in modes:
        for n in replicas:
            combo = Combo(mode, int(n))
            for check in CHECKS:
                tmp = tempfile.mkdtemp(prefix="serve-conf-")
                try:
                    run_check(check, combo, Path(tmp))
                    if verbose:
                        print(f"  ok  {combo.ident:24s} {check.__name__}")
                except Exception as e:  # noqa: BLE001 - reported, rethrown
                    failures.append(f"{combo.ident} {check.__name__}: {e!r}")
                    if verbose:
                        print(f"FAIL  {combo.ident:24s} "
                              f"{check.__name__}: {e!r}")
                finally:
                    shutil.rmtree(tmp, ignore_errors=True)
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="serving conformance matrix (mode × replicas) + the "
                    "pinned-seed soak leg (--soak N --seed S)")
    ap.add_argument("--modes", default=",".join(MODES))
    ap.add_argument("--replicas", default=",".join(map(str, REPLICAS)))
    ap.add_argument("--soak", type=int, default=0, metavar="N",
                    help="run the soak with N requests INSTEAD of the "
                         "matrix")
    ap.add_argument("--seed", type=int, default=0,
                    help="soak seed (a failing seed replays the same "
                         "workload)")
    args = ap.parse_args(argv)
    modes = tuple(args.modes.split(","))
    replicas = tuple(int(x) for x in args.replicas.split(","))
    if args.soak > 0:
        failures = []
        for mode in modes:
            tmp = tempfile.mkdtemp(prefix="serve-soak-")
            try:
                soak(Combo(mode, max(replicas)), Path(tmp),
                     seed=args.seed, requests=args.soak)
                print(f"  ok  soak {mode} seed={args.seed} n={args.soak}")
            except Exception as e:  # noqa: BLE001
                failures.append(f"soak {mode} seed={args.seed}: {e!r}")
                print(f"FAIL  soak {mode} seed={args.seed}: {e!r}")
            finally:
                shutil.rmtree(tmp, ignore_errors=True)
        return 1 if failures else 0
    failures = run_matrix(modes=modes, replicas=replicas)
    total = len(modes) * len(replicas) * len(CHECKS)
    print(f"\nserving conformance: {total - len(failures)}/{total} passed")
    for f in failures:
        print(f"  FAILED: {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
