"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates a REDUCED same-family config and runs one forward + one
train step on CPU, asserting output shapes and finiteness.  Full configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, full_config, smoke_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          lm_loss)
from repro.optim import adamw
from repro.runtime.steps import build_train_step, synthetic_batch

KEY = jax.random.PRNGKey(0)
SMOKE_ARCHS = list(ARCH_IDS)


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = smoke_config(arch)
            cache[arch] = (cfg, init_params(cfg, KEY))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_forward_shapes_and_finite(arch, arch_state):
    cfg, params = arch_state(arch)
    batch = synthetic_batch(cfg, batch=2, seq=32, key=KEY)
    logits, _, aux = forward(cfg, params, batch["tokens"],
                             batch.get("extra_embeds"), remat=False)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_one_train_step(arch, arch_state):
    cfg, params = arch_state(arch)
    opt_cfg = adamw.AdamWConfig(weight_decay=0.01)
    step = build_train_step(
        cfg, opt_config=opt_cfg, schedule="constant",
        schedule_kw={"peak_lr": 1e-3})
    opt_state = adamw.init(params, opt_cfg)
    batch = synthetic_batch(cfg, batch=2, seq=32, key=KEY)
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_opt.step) == 1
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["yi-34b", "gemma2-9b", "mamba2-370m",
                                  "hymba-1.5b", "qwen2-moe-a2.7b"])
def test_decode_matches_forward(arch, arch_state):
    cfg, params = arch_state(arch)
    if cfg.is_moe:
        cfg = cfg.with_(capacity_factor=-1.0)  # no-drop for consistency
    B, S, P = 2, 16, 12
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = forward(cfg, params, tokens, remat=False)
    cache = init_cache(cfg, B, S, dtype="float32")
    _, cache, _ = forward(cfg, params, tokens[:, :P], cache=cache, pos=0,
                          remat=False)
    errs = []
    for i in range(P, S):
        lg, cache = decode_step(cfg, params, tokens[:, i], cache)
        errs.append(float(jnp.max(jnp.abs(lg - full_logits[:, i, :]))))
    assert max(errs) < 1e-4, errs


def test_gemma2_softcaps_active(arch_state):
    cfg, params = arch_state("gemma2-9b")
    batch = synthetic_batch(cfg, batch=1, seq=16, key=KEY)
    logits, _, _ = forward(cfg, params, batch["tokens"], remat=False)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_softcap + 1e-3


def test_gemma2_local_global_flags():
    cfg = smoke_config("gemma2-9b")
    from repro.models import layer_flags
    flags = np.asarray(layer_flags(cfg))
    assert list(flags) == [False, True, False, True]  # alternating l/g


def test_hymba_global_layers():
    cfg = smoke_config("hymba-1.5b")
    from repro.models import layer_flags
    flags = np.asarray(layer_flags(cfg))
    assert flags[0] and flags[2] and not flags[1]


def test_qwen_bias_present():
    cfg = smoke_config("qwen2.5-14b")
    params = init_params(cfg, KEY)
    assert "bq" in params["layers"]["attn"]


def test_mamba2_has_no_attention_or_mlp():
    cfg = smoke_config("mamba2-370m")
    params = init_params(cfg, KEY)
    assert "attn" not in params["layers"]
    assert "mlp" not in params["layers"]
    assert "ln2" not in params["layers"]


def test_moe_shared_experts_only_qwen2():
    p2 = init_params(smoke_config("qwen2-moe-a2.7b"), KEY)
    p3 = init_params(smoke_config("qwen3-moe-235b-a22b"), KEY)
    assert "shared_w_gate" in p2["layers"]["moe"]
    assert "shared_w_gate" not in p3["layers"]["moe"]


def test_frontend_embeds_change_output():
    cfg = smoke_config("internvl2-76b")
    params = init_params(cfg, KEY)
    b = synthetic_batch(cfg, batch=1, seq=16, key=KEY)
    l1, _, _ = forward(cfg, params, b["tokens"], b["extra_embeds"],
                       remat=False)
    l2, _, _ = forward(cfg, params, b["tokens"], b["extra_embeds"] + 1.0,
                       remat=False)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 0


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs are the EXACT published shapes from the assignment."""
    cfg = full_config(arch)
    expected = {
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 0, 151936),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 0, 151936),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "paper-demo": (12, 768, 12, 4, 2048, 32768),
    }[arch]
    actual = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
              cfg.d_ff, cfg.vocab_size)
    assert actual == expected, (arch, actual, expected)


def test_moe_configs_match_assignment():
    q2 = full_config("qwen2-moe-a2.7b")
    assert (q2.n_experts, q2.top_k, q2.expert_d_ff,
            q2.n_shared_experts) == (60, 4, 1408, 4)
    q3 = full_config("qwen3-moe-235b-a22b")
    assert (q3.n_experts, q3.top_k, q3.expert_d_ff) == (128, 8, 1536)
    m2 = full_config("mamba2-370m")
    assert m2.ssm_state == 128
    hy = full_config("hymba-1.5b")
    assert hy.ssm_state == 16


def test_param_counts_plausible():
    """Analytic N within the advertised ballpark of each model name."""
    expect_b = {"yi-34b": 34, "gemma2-9b": 9, "minicpm-2b": 2.7,
                "qwen2.5-14b": 14, "mamba2-370m": 0.37,
                "hymba-1.5b": 1.5, "qwen2-moe-a2.7b": 14.3,
                "qwen3-moe-235b-a22b": 235, "musicgen-large": 3.3,
                "internvl2-76b": 76}
    for arch, nb in expect_b.items():
        n = full_config(arch).param_count() / 1e9
        assert 0.55 * nb <= n <= 1.6 * nb, (arch, n, nb)
    # MoE active params
    assert 2.0 <= full_config("qwen2-moe-a2.7b").active_param_count() / 1e9 <= 3.6
    assert 18 <= full_config("qwen3-moe-235b-a22b").active_param_count() / 1e9 <= 26
