"""The runnable examples run end-to-end (subprocess, real CLI surface)."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(script, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, os.path.join("examples", script)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout, cwd=ROOT)
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-1500:])
    return out.stdout


def test_quickstart_paper_use_cases():
    out = _run("quickstart.py")
    assert "bit_exact=True" in out
    assert "code drift detected" in out
    assert "published to main" in out


def test_debug_branch_cli_session():
    out = _run("debug_branch.py")
    assert '"bit_exact": true' in out
    assert "repro branch richard.debug" in out


def test_serve_example():
    out = _run("serve_lm.py")
    assert "served 10 requests" in out
    assert "identical generations" in out
