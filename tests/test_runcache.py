"""Incremental run cache (docs/run_cache.md): warm replay of an unchanged
branch executes ZERO node functions; editing one node re-runs exactly its
downstream cone; ``--no-cache`` forces a full re-execution."""

import json

import numpy as np
import pytest

from repro.core import Lake, Model, Pipeline, RunCache, model, node_key
from repro.core.gc import collect


# Execution counters live at MODULE level, mutated through a helper
# FUNCTION: a node referencing the CALLS dict directly — like a mutable
# closure — would (correctly) make it uncacheable (globals a node loads are
# part of the cache-safety check; functions/modules are the documented
# blind spot, see is_cache_safe), which is itself covered further down.
CALLS = {"a": 0, "b": 0, "c": 0, "d": 0}


def _bump(name: str) -> None:
    CALLS[name] += 1


def diamond_v1():
    """a -> b -> c, plus sibling d (a and d both read source_table)."""

    @model()
    def a(data=Model("source_table")):
        _bump("a")
        return {"v": data["c1"]}

    @model()
    def b(x=Model("a")):
        _bump("b")
        return {"v": x["v"] * 2.0}

    @model()
    def c(y=Model("b")):
        _bump("c")
        return {"v": y["v"] + 1.0}

    @model()
    def d(data=Model("source_table")):
        _bump("d")
        return {"v": data["c2"].astype(np.float32)}

    return Pipeline([a, b, c, d])


def diamond_v2_edited_b():
    """Same DAG with b's SOURCE changed (* 3.0): only b's cone re-runs."""

    @model()
    def a(data=Model("source_table")):
        _bump("a")
        return {"v": data["c1"]}

    @model()
    def b(x=Model("a")):
        _bump("b")
        return {"v": x["v"] * 3.0}

    @model()
    def c(y=Model("b")):
        _bump("c")
        return {"v": y["v"] + 1.0}

    @model()
    def d(data=Model("source_table")):
        _bump("d")
        return {"v": data["c2"].astype(np.float32)}

    return Pipeline([a, b, c, d])


def fresh_calls():
    CALLS.update({"a": 0, "b": 0, "c": 0, "d": 0})
    return CALLS


# ------------------------------------------------------------------ warm path
def test_warm_replay_executes_zero_node_functions(seeded_lake):
    calls = fresh_calls()
    pipe = diamond_v1()
    seeded_lake.catalog.create_branch("r.dev", "main", author="r")
    r1 = seeded_lake.run(pipe, branch="r.dev", author="r")
    assert calls == {"a": 1, "b": 1, "c": 1, "d": 1}
    assert r1.cache_misses == 4 and r1.cache_hits == 0

    r2 = seeded_lake.run(pipe, branch="r.dev", author="r")
    assert calls == {"a": 1, "b": 1, "c": 1, "d": 1}  # zero executions
    assert r2.cache_hits == 4 and r2.cache_misses == 0
    assert r2.outputs == r1.outputs  # identical snapshot digests
    # warm run is a no-op on the branch: no new commit was created
    assert r2.commit == r1.commit


def test_warm_run_recorded_in_ledger_manifest(seeded_lake):
    calls = fresh_calls()
    pipe = diamond_v1()
    seeded_lake.catalog.create_branch("r.dev", "main", author="r")
    seeded_lake.run(pipe, branch="r.dev", author="r")
    r2 = seeded_lake.run(pipe, branch="r.dev", author="r")
    m = seeded_lake.ledger.get(r2.run_id)
    assert m["executor"]["cache"] is True
    assert m["executor"]["cache_hits"] == 4
    assert m["executor"]["cache_misses"] == 0
    assert set(m["nodes"]) == {"a", "b", "c", "d"}
    for stat in m["nodes"].values():
        assert stat["cache_hit"] is True
        assert stat["wall_s"] >= 0
        assert stat["snapshot"]


# ----------------------------------------------------------- cone invalidation
def test_editing_one_node_reruns_exactly_its_downstream_cone(seeded_lake):
    calls = fresh_calls()
    seeded_lake.catalog.create_branch("r.dev", "main", author="r")
    seeded_lake.run(diamond_v1(), branch="r.dev", author="r")
    assert calls == {"a": 1, "b": 1, "c": 1, "d": 1}

    res = seeded_lake.run(diamond_v2_edited_b(), branch="r.dev",
                          author="r")
    # a and d untouched (upstream / sibling); b and its descendant c re-ran
    assert calls == {"a": 1, "b": 2, "c": 2, "d": 1}
    stats = {n: s.cache_hit for n, s in res.node_stats.items()}
    assert stats == {"a": True, "b": False, "c": False, "d": True}
    # and the re-run produced the edited semantics
    src = seeded_lake.read_table("main", "source_table")
    np.testing.assert_allclose(seeded_lake.read_table("r.dev", "b")["v"],
                               src["c1"] * 3.0)


def test_data_change_invalidates_readers(seeded_lake):
    calls = fresh_calls()
    pipe = diamond_v1()
    seeded_lake.catalog.create_branch("r.dev", "main", author="r")
    seeded_lake.run(pipe, branch="r.dev", author="r")
    new_src = {k: v[:100] for k, v in
               seeded_lake.read_table("main", "source_table").items()}
    seeded_lake.write_table("r.dev", "source_table", new_src, author="r")
    seeded_lake.run(pipe, branch="r.dev", author="r")
    # every node sits downstream of source_table -> full re-execution
    assert calls == {"a": 2, "b": 2, "c": 2, "d": 2}


# -------------------------------------------------------------------- opt-out
def test_no_cache_forces_full_reexecution(seeded_lake):
    calls = fresh_calls()
    pipe = diamond_v1()
    seeded_lake.catalog.create_branch("r.dev", "main", author="r")
    seeded_lake.run(pipe, branch="r.dev", author="r")
    res = seeded_lake.run(pipe, branch="r.dev", author="r", use_cache=False)
    assert calls == {"a": 2, "b": 2, "c": 2, "d": 2}
    assert res.cache_hits == 0
    for stat in res.node_stats.values():
        assert stat.cache_key is None  # cache never consulted
    m = seeded_lake.ledger.get(res.run_id)
    assert m["executor"]["cache"] is False


def test_replay_uses_cache_and_stays_bit_exact(seeded_lake):
    calls = fresh_calls()
    pipe = diamond_v1()
    seeded_lake.catalog.create_branch("r.dev", "main", author="r")
    res = seeded_lake.run(pipe, branch="r.dev", author="r")
    rep = seeded_lake.replay(res.run_id, pipe, branch="r.debug", author="r")
    assert rep.bit_exact, rep.diffs
    assert calls == {"a": 1, "b": 1, "c": 1, "d": 1}  # replay fully warm
    m = seeded_lake.ledger.get(rep.replay_run_id)
    assert m["executor"]["cache_hits"] == 4


# ------------------------------------------------------------------- mechanics
def test_node_key_hashes_array_params_by_content():
    """Large-array params must not collide through numpy's truncated repr."""
    a = np.arange(10_000, dtype=np.float64)
    b = a.copy()
    b[5_000] += 1.0  # repr() summarizes both to the same "[0., 1., ...]"
    assert node_key("ch", [], {"w": a}) != node_key("ch", [], {"w": b})
    assert node_key("ch", [], {"w": a}) == node_key("ch", [], {"w": a.copy()})
    # dtype is part of the identity even when values compare equal
    assert (node_key("ch", [], {"w": np.float32(1.0)})
            != node_key("ch", [], {"w": np.float64(1.0)}))
    # containers recurse down to array content
    assert (node_key("ch", [], {"w": [a, 1]})
            != node_key("ch", [], {"w": [b, 1]}))


def test_missing_source_table_raises_with_and_without_cache(seeded_lake):
    from repro.core import TableNotFound, execute

    @model()
    def reader(data=Model("no_such_table")):
        return {"v": data["x"]}

    seeded_lake.catalog.create_branch("r.miss", "main", author="r")
    for use_cache in (True, False):
        with pytest.raises(TableNotFound):
            execute(Pipeline([reader]), seeded_lake.catalog, seeded_lake.io,
                    branch="r.miss", author="r", use_cache=use_cache)


def test_unstable_closure_makes_node_uncacheable_not_wrong(seeded_lake):
    """Two pure factory nodes differing only by a LIST closure value share a
    code hash (lists aren't foldable) — they must re-execute every run, never
    serve each other's snapshot."""
    from repro.core import is_cache_safe

    def make(weights):
        @model(name="scaled")
        def scaled(data=Model("source_table")):
            return {"v": data["c1"] * sum(weights)}
        return scaled

    n1, n2 = make([1.0, 2.0]), make([30.0, 40.0])
    assert n1.code_hash == n2.code_hash  # the collision that forces the rule
    assert not n1.cache_safe and not is_cache_safe(n2.fn)

    seeded_lake.catalog.create_branch("r.uc", "main", author="r")
    seeded_lake.run(Pipeline([n1]), branch="r.uc", author="r")
    res = seeded_lake.run(Pipeline([n2]), branch="r.uc", author="r")
    assert res.cache_hits == 0  # would have been a silent wrong hit
    src = seeded_lake.read_table("main", "source_table")
    np.testing.assert_allclose(seeded_lake.read_table("r.uc", "scaled")["v"],
                               src["c1"] * 70.0, rtol=1e-5)
    # stable factory params (scalars) stay cacheable
    assert fresh_calls() is CALLS and diamond_v1().nodes["b"].cache_safe


def test_uncacheable_parent_does_not_poison_descendants(seeded_lake):
    """An uncacheable node still snapshots its output, so a cache-safe child
    keys off the parent's CONTENT: same parent output -> child hits."""
    def make(weights):
        @model(name="parent")
        def parent(data=Model("source_table")):
            return {"v": data["c1"] * sum(weights)}
        return parent

    calls = fresh_calls()

    @model()
    def c(y=Model("parent")):
        _bump("c")
        return {"v": y["v"] + 1.0}

    seeded_lake.catalog.create_branch("r.mix", "main", author="r")
    seeded_lake.run(Pipeline([make([2.0]), c]), branch="r.mix", author="r")
    seeded_lake.run(Pipeline([make([2.0]), c]), branch="r.mix", author="r")
    assert calls["c"] == 1  # parent re-ran, same digest -> child hit
    seeded_lake.run(Pipeline([make([5.0]), c]), branch="r.mix", author="r")
    assert calls["c"] == 2  # parent output changed -> child re-ran


def test_kwonly_default_distinguishes_factory_nodes(seeded_lake):
    """Factory params passed through keyword-only defaults are part of the
    code hash too — make(2.0) and make(3.0) must not cross-hit."""
    def make(n):
        @model(name="pack")
        def pack(data=Model("source_table"), *, scale=n):
            return {"v": data["c1"] * scale}
        return pack

    n1, n2 = make(2.0), make(3.0)
    assert n1.code_hash != n2.code_hash
    assert n1.cache_safe and n2.cache_safe

    seeded_lake.catalog.create_branch("r.kw", "main", author="r")
    seeded_lake.run(Pipeline([n1]), branch="r.kw", author="r")
    res = seeded_lake.run(Pipeline([n2]), branch="r.kw", author="r")
    assert res.cache_hits == 0
    src = seeded_lake.read_table("main", "source_table")
    np.testing.assert_allclose(seeded_lake.read_table("r.kw", "pack")["v"],
                               src["c1"] * 3.0, rtol=1e-6)


def test_opaque_param_object_degrades_to_uncacheable(seeded_lake):
    """A param whose type has no stable canonical form (state-hiding repr)
    must force re-execution, not serve a stale snapshot under one key."""
    class Config:
        def __init__(self, scale):
            self.scale = scale

        def __repr__(self):  # state-free on purpose: the dangerous case
            return "<Config>"

    from repro.core import execute

    calls = fresh_calls()

    @model()
    def scaled(data=Model("source_table"), cfg=None):
        _bump("a")
        return {"v": data["c1"] * cfg.scale}

    pipe = Pipeline([scaled])
    seeded_lake.catalog.create_branch("r.obj", "main", author="r")

    def run(cfg):
        return execute(pipe, seeded_lake.catalog, seeded_lake.io,
                       branch="r.obj", author="r", params={"cfg": cfg})

    run(Config(2.0))
    res = run(Config(5.0))
    assert calls["a"] == 2 and res.cache_hits == 0  # no stale hit possible
    src = seeded_lake.read_table("main", "source_table")
    np.testing.assert_allclose(seeded_lake.read_table("r.obj", "scaled")["v"],
                               src["c1"] * 5.0, rtol=1e-6)
    res = run(Config(5.0))
    assert calls["a"] == 3  # still uncacheable: correctness over speed
    assert res.node_stats["scaled"].cache_key is None  # keying was skipped


# ------------------------------------------- module-level globals in the key
SCALE = 2.0  # read by nodes below: folded into their code hash
MUTABLE_CFG = {"scale": 2.0}  # referenced directly: demotes to uncacheable


def test_module_constant_change_invalidates_cached_node(seeded_lake):
    """Regression: a node reading a module-level constant kept ONE cache
    key across edits to that constant (globals were invisible to the code
    hash), so the run after the edit silently served the stale snapshot.
    Resolvable immutable constants are now folded into the code hash
    exactly like closure values — editing the constant re-runs the node."""
    global SCALE

    def make():
        @model(name="scaled")
        def scaled(data=Model("source_table")):
            return {"v": data["c1"] * SCALE}
        return scaled

    seeded_lake.catalog.create_branch("r.gconst", "main", author="r")
    n1 = make()
    assert n1.cache_safe  # stable constants do NOT demote
    seeded_lake.run(Pipeline([n1]), branch="r.gconst", author="r")
    src = seeded_lake.read_table("main", "source_table")
    np.testing.assert_allclose(
        seeded_lake.read_table("r.gconst", "scaled")["v"],
        src["c1"] * 2.0, rtol=1e-6)
    old = SCALE
    try:
        SCALE = 5.0
        n2 = make()
        assert n2.code_hash != n1.code_hash  # the constant IS code
        res = seeded_lake.run(Pipeline([n2]), branch="r.gconst", author="r")
        assert res.cache_hits == 0  # the silently-wrong hit of the bug
        np.testing.assert_allclose(
            seeded_lake.read_table("r.gconst", "scaled")["v"],
            src["c1"] * 5.0, rtol=1e-6)
    finally:
        SCALE = old


def test_mutable_global_reference_demotes_to_uncacheable(seeded_lake):
    """A node reading a module-level MUTABLE object (dict/list/array) has
    state its code hash cannot cover — it must re-execute every run, not
    serve whatever the object held when the entry was written."""
    from repro.core import is_cache_safe

    def make():
        @model(name="cfgd")
        def cfgd(data=Model("source_table")):
            return {"v": data["c1"] * MUTABLE_CFG["scale"]}
        return cfgd

    n = make()
    assert not n.cache_safe and not is_cache_safe(n.fn)
    seeded_lake.catalog.create_branch("r.gmut", "main", author="r")
    seeded_lake.run(Pipeline([n]), branch="r.gmut", author="r")
    old = MUTABLE_CFG["scale"]
    try:
        MUTABLE_CFG["scale"] = 7.0
        res = seeded_lake.run(Pipeline([make()]), branch="r.gmut",
                              author="r")
        assert res.cache_hits == 0  # uncacheable: mutation is visible
        src = seeded_lake.read_table("main", "source_table")
        np.testing.assert_allclose(
            seeded_lake.read_table("r.gmut", "cfgd")["v"],
            src["c1"] * 7.0, rtol=1e-6)
    finally:
        MUTABLE_CFG["scale"] = old


def test_global_writer_is_uncacheable():
    """STORE_GLOBAL in a node body = module state mutation: never cache."""
    from repro.core import is_cache_safe

    @model(name="writer")
    def writer(data=Model("source_table")):
        global SCALE
        SCALE = 99.0  # never executed here — detected from bytecode
        return {"v": data["c1"]}

    assert not writer.cache_safe and not is_cache_safe(writer.fn)


def test_function_and_module_globals_stay_cacheable():
    """The documented blind spot must not over-reach: referencing modules
    (np) and functions (_bump) keeps a node cacheable — otherwise the
    demotion rule would silently disable the cache for everything."""
    pipe = diamond_v1()
    assert all(n.cache_safe for n in pipe.nodes.values())


def test_node_key_is_order_insensitive_and_code_sensitive():
    inputs = [("t1", "d1"), ("t2", "d2")]
    k1 = node_key("code", inputs, {"p": 1})
    assert k1 == node_key("code", list(reversed(inputs)), {"p": 1})
    assert k1 != node_key("other", inputs, {"p": 1})
    assert k1 != node_key("code", [("t1", "dX"), ("t2", "d2")], {"p": 1})
    assert k1 != node_key("code", inputs, {"p": 2})


def test_cache_entry_survives_roundtrip(tmp_path):
    lake = Lake(tmp_path / "lake", protect_main=False)
    key = node_key("abc", [("t", "d")], {})
    lake.store.put(b"payload")  # arbitrary blob to reference
    snap = lake.io.write_snapshot({"v": np.arange(4)})
    lake.run_cache.put(key, node="n", snapshot=snap, code_hash="abc",
                       inputs=[("t", "d")])
    entry = lake.run_cache.get(key)
    assert entry["snapshot"] == snap and entry["node"] == "n"
    assert key in lake.run_cache.keys()
    assert lake.run_cache.invalidate(key)
    assert lake.run_cache.get(key) is None


def test_gc_respects_then_drops_cache(seeded_lake):
    calls = fresh_calls()
    pipe = diamond_v1()
    seeded_lake.catalog.create_branch("r.dev", "main", author="r")
    seeded_lake.run(pipe, branch="r.dev", author="r")
    collect(seeded_lake.store)  # cache refs are roots: entries stay warm
    r2 = seeded_lake.run(pipe, branch="r.dev", author="r")
    assert r2.cache_hits == 4
    collect(seeded_lake.store, drop_cache=True)
    assert len(seeded_lake.run_cache) == 0
    seeded_lake.run(pipe, branch="r.dev", author="r")  # degrades to misses
    assert calls == {"a": 2, "b": 2, "c": 2, "d": 2}
    # dropping the cache must never break reads of committed tables
    assert seeded_lake.read_table("r.dev", "c")["v"].shape[0] > 0


# ------------------------------------------------------------------------ CLI
def test_cli_no_cache_and_jobs_flags(tmp_path, capsys):
    from repro.data.pipeline import seed_corpus
    from repro.launch.repro_cli import main

    lake = Lake(tmp_path / "lake", protect_main=False)
    seed_corpus(lake, "main", n_docs=16, seed=0, vocab_size=64, mean_len=32,
                author="cli")
    lake.catalog.create_branch("cli.run", "main", author="cli")

    argv = ["--lake", str(tmp_path / "lake"), "run", "--pipeline", "data",
            "--seq-len", "32", "--branch", "cli.run", "--jobs", "2"]
    main(argv)
    warm = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert warm["cache_misses"] > 0 and warm["cache_hits"] == 0

    main(argv)  # warm: pure cache lookups
    out = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert out["cache_hits"] > 0 and out["cache_misses"] == 0

    main(argv + ["--no-cache"])  # forced re-execution
    out = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert out["cache_hits"] == 0 and out["cache_misses"] > 0

    main(["--lake", str(tmp_path / "lake"), "cache", "stats"])
    stats = json.loads(capsys.readouterr().out.strip())
    assert stats["entries"] > 0
    main(["--lake", str(tmp_path / "lake"), "cache", "clear"])
    cleared = json.loads(capsys.readouterr().out.strip())
    assert cleared["cleared"] == stats["entries"]
