"""Garbage collection: reachability from refs and the run ledger."""

import numpy as np
import pytest

from repro.core import Lake, Model, Pipeline, model
from repro.core.errors import ObjectNotFound
from repro.core.gc import collect


def _write(lake, branch, name, val, n=64):
    return lake.write_table(branch, name,
                            {"v": np.full(n, val, np.float32)},
                            author=branch.split(".")[0])


def test_gc_keeps_everything_reachable(lake):
    lake.catalog.create_branch("u.a", "main", author="u")
    _write(lake, "u.a", "t1", 1.0)
    _write(lake, "u.a", "t2", 2.0)
    before = set(lake.store.iter_objects())
    rep = collect(lake.store)
    assert rep.swept == 0
    assert set(lake.store.iter_objects()) == before
    # everything still readable
    assert lake.read_table("u.a", "t1")["v"][0] == 1.0


def test_gc_sweeps_deleted_branch_history(lake):
    lake.catalog.create_branch("u.tmp", "main", author="u")
    _write(lake, "u.tmp", "scratch", 9.0, n=10_000)
    snap = lake.catalog.snapshot_of("u.tmp", "scratch")
    lake.catalog.delete_branch("u.tmp")
    rep = collect(lake.store)
    assert rep.swept > 0
    assert rep.bytes_freed > 0
    with pytest.raises(ObjectNotFound):
        lake.io.read(snap)


def test_gc_dry_run_deletes_nothing(lake):
    lake.catalog.create_branch("u.tmp", "main", author="u")
    _write(lake, "u.tmp", "scratch", 9.0)
    lake.catalog.delete_branch("u.tmp")
    before = len(list(lake.store.iter_objects()))
    rep = collect(lake.store, dry_run=True)
    assert rep.swept > 0
    assert len(list(lake.store.iter_objects())) == before


def test_gc_preserves_recorded_runs(lake):
    """A run's outputs stay replay-readable even after its branch dies —
    the ledger chain is a GC root."""
    src = {"x": np.arange(32, dtype=np.float32)}
    snap = lake.io.write_snapshot(src)
    lake.catalog.commit("main", {"src": snap}, "seed", _wap_token=True)

    @model()
    def out(data=Model("src")):
        return {"y": data["x"] * 3}

    pipe = Pipeline([out])
    lake.catalog.create_branch("u.run", "main", author="u")
    res = lake.run(pipe, branch="u.run", author="u")
    lake.catalog.delete_branch("u.run")
    collect(lake.store)
    # manifest + outputs still resolvable through the ledger
    manifest = lake.ledger.get(res.run_id)
    cols = lake.io.read(manifest["outputs"]["out"])
    np.testing.assert_allclose(cols["y"], src["x"] * 3)


def test_gc_preserves_tags(lake):
    lake.catalog.create_branch("u.rel", "main", author="u")
    _write(lake, "u.rel", "model", 5.0)
    lake.catalog.create_tag("v1.0", "u.rel")
    lake.catalog.delete_branch("u.rel")
    collect(lake.store)
    assert lake.read_table("v1.0", "model")["v"][0] == 5.0


def test_gc_roots_synced_tags_after_branch_deletion(tmp_path):
    """Regression: gc must root tags synced from a remote even when the
    local branch pointing at them was deleted.  Root detection used to
    match on the ref *path basename*, so any tag whose name contains "/"
    (``release/v1`` shards into a subdirectory) fell out of the root set —
    after deleting the branch, gc swept the tag's closure and the synced
    tag dangled."""
    from repro.core import (Lake, LoopbackTransport, ObjectStore,
                            RemoteServer, RemoteStore, pull, push)

    lake_a = Lake(tmp_path / "a", protect_main=False)
    lake_a.catalog.create_branch("u.rel", "main", author="u")
    _write(lake_a, "u.rel", "model", 5.0, n=2048)
    lake_a.catalog.create_tag("release/v1", "u.rel")
    remote = RemoteStore(LoopbackTransport(RemoteServer(
        ObjectStore(tmp_path / "r"))))
    push(lake_a.store, remote, "u.rel", tags=["release/*"])

    lake_b = Lake(tmp_path / "b", protect_main=False)
    pull(lake_b.store, remote, "u.rel", tags=["release/*"])
    lake_b.catalog.delete_branch("u.rel")
    lake_b.store.delete_ref("remote/origin/branch=u.rel")
    collect(lake_b.store)
    # the tag (and its remote-tracking twin) kept the closure alive
    assert lake_b.read_table("release/v1", "model")["v"][0] == 5.0
    assert lake_b.catalog.resolve("origin/release/v1") == \
        lake_b.catalog.resolve("release/v1")

    # ... even when only the remote-tracking tag ref remains
    lake_b.catalog.delete_tag("release/v1")
    collect(lake_b.store)
    head = lake_b.catalog.resolve("origin/release/v1")
    lake_b.catalog.create_branch("u.back", head, author="u")
    assert lake_b.read_table("u.back", "model")["v"][0] == 5.0

    # control: with the tracking ref gone too, the history is collectable
    lake_b.catalog.delete_branch("u.back")
    lake_b.store.delete_ref("remote/origin/tag=release/v1")
    rep = collect(lake_b.store)
    assert rep.swept > 0


def test_gc_keeps_remote_tracking_refs_alive(tmp_path):
    """Regression: objects reachable ONLY through a remote-tracking ref
    (``remote/<name>/branch=<b>``) must survive gc — deleting the local
    branch after a pull used to make the pulled history sweepable, breaking
    any subsequent replay of that branch."""
    from repro.core import (Lake, LoopbackTransport, ObjectStore,
                            RemoteServer, RemoteStore, pull, push)

    lake_a = Lake(tmp_path / "a", protect_main=False)
    _write(lake_a, "main", "t", 3.0, n=4096)
    lake_a.catalog.create_branch("u.exp", "main", author="u")
    _write(lake_a, "u.exp", "scratch", 7.0, n=4096)
    remote = RemoteStore(LoopbackTransport(RemoteServer(
        ObjectStore(tmp_path / "r"))))
    push(lake_a.store, remote, "u.exp")

    lake_b = Lake(tmp_path / "b", protect_main=False)
    # fetch without cache entries so ONLY refs keep the history alive
    pull(lake_b.store, remote, "u.exp", cache_entries=False)
    lake_b.catalog.delete_branch("u.exp")  # tracking ref is now the sole root

    rep = collect(lake_b.store)
    # the pulled closure stayed: recreate the branch from the tracking ref
    # and replay it green
    head = lake_b.catalog.resolve("origin/u.exp")
    lake_b.catalog.create_branch("u.exp2", head, author="u")
    assert lake_b.read_table("u.exp2", "scratch")["v"][0] == 7.0
    assert lake_b.read_table("u.exp2", "t")["v"][0] == 3.0

    # control: dropping the tracking ref makes that history collectable
    lake_b.catalog.delete_branch("u.exp2")
    lake_b.store.delete_ref("remote/origin/branch=u.exp")
    rep2 = collect(lake_b.store)
    assert rep2.swept > 0


# ----------------------------------------------------------- remote-side GC
def test_remote_gc_marks_from_remote_refs_never_local_state(tmp_path):
    """repro gc --remote semantics over the wire protocol: the mark phase
    walks the REMOTE's refs and the sweep runs the REMOTE's delete_object.
    Local ref state — branches that still exist here but were deleted
    there, and vice versa — must not influence what survives."""
    from repro.core import (LoopbackTransport, ObjectStore, RemoteServer,
                            RemoteStore, commit_closure, push)

    lake = Lake(tmp_path / "lake", protect_main=False)
    lake.catalog.create_branch("u.keep", "main", author="u")
    lake.catalog.create_branch("u.drop", "main", author="u")
    _write(lake, "u.keep", "kept", 1.0)
    _write(lake, "u.drop", "dropped", 2.0, n=4096)
    remote_store = ObjectStore(tmp_path / "remote")
    server = RemoteServer(remote_store)
    push(lake.store, RemoteStore(LoopbackTransport(server)), "u.keep")
    push(lake.store, RemoteStore(LoopbackTransport(server)), "u.drop")

    # the remote drops u.drop; the LOCAL lake still has the branch — which
    # must not protect the remote objects
    remote_store.delete_ref("branch=u.drop")
    drop_head = lake.catalog.head("u.drop")
    keep_head = lake.catalog.head("u.keep")
    unique_drop = (commit_closure(lake.store, drop_head)
                   - commit_closure(lake.store, keep_head))
    assert unique_drop

    gc_client = RemoteStore(LoopbackTransport(server), allow_delete=True)
    rep = collect(gc_client)
    assert rep.swept == len(unique_drop) and rep.bytes_freed > 0
    for digest in unique_drop:
        assert not remote_store.has(digest)
        assert lake.store.has(digest)  # the sweep never touches local state
    for digest in commit_closure(lake.store, keep_head):
        assert remote_store.has(digest)


def test_remote_delete_requires_opt_in(tmp_path):
    """A tier-mounted client must never be able to collect from the shared
    remote: delete_object is refused without the explicit GC opt-in."""
    from repro.core import (LoopbackTransport, ObjectStore, RemoteServer,
                            RemoteStore)
    from repro.core.errors import RemoteError

    remote_store = ObjectStore(tmp_path / "remote")
    digest = remote_store.put(b"precious" * 32)
    client = RemoteStore(LoopbackTransport(RemoteServer(remote_store)))
    with pytest.raises(RemoteError, match="immutable"):
        client.delete_object(digest)
    assert remote_store.has(digest)
    opted = RemoteStore(LoopbackTransport(RemoteServer(remote_store)),
                        allow_delete=True)
    assert opted.delete_object(digest) is True
    assert not remote_store.has(digest)
