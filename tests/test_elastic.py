"""Elastic scaling: a checkpoint saved under one topology restores onto a
DIFFERENT mesh (the node-failure / pod-resize story).  Checkpoints are
layout-free logical arrays + named sharding rules, so restore = device_put
with whatever mesh is alive."""

import json
import os
import subprocess
import sys
import textwrap

_PROG = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.checkpoint import restore, save
    from repro.configs import smoke_config
    from repro.core import Lake
    from repro.distributed import param_specs, named
    from repro.models import init_params, forward

    cfg = smoke_config("paper-demo")
    params = init_params(cfg, jax.random.PRNGKey(0))
    lake = Lake("{lake_dir}")
    if "t.run" not in lake.catalog.branches():
        lake.catalog.create_branch("t.run", "main", author="t")

    # "train" on an 8-device (4 data × 2 model) mesh and checkpoint
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    specs_a = param_specs(cfg, mesh_a)
    p_a = jax.tree.map(jax.device_put, params, named(mesh_a, specs_a))
    commit = save(lake, "t.run", step=1, params=p_a, author="t")

    # cluster shrinks: restore onto a 2-device mesh (2 data × 1 model)
    from jax.sharding import Mesh
    mesh_b = Mesh(np.array(jax.devices()[:2]).reshape(2, 1),
                  ("data", "model"))
    specs_b = param_specs(cfg, mesh_b)
    p_b, _, meta = restore(lake, commit, mesh=mesh_b, param_specs=specs_b)

    # same logical values, new physical layout; forward output identical
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                             cfg.vocab_size)
    with mesh_a:
        la, _, _ = forward(cfg, p_a, tok, remat=False)
    with mesh_b:
        lb, _, _ = forward(cfg, p_b, tok, remat=False)
    err = float(np.max(np.abs(np.asarray(la) - np.asarray(lb))))
    n_shards_b = len(p_b["embed"].sharding.device_set)
    print(json.dumps({"err": err, "step": meta["step"],
                      "n_devices_b": n_shards_b}))
""")


def test_restore_onto_different_mesh(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    prog = _PROG.replace("{lake_dir}", str(tmp_path / "lake"))
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600,
                         cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["err"] < 1e-5
    assert rec["step"] == 1
    assert rec["n_devices_b"] == 2
