"""Pipeline DAG semantics + run ledger + replay (paper §2, §4–5)."""

import numpy as np
import pytest

from repro.core import (CodeDrift, CycleError, Lake, Model, Pipeline,
                        RunNotFound, SchemaError, col, lit, model, sql_model)


def paper_pipeline(cutoff=50):
    """Pipeline P from the paper: SQL node + Python node (Listings 1–2)."""
    final_table = sql_model(
        "final_table", select=["c1", "c2", "c3"], frm="source_table",
        where=col("transaction_ts") >= lit(cutoff))

    @model(python="3.11", pip={"scikit-learn": "1.3.0"})
    def training_data(data=Model("final_table")):
        return {"x": data["c1"] * 2.0,
                "y": (data["c3"] > 3).astype(np.float32)}

    return Pipeline([final_table, training_data])


# ----------------------------------------------------------------- structure
def test_topo_order_and_sources():
    p = paper_pipeline()
    assert p.order == ["final_table", "training_data"]
    assert p.source_tables() == ["source_table"]


def test_cycle_detected():
    @model()
    def a(x=Model("b")):
        return {"v": x["v"]}

    @model()
    def b(x=Model("a")):
        return {"v": x["v"]}

    with pytest.raises(CycleError):
        Pipeline([a, b])


def test_duplicate_node_rejected():
    @model(name="n")
    def f1():
        return {"v": np.zeros(1)}

    @model(name="n")
    def f2():
        return {"v": np.ones(1)}

    from repro.core import ReproError
    with pytest.raises(ReproError):
        Pipeline([f1, f2])


def test_code_hash_changes_with_code():
    p1 = paper_pipeline(cutoff=50)
    p2 = paper_pipeline(cutoff=51)  # different WHERE literal
    assert p1.code_hash() != p2.code_hash()
    assert (p1.code_manifest()["training_data"]
            == p2.code_manifest()["training_data"])  # py node unchanged


def test_runtime_pins_recorded():
    p = paper_pipeline()
    assert p.nodes["training_data"].runtime["pip"] == {
        "scikit-learn": "1.3.0"}
    assert p.nodes["final_table"].runtime["lang"] == "sql"


# ----------------------------------------------------------------- execution
def test_run_materializes_all_nodes(seeded_lake):
    p = paper_pipeline()
    seeded_lake.catalog.create_branch("r.dev", "main", author="r")
    res = seeded_lake.run(p, branch="r.dev", author="r")
    assert set(res.outputs) == {"final_table", "training_data"}
    td = seeded_lake.read_table("r.dev", "training_data")
    src = seeded_lake.read_table("main", "source_table")
    keep = src["transaction_ts"] >= 50
    np.testing.assert_allclose(td["x"], src["c1"][keep] * 2.0)


def test_run_is_single_multi_table_commit(seeded_lake):
    p = paper_pipeline()
    seeded_lake.catalog.create_branch("r.dev", "main", author="r")
    before = len(seeded_lake.catalog.log("r.dev"))
    seeded_lake.run(p, branch="r.dev", author="r")
    after = len(seeded_lake.catalog.log("r.dev"))
    assert after == before + 1  # one transaction for the whole DAG


def test_node_returning_nothing_rejected(seeded_lake):
    @model()
    def bad(data=Model("source_table")):
        return {}

    seeded_lake.catalog.create_branch("r.d", "main", author="r")
    with pytest.raises(SchemaError):
        seeded_lake.run(Pipeline([bad]), branch="r.d", author="r")


def test_model_column_projection(seeded_lake):
    @model()
    def narrow(data=Model("source_table", columns=["c1"])):
        assert set(data) == {"c1"}
        return {"out": data["c1"]}

    seeded_lake.catalog.create_branch("r.d", "main", author="r")
    res = seeded_lake.run(Pipeline([narrow]), branch="r.d", author="r")
    assert "narrow" in res.outputs


# -------------------------------------------------------------------- ledger
def test_run_ids_unique_and_enumerable(seeded_lake):
    p = paper_pipeline()
    seeded_lake.catalog.create_branch("r.dev", "main", author="r")
    r1 = seeded_lake.run(p, branch="r.dev", author="r")
    r2 = seeded_lake.run(p, branch="r.dev", author="r")
    assert r1.run_id != r2.run_id  # data commit differs → new identity
    assert seeded_lake.ledger.runs() == [r2.run_id, r1.run_id]


def test_manifest_covers_table1(seeded_lake):
    """The run manifest must pin all 4 rows of the paper's Table 1."""
    p = paper_pipeline()
    seeded_lake.catalog.create_branch("r.dev", "main", author="r")
    res = seeded_lake.run(p, branch="r.dev", author="r", seed=7)
    m = seeded_lake.ledger.get(res.run_id)
    assert m["data_commit"]                       # input data
    assert m["code"] and m["pipeline_hash"]       # code
    assert m["runtime"]["python"] and m["runtime"]["jax"]  # runtime
    assert "hardware" in m                        # hardware
    assert m["seed"] == 7
    assert m["node_runtime"]["training_data"]["pip"]


def test_unknown_run_raises(seeded_lake):
    with pytest.raises(RunNotFound):
        seeded_lake.ledger.get("ffff0000")


# -------------------------------------------------------------------- replay
def test_replay_is_bit_exact(seeded_lake):
    p = paper_pipeline()
    seeded_lake.catalog.create_branch("r.dev", "main", author="r")
    res = seeded_lake.run(p, branch="r.dev", author="r")
    # production moves on: new data lands on main & dev
    new = {k: v[:10] for k, v in
           seeded_lake.read_table("main", "source_table").items()}
    seeded_lake.write_table("r.dev", "source_table", new, author="r")
    # replay still sees Monday's data (time travel) → identical outputs
    rep = seeded_lake.replay(res.run_id, p, branch="r.debug", author="r")
    assert rep.bit_exact, rep.diffs


def test_replay_detects_code_drift(seeded_lake):
    p = paper_pipeline()
    seeded_lake.catalog.create_branch("r.dev", "main", author="r")
    res = seeded_lake.run(p, branch="r.dev", author="r")
    p_changed = paper_pipeline(cutoff=60)
    with pytest.raises(CodeDrift):
        seeded_lake.replay(res.run_id, p_changed, branch="r.debug",
                           author="r")
    # explicit opt-in reproduces the "fix the bug" loop of use case #2
    rep = seeded_lake.replay(res.run_id, p_changed, branch="r.debug",
                             author="r", allow_code_drift=True)
    assert not rep.bit_exact  # changed code → changed outputs, as expected


def test_replay_records_parent_run(seeded_lake):
    p = paper_pipeline()
    seeded_lake.catalog.create_branch("r.dev", "main", author="r")
    res = seeded_lake.run(p, branch="r.dev", author="r")
    rep = seeded_lake.replay(res.run_id, p, branch="r.debug", author="r")
    m = seeded_lake.ledger.get(rep.replay_run_id)
    assert m["parent_run"] == res.run_id
    assert m["kind"] == "replay"
