"""Cross-host replay end-to-end: host A pushes a branch, host B pulls into a
fresh store and replays — bit-identical output digests, 100% warm run-cache
hits.  This is the paper's reproducibility claim stretched across machines:
the (code version, data commit) pin travels with the branch, and so does the
memoized work.
"""

import json

import numpy as np
import pytest

from repro.core import (Lake, Model, ObjectStore, Pipeline, RemoteServer,
                        RemoteStore, LoopbackTransport, SyncError, clone,
                        col, lit, model, pull, push, serve_http, sql_model)
from repro.core.errors import RefNotFound
from repro.launch import repro_cli


# --------------------------------------------------------------- test fixture
def paper_demo_pipeline(feature_scale: float = 2.0) -> Pipeline:
    """The Listings 1-2 shape: sql filter -> features -> two consumers."""
    final_table = sql_model(
        "final_table", select=["c1", "c2", "c3"], frm="source_table",
        where=col("transaction_ts") >= lit(50))

    @model()
    def features(data=Model("final_table")):
        return {"f0": np.sin(data["c1"]) * feature_scale,
                "f1": np.sqrt(np.abs(data["c2"]).astype(np.float64)),
                "c3": data["c3"]}

    @model()
    def training_data(data=Model("features")):
        return {"x": np.tanh(data["f0"] + data["f1"]),
                "y": (data["c3"] > 3).astype(np.float32)}

    @model()
    def data_stats(data=Model("features")):
        return {"mean_f0": np.array([data["f0"].mean()]),
                "n": np.array([data["f0"].shape[0]], np.int64)}

    return Pipeline([final_table, features, training_data, data_stats])


def make_lake(tmp_path, name, *, t0=1_700_000_000.0, remote=None) -> Lake:
    t = [t0]

    def clock():
        t[0] += 1.0
        return t[0]

    return Lake(tmp_path / name, clock=clock, remote=remote)


@pytest.fixture()
def host_a(tmp_path, source_cols):
    """Host A: seeded lake with a branch the demo pipeline ran on (cold)."""
    lake = make_lake(tmp_path, "host_a")
    snap = lake.io.write_snapshot(source_cols)
    lake.catalog.commit("main", {"source_table": snap}, "seed",
                        _wap_token=True)
    lake.catalog.create_branch("alice.exp", "main", author="alice")
    result = lake.run(paper_demo_pipeline(), branch="alice.exp",
                      author="alice")
    assert result.cache_misses == 4 and result.cache_hits == 0
    return lake, result


@pytest.fixture()
def remote(tmp_path):
    return RemoteStore(LoopbackTransport(RemoteServer(
        ObjectStore(tmp_path / "remote"))))


# ------------------------------------------------------------ the money test
def test_cross_host_replay_bit_identical_and_fully_warm(tmp_path, host_a,
                                                        remote):
    """Push from A, pull into an empty B, replay with --jobs 4: identical
    digests, 100% run-cache hits (acceptance floor is >= 95%)."""
    lake_a, run_a = host_a
    rep = push(lake_a.store, remote, "alice.exp")
    assert rep.ref_updated and rep.objects_sent > 0
    assert rep.cache_entries == 4 and rep.runs == 1

    # a different host: fresh store directory, different wall clock
    lake_b = make_lake(tmp_path, "host_b", t0=1_800_000_000.0)
    prep = pull(lake_b.store, remote, "alice.exp")
    assert prep.ref_updated
    assert lake_b.catalog.head("alice.exp") == lake_a.catalog.head(
        "alice.exp")

    run_b = lake_b.run(paper_demo_pipeline(), branch="alice.exp",
                       author="alice", jobs=4)
    assert run_b.outputs == run_a.outputs  # bit-identical digests
    total = run_b.cache_hits + run_b.cache_misses
    assert run_b.cache_hits / total == 1.0  # 100% warm

    # the table bytes themselves round-tripped
    for table in ("training_data", "data_stats"):
        a = lake_a.read_table("alice.exp", table)
        b = lake_b.read_table("alice.exp", table)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])


def test_cross_host_replay_by_run_id(tmp_path, host_a, remote):
    """``repro run --id`` on host B replays host A's run id bit-exactly —
    the ledger manifest travelled with the branch."""
    lake_a, run_a = host_a
    push(lake_a.store, remote, "alice.exp")
    lake_b = make_lake(tmp_path, "host_b")
    pull(lake_b.store, remote, "alice.exp")
    assert run_a.run_id in lake_b.ledger.runs()
    report = lake_b.replay(run_a.run_id, paper_demo_pipeline(),
                           branch="alice.debug", author="alice", jobs=4)
    assert report.bit_exact


def test_push_is_incremental_and_dedup_aware(host_a, remote):
    lake_a, _ = host_a
    first = push(lake_a.store, remote, "alice.exp")
    second = push(lake_a.store, remote, "alice.exp")
    assert second.objects_sent == 0  # everything deduped via batched exists
    assert second.objects_skipped > 0
    assert second.ref_updated is False
    assert first.objects_sent > 0

    # one more commit -> only the delta moves
    lake_a.write_table("alice.exp", "extra",
                       {"v": np.arange(8, dtype=np.float32)}, author="alice")
    third = push(lake_a.store, remote, "alice.exp")
    assert third.ref_updated
    assert 0 < third.objects_sent <= 5  # tensorfile + manifest + list + snapshot + commit


def test_push_refuses_non_fast_forward(tmp_path, host_a, remote):
    lake_a, _ = host_a
    push(lake_a.store, remote, "alice.exp")
    # host B pulls, commits, pushes — then A (now stale) tries to push
    lake_b = make_lake(tmp_path, "host_b")
    pull(lake_b.store, remote, "alice.exp")
    lake_b.write_table("alice.exp", "b_table",
                       {"v": np.ones(4, np.float32)}, author="alice")
    push(lake_b.store, remote, "alice.exp")

    lake_a.write_table("alice.exp", "a_table",
                       {"v": np.zeros(4, np.float32)}, author="alice")
    with pytest.raises(SyncError):
        push(lake_a.store, remote, "alice.exp")
    push(lake_a.store, remote, "alice.exp", force=True)  # explicit override


def test_pull_refuses_diverged_local(tmp_path, host_a, remote):
    lake_a, _ = host_a
    push(lake_a.store, remote, "alice.exp")
    lake_b = make_lake(tmp_path, "host_b")
    pull(lake_b.store, remote, "alice.exp")
    # both sides commit -> B's pull must refuse
    lake_b.write_table("alice.exp", "t_b", {"v": np.ones(4, np.float32)},
                       author="alice")
    lake_a.write_table("alice.exp", "t_a", {"v": np.zeros(4, np.float32)},
                       author="alice")
    push(lake_a.store, remote, "alice.exp", force=True)
    with pytest.raises(SyncError):
        pull(lake_b.store, remote, "alice.exp")
    pull(lake_b.store, remote, "alice.exp", force=True)
    assert lake_b.catalog.head("alice.exp") == lake_a.catalog.head(
        "alice.exp")


def test_pull_main_into_fresh_lake(tmp_path, host_a, remote):
    """Every new catalog seeds ``main`` with its own empty root commit; a
    pull must recognize it as replaceable, not a divergence."""
    lake_a, _ = host_a
    push(lake_a.store, remote, "main")
    lake_b = make_lake(tmp_path, "host_b")  # has its OWN root commit on main
    rep = pull(lake_b.store, remote, "main")
    assert rep.ref_updated
    assert lake_b.catalog.head("main") == lake_a.catalog.head("main")
    cols = lake_b.read_table("main", "source_table")
    assert cols["c1"].shape[0] == 257


def test_clone_all_branches(tmp_path, host_a, remote):
    lake_a, run_a = host_a
    push(lake_a.store, remote, "main")
    push(lake_a.store, remote, "alice.exp")
    _store, reports = clone(remote, tmp_path / "cloned")
    assert {r.branch for r in reports} == {"main", "alice.exp"}
    lake_c = Lake(tmp_path / "cloned")
    run_c = lake_c.run(paper_demo_pipeline(), branch="alice.exp",
                       author="alice", jobs=4)
    assert run_c.outputs == run_a.outputs
    assert run_c.cache_misses == 0


def test_remote_tracking_ref_and_resolution(tmp_path, host_a, remote):
    lake_a, _ = host_a
    push(lake_a.store, remote, "alice.exp")
    head = lake_a.catalog.head("alice.exp")
    assert lake_a.store.get_ref("remote/origin/branch=alice.exp") == head
    assert lake_a.catalog.resolve("origin/alice.exp") == head

    lake_b = make_lake(tmp_path, "host_b")
    pull(lake_b.store, remote, "alice.exp")
    assert lake_b.catalog.resolve("origin/alice.exp") == head


def test_tiered_store_shares_cache_without_pull(tmp_path, host_a, remote):
    """Host B mounts the remote as a read-through tier: branch heads and
    warm cache entries are visible with ZERO explicit sync commands."""
    lake_a, run_a = host_a
    push(lake_a.store, remote, "alice.exp")
    lake_b = make_lake(tmp_path, "host_b", remote=remote)
    run_b = lake_b.run(paper_demo_pipeline(), branch="alice.exp",
                       author="alice", jobs=4)
    assert run_b.outputs == run_a.outputs
    assert run_b.cache_misses == 0
    # B's writes stayed local: the remote branch head is unmoved
    assert remote.get_ref("branch=alice.exp") == lake_a.catalog.head(
        "alice.exp")


def test_push_pull_over_http(tmp_path, host_a):
    """The same e2e through real sockets (loopback HTTP server)."""
    from repro.core import connect

    lake_a, run_a = host_a
    httpd, url = serve_http(ObjectStore(tmp_path / "http_remote"))
    try:
        remote = connect(url)
        push(lake_a.store, remote, "alice.exp")
        lake_b = make_lake(tmp_path, "host_b")
        pull(lake_b.store, remote, "alice.exp")
        run_b = lake_b.run(paper_demo_pipeline(), branch="alice.exp",
                           author="alice", jobs=4)
        assert run_b.outputs == run_a.outputs
        assert run_b.cache_misses == 0
    finally:
        httpd.shutdown()


def test_edited_node_after_pull_reruns_only_downstream(tmp_path, host_a,
                                                      remote):
    """Cache semantics survive the trip: editing one node on host B re-runs
    only its downstream cone, everything upstream still hits."""
    lake_a, _ = host_a
    push(lake_a.store, remote, "alice.exp")
    lake_b = make_lake(tmp_path, "host_b")
    pull(lake_b.store, remote, "alice.exp")
    edited = paper_demo_pipeline(feature_scale=3.0)
    run_b = lake_b.run(edited, branch="alice.exp", author="alice")
    assert run_b.cache_hits == 1   # final_table (upstream of the edit)
    assert run_b.cache_misses == 3  # features + both consumers


def test_pull_without_cache_entries_is_cold(tmp_path, host_a, remote):
    """--no-cache-entries pull: history arrives, memoized work does not —
    the knob the trust model in docs/remote_store.md prescribes for
    untrusted remotes."""
    lake_a, run_a = host_a
    push(lake_a.store, remote, "alice.exp")
    lake_b = make_lake(tmp_path, "host_b")
    rep = pull(lake_b.store, remote, "alice.exp", cache_entries=False)
    assert rep.cache_entries == 0
    run_b = lake_b.run(paper_demo_pipeline(), branch="alice.exp",
                       author="alice")
    assert run_b.cache_hits == 0 and run_b.cache_misses == 4
    assert run_b.outputs == run_a.outputs  # recomputed, still bit-identical


# -------------------------------------------------------------------- the CLI
def test_cli_push_pull_clone_roundtrip(tmp_path, capsys):
    """The paper's 'a few CLI commands' claim, cross-host: run, remote add,
    push, clone, warm replay by run id."""
    lake_a_dir = str(tmp_path / "cli_a")
    remote_dir = str(tmp_path / "cli_remote")
    lake_b_dir = str(tmp_path / "cli_b")

    lake = Lake(lake_a_dir, protect_main=False)
    from repro.data.pipeline import seed_corpus

    seed_corpus(lake, "main", n_docs=30, seed=0, vocab_size=256, mean_len=48)
    lake.catalog.create_branch("u.exp", "main", author="u")

    repro_cli.main(["--lake", lake_a_dir, "run", "--branch", "u.exp",
                    "--seq-len", "64", "--author", "u"])
    run_id = json.loads(capsys.readouterr().out.strip())["run_id"]

    repro_cli.main(["--lake", lake_a_dir, "remote", "add", "origin",
                    remote_dir])
    repro_cli.main(["--lake", lake_a_dir, "push", "--branch", "u.exp"])
    out = capsys.readouterr().out
    assert "push u.exp" in out and "ref_updated=True" in out

    repro_cli.main(["clone", remote_dir, lake_b_dir, "--branch", "u.exp"])
    capsys.readouterr()
    repro_cli.main(["--lake", lake_b_dir, "run", "--id", run_id, "--branch",
                    "u.dbg", "--seq-len", "64", "--author", "u",
                    "--jobs", "4"])
    replay = json.loads(capsys.readouterr().out.strip())
    assert replay["bit_exact"] is True

    # clone recorded its origin -> pull works with defaults
    repro_cli.main(["--lake", lake_b_dir, "pull", "--branch", "u.exp"])
    assert "pull u.exp" in capsys.readouterr().out


def test_cli_push_unknown_branch_exits(tmp_path):
    lake_dir = str(tmp_path / "lake")
    Lake(lake_dir)
    with pytest.raises(SystemExit):
        repro_cli.main(["--lake", lake_dir, "push", "--branch", "ghost",
                        "--remote", str(tmp_path / "r")])


def test_cli_unconfigured_remote_name_errors(tmp_path, monkeypatch):
    """A bare remote name that was never `remote add`-ed must fail loudly —
    not silently create an empty store directory and 'push' into it."""
    monkeypatch.chdir(tmp_path)
    lake_dir = str(tmp_path / "lake")
    Lake(lake_dir)
    with pytest.raises(SystemExit, match="unknown remote"):
        repro_cli.main(["--lake", lake_dir, "push", "--branch", "main",
                        "--remote", "orign"])
    assert not (tmp_path / "orign").exists()
