"""Pytest wrapper over the serving conformance harness.

The matrix (``serving_conformance.run_check``) pins the serving-tier
contract over batching mode × replica count: oracle equivalence, tag-flip
rollouts with zero failed requests, rollback convergence, the canary WAP
gate (no partial flips), replica crashes mid-rollout, head-of-line
behavior, and warm-pool prefetch on a tiered lake.

The fast leg runs the continuous mode (the production scheduler) across
both replica widths on every tier-1 run; the fixed baseline and the
pinned-seed soak ride behind the ``slow`` marker, mirroring how
``test_sync_conformance.py`` splits its matrix.
"""

import pytest

from serving_conformance import (CHECKS, MODES, REPLICAS, Combo, run_check,
                                 soak)


@pytest.mark.parametrize("replicas", REPLICAS)
@pytest.mark.parametrize("check", CHECKS, ids=lambda c: c.__name__)
def test_conformance_continuous(tmp_path, replicas, check):
    run_check(check, Combo("continuous", replicas), tmp_path)


@pytest.mark.slow
@pytest.mark.parametrize("replicas", REPLICAS)
@pytest.mark.parametrize("check", CHECKS, ids=lambda c: c.__name__)
def test_conformance_fixed_baseline(tmp_path, replicas, check):
    """The fixed-bucket baseline leg: completion/rollout/crash contracts
    hold there too (equivalence is continuous-only by design)."""
    run_check(check, Combo("fixed", replicas), tmp_path)


@pytest.mark.slow
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("seed", (7, 23))
def test_soak_pinned_seeds(tmp_path, mode, seed):
    """Two pinned soak schedules per mode: sustained random arrivals with
    a rollout, a rollback and a replica kill mid-stream; zero failed
    requests and (continuous) oracle equivalence.  A failure replays with
    ``python -m tests.serving_conformance --soak 30 --seed <seed>``."""
    soak(Combo(mode, 2), tmp_path, seed=seed, requests=30)
