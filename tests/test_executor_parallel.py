"""Parallel wave executor: independent DAG nodes overlap under ``--jobs N``
and results are bit-identical to sequential execution (same output snapshot
digests — content addressing makes this checkable exactly)."""

import threading
import time

import numpy as np
import pytest

from repro.core import (Model, NodeExecutionError, Pipeline, ReproError,
                        execute, model)


class Tracker:
    """Records per-node (start, end) wall intervals + peak concurrency."""

    def __init__(self):
        self.lock = threading.Lock()
        self.intervals = {}
        self.active = 0
        self.peak = 0

    def enter(self, name):
        with self.lock:
            self.active += 1
            self.peak = max(self.peak, self.active)
            self.intervals[name] = [time.perf_counter(), None]

    def exit(self, name):
        with self.lock:
            self.active -= 1
            self.intervals[name][1] = time.perf_counter()

    def overlap(self, a, b) -> bool:
        (s1, e1), (s2, e2) = self.intervals[a], self.intervals[b]
        return max(s1, s2) < min(e1, e2)


def diamond(tracker, sleep_s=0.15):
    """src -> (left, right) -> merged: left/right are independent."""

    @model()
    def left(data=Model("source_table")):
        tracker.enter("left")
        time.sleep(sleep_s)
        out = {"v": data["c1"] * 2.0}
        tracker.exit("left")
        return out

    @model()
    def right(data=Model("source_table")):
        tracker.enter("right")
        time.sleep(sleep_s)
        out = {"v": data["c1"] + 5.0}
        tracker.exit("right")
        return out

    @model()
    def merged(a=Model("left"), b=Model("right")):
        tracker.enter("merged")
        out = {"v": a["v"] + b["v"]}
        tracker.exit("merged")
        return out

    return Pipeline([left, right, merged])


def test_independent_nodes_overlap_under_jobs_4(seeded_lake):
    tracker = Tracker()
    pipe = diamond(tracker)
    seeded_lake.catalog.create_branch("r.par", "main", author="r")
    report = execute(pipe, seeded_lake.catalog, seeded_lake.io,
                     branch="r.par", author="r", use_cache=False, jobs=4)
    assert report.jobs == 4
    assert tracker.peak >= 2  # left and right ran concurrently
    assert tracker.overlap("left", "right")
    # merged strictly after both parents
    assert tracker.intervals["merged"][0] >= tracker.intervals["left"][1]
    assert tracker.intervals["merged"][0] >= tracker.intervals["right"][1]


def test_sequential_never_overlaps(seeded_lake):
    tracker = Tracker()
    pipe = diamond(tracker)
    seeded_lake.catalog.create_branch("r.seq", "main", author="r")
    execute(pipe, seeded_lake.catalog, seeded_lake.io,
            branch="r.seq", author="r", use_cache=False, jobs=1)
    assert tracker.peak == 1
    assert not tracker.overlap("left", "right")


def test_parallel_results_bit_identical_to_sequential(seeded_lake):
    seeded_lake.catalog.create_branch("r.a", "main", author="r")
    seeded_lake.catalog.create_branch("r.b", "main", author="r")
    seq = execute(diamond(Tracker(), 0), seeded_lake.catalog, seeded_lake.io,
                  branch="r.a", author="r", use_cache=False, jobs=1)
    par = execute(diamond(Tracker(), 0), seeded_lake.catalog, seeded_lake.io,
                  branch="r.b", author="r", use_cache=False, jobs=4)
    assert seq.outputs == par.outputs  # same snapshot digests, node for node
    # and through the catalog: both branches converge to identical tables
    assert (seeded_lake.catalog.tables("r.a")
            == seeded_lake.catalog.tables("r.b"))


def test_parallel_run_through_lake_records_jobs(seeded_lake):
    tracker = Tracker()
    pipe = diamond(tracker, 0.05)
    seeded_lake.catalog.create_branch("r.lake", "main", author="r")
    res = seeded_lake.run(pipe, branch="r.lake", author="r", jobs=4)
    m = seeded_lake.ledger.get(res.run_id)
    assert m["executor"]["jobs"] == 4
    assert set(m["nodes"]) == {"left", "right", "merged"}


def test_node_failure_propagates_from_worker_thread(seeded_lake):
    @model()
    def boom(data=Model("source_table")):
        raise RuntimeError("node exploded")

    @model()
    def ok(data=Model("source_table")):
        return {"v": data["c1"]}

    seeded_lake.catalog.create_branch("r.err", "main", author="r")
    with pytest.raises(NodeExecutionError, match="node exploded") as ei:
        execute(Pipeline([boom, ok]), seeded_lake.catalog, seeded_lake.io,
                branch="r.err", author="r", jobs=4)
    assert isinstance(ei.value.__cause__, RuntimeError)
    # the failed run must not have committed anything
    assert "ok" not in seeded_lake.catalog.tables("r.err")


def test_failure_carries_node_identity_and_sibling_stats(seeded_lake):
    """Regression: the executor used to re-raise the bare worker exception,
    losing WHICH node failed and throwing away the NodeStats of every node
    that had already finished."""
    done = threading.Event()

    @model()
    def first(data=Model("source_table")):
        return {"v": data["c1"]}

    @model()
    def boom(data=Model("first")):
        done.set()
        raise ValueError("bad partition")

    seeded_lake.catalog.create_branch("r.id", "main", author="r")
    with pytest.raises(NodeExecutionError) as ei:
        execute(Pipeline([first, boom]), seeded_lake.catalog,
                seeded_lake.io, branch="r.id", author="r", jobs=4)
    err = ei.value
    assert err.node == "boom"
    assert err.attempts == 1
    assert "boom" in str(err) and "bad partition" in str(err)
    # the sibling that completed before the failure kept its stats
    assert set(err.node_stats) == {"first"}
    assert err.node_stats["first"].snapshot is not None
    assert done.is_set()


def test_sibling_failure_drains_in_flight_without_publishing(seeded_lake):
    """Regression: the old ``except BaseException: fut.cancel()`` path could
    not stop in-flight nodes — they kept running after the raise and WROTE
    their snapshot + cache entry into a failed run.  Now the coordinator
    drains them: the slow sibling finishes (threads can't be killed) but
    publishes nothing once the failure was observed."""
    slow_ran = threading.Event()

    @model()
    def fail_fast(data=Model("source_table")):
        raise RuntimeError("fast failure")

    @model()
    def slow(data=Model("source_table")):
        slow_ran.set()
        time.sleep(0.4)  # still in flight when fail_fast is observed
        return {"v": data["c1"] * 3.0}

    lake = seeded_lake
    lake.catalog.create_branch("r.drain", "main", author="r")
    with pytest.raises(NodeExecutionError, match="fail_fast"):
        execute(Pipeline([fail_fast, slow]), lake.catalog, lake.io,
                branch="r.drain", author="r", jobs=4)
    assert slow_ran.is_set()  # it really was in flight
    # drained: no cache entry (and thus no published snapshot) for `slow`
    cached_nodes = {e["node"] for e in
                    (lake.run_cache.get(k) for k in lake.run_cache.keys())
                    if e}
    assert "slow" not in cached_nodes
    assert "slow" not in lake.catalog.tables("r.drain")
    # and a rerun on a healthy DAG re-executes slow (no stale hit)
    @model(name="slow")
    def slow_ok(data=Model("source_table")):
        slow_ran.set()
        time.sleep(0.4)
        return {"v": data["c1"] * 3.0}

    rep = execute(Pipeline([slow_ok]), lake.catalog, lake.io,
                  branch="r.drain", author="r")
    assert not rep.node_stats["slow"].cache_hit


def test_wide_fanout_all_waves_complete(seeded_lake):
    """32 independent nodes + a fan-in: more nodes than workers."""
    nodes = []
    for i in range(32):
        def make(i=i):
            @model(name=f"n{i:02d}")
            def n(data=Model("source_table")):
                return {"v": data["c1"] + float(i)}
            return n
        nodes.append(make())

    def fan_in_fn(**inputs):
        return {"v": sum(v["v"] for v in inputs.values())}

    from repro.core.pipeline import Node, code_hash_of
    fan_in = Node(
        name="total", fn=fan_in_fn, deps=[n.name for n in nodes],
        dep_params={f"i{k}": Model(n.name) for k, n in enumerate(nodes)},
        code_hash=code_hash_of(fan_in_fn))
    pipe = Pipeline(nodes + [fan_in])
    seeded_lake.catalog.create_branch("r.wide", "main", author="r")
    report = execute(pipe, seeded_lake.catalog, seeded_lake.io,
                     branch="r.wide", author="r", jobs=4)
    assert len(report.outputs) == 33
    src = seeded_lake.read_table("main", "source_table")
    expect = src["c1"] * 32 + sum(range(32))
    np.testing.assert_allclose(
        seeded_lake.read_table("r.wide", "total")["v"], expect, rtol=1e-5)
