"""Per-kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel bodies on CPU; TPU is the target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dep — fall back to the seeded mini-sampler
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from repro.kernels.fingerprint.kernel import fingerprint
from repro.kernels.fingerprint.ops import tree_digest_hex
from repro.kernels.fingerprint.ref import digest_hex, fingerprint_ref
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import flash_gqa
from repro.kernels.mamba_ssd.ops import ssd
from repro.kernels.mamba_ssd.ref import ssd_reference

KEY = jax.random.PRNGKey(0)


# ========================================================== flash attention
def _fa_case(B, Hq, Hkv, S, T, d, dtype=jnp.float32, **kw):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, d), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, T, d), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, T, d), dtype)
    out = flash_attention(q, k, v, block_q=16, block_k=16, interpret=True,
                          **kw)
    kr = jnp.repeat(k, Hq // Hkv, axis=1)
    vr = jnp.repeat(v, Hq // Hkv, axis=1)
    exp = fa_ref.mha_reference(q, kr, vr, causal=kw.get("causal", True),
                               window=kw.get("window"),
                               softcap=kw.get("softcap"))
    tol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol)


@pytest.mark.parametrize("B,Hq,Hkv,S,T,d", [
    (1, 2, 2, 32, 32, 16),   # MHA
    (2, 4, 2, 32, 32, 16),   # GQA 2:1
    (1, 8, 1, 16, 16, 8),    # MQA
    (1, 2, 1, 24, 24, 16),   # ragged S
    (1, 2, 2, 16, 48, 16),   # T > S (prefix cache)
    (1, 3, 3, 40, 72, 32),   # ragged everything
])
def test_flash_shapes(B, Hq, Hkv, S, T, d):
    _fa_case(B, Hq, Hkv, S, T, d)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    _fa_case(1, 2, 2, 32, 32, 16, dtype=dtype)


@pytest.mark.parametrize("kw", [
    dict(window=8),                       # gemma2 local layer
    dict(softcap=50.0),                   # gemma2 logit cap
    dict(window=12, softcap=30.0),
    dict(causal=False),
])
def test_flash_options(kw):
    _fa_case(1, 4, 2, 32, 32, 16, **kw)


def test_flash_gqa_wrapper_and_grad():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 24, 4, 16))
    k = jax.random.normal(ks[1], (2, 24, 2, 16))
    v = jax.random.normal(ks[2], (2, 24, 2, 16))

    def loss_kernel(q, k, v):
        return jnp.sum(flash_gqa(q, k, v) ** 2)

    def loss_ref(q, k, v):
        qt, kt, vt = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
        kr = jnp.repeat(kt, 2, axis=1)
        vr = jnp.repeat(vt, 2, axis=1)
        out = fa_ref.mha_reference(qt, kr, vr, causal=True)
        return jnp.sum(jnp.swapaxes(out, 1, 2) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(S=st.integers(8, 48), d=st.sampled_from([8, 16, 32]),
       Hkv=st.sampled_from([1, 2]), groups=st.sampled_from([1, 2, 4]))
def test_property_flash_matches_ref(S, d, Hkv, groups):
    _fa_case(1, Hkv * groups, Hkv, S, S, d)


# ===================================================================== SSD
def _ssd_case(B, S, nh, hd, ns, chunk, h0=False, dtype=jnp.float32):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, nh, hd), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh), dtype))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    Bm = jax.random.normal(ks[3], (B, S, ns), dtype)
    Cm = jax.random.normal(ks[4], (B, S, ns), dtype)
    h0v = (0.1 * jax.random.normal(ks[0], (B, nh, hd, ns), jnp.float32)
           if h0 else None)
    y, h = ssd(x, dt, A, Bm, Cm, chunk=chunk, h0=h0v, interpret=True)
    ye, he = ssd_reference(x, dt, A, Bm, Cm, h0=h0v)
    tol = 3e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ye, np.float32), atol=tol,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(he), atol=1e-3)


@pytest.mark.parametrize("B,S,nh,hd,ns,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 4, 16, 16, 32),
    (1, 100, 2, 16, 8, 32),   # ragged tail → identity-step padding
    (1, 64, 2, 16, 8, 64),    # single chunk
    (1, 32, 1, 8, 4, 8),
])
def test_ssd_shapes(B, S, nh, hd, ns, chunk):
    _ssd_case(B, S, nh, hd, ns, chunk)


def test_ssd_initial_state():
    _ssd_case(1, 64, 2, 16, 8, 16, h0=True)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_dtypes(dtype):
    _ssd_case(2, 96, 3, 8, 4, 16, dtype=dtype)


@settings(max_examples=8, deadline=None)
@given(nchunks=st.integers(1, 4), chunk=st.sampled_from([8, 16]),
       nh=st.integers(1, 3))
def test_property_ssd_chunking_invariant(nchunks, chunk, nh):
    """Chunk size must not change the result (pure decomposition)."""
    S = nchunks * chunk
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (1, S, nh, 8))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    Bm = jax.random.normal(ks[3], (1, S, 4))
    Cm = jax.random.normal(ks[4], (1, S, 4))
    y1, h1 = ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y2, h2 = ssd(x, dt, A, Bm, Cm, chunk=S, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)


# ============================================================= fingerprint
FP_CASES = [
    ((100,), jnp.float32), ((33, 7), jnp.float32), ((1000,), jnp.bfloat16),
    ((5,), jnp.int32), ((3,), jnp.uint8), ((17,), jnp.bool_),
    ((4096,), jnp.float32), ((1,), jnp.float32),
]


@pytest.mark.parametrize("shape,dtype", FP_CASES)
def test_fingerprint_matches_ref_bitexact(shape, dtype):
    if dtype == jnp.bool_:
        x = jax.random.bernoulli(KEY, 0.5, shape)
    elif jnp.issubdtype(dtype, jnp.integer):
        x = jax.random.randint(KEY, shape, 0, 100).astype(dtype)
    else:
        x = jax.random.normal(KEY, shape).astype(dtype)
    dk = fingerprint(x, block=64, interpret=True)
    dr = fingerprint_ref(x)
    assert (np.asarray(dk) == np.asarray(dr)).all()


def test_fingerprint_block_size_invariant():
    x = jax.random.normal(KEY, (777,))
    d1 = fingerprint(x, block=32, interpret=True)
    d2 = fingerprint(x, block=256, interpret=True)
    assert (np.asarray(d1) == np.asarray(d2)).all()


def test_fingerprint_sensitivity():
    x = jax.random.normal(KEY, (257,))
    y = x.at[200].add(1e-7)
    assert digest_hex(fingerprint_ref(x)) != digest_hex(fingerprint_ref(y))
    # length extension: [x, 0] != [x]
    x0 = jnp.pad(x, (0, 1))
    assert digest_hex(fingerprint_ref(x)) != digest_hex(fingerprint_ref(x0))


def test_tree_digest_stable_across_orders():
    a = jax.random.normal(KEY, (16,))
    b = jax.random.normal(jax.random.PRNGKey(9), (8, 2))
    d1 = tree_digest_hex({"a": a, "b": b})
    d2 = tree_digest_hex({"b": b, "a": a})
    assert d1 == d2
    assert d1 != tree_digest_hex({"a": a, "b": b + 1})


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 300), seed=st.integers(0, 99))
def test_property_fingerprint_kernel_equals_ref(n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    dk = fingerprint(x, block=64, interpret=True)
    dr = fingerprint_ref(x)
    assert (np.asarray(dk) == np.asarray(dr)).all()
