"""Hierarchical multi-pod collectives — correctness on an 8-device host mesh
(subprocess so the main test process keeps 1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

_PROG = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed.collectives import (hierarchical_psum,
                                               hierarchical_psum_int8)
    from repro.distributed.compat import shard_map

    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    x = jnp.arange(512, dtype=jnp.float32).reshape(64, 8) / 7.0

    def flat_sum(v):
        return jax.lax.psum(v, ("pod", "data"))

    def hier_sum(v):
        return hierarchical_psum(v, intra_axis="data", inter_axis="pod")

    sm = lambda f: shard_map(f, mesh=mesh, in_specs=P(("pod", "data")),
                             out_specs=P(("pod", "data")),
                             check_vma=False)
    a = jax.jit(sm(flat_sum))(x)
    b = jax.jit(sm(hier_sum))(x)
    exact = float(jnp.max(jnp.abs(a - b)))

    # int8 EF variant: approximate, residual carries the error.
    # residual lives on the SCATTERED shard: (rows_per_device/|data|, cols)
    def hier_int8(v):
        r = jnp.zeros((v.shape[0] // 4, *v.shape[1:]), jnp.float32)
        out, new_r = hierarchical_psum_int8(v, r, intra_axis="data",
                                            inter_axis="pod")
        return out

    c = jax.jit(sm(hier_int8))(x)
    rel = float(jnp.max(jnp.abs(a - c)) / (jnp.max(jnp.abs(a)) + 1e-9))
    print(json.dumps({"exact_err": exact, "int8_rel_err": rel}))
""")


def test_hierarchical_psum_matches_flat():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _PROG], capture_output=True, text=True,
        env=env, timeout=600,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    # reduction ORDER differs from flat psum (RS→AR→AG) → f32 rounding noise
    assert rec["exact_err"] < 1e-4
    assert rec["int8_rel_err"] < 0.02       # int8 quantization error bound
