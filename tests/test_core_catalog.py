"""Catalog semantics: commits, branches, merges, time-travel, namespacing.

Property tests check the Git-semantics invariants the paper relies on:
branch = O(1) ref write; merge of disjoint table sets is conflict-free;
time-travel returns the commit that was HEAD at that time.
"""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dep — fall back to the seeded mini-sampler
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from repro.core import (Catalog, Lake, MergeConflict, ObjectStore,
                        PermissionDenied)
from repro.core.errors import RefNotFound, ReproError, TableNotFound


def _snap(lake, value=0.0, n=4):
    return lake.io.write_snapshot({"v": np.full(n, value, np.float32)})


# ------------------------------------------------------------------- commits
def test_root_commit_exists(lake):
    head = lake.catalog.head("main")
    info = lake.catalog.commit_info(head)
    assert info.parents == ()
    assert info.tables == {}


def test_multi_table_transaction(lake):
    s1, s2 = _snap(lake, 1), _snap(lake, 2)
    c = lake.catalog.commit("main", {"t1": s1, "t2": s2}, "both at once",
                            _wap_token=True)
    tables = lake.catalog.tables(c)
    assert tables == {"t1": s1, "t2": s2}


def test_delete_table_via_none(lake):
    s1 = _snap(lake, 1)
    lake.catalog.commit("main", {"t1": s1}, "add", _wap_token=True)
    lake.catalog.commit("main", {"t1": None}, "drop", _wap_token=True)
    assert "t1" not in lake.catalog.tables("main")


def test_log_first_parent(lake):
    for i in range(3):
        lake.catalog.commit("main", {"t": _snap(lake, i)}, f"c{i}",
                            _wap_token=True)
    log = lake.catalog.log("main")
    assert len(log) == 4  # 3 commits + root
    msgs = [lake.catalog.commit_info(d).message for d in log]
    assert msgs == ["c2", "c1", "c0", "repository root"]


# ------------------------------------------------------------------ branches
def test_branch_is_copy_on_write(lake):
    """Branching writes ONE ref and zero objects (paper §5.4)."""
    lake.catalog.commit("main", {"big": _snap(lake, 1, n=100_000)}, "big",
                        _wap_token=True)
    n_before = len(list(lake.store.iter_objects()))
    lake.catalog.create_branch("richard.debug", "main", author="richard")
    n_after = len(list(lake.store.iter_objects()))
    assert n_after == n_before  # no data copied
    assert (lake.catalog.tables("richard.debug")
            == lake.catalog.tables("main"))


def test_branch_namespacing(lake):
    lake.catalog.create_branch("richard.x", "main", author="richard")
    with pytest.raises(PermissionDenied):
        lake.catalog.commit("richard.x", {}, "np", author="alice")
    with pytest.raises(PermissionDenied):
        lake.catalog.create_branch("richard.y", "main", author="alice")
    # reads are open to everybody
    assert lake.catalog.tables("richard.x") == {}


def test_main_is_wap_protected(lake):
    with pytest.raises(PermissionDenied):
        lake.catalog.commit("main", {"t": _snap(lake)}, "direct write",
                            author="richard")


def test_duplicate_branch_rejected(lake):
    lake.catalog.create_branch("a.b", "main", author="a")
    with pytest.raises(ReproError):
        lake.catalog.create_branch("a.b", "main", author="a")


def test_delete_branch(lake):
    lake.catalog.create_branch("a.b", "main", author="a")
    lake.catalog.delete_branch("a.b")
    assert "a.b" not in lake.catalog.branches()
    with pytest.raises(PermissionDenied):
        lake.catalog.delete_branch("main")


# ------------------------------------------------------------------- merges
def test_fast_forward_merge(lake):
    lake.catalog.create_branch("dev.x", "main", author="dev")
    c = lake.catalog.commit("dev.x", {"t": _snap(lake, 5)}, "work",
                            author="dev")
    merged = lake.catalog.merge("dev.x", "main", _wap_token=True)
    assert merged == c  # fast-forward moves the ref, no merge commit
    assert lake.catalog.head("main") == c


def test_three_way_merge_disjoint_tables(lake):
    lake.catalog.create_branch("a.x", "main", author="a")
    lake.catalog.create_branch("b.x", "main", author="b")
    lake.catalog.commit("a.x", {"ta": _snap(lake, 1)}, "a", author="a")
    lake.catalog.commit("b.x", {"tb": _snap(lake, 2)}, "b", author="b")
    lake.catalog.merge("a.x", "main", _wap_token=True)
    m = lake.catalog.merge("b.x", "main", _wap_token=True)
    tables = lake.catalog.tables(m)
    assert set(tables) == {"ta", "tb"}
    info = lake.catalog.commit_info(m)
    assert len(info.parents) == 2  # true merge commit


def test_merge_conflict_same_table(lake):
    lake.catalog.create_branch("a.x", "main", author="a")
    lake.catalog.create_branch("b.x", "main", author="b")
    lake.catalog.commit("a.x", {"t": _snap(lake, 1)}, "a", author="a")
    lake.catalog.commit("b.x", {"t": _snap(lake, 2)}, "b", author="b")
    lake.catalog.merge("a.x", "main", _wap_token=True)
    with pytest.raises(MergeConflict) as ei:
        lake.catalog.merge("b.x", "main", _wap_token=True)
    assert ei.value.tables == ["t"]


def test_merge_same_snapshot_no_conflict(lake):
    """Both sides reached the identical snapshot → not a conflict."""
    s = _snap(lake, 7)
    lake.catalog.create_branch("a.x", "main", author="a")
    lake.catalog.create_branch("b.x", "main", author="b")
    lake.catalog.commit("a.x", {"t": s}, "a", author="a")
    lake.catalog.commit("b.x", {"t": s}, "b", author="b")
    lake.catalog.merge("a.x", "main", _wap_token=True)
    lake.catalog.merge("b.x", "main", _wap_token=True)
    assert lake.catalog.tables("main")["t"] == s


# -------------------------------------------------------------- time travel
def test_time_travel_at_ts(lake):
    c1 = lake.catalog.commit("main", {"t": _snap(lake, 1)}, "c1",
                             _wap_token=True)
    ts1 = lake.catalog.commit_info(c1).ts
    lake.catalog.commit("main", {"t": _snap(lake, 2)}, "c2", _wap_token=True)
    assert lake.catalog.resolve(f"main@{ts1}") == c1
    assert lake.catalog.resolve("main~1") == c1


def test_resolve_prefix_and_tag(lake):
    c1 = lake.catalog.commit("main", {"t": _snap(lake, 1)}, "c1",
                             _wap_token=True)
    assert lake.catalog.resolve(c1[:12]) == c1
    lake.catalog.create_tag("v1", "main")
    assert lake.catalog.resolve("v1") == c1
    with pytest.raises(RefNotFound):
        lake.catalog.resolve("does-not-exist")


def test_diff(lake):
    s1 = _snap(lake, 1)
    c1 = lake.catalog.commit("main", {"t": s1}, "c1", _wap_token=True)
    s2 = _snap(lake, 2)
    c2 = lake.catalog.commit("main", {"t": s2, "u": s1}, "c2",
                             _wap_token=True)
    d = lake.catalog.diff(c1, c2)
    assert set(d) == {"t", "u"}


def test_snapshot_of_missing_table(lake):
    with pytest.raises(TableNotFound):
        lake.catalog.snapshot_of("main", "ghost")


# ---------------------------------------------------------------- properties
@settings(max_examples=25, deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(["ta", "tb", "tc"]),
                              st.integers(0, 10)), min_size=1, max_size=8))
def test_property_head_reflects_last_write(tmp_path_factory, ops):
    """After any sequence of commits, tables(main) == the last write per key
    and every historical commit remains reachable (immutability)."""
    lake = Lake(tmp_path_factory.mktemp("lake"), protect_main=False)
    heads = []
    expected = {}
    for name, val in ops:
        snap = lake.io.write_snapshot({"v": np.full(3, val, np.float32)})
        heads.append(lake.catalog.commit("main", {name: snap}, "op"))
        expected[name] = snap
    assert lake.catalog.tables("main") == expected
    # every intermediate commit still resolves (nothing was rewritten)
    for h in heads:
        lake.catalog.commit_info(h)


@settings(max_examples=25, deadline=None)
@given(n_branches=st.integers(1, 5), seed=st.integers(0, 999))
def test_property_disjoint_merges_commute(tmp_path_factory, n_branches, seed):
    """Branches touching pairwise-distinct tables always merge cleanly and
    the final table set is their union."""
    lake = Lake(tmp_path_factory.mktemp("lake"), protect_main=False)
    rng = np.random.default_rng(seed)
    names = []
    for i in range(n_branches):
        b = f"u{i}.w"
        lake.catalog.create_branch(b, "main", author=f"u{i}")
        t = f"table_{i}"
        names.append(t)
        snap = lake.io.write_snapshot(
            {"v": rng.normal(size=4).astype(np.float32)})
        lake.catalog.commit(b, {t: snap}, "w", author=f"u{i}")
    order = rng.permutation(n_branches)
    for i in order:
        lake.catalog.merge(f"u{i}.w", "main")
    assert set(lake.catalog.tables("main")) == set(names)
