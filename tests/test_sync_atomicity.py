"""Regressions for two ref-update failure modes the sync layer used to
mishandle:

* **fallback tearing** — against a server that predates ``cas_refs`` the
  client degrades to per-ref CAS; a transport fault midway used to leave
  some refs updated and others stale with no rollback (exactly the torn
  state native ``cas_refs`` exists to prevent).  Now ANY mid-batch failure
  rolls the applied prefix back.
* **ambiguous non-idempotent failures** — a transport fault after a
  ``cas_ref``/``cas_refs`` request may have been delivered leaves the ref
  state unknown, but the client used to surface the same ``RemoteError``
  as a clean failure: a "failed" push could have silently succeeded.  Now
  the client raises :class:`AmbiguousRefUpdate` and push resolves it by
  re-reading the remote refs before reporting anything.
"""

import msgpack
import numpy as np
import pytest

from repro.core import (AmbiguousRefUpdate, Lake, LoopbackTransport,
                        ObjectStore, RemoteServer, RemoteStore, push,
                        push_refs)
from repro.core.errors import RemoteError


class Pr2Server(RemoteServer):
    """A server speaking only the PR-2 contract: no cas_refs op."""
    _op_cas_refs = None  # getattr finds None -> "unknown op" reply


def _op_of(payload: bytes) -> str:
    return msgpack.unpackb(payload, raw=False).get("op", "")


class FaultOnOp:
    """Raises a transport fault on selected calls of one wire op —
    either BEFORE the request reaches the server (``deliver=False``, a
    clean drop) or AFTER (``deliver=True``, the ambiguous case)."""

    def __init__(self, inner, op: str, *, fail_calls, deliver: bool):
        self.inner = inner
        self.op = op
        self.fail_calls = set(fail_calls)  # 1-based call indices to fail
        self.deliver = deliver
        self.count = 0

    def request(self, payload: bytes) -> bytes:
        if _op_of(payload) != self.op:
            return self.inner.request(payload)
        self.count += 1
        if self.count not in self.fail_calls:
            return self.inner.request(payload)
        if self.deliver:
            self.inner.request(payload)  # the server DOES apply it
        raise RemoteError(f"injected fault on {self.op} #{self.count}")

    def close(self) -> None:
        self.inner.close()


def _two_branch_lake(root) -> Lake:
    lake = Lake(root, protect_main=False)
    lake.write_table("main", "base",
                     {"v": np.arange(64, dtype=np.float32)})
    for i, branch in enumerate(("u.one", "u.two")):
        lake.catalog.create_branch(branch, "main", author="u")
        lake.write_table(branch, f"t{i}",
                         {"v": np.full(32, float(i), np.float32)},
                         author="u")
    return lake


# ------------------------------------------------ fallback-mode atomicity
def test_fallback_midway_fault_rolls_back_applied_refs(tmp_path):
    """Regression: with a pre-cas_refs server, a transport fault on the
    SECOND per-ref CAS must roll the first ref back — before the fix the
    rollback only ran on RefConflict, so a fault left branch=u.one
    updated and branch=u.two stale (torn)."""
    lake = _two_branch_lake(tmp_path / "lake")
    remote_store = ObjectStore(tmp_path / "remote")
    server = Pr2Server(remote_store)
    push_refs(lake.store, RemoteStore(LoopbackTransport(server)),
              ["u.one", "u.two"])  # seed both refs on the remote

    lake.write_table("u.one", "n1", {"v": np.ones(8, np.float32)},
                     author="u")
    lake.write_table("u.two", "n2", {"v": np.ones(8, np.float32)},
                     author="u")
    before = dict(remote_store.list_refs("branch=")[0])
    flaky = RemoteStore(FaultOnOp(LoopbackTransport(server), "cas_ref",
                                  fail_calls=[2], deliver=False),
                        retries=0)
    with pytest.raises(RemoteError):
        push_refs(lake.store, flaky, ["u.one", "u.two"])
    after = dict(remote_store.list_refs("branch=")[0])
    assert after == before, "mid-batch fault left the ref set torn"
    # tracking refs were never written either — the push reports failure
    # and leaves BOTH sides exactly where they were
    assert not [r for r in lake.store.iter_refs("remote/")
                if lake.store.get_ref(r) == lake.catalog.head("u.one")]


def test_fallback_clean_push_reports_fallback_mode(tmp_path):
    lake = _two_branch_lake(tmp_path / "lake")
    remote_store = ObjectStore(tmp_path / "remote")
    rep = push_refs(lake.store,
                    RemoteStore(LoopbackTransport(Pr2Server(remote_store))),
                    ["u.one", "u.two"])
    assert rep.ref_update_mode == "fallback"
    assert set(rep.updated_refs) == {"branch=u.one", "branch=u.two"}


def test_fallback_ambiguous_applied_ref_is_not_double_rolled(tmp_path):
    """An ambiguous per-ref CAS that actually landed resolves by re-read
    and the batch completes — no spurious failure, no rollback."""
    lake = _two_branch_lake(tmp_path / "lake")
    remote_store = ObjectStore(tmp_path / "remote")
    server = Pr2Server(remote_store)
    flaky = RemoteStore(FaultOnOp(LoopbackTransport(server), "cas_ref",
                                  fail_calls=[1], deliver=True),
                        retries=0)
    rep = push_refs(lake.store, flaky, ["u.one", "u.two"])
    assert rep.ref_update_mode == "fallback"
    for branch in ("u.one", "u.two"):
        assert remote_store.get_ref(f"branch={branch}") == \
            lake.catalog.head(branch)


# --------------------------------------------------- ambiguous cas_refs
def test_remote_store_raises_ambiguous_on_cas_transport_fault(tmp_path):
    remote_store = ObjectStore(tmp_path / "remote")
    flaky = RemoteStore(
        FaultOnOp(LoopbackTransport(RemoteServer(remote_store)),
                  "cas_refs", fail_calls=[1], deliver=False),
        retries=0)
    with pytest.raises(AmbiguousRefUpdate):
        flaky.cas_refs([("branch=x", None, "a" * 64)])
    flaky2 = RemoteStore(
        FaultOnOp(LoopbackTransport(RemoteServer(remote_store)),
                  "cas_ref", fail_calls=[1], deliver=False),
        retries=0)
    with pytest.raises(AmbiguousRefUpdate):
        flaky2.cas_ref("branch=x", None, "a" * 64)


def test_push_resolves_ambiguous_update_that_actually_applied(tmp_path):
    """Regression: the transport dies AFTER the server applied cas_refs.
    Before the fix push surfaced a RemoteError even though the remote ref
    had moved — a 'failed' push that silently succeeded.  Now push
    re-reads the refs, confirms the update, and reports success."""
    lake = _two_branch_lake(tmp_path / "lake")
    remote_store = ObjectStore(tmp_path / "remote")
    flaky = RemoteStore(
        FaultOnOp(LoopbackTransport(RemoteServer(remote_store)),
                  "cas_refs", fail_calls=[1], deliver=True),
        retries=0)
    rep = push(lake.store, flaky, "u.one")
    assert rep.ref_updated and rep.ref_update_mode == "resolved"
    assert remote_store.get_ref("branch=u.one") == \
        lake.catalog.head("u.one")
    # the local tracking ref reflects the (confirmed) success too
    assert lake.store.get_ref("remote/origin/branch=u.one") == \
        lake.catalog.head("u.one")


def test_push_reports_clean_failure_when_update_verifiably_not_applied(
        tmp_path):
    """The other ambiguity resolution: the fault hit before delivery, so
    the re-read shows the refs unchanged — push fails WITH that
    diagnosis, and no side (remote refs, local tracking refs) moved."""
    lake = _two_branch_lake(tmp_path / "lake")
    remote_store = ObjectStore(tmp_path / "remote")
    flaky = RemoteStore(
        FaultOnOp(LoopbackTransport(RemoteServer(remote_store)),
                  "cas_refs", fail_calls=[1], deliver=False),
        retries=0)
    with pytest.raises(RemoteError, match="verified unchanged"):
        push(lake.store, flaky, "u.one")
    assert "branch=u.one" not in dict(remote_store.list_refs("branch=")[0])
    assert not list(lake.store.iter_refs("remote/"))
