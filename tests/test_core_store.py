"""Unit + property tests for the content-addressed store and tensorfiles."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dep — fall back to the seeded mini-sampler
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from repro.core import ObjectStore, SchemaError, sha256_hex
from repro.core import store as store_mod
from repro.core.errors import ObjectNotFound, RefConflict, RefNotFound
from repro.core import tensorfile as tf


# --------------------------------------------------------------------- store
def test_put_get_roundtrip(tmp_path):
    store = ObjectStore(tmp_path)
    data = b"hello tensor lake" * 100
    digest = store.put(data)
    assert digest == sha256_hex(data)
    assert store.get(digest) == data
    assert store.has(digest)
    assert store.size(digest) < len(data)  # zstd compressed


def test_put_is_idempotent_dedup(tmp_path):
    store = ObjectStore(tmp_path)
    d1 = store.put(b"x" * 1000)
    d2 = store.put(b"x" * 1000)
    assert d1 == d2
    assert list(store.iter_objects()) == [d1]


def test_missing_object_raises(tmp_path):
    store = ObjectStore(tmp_path)
    with pytest.raises(ObjectNotFound):
        store.get("0" * 64)


def test_refs_cas(tmp_path):
    store = ObjectStore(tmp_path)
    store.set_ref("head", "aaa")
    assert store.get_ref("head") == "aaa"
    store.cas_ref("head", "aaa", "bbb")
    assert store.get_ref("head") == "bbb"
    with pytest.raises(RefConflict):
        store.cas_ref("head", "aaa", "ccc")  # stale expectation
    with pytest.raises(RefNotFound):
        store.get_ref("nope")


def test_small_objects_stored_raw(tmp_path):
    store = ObjectStore(tmp_path)
    d = store.put(b"tiny")
    assert store.get(d) == b"tiny"


# ------------------------------------------------------------------- codecs
CODECS = ["raw", "zlib"] + (["zstd"] if "zstd" in store_mod.WRITE_CODECS
                            else [])


@pytest.mark.parametrize("codec", CODECS)
def test_codec_roundtrip(tmp_path, codec):
    store = ObjectStore(tmp_path, codec=codec)
    for data in (b"", b"tiny", b"x" * 10_000, bytes(range(256)) * 64):
        digest = store.put(data)
        assert store.get(digest) == data


@settings(max_examples=40, deadline=None)
@given(data=st.binary(min_size=0, max_size=4096),
       codec=st.sampled_from(CODECS))
def test_property_codec_roundtrip_identity(tmp_path_factory, data, codec):
    """put/get is the identity under every writable codec, and the digest is
    codec-independent (content addressing hashes UNcompressed bytes)."""
    store = ObjectStore(tmp_path_factory.mktemp("s"), codec=codec)
    digest = store.put(data)
    assert digest == sha256_hex(data)
    assert store.get(digest) == data


def test_blobs_readable_across_codec_choices(tmp_path):
    """A store dir written with one codec stays readable when reopened with
    another — the codec byte in the framing decides per blob."""
    payloads = [b"alpha" * 100, b"beta" * 999, b"g"]
    digests = []
    for codec, data in zip(CODECS, payloads):
        digests.append(ObjectStore(tmp_path, codec=codec).put(data))
    for codec in CODECS:
        reader = ObjectStore(tmp_path, codec=codec)
        for digest, data in zip(digests, payloads):
            assert reader.get(digest) == data


def test_unknown_codec_rejected(tmp_path):
    with pytest.raises(ValueError):
        ObjectStore(tmp_path, codec="lz4")


def test_zstd_range_level_works_on_zlib_fallback(tmp_path):
    """Levels 10-22 are valid for zstd; the zlib path must clamp, not crash."""
    store = ObjectStore(tmp_path, codec="zlib", level=19)
    data = b"y" * 10_000
    assert store.get(store.put(data)) == data


# ------------------------------------------------------------ ref namespaces
def test_namespaced_refs_roundtrip(tmp_path):
    store = ObjectStore(tmp_path)
    store.set_ref("cache/ab/cdef", "d1")
    store.set_ref("cache/ab/ffff", "d2")
    store.set_ref("branch=main", "d3")
    assert store.get_ref("cache/ab/cdef") == "d1"
    assert list(store.iter_refs("cache/")) == ["cache/ab/cdef",
                                               "cache/ab/ffff"]
    assert "branch=main" in list(store.iter_refs())
    store.delete_ref("cache/ab/cdef")
    with pytest.raises(RefNotFound):
        store.get_ref("cache/ab/cdef")


@pytest.mark.parametrize("bad", ["", ".", "..", "a/../b", "a//b", "/a",
                                 ".hidden", "ns/.hidden"])
def test_bad_ref_names_rejected(tmp_path, bad):
    store = ObjectStore(tmp_path)
    with pytest.raises(ValueError):
        store.set_ref(bad, "x")


def test_cas_ref_atomic_under_threads(tmp_path):
    """N threads × K increments with CAS-retry: no lost updates."""
    import threading

    store = ObjectStore(tmp_path)
    store.set_ref("ctr", "0")
    n_threads, n_incr = 8, 25
    conflicts = [0] * n_threads

    def worker(tid):
        for _ in range(n_incr):
            while True:
                cur = store.get_ref("ctr")
                try:
                    store.cas_ref("ctr", cur, str(int(cur) + 1))
                    break
                except RefConflict:
                    conflicts[tid] += 1

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert store.get_ref("ctr") == str(n_threads * n_incr)


def test_concurrent_puts_single_object(tmp_path):
    """Racing put()s of the same content agree on one durable object."""
    import threading

    store = ObjectStore(tmp_path)
    data = b"contended blob" * 512
    digests = []
    lock = threading.Lock()

    def worker():
        d = store.put(data)
        with lock:
            digests.append(d)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert set(digests) == {sha256_hex(data)}
    assert list(store.iter_objects()) == [sha256_hex(data)]
    assert store.get(sha256_hex(data)) == data


@settings(max_examples=50, deadline=None)
@given(st.binary(min_size=0, max_size=4096))
def test_property_content_addressing(tmp_path_factory, data):
    """Same bytes → same digest; get(put(x)) == x."""
    store = ObjectStore(tmp_path_factory.mktemp("s"))
    digest = store.put(data)
    assert store.get(digest) == data
    assert store.put(data) == digest


# ---------------------------------------------------------------- tensorfile
DTYPES = [np.float32, np.float64, np.int32, np.int64, np.uint8, np.bool_]


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("row_shape", [(), (3,), (2, 4)])
def test_tensorfile_roundtrip_dtypes(dtype, row_shape):
    rng = np.random.default_rng(1)
    arr = rng.integers(0, 2, size=(17, *row_shape)).astype(dtype)
    blob, meta = tf.encode({"a": arr, "b": np.arange(17)})
    out = tf.decode(blob)
    np.testing.assert_array_equal(out["a"], arr)
    assert meta["nrows"] == 17


def test_tensorfile_bfloat16_roundtrip():
    import ml_dtypes

    arr = np.arange(32, dtype=np.float32).astype(ml_dtypes.bfloat16)
    blob, _ = tf.encode({"a": arr.reshape(8, 4)})
    out = tf.decode(blob)
    np.testing.assert_array_equal(out["a"], arr.reshape(8, 4))


def test_tensorfile_ragged_rejected():
    with pytest.raises(SchemaError):
        tf.encode({"a": np.zeros(3), "b": np.zeros(4)})


def test_tensorfile_stats():
    blob, meta = tf.encode({"a": np.array([1.0, np.nan, 3.0], np.float32)})
    st_ = meta["stats"]["a"]
    assert st_["nan_count"] == 1
    assert st_["min"] == 1.0 and st_["max"] == 3.0


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 64),
    cols=st.lists(st.sampled_from("abcdef"), min_size=1, max_size=4,
                  unique=True),
    dtype=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_tensorfile_roundtrip(n, cols, dtype, seed):
    """encode∘decode is the identity, and the digest is deterministic."""
    rng = np.random.default_rng(seed)
    data = {c: rng.integers(-5, 5, size=(n, 2)).astype(dtype) for c in cols}
    blob1, _ = tf.encode(data)
    blob2, _ = tf.encode(data)
    assert sha256_hex(blob1) == sha256_hex(blob2)  # deterministic encode
    out = tf.decode(blob1)
    for c in cols:
        np.testing.assert_array_equal(out[c], data[c])


def test_schema_project_and_compat():
    s = tf.Schema.of({"a": np.zeros((2, 3)), "b": np.zeros(2)})
    assert s.names() == ["a", "b"]
    p = s.project(["a"])
    assert p.names() == ["a"]
    with pytest.raises(SchemaError):
        s.check_compatible(p)
