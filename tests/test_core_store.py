"""Unit + property tests for the content-addressed store and tensorfiles."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ObjectStore, SchemaError, sha256_hex
from repro.core.errors import ObjectNotFound, RefConflict, RefNotFound
from repro.core import tensorfile as tf


# --------------------------------------------------------------------- store
def test_put_get_roundtrip(tmp_path):
    store = ObjectStore(tmp_path)
    data = b"hello tensor lake" * 100
    digest = store.put(data)
    assert digest == sha256_hex(data)
    assert store.get(digest) == data
    assert store.has(digest)
    assert store.size(digest) < len(data)  # zstd compressed


def test_put_is_idempotent_dedup(tmp_path):
    store = ObjectStore(tmp_path)
    d1 = store.put(b"x" * 1000)
    d2 = store.put(b"x" * 1000)
    assert d1 == d2
    assert list(store.iter_objects()) == [d1]


def test_missing_object_raises(tmp_path):
    store = ObjectStore(tmp_path)
    with pytest.raises(ObjectNotFound):
        store.get("0" * 64)


def test_refs_cas(tmp_path):
    store = ObjectStore(tmp_path)
    store.set_ref("head", "aaa")
    assert store.get_ref("head") == "aaa"
    store.cas_ref("head", "aaa", "bbb")
    assert store.get_ref("head") == "bbb"
    with pytest.raises(RefConflict):
        store.cas_ref("head", "aaa", "ccc")  # stale expectation
    with pytest.raises(RefNotFound):
        store.get_ref("nope")


def test_small_objects_stored_raw(tmp_path):
    store = ObjectStore(tmp_path)
    d = store.put(b"tiny")
    assert store.get(d) == b"tiny"


@settings(max_examples=50, deadline=None)
@given(st.binary(min_size=0, max_size=4096))
def test_property_content_addressing(tmp_path_factory, data):
    """Same bytes → same digest; get(put(x)) == x."""
    store = ObjectStore(tmp_path_factory.mktemp("s"))
    digest = store.put(data)
    assert store.get(digest) == data
    assert store.put(data) == digest


# ---------------------------------------------------------------- tensorfile
DTYPES = [np.float32, np.float64, np.int32, np.int64, np.uint8, np.bool_]


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("row_shape", [(), (3,), (2, 4)])
def test_tensorfile_roundtrip_dtypes(dtype, row_shape):
    rng = np.random.default_rng(1)
    arr = rng.integers(0, 2, size=(17, *row_shape)).astype(dtype)
    blob, meta = tf.encode({"a": arr, "b": np.arange(17)})
    out = tf.decode(blob)
    np.testing.assert_array_equal(out["a"], arr)
    assert meta["nrows"] == 17


def test_tensorfile_bfloat16_roundtrip():
    import ml_dtypes

    arr = np.arange(32, dtype=np.float32).astype(ml_dtypes.bfloat16)
    blob, _ = tf.encode({"a": arr.reshape(8, 4)})
    out = tf.decode(blob)
    np.testing.assert_array_equal(out["a"], arr.reshape(8, 4))


def test_tensorfile_ragged_rejected():
    with pytest.raises(SchemaError):
        tf.encode({"a": np.zeros(3), "b": np.zeros(4)})


def test_tensorfile_stats():
    blob, meta = tf.encode({"a": np.array([1.0, np.nan, 3.0], np.float32)})
    st_ = meta["stats"]["a"]
    assert st_["nan_count"] == 1
    assert st_["min"] == 1.0 and st_["max"] == 3.0


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 64),
    cols=st.lists(st.sampled_from("abcdef"), min_size=1, max_size=4,
                  unique=True),
    dtype=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_tensorfile_roundtrip(n, cols, dtype, seed):
    """encode∘decode is the identity, and the digest is deterministic."""
    rng = np.random.default_rng(seed)
    data = {c: rng.integers(-5, 5, size=(n, 2)).astype(dtype) for c in cols}
    blob1, _ = tf.encode(data)
    blob2, _ = tf.encode(data)
    assert sha256_hex(blob1) == sha256_hex(blob2)  # deterministic encode
    out = tf.decode(blob1)
    for c in cols:
        np.testing.assert_array_equal(out[c], data[c])


def test_schema_project_and_compat():
    s = tf.Schema.of({"a": np.zeros((2, 3)), "b": np.zeros(2)})
    assert s.names() == ["a", "b"]
    p = s.project(["a"])
    assert p.names() == ["a"]
    with pytest.raises(SchemaError):
        s.check_compatible(p)
