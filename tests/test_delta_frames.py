"""Delta-frame transfer properties: content-defined chunking, recipe
reassembly, capability downgrade, and the engine's wire accounting.

The headline contract (``docs/remote_store.md``, wire-speed section): a
push that re-sends a lightly-edited large blob ships bytes proportional to
the EDIT, not the blob — and the destination store is bit-identical to a
whole-frame push, because recipes are rebuilt and digest-verified on the
receiver before anything lands.
"""

import hashlib

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dep — fall back to the seeded mini-sampler
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from repro.core import (Lake, LoopbackTransport, ObjectStore, RemoteServer,
                        RemoteStore, push, sha256_hex)
from repro.core import delta
from repro.core.errors import ObjectNotFound


def _rand(seed: int, n: int) -> bytes:
    return np.random.default_rng(seed).integers(
        0, 256, size=n, dtype=np.uint8).tobytes()


# ----------------------------------------------------------------- chunking
def test_chunk_spans_partition_the_blob_exactly():
    data = _rand(0, 300_000)
    spans = delta.chunk_spans(data)
    assert spans[0][0] == 0
    assert all(a + ln == b for (a, ln), (b, _l2) in zip(spans, spans[1:]))
    assert spans[-1][0] + spans[-1][1] == len(data)
    # geometry: every span but the last respects min/max
    for _off, ln in spans[:-1]:
        assert delta.MIN_CHUNK <= ln <= delta.MAX_CHUNK
    assert spans[-1][1] <= delta.MAX_CHUNK
    # and chunking is deterministic
    assert delta.chunk_spans(data) == spans


def test_chunk_boundaries_are_content_defined_not_positional():
    """Insert bytes near the front: all boundaries AFTER the edit re-align,
    so most chunk hashes survive the shift (the whole point of CDC —
    fixed-size chunking would invalidate every chunk downstream)."""
    base = _rand(1, 200_000)
    edited = base[:1000] + b"INSERTED!" + base[1000:]
    h_base = {h for h, _o, _l in delta.chunk_blob(base)}
    h_edit = {h for h, _o, _l in delta.chunk_blob(edited)}
    assert len(h_base & h_edit) >= 0.7 * len(h_base)


def test_empty_and_tiny_blobs():
    assert delta.chunk_spans(b"") == []
    data = b"tiny"
    assert delta.chunk_spans(data) == [(0, 4)]
    assert delta.chunk_blob(data) == [(sha256_hex(data), 0, 4)]


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.lists(st.tuples(st.sampled_from(["insert", "delete", "edit"]),
                          st.integers(min_value=0, max_value=2 ** 30),
                          st.binary(min_size=1, max_size=300)),
                min_size=0, max_size=5))
def test_property_mutated_blob_reassembles_bit_identically(seed, mutations):
    """Random insert/delete/edit mutations; recipe built against the
    ORIGINAL blob's chunks must reassemble the mutated blob exactly."""
    base = _rand(seed, 120_000)
    data = bytearray(base)
    for kind, pos, payload in mutations:
        pos = pos % max(1, len(data))
        if kind == "insert":
            data[pos:pos] = payload
        elif kind == "delete":
            del data[pos:pos + len(payload)]
        else:
            data[pos:pos + len(payload)] = payload
    data = bytes(data)

    index = delta.ChunkIndex()
    index.add_blob(sha256_hex(base), base)
    chunks = delta.chunk_blob(data)
    recipe, cost = delta.build_recipe(data, chunks,
                                      index.has([h for h, _o, _l in chunks]))
    out = delta.assemble(recipe, index, {sha256_hex(base): base}.__getitem__)
    assert out == data
    assert cost <= len(data) + delta.REF_WIRE_COST * len(chunks)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=10_000))
def test_property_resend_cost_scales_with_edit_not_blob(seed):
    """A ~200-byte edit to a 200KB blob costs ~chunk-sized literals plus
    per-ref overhead — bounded by the dirtied chunk neighborhood, never
    proportional to the blob."""
    base = _rand(seed, 200_000)
    pos = seed % (len(base) - 300)
    edited = base[:pos] + _rand(seed + 1, 200) + base[pos + 200:]

    index = delta.ChunkIndex()
    index.add_blob(sha256_hex(base), base)
    chunks = delta.chunk_blob(edited)
    recipe, cost = delta.build_recipe(
        edited, chunks, index.has([h for h, _o, _l in chunks]))
    # the in-place edit dirties the chunks it overlaps (plus boundary
    # drift): a few average chunks of literals, refs for the rest
    assert cost <= 6 * delta.AVG_CHUNK + delta.REF_WIRE_COST * len(chunks)
    assert cost < 0.3 * len(edited)
    out = delta.assemble(recipe, index, {sha256_hex(base): base}.__getitem__)
    assert out == edited


def test_recipe_coalesces_adjacent_literal_runs():
    data = _rand(3, 100_000)
    chunks = delta.chunk_blob(data)
    recipe, cost = delta.build_recipe(data, chunks, have=set())
    # nothing shared -> ONE literal run covering the blob, not N
    assert recipe == [[delta.RAW_OP, data]]
    assert cost == len(data)
    # everything shared -> all refs
    recipe, cost = delta.build_recipe(
        data, chunks, have={h for h, _o, _l in chunks})
    assert all(op[0] == delta.REF_OP for op in recipe)
    assert cost == delta.REF_WIRE_COST * len(chunks)


def test_apply_recipe_rejects_unknown_ops():
    with pytest.raises(ObjectNotFound):
        delta.apply_recipe([["z", b"?"]], lambda h: b"")


# -------------------------------------------------------------- chunk index
def test_chunk_index_is_untrusted_stale_entries_degrade():
    base = _rand(4, 80_000)
    digest = sha256_hex(base)
    index = delta.ChunkIndex()
    index.add_blob(digest, base)
    chunks = delta.chunk_blob(base)
    recipe, _cost = delta.build_recipe(
        base, chunks, index.has([h for h, _o, _l in chunks]))

    # blob gone from the store -> ObjectNotFound, not a crash
    def gone(_d):
        raise ObjectNotFound(_d)

    with pytest.raises(ObjectNotFound):
        delta.assemble(recipe, index, gone)
    # blob replaced by different bytes -> re-hash catches the lie
    other = _rand(5, 80_000)
    with pytest.raises(ObjectNotFound):
        delta.assemble(recipe, index, {digest: other}.__getitem__)


def test_chunk_index_lru_bound_and_forget():
    index = delta.ChunkIndex(max_entries=8)
    for i in range(4):
        index.add_blob(f"{i:064d}"[:64], _rand(10 + i, 30_000))
    assert len(index) == 8  # evicted down to the bound
    digest = sha256_hex(_rand(10 + 3, 30_000))
    # forget drops only entries pointing into the named blob
    n_before = len(index)
    dropped = index.forget_blob(f"{3:064d}"[:64])
    assert dropped >= 1 and len(index) == n_before - dropped


# ----------------------------------------------------- engine integration
def _lake_with_big_tables(root, seed=0, n_tables=3, rows=64_000):
    """Incompressible float tables big enough to cross DELTA_MIN_BYTES."""
    rng = np.random.default_rng(seed)
    lake = Lake(root, protect_main=False)
    for i in range(n_tables):
        lake.write_table("main", f"w{i}",
                         {"v": rng.normal(size=rows).astype(np.float32)})
    return lake


def _mutate_small_slice(lake, frac=0.04, seed=99):
    """v2 checkpoint: overwrite a contiguous ~frac slice of each table."""
    rng = np.random.default_rng(seed)
    for name in sorted(lake.catalog.tables("main")):
        cols = lake.read_table("main", name)
        v = np.array(cols["v"])
        n = max(1, int(len(v) * frac))
        start = int(rng.integers(0, len(v) - n))
        v[start:start + n] = rng.normal(size=n).astype(np.float32)
        lake.write_table("main", name, {"v": v})


def test_push_delta_saves_wire_bytes_and_lands_bit_identical(tmp_path):
    """Checkpoint-to-checkpoint push: v1 whole, v2 as deltas.  The v2 push
    must report delta savings, and the destination must equal a plain
    whole-frame destination byte-for-byte."""
    lake = _lake_with_big_tables(tmp_path / "lake")

    dst_delta = ObjectStore(tmp_path / "delta")
    remote = RemoteStore(LoopbackTransport(RemoteServer(dst_delta)))
    rep1 = push(lake.store, remote, "main", jobs=2)
    assert rep1.ref_updated

    _mutate_small_slice(lake)
    rep2 = push(lake.store, remote, "main", jobs=2)
    assert rep2.ref_updated
    assert rep2.bytes_delta_saved > 0
    assert rep2.bytes_wire < rep2.bytes_sent  # deltas beat the raw size
    assert "delta_saved=" in rep2.summary()

    # oracle: the same two pushes with delta frames disabled
    dst_plain = ObjectStore(tmp_path / "plain")
    plain = RemoteStore(LoopbackTransport(RemoteServer(dst_plain)))
    # replay from the same source: v2 head closure includes v1 ancestry
    rep3 = push(lake.store, plain, "main", jobs=2, delta_frames=False)
    assert rep3.bytes_delta_saved == 0
    assert sorted(dst_delta.iter_objects()) >= sorted(dst_plain.iter_objects())
    for digest in dst_plain.iter_objects():
        assert dst_delta.get(digest) == dst_plain.get(digest)


def test_push_delta_wire_bytes_scale_with_edit(tmp_path):
    """The v2 push's wire bytes stay under 20% of a full-frame v2 push."""
    lake = _lake_with_big_tables(tmp_path / "lake", n_tables=4)
    remote_store = ObjectStore(tmp_path / "remote")
    remote = RemoteStore(LoopbackTransport(RemoteServer(remote_store)))
    push(lake.store, remote, "main", jobs=2)
    _mutate_small_slice(lake)

    rep_delta = push(lake.store, remote, "main", jobs=2)
    # oracle remote for the full-frame cost of the same v2 increment
    oracle_store = ObjectStore(tmp_path / "oracle")
    oracle = RemoteStore(LoopbackTransport(RemoteServer(oracle_store)))
    push(lake.store, oracle, "main", jobs=2, delta_frames=False)
    lake2 = None  # (the oracle's v2 increment includes v1; compare saved)
    assert rep_delta.bytes_delta_saved > 0.5 * rep_delta.bytes_wire


def test_old_server_downgrades_to_whole_frames_silently(tmp_path):
    """A server without the delta ops: ONE capability probe, then whole
    frames — same destination bytes, zero claimed savings, no error."""
    import msgpack as _mp

    class OldServer(RemoteServer):
        _op_has_chunks = None
        _op_put_objects_delta = None

    class OpCounter:
        def __init__(self, inner):
            self.inner, self.ops = inner, {}

        def request(self, payload):
            op = _mp.unpackb(payload, raw=False).get("op", "")
            self.ops[op] = self.ops.get(op, 0) + 1
            return self.inner.request(payload)

        def close(self):
            self.inner.close()

    lake = _lake_with_big_tables(tmp_path / "lake")
    dst = ObjectStore(tmp_path / "remote")
    counter = OpCounter(LoopbackTransport(OldServer(dst)))
    remote = RemoteStore(counter)
    push(lake.store, remote, "main", jobs=1)
    _mutate_small_slice(lake)
    rep = push(lake.store, remote, "main", jobs=1)
    assert rep.ref_updated
    assert rep.bytes_delta_saved == 0
    assert counter.ops.get("has_chunks", 0) <= 1  # probe once, not per chunk
    assert counter.ops.get("put_objects_delta", 0) == 0
    head = lake.catalog.head("main")
    assert dst.get_ref("branch=main") == head


def test_stale_receiver_chunks_fall_back_per_blob(tmp_path):
    """Receiver evicted/GC'd the blobs its index points at: the delta put
    reports them stale and the sender re-sends whole frames — the push
    still lands everything."""
    lake = _lake_with_big_tables(tmp_path / "lake", n_tables=2)
    dst = ObjectStore(tmp_path / "remote")
    server = RemoteServer(dst)
    remote = RemoteStore(LoopbackTransport(server))
    push(lake.store, remote, "main", jobs=1)

    # wipe the blobs out from under the chunk index (simulated sweep)
    for digest in list(dst.iter_objects()):
        dst.delete_object(digest)
    _mutate_small_slice(lake)
    rep = push(lake.store, remote, "main", jobs=1, force=True)
    assert rep.ref_updated
    head = lake.catalog.head("main")
    # every closure object really landed, bit-identical
    from repro.core import commit_closure
    for digest in commit_closure(lake.store, head):
        assert dst.get(digest) == lake.store.get(digest)


def test_push_fanout_shares_one_fetch_side(tmp_path):
    """Multi-remote push: every destination converges to the same refs and
    objects, and the source store serves each blob read once."""
    from repro.core import push_fanout

    lake = _lake_with_big_tables(tmp_path / "lake", n_tables=2,
                                 rows=16_000)
    reads = {"n": 0}
    real_get_many_encoded = type(lake.store).get_many_encoded

    class CountingStore(ObjectStore):
        def get_many_encoded(self, digests):
            reads["n"] += len(list(digests))
            return real_get_many_encoded(self, digests)

    src = CountingStore(lake.store.root)
    dests = [ObjectStore(tmp_path / f"r{i}") for i in range(3)]
    remotes = [(f"r{i}", RemoteStore(LoopbackTransport(RemoteServer(d))))
               for i, d in enumerate(dests)]
    reports = push_fanout(src, remotes, ["main"], jobs=2)
    assert [name for name, _rep in reports] == ["r0", "r1", "r2"]
    assert all("branch=main" in rep.updated_refs
               for _name, rep in reports)

    head = lake.catalog.head("main")
    reference = sorted(dests[0].iter_objects())
    for d in dests:
        assert d.get_ref("branch=main") == head
        assert sorted(d.iter_objects()) == reference
        for digest in reference:
            assert d.get(digest) == dests[0].get(digest)
    # the memo kept source reads at one-destination volume
    assert reads["n"] <= len(reference)


def test_cli_push_fans_out_to_multiple_remotes(tmp_path, capsys):
    from repro.core import serve_s3
    from repro.launch.repro_cli import main

    lake = Lake(tmp_path / "lake", protect_main=False)
    lake.write_table("main", "t0",
                     {"v": np.arange(256, dtype=np.float32)})
    lake.catalog.create_branch("u.exp", "main", author="u")
    httpd_a, url_a = serve_s3(tmp_path / "a")
    httpd_b, url_b = serve_s3(tmp_path / "b")
    try:
        base = ["--lake", str(tmp_path / "lake")]
        main(base + ["remote", "add", "ra", url_a])
        main(base + ["remote", "add", "rb", url_b])
        main(base + ["push", "--branch", "u.exp",
                     "--remote", "ra", "--remote", "rb"])
        out = capsys.readouterr().out
        assert out.count("ref_updated") + out.count("refs_updated=") >= 2
        assert "ra:" in out and "rb:" in out
        head = lake.catalog.head("u.exp")
        for root in (tmp_path / "a", tmp_path / "b"):
            store = ObjectStore(root)
            assert store.get_ref("branch=u.exp") == head
        # fan-out pull is refused: pull merges ONE remote's view
        with pytest.raises(SystemExit, match="pull"):
            main(base + ["pull", "--branch", "u.exp",
                         "--remote", "ra", "--remote", "rb"])
    finally:
        httpd_a.shutdown()
        httpd_b.shutdown()
