"""Regression lock on the recorded dry-run grid (results/dryrun/*.json):
every (arch × shape × mesh) cell must be ok or a DESIGN.md-sanctioned skip.

(The grid itself is produced by ``python -m repro.launch.dryrun --all
--both-meshes``; these tests gate that its committed artifacts stay
coherent — they skip gracefully when the grid has not been generated.)"""

import json
from pathlib import Path

import pytest

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

EXPECTED_SKIPS = {  # long_500k on full-attention archs (DESIGN.md)
    ("yi-34b", "long_500k"), ("gemma2-9b", "long_500k"),
    ("minicpm-2b", "long_500k"), ("qwen2.5-14b", "long_500k"),
    ("qwen2-moe-a2.7b", "long_500k"), ("qwen3-moe-235b-a22b", "long_500k"),
    ("musicgen-large", "long_500k"), ("internvl2-76b", "long_500k"),
}


def _records():
    if not RESULTS.exists():
        pytest.skip("dry-run grid not generated")
    recs = [json.loads(p.read_text()) for p in sorted(RESULTS.glob("*.json"))]
    if not recs:
        pytest.skip("dry-run grid empty")
    return recs


def test_grid_complete_and_error_free():
    recs = _records()
    by_status = {}
    for r in recs:
        by_status.setdefault(r["status"], []).append(
            (r["arch"], r["shape"], r.get("mesh")))
    assert not by_status.get("error"), by_status.get("error")
    # 10 archs × 4 shapes × 2 meshes = 80 cells
    assert len(recs) == 80
    skipped = {(a, s) for a, s, _ in by_status.get("skipped", [])}
    assert skipped == EXPECTED_SKIPS


def test_ok_cells_carry_roofline_terms():
    for r in _records():
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        assert rl["t_compute"] >= 0
        assert rl["t_memory"] > 0
        assert rl["bottleneck"] in ("compute", "memory", "collective")
        assert rl["flops_per_device"] > 0
        assert r["collectives"]["counts"], (r["arch"], r["shape"])


def test_multipod_cells_use_512_devices():
    for r in _records():
        if r["status"] == "ok" and r["mesh"] == "2x16x16":
            assert r["n_devices"] == 512
