"""Data substrate: corpus determinism, packing, stateless loader."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dep — fall back to the seeded mini-sampler
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from repro.data import (DeterministicLoader, batch_rows, build_data_pipeline,
                        generate_documents, permuted_index, seed_corpus)


def test_corpus_deterministic():
    a = generate_documents(n_docs=40, seed=9, vocab_size=256)
    b = generate_documents(n_docs=40, seed=9, vocab_size=256)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = generate_documents(n_docs=40, seed=10, vocab_size=256)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_corpus_token_range():
    d = generate_documents(n_docs=20, seed=0, vocab_size=128)
    assert d["tokens"].max() < 128
    assert d["tokens"].min() >= 0


def test_pipeline_packs_to_seq_len(lake):
    lake.catalog.create_branch("d.m", "main", author="d")
    seed_corpus(lake, "d.m", n_docs=64, seed=1, vocab_size=256,
                mean_len=100, author="d")
    lake.run(build_data_pipeline(64), branch="d.m", author="d")
    packed = lake.read_table("d.m", "packed")
    assert packed["tokens"].shape[1] == 64
    stats = lake.read_table("d.m", "data_stats")
    assert stats["max_token"][0] < 256


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 4096), seed=st.integers(0, 99),
       epoch=st.integers(0, 3))
def test_property_permutation_bijective(n, seed, epoch):
    out = permuted_index(np.arange(n), n, seed, epoch)
    assert len(set(out.tolist())) == n
    assert out.min() >= 0 and out.max() < n


def test_batches_cover_epoch_without_dups():
    n, gb = 128, 16
    seen = []
    for s in range(n // gb):
        rows, epoch = batch_rows(s, n_rows=n, global_batch=gb, seed=5)
        assert epoch == 0
        seen.extend(rows.tolist())
    assert len(set(seen)) == n


def test_epochs_reshuffle():
    n, gb = 64, 8
    e0 = np.concatenate([batch_rows(s, n_rows=n, global_batch=gb, seed=0)[0]
                         for s in range(8)])
    e1 = np.concatenate([batch_rows(8 + s, n_rows=n, global_batch=gb,
                                    seed=0)[0] for s in range(8)])
    assert not np.array_equal(e0, e1)
    assert set(e0.tolist()) == set(e1.tolist()) == set(range(n))


def test_loader_resume_identity():
    """Iterator state = step number: batches after 'resume' are identical."""
    tokens = np.arange(50 * 8, dtype=np.int32).reshape(50, 8)
    l1 = DeterministicLoader(tokens, global_batch=4, seed=3)
    run1 = [l1.batch(s)["tokens"] for s in range(10)]
    l2 = DeterministicLoader(tokens, global_batch=4, seed=3)  # "restarted"
    run2 = [l2.batch(s)["tokens"] for s in range(5, 10)]
    for a, b in zip(run1[5:], run2):
        np.testing.assert_array_equal(a, b)
