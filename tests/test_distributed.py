"""Distribution layer: sharding specs, HLO analysis, and an 8-device
mini dry-run in a subprocess (tests keep seeing 1 device)."""

import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import full_config
from repro.distributed import analysis
from repro.models import init_params


# ------------------------------------------------------------ HLO analysis
def test_shape_bytes():
    assert analysis._shape_bytes("bf16", "8,128") == 8 * 128 * 2
    assert analysis._shape_bytes("f32", "") == 4  # scalar
    assert analysis._shape_bytes("pred", "16") == 16


def test_collective_parse_simple():
    hlo = textwrap.dedent("""\
    ENTRY %main (a: f32[16]) -> f32[16] {
      %x = f32[1024,512]{1,0} all-gather(%a), replica_groups={{0,1,2,3}}
      %y = f32[256]{0} reduce-scatter(%x), replica_groups=[2,4]<=[8]
      %z = (f32[128]{0}, f32[64]{0}) all-reduce(%p, %q), replica_groups={{0,1}}
    }
    """)
    stats = analysis.parse_collectives(hlo, n_devices=8)
    assert stats.counts == {"all-gather": 1, "reduce-scatter": 1,
                            "all-reduce": 1}
    ag = 1024 * 512 * 4
    assert stats.result_bytes["all-gather"] == ag
    # link bytes: ag×3/4 + rs_out×(g-1)=256×4×3 + ar×2×1/2
    expect = ag * 3 / 4 + 256 * 4 * 3 + (128 + 64) * 4 * 2 * 0.5
    assert stats.link_bytes == pytest.approx(expect)


def test_while_trip_count_multiplies():
    hlo = textwrap.dedent("""\
    %body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
      %g = f32[64]{0} all-gather(%p), replica_groups={{0,1}}
      ROOT %t = tuple(...)
    }

    %cond (p: (s32[], f32[8])) -> pred[] {
      %c = s32[] constant(12)
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }

    ENTRY %main (a: f32[8]) -> f32[8] {
      %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body
    }
    """)
    stats = analysis.parse_collectives(hlo, n_devices=2)
    assert stats.counts["all-gather"] == 12
    assert stats.result_bytes["all-gather"] == 12 * 64 * 4


def test_dot_flops_from_text():
    hlo = textwrap.dedent("""\
    ENTRY %main (a: f32[128,256]) -> f32[128,64] {
      %p = f32[128,256]{1,0} parameter(0)
      %q = f32[256,64]{1,0} parameter(1)
      %d = f32[128,64]{1,0} dot(%p, %q), lhs_contracting_dims={1}, rhs_contracting_dims={0}
    }
    """)
    prog = analysis.HloProgram(hlo)
    flops, _ = prog.flops_bytes()
    assert flops == 2 * 128 * 64 * 256


def test_roofline_terms_and_bottleneck():
    r = analysis.Roofline(flops_per_device=197e12, bytes_per_device=819e9,
                          collective_link_bytes=100e9, n_devices=256,
                          model_flops_total=197e12 * 256 * 0.5)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.t_collective == pytest.approx(2.0)
    assert r.bottleneck == "collective"
    assert r.useful_flops_ratio == pytest.approx(0.5)


# -------------------------------------------------------------- specs
def test_param_specs_match_tree():
    from repro.distributed import param_specs
    cfg = full_config("qwen3-moe-235b-a22b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    specs = param_specs(cfg, mesh)
    params = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    # identical tree structure
    jax.tree.map(lambda s, p: None, specs, params,
                 is_leaf=lambda x: isinstance(x, P))


def test_ep_fallback_for_non_divisible_experts():
    from repro.distributed import param_specs
    mesh16 = jax.make_mesh((1, 1), ("data", "model"))
    # qwen2-moe: 60 experts — with |model|=16 EP doesn't divide.
    # At mesh (1,1) everything is unsharded; the policy is pure logic,
    # so call it with synthetic axis sizes via a fake mesh is complex —
    # instead check the decision on the production mesh inside dryrun specs
    # (covered by test_dryrun_cell_8dev below).
    cfg = full_config("qwen2-moe-a2.7b")
    specs = param_specs(cfg, mesh16)
    assert specs["layers"]["moe"]["w_gate"] is not None


# ------------------------------------------------ 8-device subprocess jit
_SUBPROC = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.configs import smoke_config
    from repro.distributed import (batch_specs, named, opt_state_specs,
                                   param_specs, make_activation_constraint)
    from repro.models import init_params
    from repro.optim import adamw
    from repro.runtime.steps import build_train_step

    cfg = smoke_config("{arch}").with_(attn_block=16)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    pspecs = param_specs(cfg, mesh)
    ac = make_activation_constraint(cfg, mesh)
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    opt_cfg = adamw.AdamWConfig()
    opt = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), params)
    ospecs = opt_state_specs(pspecs, has_master=False, compress=False)
    bspecs = batch_specs(cfg, mesh, global_batch=8)
    step = build_train_step(cfg, opt_config=opt_cfg, ac=ac)
    batch = {{"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)}}
    if cfg.n_frontend_embeds:
        batch["extra_embeds"] = jax.ShapeDtypeStruct(
            (8, cfg.n_frontend_embeds, cfg.d_model), jnp.float32)
    with mesh:
        jfn = jax.jit(step,
                      in_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                                    named(mesh, bspecs)),
                      out_shardings=(named(mesh, pspecs),
                                     named(mesh, ospecs), None))
        compiled = jfn.lower(params, opt, batch).compile()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    print(json.dumps({{"ok": True, "flops": float(ca.get("flops", 0))}}))
""")


@pytest.mark.parametrize("arch", ["paper-demo", "gemma2-9b", "mamba2-370m",
                                  "qwen2-moe-a2.7b", "hymba-1.5b"])
def test_dryrun_cell_8dev(arch):
    """End-to-end mini dry-run: jit train_step with explicit shardings on an
    8-device host mesh compiles for every model family."""
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC.format(arch=arch)],
        capture_output=True, text=True, env=env, timeout=600,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["flops"] > 0
