"""Fault injection: push/pull against a remote that drops requests
mid-transfer must resume cleanly — no corrupted refs, no partial objects
visible through any ref.

The flaky wrapper fails at the *transport* layer (the only layer a real
network fault can touch), on a deterministic schedule so failures are
reproducible.  Marked ``slow``: excluded from the default ``pytest -x -q``
run (see pytest.ini), exercised by the dedicated CI leg.
"""

import numpy as np
import pytest

from repro.core import (Lake, LoopbackTransport, ObjectStore, RemoteServer,
                        RemoteStore, commit_closure, pull, push)
from repro.core.errors import RefNotFound, RemoteError

pytestmark = pytest.mark.slow


class FlakyTransport:
    """Drops (raises on) requests whose call index lands in a window."""

    def __init__(self, inner, *, fail_from: int, fail_count: int):
        self.inner = inner
        self.calls = 0
        self.fail_from = fail_from
        self.fail_count = fail_count

    def request(self, payload: bytes) -> bytes:
        i = self.calls
        self.calls += 1
        if self.fail_from <= i < self.fail_from + self.fail_count:
            raise RemoteError(f"injected transport fault at call {i}")
        return self.inner.request(payload)

    def heal(self) -> None:
        self.fail_count = 0

    def close(self) -> None:
        self.inner.close()


def seeded_host(tmp_path, name):
    lake = Lake(tmp_path / name, protect_main=False)
    rng = np.random.default_rng(7)
    lake.write_table("main", "source_table", {
        "c1": rng.normal(size=200).astype(np.float32),
        "transaction_ts": np.arange(200, dtype=np.int64),
    })
    lake.catalog.create_branch("u.exp", "main", author="u")
    from repro.core import Model, Pipeline, model

    @model()
    def doubled(data=Model("source_table")):
        return {"v": data["c1"] * 2.0}

    @model()
    def summed(data=Model("doubled")):
        return {"s": np.cumsum(data["v"])}

    pipe = Pipeline([doubled, summed])
    result = lake.run(pipe, branch="u.exp", author="u")
    return lake, pipe, result


def assert_store_uncorrupted(store):
    """Every object present is complete and digest-valid, and every commit
    present has its full closure present (the deps-first invariant)."""
    for digest in store.iter_objects():
        data = store.get(digest)  # digest-verified by get()
        try:
            obj = __import__("msgpack").unpackb(data, raw=False)
        except Exception:
            continue
        if isinstance(obj, dict) and "parents" in obj and "tables" in obj:
            for d in commit_closure(store, digest):
                assert store.has(d), \
                    f"commit {digest[:12]} references missing {d[:12]}"


@pytest.mark.parametrize("fail_from", [3, 7, 12])
def test_push_interrupted_then_resumed(tmp_path, fail_from):
    lake_a, pipe, run_a = seeded_host(tmp_path, "host_a")
    remote_store = ObjectStore(tmp_path / "remote")
    flaky = FlakyTransport(LoopbackTransport(RemoteServer(remote_store)),
                           fail_from=fail_from, fail_count=1000)
    remote = RemoteStore(flaky, retries=0)  # every drop is fatal

    with pytest.raises(RemoteError):
        push(lake_a.store, remote, "u.exp")

    # the branch ref never moved: a reader of the remote sees no branch,
    # not a branch pointing at a half-transferred closure
    with pytest.raises(RefNotFound):
        RemoteStore(LoopbackTransport(RemoteServer(remote_store))).get_ref(
            "branch=u.exp")
    assert_store_uncorrupted(remote_store)

    # resume: the retry skips whatever already made it across
    flaky.heal()
    report = push(lake_a.store, remote, "u.exp")
    assert report.ref_updated
    assert_store_uncorrupted(remote_store)

    # a fresh host can now pull and replay fully warm
    lake_b = Lake(tmp_path / "host_b", protect_main=False)
    pull(lake_b.store, remote, "u.exp")
    run_b = lake_b.run(pipe, branch="u.exp", author="u", jobs=4)
    assert run_b.outputs == run_a.outputs
    assert run_b.cache_misses == 0


@pytest.mark.parametrize("fail_from", [2, 6, 10])
def test_pull_interrupted_then_resumed(tmp_path, fail_from):
    lake_a, pipe, run_a = seeded_host(tmp_path, "host_a")
    remote_store = ObjectStore(tmp_path / "remote")
    push(lake_a.store,
         RemoteStore(LoopbackTransport(RemoteServer(remote_store))), "u.exp")

    lake_b = Lake(tmp_path / "host_b", protect_main=False)
    flaky = FlakyTransport(LoopbackTransport(RemoteServer(remote_store)),
                           fail_from=fail_from, fail_count=1000)
    remote = RemoteStore(flaky, retries=0)
    with pytest.raises(RemoteError):
        pull(lake_b.store, remote, "u.exp")

    # A ref is only ever visible once its closure is complete: if the crash
    # cut the transfer short, neither the branch nor the tracking ref moved;
    # if it hit after the closure landed, whatever the refs point at must be
    # fully resolvable locally.
    for ref in ("branch=u.exp", "remote/origin/branch=u.exp"):
        try:
            head = lake_b.store.get_ref(ref)
        except RefNotFound:
            continue
        if ref.startswith("remote/") or head == lake_a.catalog.head("u.exp"):
            for d in commit_closure(lake_b.store, head):
                assert lake_b.store.has(d)
    assert_store_uncorrupted(lake_b.store)

    flaky.heal()
    pull(lake_b.store, remote, "u.exp")  # resume (ref may already be set)
    assert lake_b.catalog.head("u.exp") == lake_a.catalog.head("u.exp")
    run_b = lake_b.run(pipe, branch="u.exp", author="u", jobs=4)
    assert run_b.outputs == run_a.outputs
    assert run_b.cache_misses == 0


def test_transient_drops_absorbed_by_client_retries(tmp_path):
    """Isolated drops (not a dead remote) are retried transparently by the
    client for idempotent requests — one flaky window, zero failed pushes."""
    lake_a, pipe, run_a = seeded_host(tmp_path, "host_a")
    remote_store = ObjectStore(tmp_path / "remote")
    flaky = FlakyTransport(LoopbackTransport(RemoteServer(remote_store)),
                           fail_from=4, fail_count=1)
    remote = RemoteStore(flaky, retries=2)
    report = push(lake_a.store, remote, "u.exp")
    assert report.ref_updated
    assert flaky.calls > 4  # the drop actually happened and was ridden out
    assert_store_uncorrupted(remote_store)

    lake_b = Lake(tmp_path / "host_b", protect_main=False)
    pull(lake_b.store,
         RemoteStore(LoopbackTransport(RemoteServer(remote_store))), "u.exp")
    run_b = lake_b.run(pipe, branch="u.exp", author="u")
    assert run_b.outputs == run_a.outputs and run_b.cache_misses == 0


def test_resumed_push_skips_transferred_objects(tmp_path):
    """Resume is dedup-aware: the second attempt re-sends only what the
    crash cut off, not the whole closure."""
    lake_a, _pipe, _run = seeded_host(tmp_path, "host_a")
    remote_store = ObjectStore(tmp_path / "remote")
    # let a handful of object puts through, then cut the line
    flaky = FlakyTransport(LoopbackTransport(RemoteServer(remote_store)),
                           fail_from=9, fail_count=1000)
    remote = RemoteStore(flaky, retries=0)
    with pytest.raises(RemoteError):
        push(lake_a.store, remote, "u.exp")
    survived = len(list(remote_store.iter_objects()))
    assert survived > 0

    # control: the same push into an empty remote = the full closure cost
    control_store = ObjectStore(tmp_path / "control")
    control = push(lake_a.store, RemoteStore(LoopbackTransport(
        RemoteServer(control_store))), "u.exp")

    flaky.heal()
    report = push(lake_a.store, remote, "u.exp")
    assert report.ref_updated
    # resumed, not restarted: the second attempt re-sent only what the
    # crash cut off
    assert report.objects_sent == control.objects_sent - survived
    assert len(list(remote_store.iter_objects())) == \
        len(list(control_store.iter_objects()))
