"""Concurrent-writer fuzz for the transaction layer (ROADMAP item 4).

N threads hammer one branch with seeded-random schedules in the
``fault_schedule`` style: each thread mostly writes its own table
(disjoint) and sometimes a shared or contract-gated table (overlapping).
``SeededSchedule`` injects positionally deterministic delays around the
store ops, so a seed names a reproducible interleaving pattern.

Invariants checked after the storm:
  1. zero lost updates — every commit a writer observed as landed is on
     the branch's first-parent history;
  2. conflicts iff overlap — a disjoint-table commit never surfaces a
     caller-visible conflict (rebases stay internal);
  3. every contract-gated commit that landed satisfies the contract, and
     every violating attempt was rejected (no NaN snapshot anywhere in
     the gated table's landed history).
"""

import random
import threading

import numpy as np
import pytest

from fault_schedule import FaultyStore, SeededSchedule
from repro.core import (Catalog, ContractViolation, ObjectStore, TableIO,
                        TransactionConflict, rule)

#: the CI catalog-txn job runs exactly these (reproducible schedules);
#: change them only with a reason — a failure names its seed
PINNED_SEEDS = [1318, 40913]

N_WRITERS = 5
ROUNDS = 6


def _storm(tmp_path, seed):
    sched = SeededSchedule(seed, p_kill=0.0, p_delay=0.6, max_delay=0.002,
                           delay_points=("cas_ref", "get_ref", "put"))
    store = FaultyStore(ObjectStore(tmp_path / "lake"), sched)
    cat = Catalog(store, protect_main=False)
    io = TableIO(store)

    ok = io.write_snapshot({"v": np.ones(4, np.float32)})
    cat.commit("main", {"gated": ok, "shared": ok}, "seed tables")
    cat.add_contract("gated", [rule("no_nans"), rule("not_empty")])

    landed = []      # (thread, round, table, digest, snapshot)
    conflicts = []   # (thread, round, table, exc)
    rejections = []  # (thread, round) — contract rejections
    errors = []      # anything else: an invariant failure by itself
    lock = threading.Lock()

    def writer(i):
        rng = random.Random(f"{seed}:writer:{i}")
        for r in range(ROUNDS):
            roll = rng.random()
            if roll < 0.60:
                table = f"t{i}"                       # disjoint
                cols = {"v": np.full(4, float(r), np.float32)}
            elif roll < 0.85:
                table = "shared"                      # overlapping
                cols = {"v": np.full(4, float(i), np.float32)}
            else:
                table = "gated"                       # contract-gated
                cols = ({"v": np.array([1.0, np.nan], np.float32)}
                        if rng.random() < 0.5
                        else {"v": np.ones(4, np.float32)})
            try:
                snap = io.write_snapshot(cols)
                digest = cat.commit("main", {table: snap},
                                    f"w{i} r{r} {table}",
                                    author=f"w{i}")
                with lock:
                    landed.append((i, r, table, digest, snap))
            except ContractViolation:
                with lock:
                    rejections.append((i, r))
            except TransactionConflict as e:
                with lock:
                    conflicts.append((i, r, table, e))
            except Exception as e:  # noqa: BLE001 - surfaced as failure
                with lock:
                    errors.append((i, r, table, repr(e)))

    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(N_WRITERS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(120)
        assert not t.is_alive(), "writer wedged"
    return cat, io, landed, conflicts, rejections, errors, sched


@pytest.mark.parametrize("seed", PINNED_SEEDS)
def test_concurrent_writer_storm(tmp_path, seed):
    cat, io, landed, conflicts, rejections, errors, sched = _storm(
        tmp_path, seed)
    assert not errors, f"unexpected writer errors: {errors}\n{sched.to_json()}"

    # 1. zero lost updates: every landed commit is on main's history
    history = set(cat.log("main", first_parent=True))
    missing = [(i, r, t) for i, r, t, digest, _ in landed
               if digest not in history]
    assert not missing, f"lost updates: {missing}\n{sched.to_json()}"

    # 2. conflicts iff overlap: a disjoint-table commit never conflicts
    disjoint_conflicts = [c for c in conflicts if c[2].startswith("t")]
    assert not disjoint_conflicts, (
        f"disjoint writers conflicted: {disjoint_conflicts}\n"
        f"{sched.to_json()}")
    assert cat.txn_stats["conflicts"] == len(conflicts)
    assert cat.txn_stats["contract_rejections"] == len(rejections)
    # per-thread sequencing: the final t{i} is thread i's last landed write
    tables = cat.tables("main")
    for i in range(N_WRITERS):
        mine = [s for (w, r, t, d, s) in landed if t == f"t{i}"]
        if mine:
            assert tables[f"t{i}"] == mine[-1]

    # 3. contracts held under concurrency: no landed snapshot of the
    # gated table — anywhere in history — contains NaNs
    seen = set()
    for digest in cat.log("main", first_parent=False):
        snap = cat.tables(digest).get("gated")
        if snap is None or snap in seen:
            continue
        seen.add(snap)
        frame = io.read(snap)
        assert not np.isnan(frame["v"]).any(), (
            f"violating snapshot landed at {digest}\n{sched.to_json()}")
