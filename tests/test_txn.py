"""Optimistic table-level transactions (ROADMAP item 4).

The catalog's ref CAS protects the *ref*, not the *tables*: before this
suite's subject existed, two writers committing to different tables on the
same branch collided at the ref level and one had to retry from scratch.
The transaction layer rebases a commit whose declared read/write table set
is untouched by the concurrent head movement; only genuinely overlapping
snapshots raise.

Interleavings are scheduled with tests/fault_schedule.py (same instrument
as the gc-vs-push races), not hoped for.
"""

import threading

import numpy as np
import pytest

from fault_schedule import FaultyStore, Schedule
from repro.core import (CONTRACTS_TABLE, Catalog, ExpectationFailed,
                        ObjectStore, PermissionDenied, RefConflict,
                        ReproError, TableIO, TransactionConflict, no_nans,
                        publish)


def _snap(lake, value=0.0, n=4):
    return lake.io.write_snapshot({"v": np.full(n, value, np.float32)})


def _faulty_lake(tmp_path, schedule):
    """A second catalog handle over the same lake directory whose store ops
    fire ``schedule`` sync points (the handle under test)."""
    store = FaultyStore(ObjectStore(tmp_path / "lake"), schedule)
    return Catalog(store, protect_main=False), TableIO(store)


def _wait_any(gates, timeout=30.0):
    """Block until one of ``gates`` is reached; return it.  Lets a test
    freeze a thread at its ref-write sync point without hard-coding which
    primitive (``set_ref`` vs ``cas_ref``) the implementation uses."""
    waited = 0.0
    while waited < timeout:
        for g in gates:
            if g.reached.wait(0.02):
                return g
            waited += 0.02
    raise AssertionError("no gate reached")


# ----------------------------------------------------- failing-first bugfixes
def test_publish_pins_audited_commit(lake, monkeypatch):
    """wap.publish TOCTOU: a commit landing on the source branch between
    the audit and the merge must NOT be published to protected main.

    Pre-fix, the audit ran against ``report.commit`` but the merge re-read
    the src branch head — the rogue (unaudited, NaN-ridden) snapshot
    sailed through to main."""
    lake.catalog.create_branch("r.dev", "main", author="r")
    good = lake.io.write_snapshot({"x": np.ones(5, np.float32)})
    lake.catalog.commit("r.dev", {"training_data": good}, "good", author="r")
    bad = lake.io.write_snapshot(
        {"x": np.array([1.0, np.nan], np.float32)})

    real_merge = lake.catalog.merge

    def merge_after_rogue_commit(src_ref, dst_branch, **kw):
        # the interleaving: a concurrent writer lands unaudited data on the
        # source branch after the audit passed, before the merge runs
        lake.catalog.commit("r.dev", {"training_data": bad}, "rogue",
                            author="r")
        return real_merge(src_ref, dst_branch, **kw)

    monkeypatch.setattr(lake.catalog, "merge", merge_after_rogue_commit)
    publish(lake.catalog, lake.io, "r.dev", [no_nans("training_data")],
            author="r")
    assert lake.catalog.tables("main")["training_data"] == good  # not `bad`


def test_publish_reaudits_when_branch_moves_before_stamp(lake, tmp_path):
    """The other half of the publish window: the src branch moves between
    the audit and the audit-stamp commit.  The stamp is CAS-pinned to the
    audited commit, so the movement forces a re-audit — which now sees the
    NaNs and refuses to publish (pre-fix: a raw RefConflict leaked, or
    worse, the stamp landed on the moved head)."""
    lake.catalog.create_branch("r.dev", "main", author="r")
    good = lake.io.write_snapshot({"x": np.ones(5, np.float32)})
    lake.catalog.commit("r.dev", {"training_data": good}, "good", author="r")
    bad = lake.io.write_snapshot(
        {"x": np.array([1.0, np.nan], np.float32)})

    sched = Schedule()
    gates = [sched.gate("cas_ref:before"), sched.gate("set_ref:before")]
    cat, io = _faulty_lake(tmp_path, sched)

    result = {}

    def do_publish():
        try:
            result["head"] = publish(cat, io, "r.dev",
                                     [no_nans("training_data")], author="r")
        except Exception as e:  # noqa: BLE001 - the assertion inspects it
            result["error"] = e

    t = threading.Thread(target=do_publish)
    t.start()
    _wait_any(gates)  # publisher frozen at the audit-stamp ref write
    lake.catalog.commit("r.dev", {"training_data": bad}, "rogue", author="r")
    for g in gates:
        g.open()
    t.join(30)
    assert not t.is_alive()
    # the re-audit saw the rogue NaNs: publication refused, main untouched
    assert isinstance(result.get("error"), ExpectationFailed), result
    assert "training_data" not in lake.catalog.tables("main")


def test_create_branch_race_single_winner(lake, tmp_path):
    """Catalog.create_branch check-then-set race: two concurrent creates of
    the same name must produce exactly one winner, and the winner's ref
    must survive (pre-fix the loser silently overwrote it)."""
    c1 = lake.catalog.commit("main", {"t": _snap(lake, 1)}, "c1",
                             _wap_token=True)
    c2 = lake.catalog.commit("main", {"t": _snap(lake, 2)}, "c2",
                             _wap_token=True)

    sched = Schedule()
    gates = [sched.gate("cas_ref:before"), sched.gate("set_ref:before")]
    cat, _io = _faulty_lake(tmp_path, sched)

    result = {}

    def create_slow():
        try:
            result["digest"] = cat.create_branch("u.same", c1, author="u")
        except ReproError as e:
            result["error"] = e

    t = threading.Thread(target=create_slow)
    t.start()
    _wait_any(gates)  # slow creator frozen between its check and its write
    winner = lake.catalog.create_branch("u.same", c2, author="u")
    for g in gates:
        g.open()
    t.join(30)
    assert not t.is_alive()
    assert winner == c2
    assert "error" in result, "both concurrent create_branch calls succeeded"
    assert lake.catalog.head("u.same") == c2  # winner's ref intact


def test_merge_ff_rebases_over_disjoint_concurrent_commit(lake, tmp_path):
    """Catalog.merge fast-forward race: a concurrent commit touching a
    DIFFERENT table on dst mid-merge must not abort the merge (pre-fix a
    raw RefConflict leaked to the caller)."""
    sa = _snap(lake, 1)
    lake.catalog.create_branch("dev.x", "main", author="dev")
    lake.catalog.commit("dev.x", {"table_a": sa}, "a", author="dev")

    sched = Schedule()
    gate = sched.gate("cas_ref:before")
    cat, _io = _faulty_lake(tmp_path, sched)

    result = {}

    def do_merge():
        try:
            result["merged"] = cat.merge("dev.x", "main", _wap_token=True)
        except ReproError as e:
            result["error"] = e

    t = threading.Thread(target=do_merge)
    t.start()
    gate.wait_reached()  # merge frozen at its ref CAS
    sb = _snap(lake, 2)
    lake.catalog.commit("main", {"table_b": sb}, "concurrent", _wap_token=True)
    gate.open()
    t.join(30)
    assert not t.is_alive()
    assert "error" not in result, f"merge aborted: {result.get('error')!r}"
    tables = lake.catalog.tables("main")
    assert tables.get("table_a") == sa and tables.get("table_b") == sb

# --------------------------------------------- tentpole: rebase-on-CAS-miss
def test_commit_rebases_over_disjoint_concurrent_commit(lake):
    """A stale-base commit to table_a lands cleanly over a concurrent
    commit to table_b: the declared sets don't overlap, so the catalog
    rebases instead of conflicting."""
    lake.catalog.create_branch("u.b", "main", author="u")
    base = lake.catalog.head("u.b")
    sa, sb = _snap(lake, 1), _snap(lake, 2)
    lake.catalog.commit("u.b", {"table_b": sb}, "b", author="u")
    lake.catalog.commit("u.b", {"table_a": sa}, "a", author="u", base=base)
    tables = lake.catalog.tables("u.b")
    assert tables["table_a"] == sa and tables["table_b"] == sb


def test_concurrent_disjoint_writers_both_land(lake, tmp_path):
    """The CAS-miss path proper: writer A frozen at its ref CAS while
    writer B lands a different table.  Pre-fix A's caller saw a raw
    RefConflict; now the rebase absorbs it (and is counted)."""
    lake.catalog.create_branch("u.b", "main", author="u")
    sched = Schedule()
    gate = sched.gate("cas_ref:before")
    cat, io = _faulty_lake(tmp_path, sched)

    sa = lake.io.write_snapshot({"v": np.full(4, 1.0, np.float32)})
    result = {}

    def writer_a():
        try:
            result["digest"] = cat.commit("u.b", {"table_a": sa}, "a",
                                          author="u")
        except ReproError as e:
            result["error"] = e

    t = threading.Thread(target=writer_a)
    t.start()
    gate.wait_reached()  # A frozen between building its commit and the CAS
    sb = _snap(lake, 2)
    lake.catalog.commit("u.b", {"table_b": sb}, "b", author="u")
    gate.open()
    t.join(30)
    assert not t.is_alive()
    assert "error" not in result, f"disjoint writer aborted: {result}"
    tables = lake.catalog.tables("u.b")
    assert tables["table_a"] == sa and tables["table_b"] == sb
    assert cat.txn_stats["rebases"] == 1
    assert cat.txn_stats["conflicts"] == 0


def test_overlapping_writers_conflict(lake):
    """Two writers to the SAME table from the same base: the loser gets
    TransactionConflict naming exactly the overlapping table."""
    lake.catalog.create_branch("u.b", "main", author="u")
    lake.catalog.commit("u.b", {"t": _snap(lake, 0)}, "init", author="u")
    base = lake.catalog.head("u.b")
    lake.catalog.commit("u.b", {"t": _snap(lake, 1)}, "w1", author="u")
    with pytest.raises(TransactionConflict) as ei:
        lake.catalog.commit("u.b", {"t": _snap(lake, 2)}, "w2", author="u",
                            base=base)
    assert ei.value.tables == ["t"]
    assert not ei.value.exhausted and not ei.value.pinned
    # TransactionConflict IS a MergeConflict: existing handlers keep working
    from repro.core import MergeConflict
    assert isinstance(ei.value, MergeConflict)


def test_declared_read_set_conflicts(lake):
    """A commit whose READ table moved since its base conflicts too —
    writing derived data computed from stale inputs is a lost update in
    disguise."""
    lake.catalog.create_branch("u.b", "main", author="u")
    lake.catalog.commit("u.b", {"src": _snap(lake, 0)}, "init", author="u")
    base = lake.catalog.head("u.b")
    lake.catalog.commit("u.b", {"src": _snap(lake, 9)}, "mutate", author="u")
    with pytest.raises(TransactionConflict) as ei:
        lake.catalog.commit("u.b", {"derived": _snap(lake, 1)}, "derive",
                            author="u", base=base, read_tables=["src"])
    assert ei.value.tables == ["src"]


def test_pinned_commit_refuses_any_movement(lake):
    """expected_head= pins the commit: exactly one attempt, movement of
    ANY kind (even a disjoint table) raises with pinned=True."""
    lake.catalog.create_branch("u.b", "main", author="u")
    pinned_to = lake.catalog.commit("u.b", {"t": _snap(lake, 0)}, "init",
                                    author="u")
    lake.catalog.commit("u.b", {"other": _snap(lake, 1)}, "move", author="u")
    with pytest.raises(TransactionConflict) as ei:
        lake.catalog.commit("u.b", {"t": _snap(lake, 2)}, "stale",
                            author="u", expected_head=pinned_to)
    assert ei.value.pinned and ei.value.attempts == 1


def test_rebase_attempts_are_bounded(lake, monkeypatch):
    """Sustained contention exhausts the bounded rebase loop loudly."""
    lake.catalog.create_branch("u.b", "main", author="u")

    def always_contended(name, expected, new):
        raise RefConflict(f"contended: {name}")

    monkeypatch.setattr(lake.catalog.store, "cas_ref", always_contended)
    with pytest.raises(TransactionConflict) as ei:
        lake.catalog.commit("u.b", {"t": _snap(lake, 1)}, "w", author="u",
                            max_attempts=3)
    assert ei.value.exhausted and ei.value.attempts == 3
    assert ei.value.tables == []  # nothing semantically overlapped


def test_reserved_contracts_table_rejected(lake):
    """Only add_contract/drop_contract may move the contracts entry."""
    with pytest.raises(PermissionDenied):
        lake.catalog.commit("main", {CONTRACTS_TABLE: "deadbeef"}, "sneak",
                            _wap_token=True)


# ------------------------------------------------- tentpole: Transaction API
def test_transaction_read_write_rebases(seeded_lake):
    lake = seeded_lake
    lake.catalog.create_branch("u.b", "main", author="u")
    txn = lake.transaction("u.b", author="u")
    cols = txn.read("source_table")
    assert txn.reads == {"source_table"}
    txn.write("derived", {"x": cols["c1"] * 2.0})
    # a concurrent disjoint commit lands mid-transaction
    other = lake.io.write_snapshot({"v": np.ones(3, np.float32)})
    lake.catalog.commit("u.b", {"unrelated": other}, "concurrent",
                        author="u")
    txn.commit("derived from source")
    tables = lake.catalog.tables("u.b")
    assert "derived" in tables and tables["unrelated"] == other
    np.testing.assert_allclose(
        lake.read_table("u.b", "derived")["x"], cols["c1"] * 2.0)


def test_transaction_conflict_on_read_table_movement(seeded_lake):
    lake = seeded_lake
    lake.catalog.create_branch("u.b", "main", author="u")
    txn = lake.transaction("u.b", author="u")
    cols = txn.read("source_table")
    txn.write("derived", {"x": cols["c1"] * 2.0})
    # the INPUT moves under the transaction: derived would be stale
    moved = lake.io.write_snapshot({"c1": np.zeros(3, np.float32)})
    lake.catalog.commit("u.b", {"source_table": moved}, "mutate input",
                        author="u")
    with pytest.raises(TransactionConflict) as ei:
        txn.commit("derived from stale source")
    assert ei.value.tables == ["source_table"]


def test_transaction_io_handle_records_reads(seeded_lake):
    """Read-set capture at the TableIO layer: code holding only the
    transaction's io handle still contributes to the declared set."""
    lake = seeded_lake
    lake.catalog.create_branch("u.b", "main", author="u")
    txn = lake.transaction("u.b", author="u")
    snap = txn.snapshot_of("source_table")
    txn.reads.clear()  # snapshot_of recorded it; prove io.read does too
    txn.io.read(snap)
    assert txn.reads == {"source_table"}
