"""Provably-lossless compaction: streaming micro-batch ingest produces
fragment-heavy snapshots; ``compact_snapshot`` rewrites them into
target-sized files with a runtime logical-digest proof, reuses right-sized
files verbatim, and ``compact_table`` loses every race to ingestion."""

import msgpack
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from repro.core import (CompactionError, Lake, ObjectStore, TableIO,
                        TransactionConflict, compact_snapshot, compact_table)
from repro.core.errors import SchemaError
from repro.core.gc import collect


@pytest.fixture()
def store(tmp_path):
    return ObjectStore(tmp_path / "store")


@pytest.fixture()
def io(store):
    return TableIO(store, target_rows_per_file=16)


def _stream(io, n_batches, batch_rows, *, start=0):
    """Ingest ``n_batches`` tiny batches — one manifest + fragment each."""
    vals = iter(range(start, start + n_batches * batch_rows))

    def batches():
        for _ in range(n_batches):
            a = np.fromiter((next(vals) for _ in range(batch_rows)),
                            dtype=np.int64, count=batch_rows)
            yield {"a": a, "b": (a * 2).astype(np.float32)}

    return io.append_stream(None, batches())


# --------------------------------------------------------- append_stream
def test_append_stream_lands_one_manifest_per_batch(io):
    head = _stream(io, 10, 3)
    snap = io.load_snapshot(head)
    assert snap.nfiles == 10 and snap.nrows == 30
    np.testing.assert_array_equal(io.read(head)["a"], np.arange(30))


def test_append_stream_chains_onto_parent(io):
    head = _stream(io, 4, 3)
    head = io.append_stream(head, iter([{"a": np.arange(12, 15,
                                                        dtype=np.int64),
                                         "b": np.zeros(3,
                                                       dtype=np.float32)}]))
    np.testing.assert_array_equal(io.read(head)["a"], np.arange(15))


def test_append_stream_rejects_empty(io):
    with pytest.raises(SchemaError):
        io.append_stream(None, iter([]))


# ------------------------------------------------------------- compaction
def test_compact_rewrites_fragments_and_proves_digest(io):
    head = _stream(io, 20, 3)  # 20 fragments of 3 rows, target 16
    before = io.logical_digest(head)
    report = compact_snapshot(io, head)
    assert report.files_before == 20
    assert report.files_after == 4  # 60 rows / 16 = 3 full + 1 tail
    assert report.rows == 60
    assert report.logical_digest == before == io.logical_digest(
        report.new_snapshot)
    np.testing.assert_array_equal(io.read(report.new_snapshot)["a"],
                                  np.arange(60))


def test_compact_reuses_right_sized_files_verbatim(io, store):
    big = io.write_snapshot({"a": np.arange(32, dtype=np.int64),
                             "b": np.zeros(32, dtype=np.float32)})
    head = io.append_stream(big, iter(
        [{"a": np.arange(32 + i * 2, 34 + i * 2, dtype=np.int64),
          "b": np.zeros(2, dtype=np.float32)} for i in range(8)]))
    old_entries = [e for m in io.load_snapshot(head).manifests
                   for e in io.manifest_entries(m)]
    report = compact_snapshot(io, head)
    new_entries = [e for m in io.load_snapshot(report.new_snapshot).manifests
                   for e in io.manifest_entries(m)]
    # the two 16-row files from the bulk write carry over by digest —
    # zero bytes read or written for them
    assert new_entries[0].digest == old_entries[0].digest
    assert new_entries[1].digest == old_entries[1].digest
    assert report.bytes_read == sum(e.nbytes for e in old_entries[2:])
    # write amplification bounded by the fragment tail, not the table
    assert report.bytes_written <= report.bytes_read


def test_compact_keep_history_lineage(io):
    head = _stream(io, 6, 3)
    kept = compact_snapshot(io, head)
    snap = io.load_snapshot(kept.new_snapshot)
    assert snap.parent == head and snap.op == "compact"
    assert io.history(kept.new_snapshot)[:2] == [kept.new_snapshot, head]
    fresh = compact_snapshot(io, head, keep_history=False)
    assert io.load_snapshot(fresh.new_snapshot).parent is None
    assert io.history(fresh.new_snapshot) == [fresh.new_snapshot]


def test_compact_refuses_to_publish_on_digest_mismatch(io, monkeypatch):
    head = _stream(io, 6, 3)
    real = io.logical_digest
    seen = []

    def corrupting(digest):
        seen.append(digest)
        out = real(digest)
        return out if len(seen) == 1 else "0" * 64  # corrupt the after-hash

    monkeypatch.setattr(io, "logical_digest", corrupting)
    with pytest.raises(CompactionError):
        compact_snapshot(io, head)


def test_compact_legacy_v0_snapshot(io, store):
    """Pre-hierarchy snapshots compact too — the rewrite IS the
    migration, digest-proved like any other."""
    from repro.core import tensorfile

    entries = []
    for start in range(0, 30, 3):
        a = np.arange(start, start + 3, dtype=np.int64)
        blob, meta = tensorfile.encode({"a": a})
        entries.append([store.put(blob), meta["nrows"], meta["nbytes"],
                        meta["stats"]])
        schema = meta["schema"]
    legacy = store.put(msgpack.packb(
        {"schema": schema, "manifest": entries, "parent": None,
         "op": "overwrite", "seq": 0}, use_bin_type=True))
    report = compact_snapshot(io, legacy)
    assert report.files_before == 10 and report.files_after == 2
    assert report.logical_digest == io.logical_digest(legacy)
    np.testing.assert_array_equal(io.read(report.new_snapshot)["a"],
                                  np.arange(30))


_LAYOUT = st.lists(st.integers(min_value=1, max_value=9), min_size=1,
                   max_size=8)


@settings(max_examples=30, deadline=None)
@given(fragment_rows=_LAYOUT,
       target=st.integers(min_value=1, max_value=24))
def test_compaction_lossless_for_arbitrary_layouts(tmp_path, fragment_rows,
                                                   target):
    """THE compaction property: for random fragment layouts and target
    sizes, the compacted snapshot holds byte-identical logical contents
    and every output file except the tail is exactly ``target`` rows."""
    key = abs(hash((tuple(fragment_rows), target))) % (1 << 30)
    io = TableIO(ObjectStore(tmp_path / f"s{key}"), target_rows_per_file=16)
    n = 0

    def batches():
        nonlocal n
        for rows in fragment_rows:
            a = np.arange(n, n + rows, dtype=np.int64)
            n += rows
            yield {"a": a}

    head = io.append_stream(None, batches())
    report = compact_snapshot(io, head, target_rows_per_file=target)
    assert report.logical_digest == io.logical_digest(head)
    total = sum(fragment_rows)
    sizes = [e.nrows
             for m in io.load_snapshot(report.new_snapshot).manifests
             for e in io.manifest_entries(m)]
    assert sum(sizes) == total == report.rows
    # re-chunked files come out at exactly ``target``; already-big files
    # are reused verbatim (>= target) — either way no small fragment
    # survives except possibly one tail
    assert all(s >= target for s in sizes[:-1])
    np.testing.assert_array_equal(io.read(report.new_snapshot)["a"],
                                  np.arange(total))


# ---------------------------------------------------------- compact_table
def test_compact_table_through_transaction(tmp_path):
    lake = Lake(tmp_path / "lake", protect_main=False)
    io = TableIO(lake.store, target_rows_per_file=16)
    head = io.append_stream(None, iter(
        [{"v": np.arange(i * 4, i * 4 + 4, dtype=np.int64)}
         for i in range(12)]))
    lake.catalog.commit("main", {"events": head}, "ingest")
    report = compact_table(lake.catalog, "events",
                           target_rows_per_file=16)
    assert report.table == "events"
    assert report.files_before == 12 and report.files_after == 3
    out = lake.read_table("main", "events")["v"]
    np.testing.assert_array_equal(out, np.arange(48))
    # the branch head moved via a real commit
    from repro.core.catalog import Commit

    head_commit = Commit.from_obj(msgpack.unpackb(
        lake.store.get(lake.catalog.head("main")), raw=False))
    assert head_commit.message.startswith("compact events")


def test_compact_table_retries_when_ingestion_wins(tmp_path):
    """append/compact is a genuine conflict (NOT an append/append merge);
    the compactor must yield and retry from the new head."""
    lake = Lake(tmp_path / "lake", protect_main=False)
    io = TableIO(lake.store, target_rows_per_file=16)
    head = io.append_stream(None, iter(
        [{"v": np.arange(i * 4, i * 4 + 4, dtype=np.int64)}
         for i in range(8)]))
    lake.catalog.commit("main", {"events": head}, "ingest")

    real_commit = lake.catalog.commit
    raced = []

    def racing_commit(branch, updates, message, **kw):
        # an ingest batch sneaks in ahead of the compactor's first commit
        if message.startswith("compact") and not raced:
            raced.append(True)
            txn = lake.catalog.transaction("main", author="ingest")
            txn.write("events", {"v": np.arange(900, 904, dtype=np.int64)},
                      append=True)
            txn.commit("late batch")
        return real_commit(branch, updates, message, **kw)

    lake.catalog.commit = racing_commit
    try:
        report = compact_table(lake.catalog, "events",
                               target_rows_per_file=16)
    finally:
        del lake.catalog.commit
    assert raced == [True]
    out = lake.read_table("main", "events")["v"]
    assert out.shape[0] == 36  # the late batch survived compaction
    assert 900 in out and 903 in out
    assert report.rows == 36  # retried against the post-ingest head


def test_compact_table_gives_up_after_max_attempts(tmp_path):
    lake = Lake(tmp_path / "lake", protect_main=False)
    io = TableIO(lake.store, target_rows_per_file=16)
    head = io.append_stream(None, iter(
        [{"v": np.arange(i * 2, i * 2 + 2, dtype=np.int64)}
         for i in range(4)]))
    lake.catalog.commit("main", {"events": head}, "ingest")
    real_commit = lake.catalog.commit
    n = [0]

    def always_racing(branch, updates, message, **kw):
        if message.startswith("compact"):
            n[0] += 1
            txn = lake.catalog.transaction("main", author="ingest")
            txn.write("events",
                      {"v": np.arange(n[0] * 10, n[0] * 10 + 2,
                                      dtype=np.int64)}, append=True)
            txn.commit(f"batch {n[0]}")
        return real_commit(branch, updates, message, **kw)

    lake.catalog.commit = always_racing
    try:
        with pytest.raises(TransactionConflict):
            compact_table(lake.catalog, "events", target_rows_per_file=16,
                          max_attempts=3)
    finally:
        del lake.catalog.commit
    assert n[0] == 3  # one losing race per attempt, then gave up


def test_gc_collects_compacted_away_fragments(tmp_path):
    """Staging pattern (compact BEFORE publishing, ``keep_history=False``):
    only the compacted snapshot enters the catalog, so the raw ingest
    fragments are never reachable from any ref and GC reclaims them —
    while everything the published snapshot needs survives."""
    lake = Lake(tmp_path / "lake", protect_main=False)
    io = TableIO(lake.store, target_rows_per_file=16)
    head = io.append_stream(None, iter(
        [{"v": np.arange(i * 4, i * 4 + 4, dtype=np.int64)}
         for i in range(8)]))  # staged only — no commit yet
    old_fragments = [e.digest for m in io.load_snapshot(head).manifests
                     for e in io.manifest_entries(m)]
    report = compact_snapshot(io, head, keep_history=False)
    lake.catalog.commit("main", {"events": report.new_snapshot},
                        "publish compacted")

    gc_report = collect(lake.store, prune_age=0)
    assert gc_report.swept > 0
    for digest in old_fragments:
        assert not lake.store.has(digest)  # fragments actually reclaimed
    assert not lake.store.has(head)  # and the staging snapshot chain
    np.testing.assert_array_equal(lake.read_table("main", "events")["v"],
                                  np.arange(32))
