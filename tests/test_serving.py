"""Serving engine + continuous batcher: commit-pinned weights, batched
generation, determinism, and the scheduling contracts (head-of-line fix,
oracle equivalence under any arrival schedule)."""

import functools

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dep — fall back to the seeded mini-sampler
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from repro.checkpoint import save
from repro.configs import smoke_config
from repro.core import Lake
from repro.models import init_params
from repro.serving import (BatchedServer, ContinuousBatcher,
                           FixedBatchedServer, ServeEngine)

KEY = jax.random.PRNGKey(0)


@pytest.fixture()
def engine_and_lake(lake):
    cfg = smoke_config("paper-demo")
    params = init_params(cfg, KEY)
    lake.catalog.create_branch("t.run", "main", author="t")
    commit = save(lake, "t.run", step=1, params=params, author="t")
    engine = ServeEngine.from_catalog(lake, commit, cfg, max_len=64,
                                      batch_size=2)
    return engine, lake, cfg, commit


def test_generate_shapes(engine_and_lake):
    engine, _, cfg, commit = engine_and_lake
    prompts = np.random.default_rng(0).integers(
        3, cfg.vocab_size, (2, 10)).astype(np.int32)
    res = engine.generate(prompts, n_tokens=6)
    assert res.tokens.shape == (2, 6)
    assert res.model_commit == commit
    assert (res.tokens >= 0).all() and (res.tokens < cfg.vocab_size).all()


def test_same_commit_same_generation(engine_and_lake):
    engine, lake, cfg, commit = engine_and_lake
    engine2 = ServeEngine.from_catalog(lake, commit, cfg, max_len=64,
                                       batch_size=2)
    p = np.random.default_rng(1).integers(3, cfg.vocab_size,
                                          (2, 8)).astype(np.int32)
    g1 = engine.generate(p, n_tokens=5).tokens
    g2 = engine2.generate(p, n_tokens=5).tokens
    np.testing.assert_array_equal(g1, g2)


def test_batched_server_completes_all(engine_and_lake):
    engine, *_ = engine_and_lake
    server = BatchedServer(engine)
    rng = np.random.default_rng(2)
    for rid in range(5):
        server.submit(rid, rng.integers(3, 100, 8).astype(np.int32), 4)
    done = 0
    while server.pending:
        done += server.step()
    assert done == 5
    assert set(server.completed) == set(range(5))
    for res in server.completed.values():
        assert res.tokens.shape[1] == 4


def test_decode_equals_teacher_forcing(engine_and_lake):
    """Greedy generation must equal argmax of the full forward run on the
    same (prompt + generated) sequence — the KV cache is exact."""
    engine, _, cfg, _ = engine_and_lake
    from repro.models import forward

    p = np.random.default_rng(3).integers(3, cfg.vocab_size,
                                          (2, 12)).astype(np.int32)
    gen = engine.generate(p, n_tokens=4).tokens
    seq = np.concatenate([p, gen], axis=1)
    logits, _, _ = forward(cfg, engine.params, jax.numpy.asarray(seq),
                           remat=False)
    for t in range(4):
        expect = np.asarray(jax.numpy.argmax(logits[:, 11 + t, :], axis=-1))
        np.testing.assert_array_equal(gen[:, t], expect)


# -------------------------------------------------- head-of-line regression
def test_short_request_not_blocked_by_long(engine_and_lake):
    """REGRESSION (head-of-line blocking): the old fixed-bucket
    ``BatchedServer`` decoded every batch for ``max(n_tokens)`` steps and
    admitted nothing new until the whole bucket drained, so a short
    request submitted after a long one waited out the long one's entire
    generation.  ``BatchedServer`` is now the continuous batcher: the
    short request must complete while the long one is still in flight."""
    engine, _, cfg, _ = engine_and_lake
    prompt = np.random.default_rng(4).integers(
        3, cfg.vocab_size, 6).astype(np.int32)
    server = BatchedServer(engine)
    server.submit(0, prompt, 30)          # the long head
    server.step()                         # 0 is now mid-generation
    server.submit(1, prompt, 2)           # short, submitted later
    steps = 0
    while 1 not in server.completed:
        server.step()
        steps += 1
        assert steps < 30, "short request starved behind the long one"
    assert 0 not in server.completed, \
        "head-of-line blocking: the short request waited for the long one"
    while server.pending:
        server.step()
    assert server.completed[0].tokens.shape[1] == 30


def test_fixed_baseline_has_head_of_line_blocking(engine_and_lake):
    """The control: the preserved fixed baseline DOES block — both land in
    one bucket and complete together, which is why it is only the
    benchmark baseline (see FixedBatchedServer's docstring)."""
    engine, _, cfg, _ = engine_and_lake
    prompt = np.random.default_rng(5).integers(
        3, cfg.vocab_size, 6).astype(np.int32)
    server = FixedBatchedServer(engine)
    server.submit(0, prompt, 30)
    server.submit(1, prompt, 2)
    done = server.step()                  # one bucket serves both, together
    assert done == 2
    assert set(server.completed) == {0, 1}


# ------------------------------------------- oracle-equivalence property
@functools.lru_cache(maxsize=1)
def _prop_engines():
    """Shared engines for the property test (jits are cached per config,
    so the examples pay compile cost once)."""
    cfg = smoke_config("paper-demo")
    params = init_params(cfg, KEY)
    batched = ServeEngine(cfg, params, max_len=48, batch_size=2,
                          model_commit="e" * 64)
    solo = ServeEngine(cfg, params, max_len=48, batch_size=1,
                       model_commit="e" * 64)
    return cfg, batched, solo


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(min_value=1, max_value=8),
                          st.integers(min_value=1, max_value=6)),
                min_size=1, max_size=6),
       st.integers(min_value=0, max_value=6))
def test_continuous_equals_sequential_any_schedule(spec, split):
    """PROPERTY: for ANY mix of prompt lengths / generation lengths and
    ANY arrival split (some requests submitted up front, the rest injected
    after generation has started), every continuously-batched token stream
    is bit-identical to generating that request alone, sequentially."""
    cfg, batched, solo = _prop_engines()
    prompts = [np.random.default_rng(1000 + 13 * i + plen).integers(
        3, cfg.vocab_size, plen).astype(np.int32)
        for i, (plen, _n) in enumerate(spec)]
    server = ContinuousBatcher(batched, slots=2)
    k = split % (len(spec) + 1)
    for i in range(k):
        server.submit(i, prompts[i], spec[i][1])
    server.step()                 # first wave is mid-generation...
    for i in range(k, len(spec)):
        server.submit(i, prompts[i], spec[i][1])   # ...when these arrive
    while server.pending:
        server.step()
    for i, (_plen, n) in enumerate(spec):
        oracle = solo.generate(prompts[i][None], n_tokens=n).tokens[0]
        np.testing.assert_array_equal(
            server.completed[i].tokens[0], oracle,
            err_msg=f"request {i} (spec {spec}, split {k}) diverged from "
                    "sequential generation")
