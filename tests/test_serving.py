"""Serving engine: commit-pinned weights, batched generation, determinism."""

import jax
import numpy as np
import pytest

from repro.checkpoint import save
from repro.configs import smoke_config
from repro.core import Lake
from repro.models import init_params
from repro.serving import BatchedServer, ServeEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture()
def engine_and_lake(lake):
    cfg = smoke_config("paper-demo")
    params = init_params(cfg, KEY)
    lake.catalog.create_branch("t.run", "main", author="t")
    commit = save(lake, "t.run", step=1, params=params, author="t")
    engine = ServeEngine.from_catalog(lake, commit, cfg, max_len=64,
                                      batch_size=2)
    return engine, lake, cfg, commit


def test_generate_shapes(engine_and_lake):
    engine, _, cfg, commit = engine_and_lake
    prompts = np.random.default_rng(0).integers(
        3, cfg.vocab_size, (2, 10)).astype(np.int32)
    res = engine.generate(prompts, n_tokens=6)
    assert res.tokens.shape == (2, 6)
    assert res.model_commit == commit
    assert (res.tokens >= 0).all() and (res.tokens < cfg.vocab_size).all()


def test_same_commit_same_generation(engine_and_lake):
    engine, lake, cfg, commit = engine_and_lake
    engine2 = ServeEngine.from_catalog(lake, commit, cfg, max_len=64,
                                       batch_size=2)
    p = np.random.default_rng(1).integers(3, cfg.vocab_size,
                                          (2, 8)).astype(np.int32)
    g1 = engine.generate(p, n_tokens=5).tokens
    g2 = engine2.generate(p, n_tokens=5).tokens
    np.testing.assert_array_equal(g1, g2)


def test_batched_server_completes_all(engine_and_lake):
    engine, *_ = engine_and_lake
    server = BatchedServer(engine)
    rng = np.random.default_rng(2)
    for rid in range(5):
        server.submit(rid, rng.integers(3, 100, 8).astype(np.int32), 4)
    done = 0
    while server.queue:
        done += server.step()
    assert set(server.completed) == set(range(5))
    for res in server.completed.values():
        assert res.tokens.shape[1] == 4


def test_decode_equals_teacher_forcing(engine_and_lake):
    """Greedy generation must equal argmax of the full forward run on the
    same (prompt + generated) sequence — the KV cache is exact."""
    engine, _, cfg, _ = engine_and_lake
    from repro.models import forward

    p = np.random.default_rng(3).integers(3, cfg.vocab_size,
                                          (2, 12)).astype(np.int32)
    gen = engine.generate(p, n_tokens=4).tokens
    seq = np.concatenate([p, gen], axis=1)
    logits, _, _ = forward(cfg, engine.params, jax.numpy.asarray(seq),
                           remat=False)
    for t in range(4):
        expect = np.asarray(jax.numpy.argmax(logits[:, 11 + t, :], axis=-1))
        np.testing.assert_array_equal(gen[:, t], expect)
