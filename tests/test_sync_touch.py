"""Touch-on-dedup and restart-safe server-side GC marks.

A long push's already-present (deduped) objects used to keep their old
mtimes while the rest of the closure uploaded — old enough to fall past
the ``--prune-age`` grace window and be swept mid-push.  The sync engine
now refreshes their clocks (``touch_many``) as it dedups.  Separately,
``gc_mark`` used to keep its live-set in server process memory, so a
server restart between mark and sweep silently lost the mark; marks now
persist in the store keyspace (``gc/mark/<generation>`` refs) and any
server instance over the same store can consume them.
"""

import os

import numpy as np
import pytest

from repro.core import (Lake, LoopbackTransport, ObjectStore, RemoteError,
                        RemoteServer, RemoteStore, TieredStore, push)
from repro.core.errors import RefNotFound


def _make_lake(tmp_path, name="lake"):
    lake = Lake(tmp_path / name, protect_main=False)
    lake.write_table("main", "t",
                     {"v": np.arange(32, dtype=np.int64)})
    return lake


def _age_all(store, seconds=10_000):
    for digest in store.iter_objects():
        p = store._path(digest)
        os.utime(p, (p.stat().st_atime, p.stat().st_mtime - seconds))


def _mtimes(store):
    return {d: store._path(d).stat().st_mtime for d in store.iter_objects()}


# ----------------------------------------------------------- touch-on-dedup
def test_object_store_touch_many(tmp_path):
    store = ObjectStore(tmp_path / "s")
    a = store.put(b"one")
    b = store.put(b"two")
    _age_all(store)
    old = _mtimes(store)
    touched = store.touch_many([a, b, "0" * 64])  # one missing digest
    assert touched == 2
    now = _mtimes(store)
    assert now[a] > old[a] and now[b] > old[b]


def test_push_touches_deduped_remote_objects(tmp_path):
    """The regression: a delta push must refresh the clocks of the
    closure objects the remote already had, or a concurrent prune-age
    sweep could collect them before the final ref flip."""
    lake = _make_lake(tmp_path)
    remote_store = ObjectStore(tmp_path / "remote")
    remote = RemoteStore(LoopbackTransport(RemoteServer(remote_store)))
    push(lake.store, remote, "main")

    _age_all(remote_store)  # objects now look ancient to a sweep
    old = _mtimes(remote_store)
    snap = lake.io.append(lake.catalog.snapshot_of("main", "t"),
                          {"v": np.arange(100, 104, dtype=np.int64)})
    lake.catalog.commit("main", {"t": snap}, "delta")
    report = push(lake.store, remote, "main")

    assert report.objects_touched > 0
    now = _mtimes(remote_store)
    refreshed = [d for d in old if now[d] > old[d]]
    # every deduped object the delta closure re-visited got a fresh clock
    assert report.objects_touched == len(refreshed)
    # in particular the parent snapshot's data files are young again
    for d in refreshed:
        assert now[d] - old[d] > 9_000


def test_touch_count_survives_server_without_the_op(tmp_path, monkeypatch):
    """A server predating ``touch_objects`` answers unknown-op; the push
    must still succeed with 0 touched (the generation token's retry path
    covers it), never crash."""
    monkeypatch.delattr(RemoteServer, "_op_touch_objects")
    lake = _make_lake(tmp_path)
    remote = RemoteStore(LoopbackTransport(RemoteServer(
        ObjectStore(tmp_path / "remote"))))
    push(lake.store, remote, "main")
    snap = lake.io.append(lake.catalog.snapshot_of("main", "t"),
                          {"v": np.arange(100, 104, dtype=np.int64)})
    lake.catalog.commit("main", {"t": snap}, "delta")
    report = push(lake.store, remote, "main")
    assert report.objects_sent > 0
    assert report.objects_touched == 0
    assert remote.touch_many(["0" * 64]) == 0  # degrades quietly


def test_tiered_store_touches_local_tier_only(tmp_path):
    local = ObjectStore(tmp_path / "local")
    remote_store = ObjectStore(tmp_path / "remote")
    remote = RemoteStore(LoopbackTransport(RemoteServer(remote_store)))
    tiered = TieredStore(local, remote)
    digest = tiered.put(b"payload")  # lands locally
    remote_store.put(b"payload")  # and (separately) on the remote
    _age_all(local)
    _age_all(remote_store)
    old_remote = _mtimes(remote_store)
    assert tiered.touch_many([digest]) == 1
    # the shared remote's clocks are never mutated from a tier mount
    assert _mtimes(remote_store) == old_remote
    assert _mtimes(local)[digest] > old_remote[digest]


# ------------------------------------------------- restart-safe gc marks
def _remote_pair(tmp_path):
    """A pushed lake + a remote whose server we can 'restart' at will."""
    lake = _make_lake(tmp_path)
    remote_root = tmp_path / "remote"
    remote = RemoteStore(LoopbackTransport(RemoteServer(
        ObjectStore(remote_root))), allow_delete=True)
    push(lake.store, remote, "main")
    return lake, remote_root, remote


def _fresh_server(remote_root):
    return RemoteStore(LoopbackTransport(RemoteServer(
        ObjectStore(remote_root))), allow_delete=True)


def test_gc_mark_is_persisted_in_store_keyspace(tmp_path):
    _lake, remote_root, remote = _remote_pair(tmp_path)
    generation, live = remote.gc_mark()
    assert live > 0
    store = ObjectStore(remote_root)
    mark_digest = store.get_ref(f"gc/mark/{generation}")
    assert store.has(mark_digest)  # the live set is a real blob


def test_sweep_works_across_server_restart(tmp_path):
    """THE restart regression: mark on one server instance, sweep on a
    fresh instance over the same store — previously the in-memory mark
    vanished and the sweep failed (or worse, ran markless)."""
    lake, remote_root, remote = _remote_pair(tmp_path)
    # make some remote garbage: an object nothing references
    orphan = ObjectStore(remote_root).put(b"orphaned bytes")
    generation, _live = remote.gc_mark()

    restarted = _fresh_server(remote_root)  # simulated restart
    swept, freed, _young = restarted.gc_sweep(generation)
    assert swept >= 1 and freed > 0
    store = ObjectStore(remote_root)
    assert not store.has(orphan)
    # the consumed mark is gone: ref deleted, blob reclaimed
    with pytest.raises(RefNotFound):
        store.get_ref(f"gc/mark/{generation}")
    # and everything the branch needs survived
    lake2 = Lake(tmp_path / "lake2", protect_main=False)
    from repro.core import pull

    pull(lake2.store, restarted, "main")
    np.testing.assert_array_equal(lake2.read_table("main", "t")["v"],
                                  np.arange(32))


def test_sweep_of_unknown_generation_errors(tmp_path):
    _lake, _root, remote = _remote_pair(tmp_path)
    with pytest.raises(RemoteError, match="unknown gc generation"):
        remote.gc_sweep("999999")


def test_mark_is_consumed_exactly_once(tmp_path):
    _lake, remote_root, remote = _remote_pair(tmp_path)
    generation, _ = remote.gc_mark()
    remote.gc_sweep(generation)
    with pytest.raises(RemoteError, match="unknown gc generation"):
        _fresh_server(remote_root).gc_sweep(generation)


def test_dry_run_mark_writes_nothing(tmp_path):
    """A dry run must not mutate the store — its mark stays in process
    memory (and therefore does NOT survive a restart, by design)."""
    _lake, remote_root, remote = _remote_pair(tmp_path)
    store = ObjectStore(remote_root)
    objects_before = set(store.iter_objects())
    refs_before = set(store.iter_refs())
    generation, _ = remote.gc_mark(dry_run=True)
    assert set(store.iter_objects()) == objects_before
    assert set(store.iter_refs()) == refs_before
    # the dry token works against the SAME instance...
    swept, _freed, _young = remote.gc_sweep(generation, dry_run=True)
    assert swept >= 0
    # ...but a restarted server never heard of it
    with pytest.raises(RemoteError, match="unknown gc generation"):
        _fresh_server(remote_root).gc_sweep(generation, dry_run=True)


def test_abandoned_marks_are_pruned_to_newest_four(tmp_path):
    """Crashed GC clients must not leak unbounded live-set blobs: only
    the newest ``_GC_MARK_KEEP`` pending marks survive a new mark."""
    _lake, remote_root, remote = _remote_pair(tmp_path)
    tokens = [remote.gc_mark()[0] for _ in range(6)]
    store = ObjectStore(remote_root)
    pending = sorted(
        (ref[len("gc/mark/"):] for ref in store.iter_refs("gc/mark/")),
        key=int)
    assert len(pending) == RemoteServer._GC_MARK_KEEP
    assert pending == sorted(tokens, key=int)[-RemoteServer._GC_MARK_KEEP:]
    # the newest mark still sweeps fine after the pruning
    swept, _freed, _young = remote.gc_sweep(tokens[-1])
    assert swept >= 0


def test_concurrent_sweep_expiry_reports_clearly(tmp_path):
    """If another sweep collected a mark blob out from under a pending
    ref, the sweep reports an actionable error instead of crashing."""
    _lake, remote_root, remote = _remote_pair(tmp_path)
    generation, _ = remote.gc_mark()
    store = ObjectStore(remote_root)
    store.delete_object(store.get_ref(f"gc/mark/{generation}"))
    with pytest.raises(RemoteError, match="expired"):
        _fresh_server(remote_root).gc_sweep(generation)
    with pytest.raises(RefNotFound):  # the dangling ref was cleaned up
        store.get_ref(f"gc/mark/{generation}")
