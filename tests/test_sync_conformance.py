"""Pytest wrapper over the sync conformance harness + targeted regressions.

The matrix (``sync_conformance.run_check``) pins the contract over
backend × transport × concurrency; the named tests below pin the specific
claims this layer makes:

* seeded thread-fuzz: random interleavings of two concurrent pushes of
  overlapping closures never corrupt refs or lose blobs;
* ``SyncReport`` accounting is exact when the remote already holds part of
  the closure (dedup was previously only exercised implicitly);
* tag semantics: ``resolve("tag=...")`` round-trips through
  push/pull/clone, and gc on both tiers keeps tag-rooted closures alive;
* a multi-ref push with one failing fast-forward leaves every ref (local
  and remote) unchanged.
"""

import random
import tempfile
import threading
import time
from pathlib import Path

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dep — fall back to the seeded mini-sampler
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from repro.core import (Lake, LoopbackTransport, ObjectStore, RemoteServer,
                        RemoteStore, SyncError, clone, commit_closure, pull,
                        push, push_refs)
from repro.core.gc import collect
from sync_conformance import CHECKS, Combo, fuzz_once, run_check

_FAST_TRANSPORTS = ("direct", "loopback")  # http exercised on the slow leg


@pytest.mark.parametrize("backend", ("fs", "tiered"))
@pytest.mark.parametrize("transport", _FAST_TRANSPORTS)
@pytest.mark.parametrize("jobs", (1, 4))
@pytest.mark.parametrize("check", CHECKS, ids=lambda c: c.__name__)
def test_conformance_matrix(tmp_path, backend, transport, jobs, check):
    run_check(check, Combo(backend, transport, jobs), tmp_path)


@pytest.mark.slow
@pytest.mark.parametrize("jobs", (1, 8))
@pytest.mark.parametrize("check", CHECKS, ids=lambda c: c.__name__)
def test_conformance_matrix_http(tmp_path, jobs, check):
    run_check(check, Combo("fs", "http", jobs), tmp_path)


@pytest.mark.slow
@pytest.mark.parametrize("backend", ("fs", "tiered"))
@pytest.mark.parametrize("jobs", (1, 8))
@pytest.mark.parametrize("check", CHECKS, ids=lambda c: c.__name__)
def test_conformance_matrix_s3(tmp_path, backend, jobs, check):
    """The s3 leg: the remote is reached through the S3 REST dialect
    (stub server), the oracle reads the bucket tree directly."""
    run_check(check, Combo(backend, "s3", jobs), tmp_path)


@pytest.mark.slow
@pytest.mark.parametrize("jobs", (1, 8))
@pytest.mark.parametrize("check", CHECKS, ids=lambda c: c.__name__)
def test_conformance_matrix_s3_sigv4(tmp_path, jobs, check):
    """The signed leg: same contract, but the stub verifies a SigV4
    signature on EVERY request — any canonicalization drift between the
    backend and the spec fails the whole suite, not just a unit test."""
    run_check(check, Combo("fs", "s3+sigv4", jobs), tmp_path)


@pytest.mark.slow
@pytest.mark.parametrize("backend", ("fs", "s3"))
@pytest.mark.parametrize("seed", (101, 202))
def test_gc_race_fuzz_fixed_seeds(tmp_path, backend, seed):
    """The seeded gc-race fuzz leg on two pinned schedules per backend:
    concurrent push/pull/gc under injected kills/delays, closure
    integrity checked after quiesce.  The CI gc-race job runs the wider
    sweep (``python -m tests.sync_conformance --fuzz 30``); a failure
    here replays exactly with the same seed."""
    violations = fuzz_once(backend, seed, tmp_path, jobs=4)
    assert not violations, "\n".join(violations)


# ----------------------------------------------------- seeded thread-fuzz
class JitterTransport:
    """Seeded per-request sleep before forwarding: randomizes how two
    concurrent transfers interleave, reproducibly."""

    def __init__(self, inner, seed: int, max_delay: float = 0.0015):
        self.inner = inner
        self.rng = random.Random(seed)
        self.max_delay = max_delay
        self._lock = threading.Lock()

    def request(self, payload: bytes) -> bytes:
        with self._lock:
            delay = self.rng.random() * self.max_delay
        time.sleep(delay)
        return self.inner.request(payload)

    def close(self) -> None:
        self.inner.close()


def _overlapping_lake(root: Path) -> Lake:
    """Two branches sharing base history — their closures overlap on every
    object main reaches."""
    lake = Lake(root, protect_main=False)
    lake.write_table("main", "base",
                     {"v": np.arange(128, dtype=np.float32)})
    for i, branch in enumerate(("u.one", "u.two")):
        lake.catalog.create_branch(branch, "main", author="u")
        lake.write_table(branch, f"t{i}",
                         {"v": np.full(64, float(i), np.float32)},
                         author="u")
    return lake


def _assert_remote_intact(lake: Lake, remote_store: ObjectStore,
                          branches) -> None:
    for branch in branches:
        head = remote_store.get_ref(f"branch={branch}")
        for digest in commit_closure(lake.store, head):
            assert remote_store.has(digest), \
                f"{branch}: closure digest {digest[:12]} lost"
        remote_store.get(head)  # digest-verified read


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_fuzz_concurrent_overlapping_pushes(tmp_path_factory, seed):
    """Property: however two pushes of overlapping closures interleave,
    the remote ends with both heads and both complete closures."""
    root = tmp_path_factory.mktemp("fuzz")
    lake = _overlapping_lake(root / "lake")
    remote_store = ObjectStore(root / "remote")
    server = RemoteServer(remote_store)

    errors = []

    def pusher(branch: str, idx: int) -> None:
        remote = RemoteStore(JitterTransport(
            LoopbackTransport(server), seed + idx))
        try:
            push(lake.store, remote, branch, jobs=4)
        except BaseException as e:  # noqa: BLE001 - surfaced via the assert
            errors.append((branch, e))

    threads = [threading.Thread(target=pusher, args=(b, i))
               for i, b in enumerate(("u.one", "u.two"))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, f"concurrent pushes failed: {errors!r}"
    for branch in ("u.one", "u.two"):
        assert remote_store.get_ref(f"branch={branch}") == \
            lake.catalog.head(branch)
    _assert_remote_intact(lake, remote_store, ("u.one", "u.two"))


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_fuzz_concurrent_divergent_pushes_one_wins(tmp_path_factory, seed):
    """Property: two hosts racing divergent heads of the SAME branch never
    corrupt the ref — exactly one wins, the loser gets a clean SyncError,
    and whichever head the ref holds has its full closure present."""
    root = tmp_path_factory.mktemp("fuzz-div")
    remote_store = ObjectStore(root / "remote")
    server = RemoteServer(remote_store)
    seeder = RemoteStore(LoopbackTransport(server))

    lake_a = _overlapping_lake(root / "a")
    push(lake_a.store, seeder, "u.one")
    lake_b = Lake(root / "b", protect_main=False)
    pull(lake_b.store, seeder, "u.one")
    # both sides commit different data on top of the shared head
    lake_a.write_table("u.one", "side_a",
                       {"v": np.full(32, 1.0, np.float32)}, author="u")
    lake_b.write_table("u.one", "side_b",
                       {"v": np.full(32, 2.0, np.float32)}, author="u")

    outcomes = {}

    def pusher(name: str, lake: Lake, idx: int) -> None:
        remote = RemoteStore(JitterTransport(
            LoopbackTransport(server), seed + idx))
        try:
            outcomes[name] = push(lake.store, remote, "u.one", jobs=4)
        except SyncError as e:
            outcomes[name] = e

    threads = [threading.Thread(target=pusher, args=(n, lk, i))
               for i, (n, lk) in enumerate((("a", lake_a), ("b", lake_b)))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    winners = [n for n, out in outcomes.items()
               if not isinstance(out, Exception)]
    assert len(winners) >= 1, f"both pushes failed: {outcomes!r}"
    final = remote_store.get_ref("branch=u.one")
    heads = {"a": lake_a.catalog.head("u.one"),
             "b": lake_b.catalog.head("u.one")}
    assert final in heads.values()
    winner_lake = lake_a if final == heads["a"] else lake_b
    for digest in commit_closure(winner_lake.store, final):
        assert remote_store.has(digest)


# --------------------------------------------- exact accounting regression
def test_sync_report_exact_when_remote_has_partial_closure(tmp_path):
    """Regression: byte/object accounting stays exact when the remote
    already holds part of the closure — every counted object corresponds
    to exactly one new remote blob, bytes match uncompressed sizes, and
    nothing is double-counted across the commit/cache/run phases."""
    lake = _overlapping_lake(tmp_path / "lake")
    remote_store = ObjectStore(tmp_path / "remote")
    remote = RemoteStore(LoopbackTransport(RemoteServer(remote_store)))

    push(lake.store, remote, "u.one", jobs=4)
    before = set(remote_store.iter_objects())

    # u.two shares main's whole history with u.one — a large overlap the
    # second push must skip without losing count of what it did send
    report = push(lake.store, remote, "u.two", jobs=4)
    after = set(remote_store.iter_objects())
    new = after - before
    # grafted ledger links are destination-side bookkeeping written by the
    # ledger, not transferred objects — exclude them from the oracle
    graft_links = {d for d in new
                   if b"manifest" in remote_store.get(d)
                   and b"run_id" in remote_store.get(d)}
    assert report.objects_sent == len(new - graft_links)
    assert report.bytes_sent == sum(len(lake.store.get(d))
                                    for d in new - graft_links)
    assert report.objects_skipped > 0

    # an identical re-push moves nothing and still reports exactly that
    again = push(lake.store, remote, "u.two", jobs=4)
    assert again.objects_sent == 0 and again.bytes_sent == 0
    assert set(remote_store.iter_objects()) == after


# ------------------------------------------------------------ tag semantics
def _tagged_lake(root: Path) -> Lake:
    lake = Lake(root, protect_main=False)
    lake.write_table("main", "base",
                     {"v": np.arange(64, dtype=np.float32)})
    lake.catalog.create_branch("u.rel", "main", author="u")
    lake.write_table("u.rel", "model",
                     {"w": np.full(64, 5.0, np.float32)}, author="u")
    lake.catalog.create_tag("v1.0", "u.rel")
    return lake


def test_tag_resolve_round_trips_through_push_pull_clone(tmp_path):
    lake_a = _tagged_lake(tmp_path / "a")
    tagged = lake_a.catalog.resolve("tag=v1.0")
    assert tagged == lake_a.catalog.resolve("v1.0")
    remote_store = ObjectStore(tmp_path / "remote")
    remote = RemoteStore(LoopbackTransport(RemoteServer(remote_store)))
    push(lake_a.store, remote, "u.rel", tags=["*"])

    lake_b = Lake(tmp_path / "b", protect_main=False)
    pull(lake_b.store, remote, "u.rel", tags=["*"])
    assert lake_b.catalog.resolve("tag=v1.0") == tagged
    assert lake_b.catalog.resolve("origin/v1.0") == tagged
    np.testing.assert_array_equal(lake_b.read_table("tag=v1.0", "model")["w"],
                                  lake_a.read_table("v1.0", "model")["w"])

    # clone pulls tags by default
    _store, _reports = clone(remote, tmp_path / "c", branch="u.rel")
    lake_c = Lake(tmp_path / "c", protect_main=False)
    assert lake_c.catalog.resolve("tag=v1.0") == tagged
    assert lake_c.read_table("v1.0", "model")["w"][0] == 5.0


def test_gc_on_both_tiers_keeps_tag_rooted_closures(tmp_path):
    lake_a = _tagged_lake(tmp_path / "a")
    tagged = lake_a.catalog.resolve("v1.0")
    remote_store = ObjectStore(tmp_path / "remote")
    remote = RemoteStore(LoopbackTransport(RemoteServer(remote_store)))
    push(lake_a.store, remote, "u.rel", tags=["v1.0"])

    # local tier: pull, drop the branch, gc — the tag still resolves
    lake_b = Lake(tmp_path / "b", protect_main=False)
    pull(lake_b.store, remote, "u.rel", tags=["v1.0"])
    lake_b.catalog.delete_branch("u.rel")
    lake_b.store.delete_ref("remote/origin/branch=u.rel")
    collect(lake_b.store)
    assert lake_b.read_table("v1.0", "model")["w"][0] == 5.0

    # remote tier: the branch is deleted server-side; the tag alone must
    # keep the closure alive through a remote-side gc
    remote_store.delete_ref("branch=u.rel")
    collect(remote_store)
    for digest in commit_closure(lake_a.store, tagged):
        assert remote_store.has(digest)


def test_push_rejects_tag_clobber_without_force(tmp_path):
    lake = _tagged_lake(tmp_path / "a")
    remote_store = ObjectStore(tmp_path / "remote")
    remote = RemoteStore(LoopbackTransport(RemoteServer(remote_store)))
    push(lake.store, remote, "u.rel", tags=["v1.0"])

    lake.write_table("u.rel", "model",
                     {"w": np.full(64, 6.0, np.float32)}, author="u")
    lake.catalog.delete_tag("v1.0")
    lake.catalog.create_tag("v1.0", "u.rel")  # same name, new target
    with pytest.raises(SyncError, match="immutable"):
        push(lake.store, remote, "u.rel", tags=["v1.0"])
    # the refused push updated NOTHING, branch ref included
    assert remote_store.get_ref("branch=u.rel") != \
        lake.catalog.head("u.rel")
    push(lake.store, remote, "u.rel", tags=["v1.0"], force=True)
    assert remote_store.get_ref("tag=v1.0") == lake.catalog.head("u.rel")


def test_push_falls_back_when_server_lacks_cas_refs(tmp_path):
    """Compatibility: a server speaking only the PR-2 wire contract (no
    ``cas_refs`` op) still accepts pushes — the client degrades to
    per-ref CAS-with-rollback instead of aborting after the transfer."""
    class Pr2Server(RemoteServer):
        _op_cas_refs = None  # getattr finds None -> "unknown op" reply

    lake = _overlapping_lake(tmp_path / "lake")
    remote_store = ObjectStore(tmp_path / "remote")
    remote = RemoteStore(LoopbackTransport(Pr2Server(remote_store)))
    rep = push_refs(lake.store, remote, ["u.one", "u.two"])
    assert set(rep.updated_refs) == {"branch=u.one", "branch=u.two"}
    for branch in ("u.one", "u.two"):
        assert remote_store.get_ref(f"branch={branch}") == \
            lake.catalog.head(branch)


# -------------------------------------------- multi-ref rollback, explicit
def test_multi_ref_push_failed_ff_leaves_every_ref_unchanged(tmp_path):
    """Acceptance: a multi-ref push where one ref fast-forward fails leaves
    every ref — local tracking refs and remote heads — unchanged."""
    lake_a = _overlapping_lake(tmp_path / "a")
    remote_store = ObjectStore(tmp_path / "remote")
    remote = RemoteStore(LoopbackTransport(RemoteServer(remote_store)))
    push_refs(lake_a.store, remote, ["u.one", "u.two"])

    # another host moves u.one forward on the remote
    lake_b = Lake(tmp_path / "b", protect_main=False)
    pull(lake_b.store, remote, "u.one")
    lake_b.write_table("u.one", "b_only",
                       {"v": np.ones(16, np.float32)}, author="u")
    push(lake_b.store, remote, "u.one")

    # A diverges on u.one and advances u.two, then pushes both
    lake_a.write_table("u.one", "a_only",
                       {"v": np.zeros(16, np.float32)}, author="u")
    lake_a.write_table("u.two", "a_two",
                       {"v": np.zeros(16, np.float32)}, author="u")
    remote_refs_before = dict(remote_store.list_refs("branch=")[0])
    local_refs_before = {r: lake_a.store.get_ref(r)
                         for r in lake_a.store.iter_refs("remote/")}
    with pytest.raises(SyncError):
        push_refs(lake_a.store, remote, ["u.one", "u.two"])
    assert dict(remote_store.list_refs("branch=")[0]) == remote_refs_before
    assert {r: lake_a.store.get_ref(r)
            for r in lake_a.store.iter_refs("remote/")} == local_refs_before
    # u.two in particular did NOT advance even though its own FF was clean
    assert remote_store.get_ref("branch=u.two") != \
        lake_a.catalog.head("u.two")
