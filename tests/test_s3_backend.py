"""S3 backend contract tests: the StoreBackend semantics every other
backend honors, now over the S3 REST dialect (conditional writes, paged
ListObjectsV2 listing, HEAD fan-out), against the in-process stub server.

The full sync contract runs through this backend in the conformance matrix
(``tests/sync_conformance.py``, transport ``s3``); this file pins the
backend-level primitives plus the wire-frame compression round-trip
property (compressed and raw transfers land bit-identical remote stores).
"""

import json
import threading

import numpy as np
import pytest

from repro.core import (Lake, LoopbackTransport, ObjectStore, RemoteServer,
                        RemoteStore, S3Backend, commit_closure, connect,
                        decode_frame, push, serve_s3, sha256_hex)
from repro.core.errors import ObjectNotFound, RefConflict, RefNotFound


@pytest.fixture()
def s3(tmp_path):
    httpd, url = serve_s3(tmp_path / "bucket")
    backend = connect(url)
    assert isinstance(backend, S3Backend)
    yield backend
    backend.close()
    httpd.shutdown()


# ------------------------------------------------------------------ objects
def test_object_round_trip_verified_and_deduped(s3, tmp_path):
    data = b"tensorfile-ish payload " * 64
    digest = s3.put(data)
    assert digest == sha256_hex(data)
    assert s3.get(digest) == data
    assert s3.put(data) == digest  # idempotent re-put
    assert s3.has(digest) and not s3.has("0" * 64)
    assert 0 < s3.size(digest) < len(data)  # stored compressed
    with pytest.raises(ObjectNotFound):
        s3.get("f" * 64)
    # the bucket tree IS the filesystem store layout: a direct ObjectStore
    # over the same directory decodes the stub-written payload
    oracle = ObjectStore(tmp_path / "bucket")
    assert oracle.get(digest) == data
    # ...and a blob written by the filesystem store is served by the stub
    d2 = oracle.put(b"written locally, read over S3")
    assert s3.get(d2) == b"written locally, read over S3"


def test_batched_ops_fan_out(s3):
    blobs = [bytes([i]) * (100 + i) for i in range(20)]
    digests = s3.put_many(blobs)
    assert digests == [sha256_hex(b) for b in blobs]
    assert s3.has_many(digests + ["0" * 64]) == set(digests)
    fetched = s3.get_many(digests)
    assert [fetched[d] for d in digests] == blobs


def test_paged_listing_enumerates_exactly_once(s3):
    digests = {s3.put(bytes([i]) * 80) for i in range(25)}
    seen = []
    token = None
    while True:
        page, token = s3.list_objects(page_token=token, limit=7)
        seen.extend(page)
        if token is None:
            break
    assert sorted(seen) == sorted(digests)  # everything, exactly once
    assert seen == sorted(seen)  # sorted order (resumable)
    assert sorted(s3.iter_objects()) == sorted(digests)


def test_delete_object_is_idempotent(s3):
    digest = s3.put(b"sweep me" * 30)
    assert s3.delete_object(digest) is True
    assert s3.delete_object(digest) is False  # already gone
    assert not s3.has(digest)


def test_encoded_payload_passthrough(s3, tmp_path):
    """get_encoded hands out the exact stored payload; put_encoded stores
    a foreign store's payload byte-for-byte (compression never re-paid)."""
    data = np.arange(4096, dtype=np.float32).tobytes()
    digest = s3.put(data)
    payload = s3.get_encoded(digest)
    assert decode_frame(payload) == data
    # the payload on the bucket's disk is byte-identical to what the
    # backend hands out
    oracle = ObjectStore(tmp_path / "bucket")
    assert oracle.get_encoded(digest) == payload
    # round-trip into a second bucket without recompression
    httpd2, url2 = serve_s3(tmp_path / "bucket2")
    try:
        other = connect(url2)
        assert other.put_encoded(payload) == digest
        assert other.get_encoded(digest) == payload
    finally:
        httpd2.shutdown()


# --------------------------------------------------------------------- refs
def test_ref_cas_conditional_write_semantics(s3):
    with pytest.raises(RefNotFound):
        s3.get_ref("branch=missing")
    s3.cas_ref("branch=b", None, "a" * 64)  # If-None-Match: * create
    assert s3.get_ref("branch=b") == "a" * 64
    with pytest.raises(RefConflict):
        s3.cas_ref("branch=b", None, "b" * 64)  # create-only: exists
    with pytest.raises(RefConflict):
        s3.cas_ref("branch=b", "c" * 64, "b" * 64)  # wrong expected
    s3.cas_ref("branch=b", "a" * 64, "b" * 64)
    assert s3.get_ref("branch=b") == "b" * 64
    s3.delete_ref("branch=b")
    with pytest.raises(RefNotFound):
        s3.delete_ref("branch=b")


def test_cas_refs_stale_expectation_updates_nothing(s3):
    s3.set_ref("branch=one", "a" * 64)
    s3.set_ref("branch=two", "b" * 64)
    with pytest.raises(RefConflict):
        s3.cas_refs([("branch=one", "a" * 64, "c" * 64),
                     ("branch=two", "X" * 64, "c" * 64)])  # stale
    assert s3.get_ref("branch=one") == "a" * 64  # preflight: nothing moved
    assert s3.get_ref("branch=two") == "b" * 64
    s3.cas_refs([("branch=one", "a" * 64, "c" * 64),
                 ("branch=two", "b" * 64, "c" * 64),
                 ("tag=v1", None, "d" * 64)])
    assert s3.get_ref("tag=v1") == "d" * 64


def test_cas_ref_loses_no_updates_under_concurrent_writers(s3):
    """N threads CAS-increment one ref; conditional writes mean every
    successful swap observed the true current value — no lost updates."""
    s3.set_ref("branch=ctr", "0" * 64)
    applied = []
    lock = threading.Lock()

    def writer(tid):
        my = f"{tid + 1:02d}" * 32  # distinct from the all-zeros seed
        while True:
            current = s3.get_ref("branch=ctr")
            try:
                s3.cas_ref("branch=ctr", current, my)
            except RefConflict:
                continue  # raced: re-read and retry
            with lock:
                applied.append((current, my))
            return

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(applied) == 6
    # the swaps form one linear chain from the seed to the final value
    chain = {old: new for old, new in applied}
    assert len(chain) == 6  # no two swaps claimed the same predecessor
    cur = "0" * 64
    for _ in range(6):
        cur = chain[cur]
    assert s3.get_ref("branch=ctr") == cur


def test_cas_refs_ambiguous_midbatch_fault_never_tears(s3, monkeypatch):
    """A transport fault during a mid-batch conditional write must not
    leave the applied prefix behind: resolved by re-read when the write
    landed, rolled back (with a clean diagnosis) when it did not."""
    from repro.core.errors import AmbiguousRefUpdate, RemoteError

    s3.set_ref("branch=one", "a" * 64)
    s3.set_ref("branch=two", "b" * 64)
    real = type(s3)._conditional_put
    calls = {"n": 0, "deliver": False}

    def flaky(self, name, digest, etag):
        calls["n"] += 1
        if calls["n"] == 2:  # second write of the batch faults
            if calls["deliver"]:
                real(self, name, digest, etag)  # the server DID apply it
            raise AmbiguousRefUpdate("injected fault mid conditional write")
        return real(self, name, digest, etag)

    monkeypatch.setattr(type(s3), "_conditional_put", flaky)
    # not delivered: verified unchanged -> rollback, both refs restored
    with pytest.raises(RemoteError, match="verified unchanged"):
        s3.cas_refs([("branch=one", "a" * 64, "c" * 64),
                     ("branch=two", "b" * 64, "c" * 64)])
    assert s3.get_ref("branch=one") == "a" * 64
    assert s3.get_ref("branch=two") == "b" * 64
    # delivered: re-read confirms the write -> the batch completes
    calls.update(n=0, deliver=True)
    s3.cas_refs([("branch=one", "a" * 64, "c" * 64),
                 ("branch=two", "b" * 64, "c" * 64)])
    assert s3.get_ref("branch=one") == "c" * 64
    assert s3.get_ref("branch=two") == "c" * 64


def test_ref_listing_pages_and_prefixes(s3):
    for i in range(12):
        s3.set_ref(f"cache/{i:02d}/entry", f"{i:064d}"[:64])
    s3.set_ref("branch=main", "a" * 64)
    names = list(s3.iter_refs("cache/"))
    assert len(names) == 12 and all(n.startswith("cache/") for n in names)
    page, token = s3.list_refs("cache/", limit=5)
    assert len(page) == 5 and token is not None
    assert all(v for _n, v in page)


def test_has_raises_on_server_errors_instead_of_reading_absent(s3,
                                                               monkeypatch):
    """A 503/403 on HEAD must surface as an error, never as 'absent' —
    remote GC's mark phase trusts has(), and a swallowed throttle would
    let the sweep delete live objects."""
    from repro.core.errors import RemoteError

    digest = s3.put(b"live data" * 20)
    real = type(s3)._request

    def throttled(self, method, key, **kw):
        if method == "HEAD":
            return 503, {}, b"SlowDown"
        return real(self, method, key, **kw)

    monkeypatch.setattr(type(s3), "_request", throttled)
    with pytest.raises(RemoteError, match="503"):
        s3.has(digest)


def test_response_headers_are_case_normalized(s3):
    """Version tokens must survive servers that spell ETag differently —
    _request lower-cases header names, consumers read the canonical
    lowercase form."""
    digest = s3.put(b"etag me" * 20)
    status, headers, _body = s3._request("HEAD", f"objects/{digest[:2]}/"
                                         f"{digest[2:]}")
    assert status == 200
    assert "etag" in headers  # the stub sent "ETag"
    assert all(k == k.lower() for k in headers)


def test_rollback_of_created_ref_never_clobbers_racer_update(s3,
                                                             monkeypatch):
    """cas_refs rollback deletes a ref it created with an If-Match guard:
    if a racer CASed that ref onward in the conflict window, the racer's
    committed update survives the rollback."""
    s3.set_ref("branch=exist", "a" * 64)
    real = type(s3)._conditional_put
    state = {"n": 0}

    def racing(self, name, digest, etag):
        state["n"] += 1
        if state["n"] == 1:  # our create of branch=new succeeds...
            ok, tok = real(self, name, digest, etag)
            # ...then a racer immediately CASes it onward (a committed,
            # acknowledged update — bypassing the patch to avoid recursion)
            cur, cur_etag = self._read_ref("branch=new")
            assert cur == digest
            ok2, _tok2 = real(self, "branch=new", "d" * 64, cur_etag)
            assert ok2
            return ok, tok
        return False, None  # second write of the batch loses its race

    monkeypatch.setattr(type(s3), "_conditional_put", racing)
    with pytest.raises(RefConflict):
        s3.cas_refs([("branch=new", None, "c" * 64),
                     ("branch=exist", "a" * 64, "c" * 64)])
    monkeypatch.undo()
    # the guarded rollback 412'd: the racer's update is intact
    assert s3.get_ref("branch=new") == "d" * 64
    assert s3.get_ref("branch=exist") == "a" * 64


def test_tiered_store_forwards_encoded_capability(tmp_path):
    """TieredStore exposes the mounted remote's encoded-op support, so
    the engine's kill switch sees through the tier."""
    from repro.core import TieredStore

    class NoEncodedRemote(RemoteStore):
        pass

    remote = NoEncodedRemote(LoopbackTransport(RemoteServer(
        ObjectStore(tmp_path / "r"))))
    tiered = TieredStore(ObjectStore(tmp_path / "l"), remote)
    assert tiered._supports_encoded() is True
    remote._encoded_ops = False  # server said "unknown op"
    assert tiered._supports_encoded() is False


def test_ref_names_with_reserved_characters_round_trip(s3):
    """Keys are percent-encoded on the wire: names with spaces, %, ? or #
    must round-trip verbatim instead of breaking the request line,
    truncating at the query separator, or aliasing with their decoded
    spelling."""
    names = ["branch=exp 1", "tag=rel%41", "branch=q?x", "tag=h#v"]
    for i, name in enumerate(names):
        s3.set_ref(name, f"{i:064d}"[:64])
    for i, name in enumerate(names):
        assert s3.get_ref(name) == f"{i:064d}"[:64]
    assert "tag=relA" not in list(s3.iter_refs())  # no decoded alias
    assert sorted(n for n, _v in s3.list_refs()[0]) == sorted(names)
    for name in names:
        s3.delete_ref(name)
    assert not list(s3.iter_refs())


def test_listing_survives_server_side_max_keys_cap(s3, monkeypatch):
    """Truncation comes from IsTruncated, not from page-size arithmetic:
    a server capping max-keys below the requested limit must not make the
    tail of the listing silently invisible."""
    from repro.core import s3stub

    digests = {s3.put(bytes([i]) * 80) for i in range(12)}
    monkeypatch.setattr(s3stub, "_MAX_KEYS_CAP", 5)  # server caps pages
    page, token = s3.list_objects(limit=1000)
    assert len(page) == 5 and token is not None  # short page, more behind
    assert sorted(s3.iter_objects()) == sorted(digests)  # nothing hidden
    for i in range(7):
        s3.set_ref(f"cache/{i:02d}/e", "a" * 64)
    assert len(list(s3.iter_refs("cache/"))) == 7


def test_engine_stops_retrying_encoded_path_on_old_server(tmp_path):
    """Against a server that permanently lacks the encoded ops, the
    transfer engine must fall back ONCE, not re-attempt (and re-fetch +
    re-decode) for every chunk."""
    import msgpack as _mp

    from repro.core import Lake, LoopbackTransport, RemoteServer, RemoteStore
    from repro.core import push as _push

    class OldServer(RemoteServer):
        _op_get_objects_encoded = None
        _op_put_objects_encoded = None

    class OpCounter:
        def __init__(self, inner):
            self.inner = inner
            self.ops = {}

        def request(self, payload):
            op = _mp.unpackb(payload, raw=False).get("op", "")
            self.ops[op] = self.ops.get(op, 0) + 1
            return self.inner.request(payload)

        def close(self):
            self.inner.close()

    lake = Lake(tmp_path / "lake", protect_main=False)
    for i in range(12):  # enough leaf blobs for several transfer chunks
        lake.write_table("main", f"t{i}",
                         {"v": np.arange(512, dtype=np.float32) * i})
    lake.catalog.create_branch("u.exp", "main", author="u")
    counter = OpCounter(LoopbackTransport(OldServer(
        ObjectStore(tmp_path / "remote"))))
    rep = _push(lake.store, RemoteStore(counter), "u.exp", jobs=1)
    assert rep.ref_updated and rep.bytes_wire == rep.bytes_sent
    # one probe, then the engine stays on the raw path
    assert counter.ops.get("put_objects_encoded", 0) <= 1
    assert counter.ops.get("put_objects", 0) + counter.ops.get(
        "put_object", 0) > 1


# --------------------------------------------- wire-frame round-trip property
def _random_lake(root, seed: int) -> Lake:
    rng = np.random.default_rng(seed)
    lake = Lake(root, protect_main=False)
    for i in range(int(rng.integers(2, 5))):
        n = int(rng.integers(16, 400))
        cols = {"v": rng.normal(size=n).astype(np.float32),
                "k": np.arange(n, dtype=np.int64) * int(rng.integers(1, 9))}
        lake.write_table("main", f"t{i}", cols)
    lake.catalog.create_branch("u.exp", "main", author="u")
    lake.write_table("u.exp", "extra",
                     {"v": np.repeat(rng.normal(size=8), 64)
                      .astype(np.float64)}, author="u")
    return lake


@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_compressed_and_raw_transfers_are_bit_identical(tmp_path, seed):
    """Property: pushing the same closure with compressed wire frames and
    with raw frames yields byte-identical remote stores — same digest
    sets, same refs, same decoded contents — through both the msgpack
    wire and the S3 dialect."""
    lake = _random_lake(tmp_path / "lake", seed)
    head = lake.catalog.head("u.exp")
    closure = commit_closure(lake.store, head)

    stores = {}
    # msgpack wire, compressed vs raw frames
    for mode, compress in (("wire_c", True), ("wire_r", False)):
        store = ObjectStore(tmp_path / mode)
        remote = RemoteStore(LoopbackTransport(RemoteServer(store)))
        push(lake.store, remote, "u.exp", jobs=4, compress_wire=compress)
        stores[mode] = store
    # S3 dialect, compressed vs raw frames
    for mode, compress in (("s3_c", True), ("s3_r", False)):
        httpd, url = serve_s3(tmp_path / mode)
        try:
            push(lake.store, connect(url), "u.exp", jobs=4,
                 compress_wire=compress)
        finally:
            httpd.shutdown()
        stores[mode] = ObjectStore(tmp_path / mode)

    reference = sorted(stores["wire_c"].iter_objects())
    assert set(reference) >= closure
    ref_refs = sorted(stores["wire_c"].list_refs()[0])
    for mode, store in stores.items():
        assert sorted(store.iter_objects()) == reference, mode
        assert sorted(store.list_refs()[0]) == ref_refs, mode
        for digest in closure:
            assert store.get(digest) == stores["wire_c"].get(digest), mode


# ---------------------------------------------------------------------- CLI
def test_cli_s3_remote_push_clone_gc(tmp_path, capsys):
    from repro.launch.repro_cli import main

    httpd, url = serve_s3(tmp_path / "bucket")
    try:
        lake = Lake(tmp_path / "lake", protect_main=False)
        lake.write_table("main", "t0",
                         {"v": np.arange(256, dtype=np.float32)})
        lake.catalog.create_branch("u.exp", "main", author="u")
        lake.write_table("u.exp", "t1",
                         {"v": np.ones(256, np.float32)}, author="u")

        base = ["--lake", str(tmp_path / "lake")]
        main(base + ["remote", "add", "s3", url])
        main(base + ["push", "--branch", "u.exp", "--remote", "s3"])
        out = capsys.readouterr().out
        assert "ref_updated=True" in out

        main(["clone", url, str(tmp_path / "clone")])
        capsys.readouterr()
        cloned = Lake(tmp_path / "clone", protect_main=False)
        assert cloned.catalog.head("u.exp") == lake.catalog.head("u.exp")
        np.testing.assert_array_equal(
            cloned.read_table("u.exp", "t1")["v"],
            lake.read_table("u.exp", "t1")["v"])

        # remote-side GC over the S3 dialect: while branch=u.exp roots the
        # pushed closure, nothing is sweepable
        remote = connect(url)
        head = lake.catalog.head("u.exp")
        n_before = len(list(remote.iter_objects()))
        main(base + ["gc", "--remote", "s3"])
        report = json.loads(capsys.readouterr().out.strip())
        assert report["target"] == "s3" and report["swept"] == 0
        # with the default grace window the just-pushed (young) objects
        # would be skipped, not swept — drop the only remote root and
        # sweep with --prune-age 0 for real.  The REMOTE's ref state
        # decides, not the local lake (which still has its branches).
        remote.delete_ref("branch=u.exp")
        main(base + ["gc", "--remote", "s3"])  # default window: all young
        report = json.loads(capsys.readouterr().out.strip())
        assert report["swept"] == 0 and report["skipped_young"] == n_before
        for digest in commit_closure(lake.store, head):
            assert remote.has(digest)
        main(base + ["gc", "--remote", "s3", "--prune-age", "0"])
        report = json.loads(capsys.readouterr().out.strip())
        assert report["swept"] == n_before and report["bytes_freed"] > 0
        assert not list(remote.iter_objects())
        # the sweep never touched local state
        for digest in commit_closure(lake.store, head):
            assert lake.store.has(digest)
    finally:
        httpd.shutdown()
