"""S3 backend contract tests: the StoreBackend semantics every other
backend honors, now over the S3 REST dialect (conditional writes, paged
ListObjectsV2 listing, HEAD fan-out), against the in-process stub server.

The full sync contract runs through this backend in the conformance matrix
(``tests/sync_conformance.py``, transport ``s3``); this file pins the
backend-level primitives plus the wire-frame compression round-trip
property (compressed and raw transfers land bit-identical remote stores).
"""

import json
import threading

import numpy as np
import pytest

from repro.core import (Lake, LoopbackTransport, ObjectStore, RemoteServer,
                        RemoteStore, S3Backend, commit_closure, connect,
                        decode_frame, push, serve_s3, sha256_hex)
from repro.core.errors import ObjectNotFound, RefConflict, RefNotFound


@pytest.fixture()
def s3(tmp_path):
    httpd, url = serve_s3(tmp_path / "bucket")
    backend = connect(url)
    assert isinstance(backend, S3Backend)
    yield backend
    backend.close()
    httpd.shutdown()


# ------------------------------------------------------------------ objects
def test_object_round_trip_verified_and_deduped(s3, tmp_path):
    data = b"tensorfile-ish payload " * 64
    digest = s3.put(data)
    assert digest == sha256_hex(data)
    assert s3.get(digest) == data
    assert s3.put(data) == digest  # idempotent re-put
    assert s3.has(digest) and not s3.has("0" * 64)
    assert 0 < s3.size(digest) < len(data)  # stored compressed
    with pytest.raises(ObjectNotFound):
        s3.get("f" * 64)
    # the bucket tree IS the filesystem store layout: a direct ObjectStore
    # over the same directory decodes the stub-written payload
    oracle = ObjectStore(tmp_path / "bucket")
    assert oracle.get(digest) == data
    # ...and a blob written by the filesystem store is served by the stub
    d2 = oracle.put(b"written locally, read over S3")
    assert s3.get(d2) == b"written locally, read over S3"


def test_batched_ops_fan_out(s3):
    blobs = [bytes([i]) * (100 + i) for i in range(20)]
    digests = s3.put_many(blobs)
    assert digests == [sha256_hex(b) for b in blobs]
    assert s3.has_many(digests + ["0" * 64]) == set(digests)
    fetched = s3.get_many(digests)
    assert [fetched[d] for d in digests] == blobs


def test_paged_listing_enumerates_exactly_once(s3):
    digests = {s3.put(bytes([i]) * 80) for i in range(25)}
    seen = []
    token = None
    while True:
        page, token = s3.list_objects(page_token=token, limit=7)
        seen.extend(page)
        if token is None:
            break
    assert sorted(seen) == sorted(digests)  # everything, exactly once
    assert seen == sorted(seen)  # sorted order (resumable)
    assert sorted(s3.iter_objects()) == sorted(digests)


def test_delete_object_is_idempotent(s3):
    digest = s3.put(b"sweep me" * 30)
    assert s3.delete_object(digest) is True
    assert s3.delete_object(digest) is False  # already gone
    assert not s3.has(digest)


def test_encoded_payload_passthrough(s3, tmp_path):
    """get_encoded hands out the exact stored payload; put_encoded stores
    a foreign store's payload byte-for-byte (compression never re-paid)."""
    data = np.arange(4096, dtype=np.float32).tobytes()
    digest = s3.put(data)
    payload = s3.get_encoded(digest)
    assert decode_frame(payload) == data
    # the payload on the bucket's disk is byte-identical to what the
    # backend hands out
    oracle = ObjectStore(tmp_path / "bucket")
    assert oracle.get_encoded(digest) == payload
    # round-trip into a second bucket without recompression
    httpd2, url2 = serve_s3(tmp_path / "bucket2")
    try:
        other = connect(url2)
        assert other.put_encoded(payload) == digest
        assert other.get_encoded(digest) == payload
    finally:
        httpd2.shutdown()


# --------------------------------------------------------------------- refs
def test_ref_cas_conditional_write_semantics(s3):
    with pytest.raises(RefNotFound):
        s3.get_ref("branch=missing")
    s3.cas_ref("branch=b", None, "a" * 64)  # If-None-Match: * create
    assert s3.get_ref("branch=b") == "a" * 64
    with pytest.raises(RefConflict):
        s3.cas_ref("branch=b", None, "b" * 64)  # create-only: exists
    with pytest.raises(RefConflict):
        s3.cas_ref("branch=b", "c" * 64, "b" * 64)  # wrong expected
    s3.cas_ref("branch=b", "a" * 64, "b" * 64)
    assert s3.get_ref("branch=b") == "b" * 64
    s3.delete_ref("branch=b")
    with pytest.raises(RefNotFound):
        s3.delete_ref("branch=b")


def test_cas_refs_stale_expectation_updates_nothing(s3):
    s3.set_ref("branch=one", "a" * 64)
    s3.set_ref("branch=two", "b" * 64)
    with pytest.raises(RefConflict):
        s3.cas_refs([("branch=one", "a" * 64, "c" * 64),
                     ("branch=two", "X" * 64, "c" * 64)])  # stale
    assert s3.get_ref("branch=one") == "a" * 64  # preflight: nothing moved
    assert s3.get_ref("branch=two") == "b" * 64
    s3.cas_refs([("branch=one", "a" * 64, "c" * 64),
                 ("branch=two", "b" * 64, "c" * 64),
                 ("tag=v1", None, "d" * 64)])
    assert s3.get_ref("tag=v1") == "d" * 64


def test_cas_ref_loses_no_updates_under_concurrent_writers(s3):
    """N threads CAS-increment one ref; conditional writes mean every
    successful swap observed the true current value — no lost updates."""
    s3.set_ref("branch=ctr", "0" * 64)
    applied = []
    lock = threading.Lock()

    def writer(tid):
        my = f"{tid + 1:02d}" * 32  # distinct from the all-zeros seed
        while True:
            current = s3.get_ref("branch=ctr")
            try:
                s3.cas_ref("branch=ctr", current, my)
            except RefConflict:
                continue  # raced: re-read and retry
            with lock:
                applied.append((current, my))
            return

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(applied) == 6
    # the swaps form one linear chain from the seed to the final value
    chain = {old: new for old, new in applied}
    assert len(chain) == 6  # no two swaps claimed the same predecessor
    cur = "0" * 64
    for _ in range(6):
        cur = chain[cur]
    assert s3.get_ref("branch=ctr") == cur


def test_cas_refs_ambiguous_midbatch_fault_never_tears(s3, monkeypatch):
    """A transport fault during a mid-batch conditional write must not
    leave the applied prefix behind: resolved by re-read when the write
    landed, rolled back (with a clean diagnosis) when it did not."""
    from repro.core.errors import AmbiguousRefUpdate, RemoteError

    s3.set_ref("branch=one", "a" * 64)
    s3.set_ref("branch=two", "b" * 64)
    real = type(s3)._conditional_put
    calls = {"n": 0, "deliver": False}

    def flaky(self, name, digest, etag):
        calls["n"] += 1
        if calls["n"] == 2:  # second write of the batch faults
            if calls["deliver"]:
                real(self, name, digest, etag)  # the server DID apply it
            raise AmbiguousRefUpdate("injected fault mid conditional write")
        return real(self, name, digest, etag)

    monkeypatch.setattr(type(s3), "_conditional_put", flaky)
    # not delivered: verified unchanged -> rollback, both refs restored
    with pytest.raises(RemoteError, match="verified unchanged"):
        s3.cas_refs([("branch=one", "a" * 64, "c" * 64),
                     ("branch=two", "b" * 64, "c" * 64)])
    assert s3.get_ref("branch=one") == "a" * 64
    assert s3.get_ref("branch=two") == "b" * 64
    # delivered: re-read confirms the write -> the batch completes
    calls.update(n=0, deliver=True)
    s3.cas_refs([("branch=one", "a" * 64, "c" * 64),
                 ("branch=two", "b" * 64, "c" * 64)])
    assert s3.get_ref("branch=one") == "c" * 64
    assert s3.get_ref("branch=two") == "c" * 64


def test_ref_listing_pages_and_prefixes(s3):
    for i in range(12):
        s3.set_ref(f"cache/{i:02d}/entry", f"{i:064d}"[:64])
    s3.set_ref("branch=main", "a" * 64)
    names = list(s3.iter_refs("cache/"))
    assert len(names) == 12 and all(n.startswith("cache/") for n in names)
    page, token = s3.list_refs("cache/", limit=5)
    assert len(page) == 5 and token is not None
    assert all(v for _n, v in page)


def test_has_raises_on_server_errors_instead_of_reading_absent(s3,
                                                               monkeypatch):
    """A 503/403 on HEAD must surface as an error, never as 'absent' —
    remote GC's mark phase trusts has(), and a swallowed throttle would
    let the sweep delete live objects."""
    from repro.core.errors import RemoteError

    digest = s3.put(b"live data" * 20)
    real = type(s3)._request

    def throttled(self, method, key, **kw):
        if method == "HEAD":
            return 503, {}, b"SlowDown"
        return real(self, method, key, **kw)

    monkeypatch.setattr(type(s3), "_request", throttled)
    with pytest.raises(RemoteError, match="503"):
        s3.has(digest)


def test_response_headers_are_case_normalized(s3):
    """Version tokens must survive servers that spell ETag differently —
    _request lower-cases header names, consumers read the canonical
    lowercase form."""
    digest = s3.put(b"etag me" * 20)
    status, headers, _body = s3._request("HEAD", f"objects/{digest[:2]}/"
                                         f"{digest[2:]}")
    assert status == 200
    assert "etag" in headers  # the stub sent "ETag"
    assert all(k == k.lower() for k in headers)


def test_rollback_of_created_ref_never_clobbers_racer_update(s3,
                                                             monkeypatch):
    """cas_refs rollback deletes a ref it created with an If-Match guard:
    if a racer CASed that ref onward in the conflict window, the racer's
    committed update survives the rollback."""
    s3.set_ref("branch=exist", "a" * 64)
    real = type(s3)._conditional_put
    state = {"n": 0}

    def racing(self, name, digest, etag):
        state["n"] += 1
        if state["n"] == 1:  # our create of branch=new succeeds...
            ok, tok = real(self, name, digest, etag)
            # ...then a racer immediately CASes it onward (a committed,
            # acknowledged update — bypassing the patch to avoid recursion)
            cur, cur_etag = self._read_ref("branch=new")
            assert cur == digest
            ok2, _tok2 = real(self, "branch=new", "d" * 64, cur_etag)
            assert ok2
            return ok, tok
        return False, None  # second write of the batch loses its race

    monkeypatch.setattr(type(s3), "_conditional_put", racing)
    with pytest.raises(RefConflict):
        s3.cas_refs([("branch=new", None, "c" * 64),
                     ("branch=exist", "a" * 64, "c" * 64)])
    monkeypatch.undo()
    # the guarded rollback 412'd: the racer's update is intact
    assert s3.get_ref("branch=new") == "d" * 64
    assert s3.get_ref("branch=exist") == "a" * 64


def test_tiered_store_forwards_encoded_capability(tmp_path):
    """TieredStore exposes the mounted remote's encoded-op support, so
    the engine's kill switch sees through the tier."""
    from repro.core import TieredStore

    class NoEncodedRemote(RemoteStore):
        pass

    remote = NoEncodedRemote(LoopbackTransport(RemoteServer(
        ObjectStore(tmp_path / "r"))))
    tiered = TieredStore(ObjectStore(tmp_path / "l"), remote)
    assert tiered._supports_encoded() is True
    remote._encoded_ops = False  # server said "unknown op"
    assert tiered._supports_encoded() is False


def test_ref_names_with_reserved_characters_round_trip(s3):
    """Keys are percent-encoded on the wire: names with spaces, %, ? or #
    must round-trip verbatim instead of breaking the request line,
    truncating at the query separator, or aliasing with their decoded
    spelling."""
    names = ["branch=exp 1", "tag=rel%41", "branch=q?x", "tag=h#v"]
    for i, name in enumerate(names):
        s3.set_ref(name, f"{i:064d}"[:64])
    for i, name in enumerate(names):
        assert s3.get_ref(name) == f"{i:064d}"[:64]
    assert "tag=relA" not in list(s3.iter_refs())  # no decoded alias
    assert sorted(n for n, _v in s3.list_refs()[0]) == sorted(names)
    for name in names:
        s3.delete_ref(name)
    assert not list(s3.iter_refs())


def test_listing_survives_server_side_max_keys_cap(s3, monkeypatch):
    """Truncation comes from IsTruncated, not from page-size arithmetic:
    a server capping max-keys below the requested limit must not make the
    tail of the listing silently invisible."""
    from repro.core import s3stub

    digests = {s3.put(bytes([i]) * 80) for i in range(12)}
    monkeypatch.setattr(s3stub, "_MAX_KEYS_CAP", 5)  # server caps pages
    page, token = s3.list_objects(limit=1000)
    assert len(page) == 5 and token is not None  # short page, more behind
    assert sorted(s3.iter_objects()) == sorted(digests)  # nothing hidden
    for i in range(7):
        s3.set_ref(f"cache/{i:02d}/e", "a" * 64)
    assert len(list(s3.iter_refs("cache/"))) == 7


def test_engine_stops_retrying_encoded_path_on_old_server(tmp_path):
    """Against a server that permanently lacks the encoded ops, the
    transfer engine must fall back ONCE, not re-attempt (and re-fetch +
    re-decode) for every chunk."""
    import msgpack as _mp

    from repro.core import Lake, LoopbackTransport, RemoteServer, RemoteStore
    from repro.core import push as _push

    class OldServer(RemoteServer):
        _op_get_objects_encoded = None
        _op_put_objects_encoded = None

    class OpCounter:
        def __init__(self, inner):
            self.inner = inner
            self.ops = {}

        def request(self, payload):
            op = _mp.unpackb(payload, raw=False).get("op", "")
            self.ops[op] = self.ops.get(op, 0) + 1
            return self.inner.request(payload)

        def close(self):
            self.inner.close()

    lake = Lake(tmp_path / "lake", protect_main=False)
    for i in range(12):  # enough leaf blobs for several transfer chunks
        lake.write_table("main", f"t{i}",
                         {"v": np.arange(512, dtype=np.float32) * i})
    lake.catalog.create_branch("u.exp", "main", author="u")
    counter = OpCounter(LoopbackTransport(OldServer(
        ObjectStore(tmp_path / "remote"))))
    rep = _push(lake.store, RemoteStore(counter), "u.exp", jobs=1)
    assert rep.ref_updated and rep.bytes_wire == rep.bytes_sent
    # one probe, then the engine stays on the raw path
    assert counter.ops.get("put_objects_encoded", 0) <= 1
    assert counter.ops.get("put_objects", 0) + counter.ops.get(
        "put_object", 0) > 1


# --------------------------------------------- wire-frame round-trip property
def _random_lake(root, seed: int) -> Lake:
    rng = np.random.default_rng(seed)
    lake = Lake(root, protect_main=False)
    for i in range(int(rng.integers(2, 5))):
        n = int(rng.integers(16, 400))
        cols = {"v": rng.normal(size=n).astype(np.float32),
                "k": np.arange(n, dtype=np.int64) * int(rng.integers(1, 9))}
        lake.write_table("main", f"t{i}", cols)
    lake.catalog.create_branch("u.exp", "main", author="u")
    lake.write_table("u.exp", "extra",
                     {"v": np.repeat(rng.normal(size=8), 64)
                      .astype(np.float64)}, author="u")
    return lake


@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_compressed_and_raw_transfers_are_bit_identical(tmp_path, seed):
    """Property: pushing the same closure with compressed wire frames and
    with raw frames yields byte-identical remote stores — same digest
    sets, same refs, same decoded contents — through both the msgpack
    wire and the S3 dialect."""
    lake = _random_lake(tmp_path / "lake", seed)
    head = lake.catalog.head("u.exp")
    closure = commit_closure(lake.store, head)

    stores = {}
    # msgpack wire, compressed vs raw frames
    for mode, compress in (("wire_c", True), ("wire_r", False)):
        store = ObjectStore(tmp_path / mode)
        remote = RemoteStore(LoopbackTransport(RemoteServer(store)))
        push(lake.store, remote, "u.exp", jobs=4, compress_wire=compress)
        stores[mode] = store
    # S3 dialect, compressed vs raw frames
    for mode, compress in (("s3_c", True), ("s3_r", False)):
        httpd, url = serve_s3(tmp_path / mode)
        try:
            push(lake.store, connect(url), "u.exp", jobs=4,
                 compress_wire=compress)
        finally:
            httpd.shutdown()
        stores[mode] = ObjectStore(tmp_path / mode)

    reference = sorted(stores["wire_c"].iter_objects())
    assert set(reference) >= closure
    ref_refs = sorted(stores["wire_c"].list_refs()[0])
    for mode, store in stores.items():
        assert sorted(store.iter_objects()) == reference, mode
        assert sorted(store.list_refs()[0]) == ref_refs, mode
        for digest in closure:
            assert store.get(digest) == stores["wire_c"].get(digest), mode


# ---------------------------------------------------------------------- CLI
def test_cli_s3_remote_push_clone_gc(tmp_path, capsys):
    from repro.launch.repro_cli import main

    httpd, url = serve_s3(tmp_path / "bucket")
    try:
        lake = Lake(tmp_path / "lake", protect_main=False)
        lake.write_table("main", "t0",
                         {"v": np.arange(256, dtype=np.float32)})
        lake.catalog.create_branch("u.exp", "main", author="u")
        lake.write_table("u.exp", "t1",
                         {"v": np.ones(256, np.float32)}, author="u")

        base = ["--lake", str(tmp_path / "lake")]
        main(base + ["remote", "add", "s3", url])
        main(base + ["push", "--branch", "u.exp", "--remote", "s3"])
        out = capsys.readouterr().out
        assert "ref_updated=True" in out

        main(["clone", url, str(tmp_path / "clone")])
        capsys.readouterr()
        cloned = Lake(tmp_path / "clone", protect_main=False)
        assert cloned.catalog.head("u.exp") == lake.catalog.head("u.exp")
        np.testing.assert_array_equal(
            cloned.read_table("u.exp", "t1")["v"],
            lake.read_table("u.exp", "t1")["v"])

        # remote-side GC over the S3 dialect: while branch=u.exp roots the
        # pushed closure, nothing is sweepable
        remote = connect(url)
        head = lake.catalog.head("u.exp")
        n_before = len(list(remote.iter_objects()))
        main(base + ["gc", "--remote", "s3"])
        report = json.loads(capsys.readouterr().out.strip())
        assert report["target"] == "s3" and report["swept"] == 0
        # with the default grace window the just-pushed (young) objects
        # would be skipped, not swept — drop the only remote root and
        # sweep with --prune-age 0 for real.  The REMOTE's ref state
        # decides, not the local lake (which still has its branches).
        remote.delete_ref("branch=u.exp")
        main(base + ["gc", "--remote", "s3"])  # default window: all young
        report = json.loads(capsys.readouterr().out.strip())
        assert report["swept"] == 0 and report["skipped_young"] == n_before
        for digest in commit_closure(lake.store, head):
            assert remote.has(digest)
        main(base + ["gc", "--remote", "s3", "--prune-age", "0"])
        report = json.loads(capsys.readouterr().out.strip())
        assert report["swept"] == n_before and report["bytes_freed"] > 0
        assert not list(remote.iter_objects())
        # the sweep never touched local state
        for digest in commit_closure(lake.store, head):
            assert lake.store.has(digest)
    finally:
        httpd.shutdown()


# ----------------------------------------------- retry / throttle (bugfix)
@pytest.fixture()
def s3h(tmp_path):
    """Backend plus its stub httpd (fault-injection tests)."""
    httpd, url = serve_s3(tmp_path / "bucket")
    backend = connect(url)
    yield backend, httpd
    backend.close()
    httpd.shutdown()


def test_retryable_5xx_is_retried_with_backoff(s3h):
    """Regression: a 503 SlowDown on an idempotent request used to surface
    immediately (only transport exceptions were retried).  Two injected
    503s then success must be invisible to the caller."""
    s3, httpd = s3h
    s3.backoff = 0.001  # keep the test fast
    digest = s3.put(b"throttle me" * 40)
    httpd.inject_faults(2, status=503, method="GET")
    assert s3.get(digest) == b"throttle me" * 40
    assert httpd.faults.served == 2  # both faults were really injected


def test_5xx_surfaces_after_retry_budget_exhausted(s3h):
    """More consecutive 503s than the retry budget -> the error reaches
    the caller instead of retrying forever."""
    from repro.core.errors import RemoteError

    s3, httpd = s3h
    s3.backoff = 0.001
    digest = s3.put(b"hopeless" * 40)
    httpd.inject_faults(s3.retries + 5, status=503, method="GET")
    with pytest.raises(RemoteError, match="503"):
        s3.get(digest)


def test_500_internal_error_also_retried(s3h):
    s3, httpd = s3h
    s3.backoff = 0.001
    digest = s3.put(b"ie" * 60)
    httpd.inject_faults(1, status=500, method="HEAD")
    assert s3.has(digest) is True
    assert httpd.faults.served == 1


def test_conditional_write_is_never_blindly_retried(s3h):
    """A 5xx on a conditional ref write is ambiguous (the server may have
    applied it before failing to answer) — replaying it could clobber a
    racer.  The backend must surface the error after ONE attempt, even
    though a retry would have 'succeeded'."""
    from repro.core.errors import RemoteError

    s3, httpd = s3h
    s3.backoff = 0.001
    s3.set_ref("branch=b", "a" * 64)
    httpd.inject_faults(1, status=503, method="PUT", key_contains="refs/")
    with pytest.raises(RemoteError, match="503"):
        s3.cas_ref("branch=b", "a" * 64, "b" * 64)
    assert httpd.faults.served == 1  # exactly one attempt hit the server
    assert s3.get_ref("branch=b") == "a" * 64  # fault preceded the apply


# ----------------------------------------------- Last-Modified vs locale
def _set_non_c_time_locale():
    """Switch LC_TIME to a locale whose month names differ from C, or
    skip.  Exercises the header path that strftime/strptime("%b") would
    corrupt."""
    import locale

    for cand in ("fr_FR.UTF-8", "de_DE.UTF-8", "es_ES.UTF-8", "fr_FR",
                 "de_DE"):
        try:
            locale.setlocale(locale.LC_TIME, cand)
            return cand
        except locale.Error:
            continue
    pytest.skip("no non-C LC_TIME locale installed")


def test_last_modified_round_trip_is_locale_proof(tmp_path):
    """The stub must emit IMF-fixdate GMT headers and the backend must
    parse them via email.utils regardless of LC_TIME.  Pinned under a
    non-C locale so a regression to strftime('%a/%b') month names fails
    here instead of in production."""
    import locale
    import time as _time

    saved = locale.setlocale(locale.LC_TIME)
    _set_non_c_time_locale()
    try:
        httpd, url = serve_s3(tmp_path / "bucket")
        try:
            s3 = connect(url)
            before = _time.time()
            digest = s3.put(b"when was I written" * 20)
            mtime = s3.mtime(digest)
            size, stat_mtime = s3.stat(digest)
            # HTTP dates have 1s resolution; allow the floor
            assert before - 1.5 <= mtime <= _time.time() + 1.5
            assert stat_mtime == pytest.approx(mtime, abs=1.5)
            assert size == s3.size(digest)
            # and the header itself is an RFC 7231 GMT fixdate, with an
            # English month name even under fr/de locales
            status, headers, _b = s3._request(
                "HEAD", f"objects/{digest[:2]}/{digest[2:]}")
            assert status == 200
            lm = headers["last-modified"]
            assert lm.endswith("GMT")
            assert any(m in lm for m in
                       ("Jan", "Feb", "Mar", "Apr", "May", "Jun", "Jul",
                        "Aug", "Sep", "Oct", "Nov", "Dec"))
        finally:
            httpd.shutdown()
    finally:
        locale.setlocale(locale.LC_TIME, saved)


def test_sigv4_amz_date_is_locale_proof():
    """x-amz-date never goes through strftime month names."""
    import locale
    from datetime import datetime, timezone

    from repro.core import sigv4

    saved = locale.setlocale(locale.LC_TIME)
    _set_non_c_time_locale()
    try:
        stamp = sigv4._amz_date(
            datetime(2026, 8, 7, 23, 59, 5, tzinfo=timezone.utc))
        assert stamp == "20260807T235905Z"
    finally:
        locale.setlocale(locale.LC_TIME, saved)


# ------------------------------------- pagination under max-keys=1 (audit)
def test_pagination_at_max_keys_one_with_reserved_characters(tmp_path):
    """Server pages capped at ONE key, ref names that need percent
    encoding: continuation (start-after) tokens must round-trip encoded —
    a token that decodes or truncates loses or duplicates keys."""
    httpd, url = serve_s3(tmp_path / "bucket", max_keys_cap=1)
    try:
        s3 = connect(url)
        names = ["branch=exp 1", "branch=pct%25", "tag=h#v", "tag=q?x",
                 "branch=a+b", "cache/00/e", "cache/01/e"]
        for i, name in enumerate(names):
            s3.set_ref(name, f"{i:064d}"[:64])
        listed = []
        token = None
        pages = 0
        while True:
            page, token = s3.list_refs(page_token=token, limit=1000)
            assert len(page) <= 1  # the cap really bites
            listed.extend(page)
            pages += 1
            if token is None:
                break
        assert pages >= len(names)
        assert sorted(n for n, _v in listed) == sorted(names)
        for i, name in enumerate(names):
            value = dict(listed)[name]
            assert value == f"{i:064d}"[:64]
        # object listing under the same cap
        digests = {s3.put(bytes([i]) * 90) for i in range(5)}
        assert sorted(s3.iter_objects()) == sorted(digests)
        assert sorted(n for n in s3.iter_refs()) == sorted(names)
    finally:
        httpd.shutdown()


# --------------------------------------------------------- SigV4 signing
@pytest.fixture()
def signed(tmp_path):
    """Stub in verification mode + a backend that signs (creds from URL)."""
    from repro.core.sigv4 import Credentials

    creds = Credentials("AKIDEXAMPLE", "wJalrXUtnFEMI/K7MDENG+bPxRfiCY")
    httpd, url = serve_s3(tmp_path / "bucket", credentials=creds)
    backend = connect(url)
    yield backend, httpd, creds, url
    backend.close()
    httpd.shutdown()


def test_signed_round_trip_all_primitives(signed, tmp_path):
    """With verification armed, every request the backend makes must carry
    a signature the stub re-derives identically: objects, batched ops,
    paged listings, conditional ref writes, and keys needing percent
    encoding all round-trip."""
    s3, httpd, _creds, _url = signed
    data = b"signed payload " * 100
    digest = s3.put(data)
    assert s3.get(digest) == data
    assert s3.has(digest)
    assert s3.stat(digest)[0] == s3.size(digest)
    blobs = [bytes([i]) * 120 for i in range(8)]
    assert s3.has_many(s3.put_many(blobs)) == set(
        sha256_hex(b) for b in blobs)
    # percent-encoded key names exercise single-encoding of the canonical
    # URI; query canonicalization is exercised by the listing params
    for name in ("branch=exp 1", "tag=rel%41", "tag=h#v"):
        s3.set_ref(name, "a" * 64)
        assert s3.get_ref(name) == "a" * 64
    s3.cas_ref("branch=exp 1", "a" * 64, "b" * 64)
    assert sorted(s3.iter_objects()) == sorted(
        [digest] + [sha256_hex(b) for b in blobs])
    assert len(list(s3.iter_refs())) == 3
    s3.delete_ref("tag=h#v")
    assert s3.delete_object(digest) is True


def test_wrong_secret_is_rejected(signed, tmp_path):
    from repro.core.errors import RemoteError
    from repro.core.s3 import S3Backend

    s3, httpd, creds, url = signed
    digest = s3.put(b"protected" * 30)
    bad = url.replace(creds.secret_key.replace("/", "%2F")
                      .replace("+", "%2B"), "WRONGSECRET")
    assert "WRONGSECRET" in bad  # the replace really happened
    evil = connect(bad)
    with pytest.raises(RemoteError, match="403"):
        evil.get(digest)
    evil.close()


def test_unsigned_request_is_rejected_when_verification_armed(signed):
    from repro.core.errors import RemoteError

    s3, httpd, _creds, _url = signed
    digest = s3.put(b"no anonymous reads" * 10)
    anon = type(s3)(s3.endpoint, s3.bucket, credentials=None)
    try:
        with pytest.raises(RemoteError, match="403"):
            anon.get(digest)
    finally:
        anon.close()


def test_session_token_is_signed_and_forwarded(tmp_path):
    """STS-style credentials add x-amz-security-token to the signed set."""
    from repro.core.s3 import S3Backend
    from repro.core.sigv4 import Credentials

    creds = Credentials("AKID", "secret", session_token="tok/en+123")
    httpd, url = serve_s3(tmp_path / "bucket",
                          credentials=Credentials("AKID", "secret"))
    try:
        host, port = httpd.server_address
        s3 = S3Backend(f"http://{host}:{port}", "lake",
                       credentials=Credentials("AKID", "secret",
                                               session_token="tok/en+123"))
        digest = s3.put(b"sts" * 50)
        assert s3.get(digest) == b"sts" * 50
        s3.close()
    finally:
        httpd.shutdown()


def test_credentials_from_env(monkeypatch):
    from repro.core.sigv4 import Credentials

    monkeypatch.delenv("AWS_ACCESS_KEY_ID", raising=False)
    monkeypatch.delenv("AWS_SECRET_ACCESS_KEY", raising=False)
    monkeypatch.delenv("AWS_SESSION_TOKEN", raising=False)
    assert Credentials.from_env() is None
    monkeypatch.setenv("AWS_ACCESS_KEY_ID", "AK")
    assert Credentials.from_env() is None  # secret still missing
    monkeypatch.setenv("AWS_SECRET_ACCESS_KEY", "SK")
    creds = Credentials.from_env()
    assert creds == Credentials("AK", "SK")
    monkeypatch.setenv("AWS_SESSION_TOKEN", "TOK")
    assert Credentials.from_env().session_token == "TOK"


def test_sigv4_known_answer_vector():
    """Signature against a fixed clock/key is deterministic — pins the
    canonical-request and key-derivation math to exact output, so any
    canonicalization drift fails loudly even without the stub."""
    from datetime import datetime, timezone

    from repro.core.sigv4 import Credentials, SigV4Signer

    signer = SigV4Signer(
        Credentials("AKIDEXAMPLE",
                    "wJalrXUtnFEMI/K7MDENG+bPxRfiCYEXAMPLEKEY"),
        region="us-east-1",
        clock=lambda: datetime(2015, 8, 30, 12, 36, 0, tzinfo=timezone.utc))
    headers = signer.sign("GET", "example.amazonaws.com",
                          "/lake/refs/branch%3Dmain",
                          [("list-type", "2"), ("prefix", "refs/")], b"")
    assert headers["x-amz-date"] == "20150830T123600Z"
    auth = headers["Authorization"]
    assert auth.startswith(
        "AWS4-HMAC-SHA256 Credential=AKIDEXAMPLE/20150830/us-east-1/s3/"
        "aws4_request, SignedHeaders=host;x-amz-content-sha256;x-amz-date, "
        "Signature=")
    # byte-for-byte repeatability (same clock -> same signature)
    again = signer.sign("GET", "example.amazonaws.com",
                        "/lake/refs/branch%3Dmain",
                        [("list-type", "2"), ("prefix", "refs/")], b"")
    assert again == headers


# ------------------------------------------------- multipart + ranged GET
@pytest.fixture()
def mp(tmp_path):
    """Backend with toy multipart thresholds against the stub."""
    from repro.core.s3 import S3Backend

    httpd, url = serve_s3(tmp_path / "bucket")
    backend = S3Backend.from_url(url, multipart_threshold=64 << 10,
                                 part_size=64 << 10)
    yield backend, httpd, tmp_path / "bucket"
    backend.close()
    httpd.shutdown()


def test_multipart_upload_and_ranged_get_round_trip(mp):
    backend, httpd, root = mp
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, size=700_000, dtype=np.uint8).tobytes()
    digest = backend.put(data)  # compressed payload still > threshold
    assert backend.get(digest) == data  # ranged GET reassembly
    assert not httpd.uploads  # completed upload left no in-flight state
    # the stored object is indistinguishable from a single-shot PUT
    oracle = ObjectStore(root)
    assert oracle.get(digest) == data
    # and small objects still take the single-request path
    small = backend.put(b"tiny")
    assert backend.get(small) == b"tiny"


def test_failed_multipart_upload_aborts_and_leaves_no_orphans(mp,
                                                              monkeypatch):
    from repro.core.errors import RemoteError

    backend, httpd, root = mp
    backend.backoff = 0.001
    rng = np.random.default_rng(1)
    data = rng.integers(0, 256, size=400_000, dtype=np.uint8).tobytes()
    # every part PUT for this key answers 500, beyond the retry budget
    httpd.inject_faults(100, status=500, method="PUT",
                        key_contains="objects/")
    with pytest.raises(RemoteError):
        backend.put(data)
    assert not httpd.uploads  # abort ran: no orphaned multipart state
    assert not list(backend.iter_objects())  # and no partial object
    # the backend recovers once the weather clears
    httpd.faults._entries.clear()
    digest = backend.put(data)
    assert backend.get(digest) == data


def test_part_level_retry_heals_transient_faults(mp):
    backend, httpd, _root = mp
    backend.backoff = 0.001
    rng = np.random.default_rng(2)
    data = rng.integers(0, 256, size=300_000, dtype=np.uint8).tobytes()
    # one transient 503 somewhere inside the part sequence
    httpd.inject_faults(1, status=503, method="PUT",
                        key_contains="objects/")
    digest = backend.put(data)
    assert httpd.faults.served == 1
    assert backend.get(digest) == data
    assert not httpd.uploads


def test_ranged_get_downgrades_on_200(mp, monkeypatch):
    """A server that ignores Range and answers 200 with the whole body
    must still round-trip (downgrade, not error)."""
    backend, httpd, _root = mp
    rng = np.random.default_rng(3)
    data = rng.integers(0, 256, size=300_000, dtype=np.uint8).tobytes()
    digest = backend.put(data)
    real = type(backend)._request

    def no_range(self, method, key, *, headers=None, **kw):
        if headers and "Range" in headers:
            headers = {k: v for k, v in headers.items() if k != "Range"}
        return real(self, method, key, headers=headers, **kw)

    monkeypatch.setattr(type(backend), "_request", no_range)
    assert backend.get(digest) == data


def test_multipart_and_single_shot_store_identical_bytes(tmp_path):
    """Property at the boundary: the same blob uploaded multipart and
    single-shot lands byte-identical payloads (completes assemble in part
    order, no framing corruption at part seams)."""
    from repro.core.s3 import S3Backend

    rng = np.random.default_rng(4)
    data = rng.integers(0, 256, size=200_000, dtype=np.uint8).tobytes()
    httpd_a, url_a = serve_s3(tmp_path / "a")
    httpd_b, url_b = serve_s3(tmp_path / "b")
    try:
        multi = S3Backend.from_url(url_a, multipart_threshold=1,
                                   part_size=33_333)  # ragged final part
        single = S3Backend.from_url(url_b)
        da, db = multi.put(data), single.put(data)
        assert da == db
        assert multi.get_encoded(da) == single.get_encoded(db)
        multi.close(), single.close()
    finally:
        httpd_a.shutdown()
        httpd_b.shutdown()
