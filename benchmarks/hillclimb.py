"""§Perf hillclimb harness: lower + analyze named variants of the three
chosen (arch × shape) pairs and log hypothesis → before → after.

Run (one experiment at a time; each compiles a 256-device cell):
  PYTHONPATH=src python -m benchmarks.hillclimb --pair hymba --variant block_remat
  PYTHONPATH=src python -m benchmarks.hillclimb --all
Results append to results/perf/hillclimb.json.
"""

from __future__ import annotations

import argparse
import json
import os
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "perf"

# the three §Perf pairs (chosen per the mandate — see EXPERIMENTS.md):
PAIRS = {
    "hymba": ("hymba-1.5b", "train_4k"),        # worst roofline fraction
    "qwen3": ("qwen3-moe-235b-a22b", "prefill_32k"),  # most collective-bound
    "yi": ("yi-34b", "train_4k"),               # representative train cell
}

# variant name -> (lower_cell kwargs)
VARIANTS = {
    "baseline": {},
    "block_remat": {"cfg_overrides": {"attn_block_remat": True}},
    "moe_ep_constraints": {"moe_constraints": True},
    "moe_bf16_combine": {
        "cfg_overrides": {"moe_combine_dtype": "bfloat16"}},
    "moe_ep_shardmap": {"cfg_overrides": {"moe_impl": "ep"}},
    "moe_ep+block_remat": {"cfg_overrides": {"moe_impl": "ep",
                                             "attn_block_remat": True}},
    "ssm_chunk128": {"cfg_overrides": {"ssm_chunk": 128,
                                       "attn_block_remat": True}},
    "ssm_chunk64": {"cfg_overrides": {"ssm_chunk": 64,
                                      "attn_block_remat": True}},
    "moe_ep+bf16": {"moe_constraints": True,
                    "cfg_overrides": {"moe_combine_dtype": "bfloat16"}},
    "serve_layout": {"serving_layout": True},
    "serve_layout+moe": {"serving_layout": True, "moe_constraints": True,
                         "cfg_overrides": {"moe_combine_dtype": "bfloat16"}},
    "block2048": {"cfg_overrides": {"attn_block": 2048,
                                    "attn_block_remat": True}},
    "block4096": {"cfg_overrides": {"attn_block": 4096,
                                    "attn_block_remat": True}},
    "block512": {"cfg_overrides": {"attn_block": 512,
                                   "attn_block_remat": True}},
    "pure_fsdp": {"pure_fsdp": True,
                  "cfg_overrides": {"attn_block_remat": True}},
    "no_remat": {"remat": False},
    "no_tp": {"tp": False},
    "xla_attention": {"attention_impl": "xla"},
}


def run_variant(pair: str, variant: str) -> dict:
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    import jax
    from repro.distributed import analysis
    from repro.launch.dryrun import lower_cell

    arch, shape = PAIRS[pair]
    kw = VARIANTS[variant]
    lo, co, ctx = lower_cell(arch, shape, multi_pod=False, **kw)
    roof, coll = analysis.roofline_from_compiled(
        co, n_devices=256, model_flops_total=ctx["model_flops_total"])
    rec = {
        "pair": pair, "arch": arch, "shape": shape, "variant": variant,
        "roofline": roof.to_dict(),
        "collective_counts": coll.counts,
        "compile_seconds": ctx["compile_seconds"],
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    log_path = RESULTS / "hillclimb.json"
    log = json.loads(log_path.read_text()) if log_path.exists() else []
    log = [r for r in log
           if not (r["pair"] == pair and r["variant"] == variant)]
    log.append(rec)
    log_path.write_text(json.dumps(log, indent=1, default=float))
    r = roof
    print(f"{pair}/{variant}: t=({r.t_compute:.2f},{r.t_memory:.2f},"
          f"{r.t_collective:.2f}) bneck={r.bottleneck} "
          f"useful={r.useful_flops_ratio:.3f} mfu_bound={r.mfu_bound:.4f}",
          flush=True)
    jax.clear_caches()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=list(PAIRS))
    ap.add_argument("--variant", choices=list(VARIANTS))
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    if args.all:
        plan = [("hymba", "baseline"), ("hymba", "block_remat"),
                ("qwen3", "baseline"), ("qwen3", "moe_ep_constraints"),
                ("yi", "baseline"), ("yi", "block_remat")]
        for pair, variant in plan:
            run_variant(pair, variant)
    else:
        assert args.pair and args.variant
        run_variant(args.pair, args.variant)


if __name__ == "__main__":
    main()
