"""Serving tier — continuous batching vs the fixed baseline, sustained
latency, and the cost of a tag-flip rollout under load.

Three legs (CSV via ``common.emit``, PASS/FAIL lines for the CI smoke):

* ``throughput``: one long-tail mixed-length workload served by the fixed
  bucket scheduler and by the continuous batcher over the SAME engine —
  requests/s both ways, with the continuous outputs checked token-for-token
  against sequential generation (the speed is free of correctness caveats;
  target: ≥2x on mixed lengths, the head-of-line dividend);
* ``latency``: a 2-replica fleet under steady arrivals — sustained RPS,
  p50/p99 request latency;
* ``rollout``: the same fleet with ``serving/prod`` flipped mid-stream —
  zero failed requests required, and the completion "blip" (longest streak
  of decode intervals with work pending but nothing finishing, across the
  rollout) must stay within ONE fixed-batch interval (the time a fixed
  bucket holds its batch: max ``n_tokens`` in flight).

Run:  PYTHONPATH=src:. python -m benchmarks.bench_serve
"""

from __future__ import annotations

import tempfile
import time

import jax
import numpy as np

from repro.checkpoint import save
from repro.configs import smoke_config
from repro.core import Lake
from repro.models import init_params
from repro.serving import (ContinuousBatcher, FixedBatchedServer,
                           ServeEngine, ServingFleet, flip_tag)
from .common import emit

MAX_LEN = 80
SLOTS = 4
LONG_N, SHORT_LO, SHORT_HI = 64, 1, 3


def _world(tmp):
    """A lake with two checkpoints and ``serving/prod`` on the first."""
    lake = Lake(tmp, protect_main=False)
    cfg = smoke_config("paper-demo")
    lake.catalog.create_branch("t.run", "main", author="t")
    a = save(lake, "t.run", step=1,
             params=init_params(cfg, jax.random.PRNGKey(0)), author="t")
    b = save(lake, "t.run", step=2,
             params=init_params(cfg, jax.random.PRNGKey(1)), author="t")
    flip_tag(lake, a)
    return lake, cfg, a, b


def _workload(cfg, n, *, seed=0):
    """Long-tail mix: mostly short generations, every 4th one long — the
    shape that makes fixed buckets pay ``bs × max(n_tokens)`` for rows
    that needed a fraction of it."""
    rng = np.random.default_rng(seed)
    return [(rid,
             rng.integers(3, cfg.vocab_size,
                          size=int(rng.integers(4, 12))).astype(np.int32),
             LONG_N if rid % 4 == 0
             else int(rng.integers(SHORT_LO, SHORT_HI + 1)))
            for rid in range(n)]


def throughput(lake, cfg, commit, *, n=16):
    reqs = _workload(cfg, n)
    engine = ServeEngine.from_catalog(lake, commit, cfg, max_len=MAX_LEN,
                                      batch_size=SLOTS)
    solo = ServeEngine.from_catalog(lake, commit, cfg, max_len=MAX_LEN,
                                    batch_size=1)
    oracle = {rid: solo.generate(p[None], n_tokens=k).tokens[0]
              for rid, p, k in reqs}  # also warms every jit

    def run(server):
        for rid, p, k in reqs:
            server.submit(rid, p, k)
        t0 = time.perf_counter()
        while server.pending:
            server.step()
        return time.perf_counter() - t0

    run(FixedBatchedServer(engine))        # warm every jit both schedulers
    run(ContinuousBatcher(engine, slots=SLOTS))
    t_fixed = min(run(FixedBatchedServer(engine)) for _ in range(2))
    cont = ContinuousBatcher(engine, slots=SLOTS)
    t_cont = run(cont)
    t_cont = min(t_cont, run(ContinuousBatcher(engine, slots=SLOTS)))
    for rid, _p, _k in reqs:  # the speedup must not cost correctness
        np.testing.assert_array_equal(cont.completed[rid].tokens[0],
                                      oracle[rid])
    emit("serve_fixed_rps", t_fixed / n * 1e6, f"{n / t_fixed:.1f} req/s")
    emit("serve_continuous_rps", t_cont / n * 1e6,
         f"{n / t_cont:.1f} req/s")
    speedup = t_fixed / t_cont
    emit("serve_continuous_speedup", t_cont * 1e6,
         f"{speedup:.2f}x vs fixed (bit-identical to sequential)")
    status = "PASS" if speedup >= 2.0 else "FAIL"
    print(f"{status}: continuous batching {speedup:.2f}x over fixed "
          f"buckets on the long-tail mix (target >=2x)")
    return speedup


def latency(lake, cfg, *, n=24):
    fleet = ServingFleet(lake, cfg, replicas=2, slots=SLOTS,
                         max_len=MAX_LEN)
    reqs = _workload(cfg, n, seed=1)
    t0 = time.perf_counter()
    for rid, p, k in reqs:   # steady arrivals: one request per interval
        fleet.submit(rid, p, k)
        fleet.step()
    fleet.drain()
    wall = time.perf_counter() - t0
    lats = np.asarray(sorted(fleet.latency.values())) * 1e6
    emit("serve_sustained_rps", wall / n * 1e6, f"{n / wall:.1f} req/s")
    emit("serve_latency_p50", float(np.percentile(lats, 50)))
    emit("serve_latency_p99", float(np.percentile(lats, 99)))


def rollout_blip(lake, cfg, commit_b, *, n=24):
    """Sustained load with a tag flip mid-stream: count completions per
    fleet step; the blip is the longest pending-but-idle streak."""
    fleet = ServingFleet(lake, cfg, replicas=2, slots=SLOTS,
                         max_len=MAX_LEN, poll_every=2)
    reqs = _workload(cfg, n, seed=2)
    gaps, gap = [], 0
    for i, (rid, p, k) in enumerate(reqs):
        fleet.submit(rid, p, k)
        if i == n // 3:
            flip_tag(lake, commit_b)
        done = fleet.step()
        gap = 0 if done else (gap + 1 if fleet.pending else gap)
        gaps.append(gap)
    while fleet.pending:
        done = fleet.step()
        gap = 0 if done else (gap + 1 if fleet.pending else gap)
        gaps.append(gap)
    for _ in range(3 * fleet.poll_every):  # finish the rolling update
        fleet.step()

    failed = [rid for rid, _p, k in reqs
              if rid not in fleet.completed
              or fleet.completed[rid].tokens.shape[1] != k]
    blip = max(gaps)
    batch_interval = LONG_N  # what one fixed bucket holds its batch for
    emit("serve_rollout_blip_intervals", float(blip),
         f"budget={batch_interval} (one fixed-batch interval)")
    emit("serve_rollout_failed_requests", float(len(failed)))
    ok = not failed and blip <= batch_interval and fleet.rollouts == 1 \
        and all(r.commit == commit_b for r in fleet.replicas if r.alive)
    print(f"{'PASS' if ok else 'FAIL'}: tag-flip rollout under load — "
          f"{len(failed)} failed requests, blip {blip} intervals "
          f"(budget {batch_interval}), fleet converged on the new commit")
    return ok


def main():
    with tempfile.TemporaryDirectory(prefix="bench-serve-") as tmp:
        lake, cfg, a, b = _world(tmp)
        speedup = throughput(lake, cfg, a)
        latency(lake, cfg)
        ok = rollout_blip(lake, cfg, b)
        if speedup < 2.0 or not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
