"""Multi-pod gradient-reduction schedules — collective bytes compared.

Lowers three reductions of a gradient-sized tensor on the 2×16×16 mesh and
reports per-device link bytes from the compiled HLO:

  flat        — jax.lax.psum over ("pod","data") (what SPMD does)
  hierarchical— RS(data) → AR(pod) → AG(data)   (cross-pod hop carries 1/16)
  hier+int8   — same, cross-pod hop quantized int8 with error feedback

Run standalone (needs 512 host devices → separate process):
  PYTHONPATH=src python -m benchmarks.bench_multipod
"""

from __future__ import annotations

import os


def main(n_params: int = 25_165_824):  # rows divisible by the 32 dp shards
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed import analysis
    from repro.distributed.collectives import (hierarchical_psum,
                                               hierarchical_psum_int8)
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=True)
    rows = n_params // 1024
    x = jax.ShapeDtypeStruct((rows, 1024), jnp.float32)
    spec = P(("pod", "data"))
    shd = NamedSharding(mesh, spec)

    import re

    def lower(fn, *extra):
        sm = jax.shard_map(fn, mesh=mesh,
                           in_specs=(spec,) * (1 + len(extra)),
                           out_specs=spec, check_vma=False)
        with mesh:
            c = jax.jit(sm, in_shardings=(shd,) * (1 + len(extra))) \
                .lower(x, *extra).compile()
        text = c.as_text()
        stats = analysis.parse_collectives(text, n_devices=512)
        # cross-pod traffic: collectives whose replica group size == 2
        # (the pod axis) — the slow-link bytes that matter at multi-pod
        cross = 0.0
        for line in text.splitlines():
            mt = analysis._TUPLE_OP_RE.search(line)
            m = None if mt else analysis._OP_RE.search(line)
            if not m and not mt:
                continue
            if mt:
                rb = sum(analysis._shape_bytes(d, s) for d, s in
                         analysis._SHAPE_RE.findall(mt.group(1)))
            else:
                rb = analysis._shape_bytes(m.group(1), m.group(2))
            # cross-pod traffic: a collective crosses the pod boundary if
            # any replica group contains ids from both pods (<256 and ≥256)
            spans = "collective-permute" in line  # pairwise pod exchange
            mg = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
            if mg:
                ids = [int(v) for v in mg.group(1).split(",")]
                spans = spans or (min(ids) < 256 <= max(ids))
            else:
                g = analysis._group_size(line, 512)
                spans = spans or g in (2, 32, 512)  # pod-spanning groups
            if spans:
                cross += rb
        return stats, cross

    flat = lower(lambda g: jax.lax.psum(g, ("pod", "data")))
    hier = lower(lambda g: hierarchical_psum(g, intra_axis="data",
                                             inter_axis="pod"))
    # residual lives on the scattered shard: per-device rows/|data|;
    # as a GLOBAL array under P(("pod","data")) that is rows/|data| total
    r = jax.ShapeDtypeStruct((rows // 16, 1024), jnp.float32)
    hier8 = lower(lambda g, res: hierarchical_psum_int8(
        g, res, intra_axis="data", inter_axis="pod")[0], r)

    print("name,us_per_call,derived")
    gb = n_params * 4 / 1e9
    for name, (st, cross) in [("multipod/flat_psum", flat),
                              ("multipod/hierarchical", hier),
                              ("multipod/hierarchical_int8", hier8)]:
        t = st.link_bytes / analysis.ICI_BW
        print(f"{name},{t * 1e6:.1f},"
              f"crosspod_MB_per_dev={cross / 1e6:.2f};"
              f"total_link_GB_per_dev={st.link_bytes / 1e9:.3f};"
              f"grad_GB={gb:.2f};counts={dict(st.counts)}")


if __name__ == "__main__":
    main()
