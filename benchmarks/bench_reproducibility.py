"""Paper Table 1 — the reproducibility checklist.

Measures the END-TO-END cost of what the table demands: pinning input data,
code, runtime and hardware per run, and replaying a run bit-exactly.
Derived column reports the replay fidelity (bit_exact) and which checklist
rows the run manifest actually pins."""

from __future__ import annotations

import tempfile

import numpy as np

from repro.core import Lake, Model, Pipeline, col, lit, model, sql_model
from .common import emit, timeit


def _pipeline():
    final_table = sql_model("final_table", select=["c1", "c2"],
                            frm="source_table",
                            where=col("ts") >= lit(100))

    @model()
    def training_data(data=Model("final_table")):
        return {"x": data["c1"] * 2.0, "y": data["c2"]}

    return Pipeline([final_table, training_data])


def main(n_rows: int = 100_000):
    with tempfile.TemporaryDirectory() as tmp:
        lake = Lake(tmp)
        rng = np.random.default_rng(0)
        src = {"c1": rng.normal(size=n_rows).astype(np.float32),
               "c2": rng.integers(0, 9, n_rows).astype(np.int64),
               "ts": np.arange(n_rows, dtype=np.int64)}
        snap = lake.io.write_snapshot(src)
        lake.catalog.commit("main", {"source_table": snap}, "seed",
                            _wap_token=True)
        pipe = _pipeline()
        lake.catalog.create_branch("b.dev", "main", author="b")

        res_holder = {}

        def do_run():
            res_holder["res"] = lake.run(pipe, branch="b.dev", author="b")

        us_run = timeit(do_run, repeats=3)
        res = res_holder["res"]
        manifest = lake.ledger.get(res.run_id)
        pinned = [k for k in ("data_commit", "code", "runtime", "hardware")
                  if manifest.get(k)]
        emit("table1/run_with_manifest", us_run,
             f"rows={n_rows};pins={'+'.join(pinned)}")

        i = [0]

        def do_replay():
            i[0] += 1
            rep = lake.replay(res.run_id, pipe, branch=f"b.dbg{i[0]}",
                              author="b")
            assert rep.bit_exact
        us_rep = timeit(do_replay, repeats=3)
        emit("table1/replay_bit_exact", us_rep, "bit_exact=True")

        # runtime pinning: code drift must be detected
        def drifted():
            p2 = Pipeline([sql_model("final_table", select=["c1", "c2"],
                                     frm="source_table",
                                     where=col("ts") >= lit(999)),
                           pipe.nodes["training_data"]])
            from repro.core import CodeDrift
            try:
                lake.replay(res.run_id, p2, branch="b.never", author="b")
                return False
            except CodeDrift:
                return True
        emit("table1/code_drift_detected", timeit(drifted, repeats=3),
             f"detected={drifted()}")


if __name__ == "__main__":
    main()
