"""Roofline summary — reads results/dryrun/*.json (produced by
``repro.launch.dryrun``) and emits one CSV row per (arch × shape × mesh)
cell: the three roofline terms, the bottleneck, and the MFU bound."""

from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def main():
    recs = []
    for p in sorted(RESULTS.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") != "ok":
            continue
        recs.append(r)
    for r in recs:
        rl = r["roofline"]
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        us = rl["step_time_lb"] * 1e6
        derived = (f"bottleneck={rl['bottleneck']};"
                   f"tc={rl['t_compute']:.4f};tm={rl['t_memory']:.4f};"
                   f"tx={rl['t_collective']:.4f};"
                   f"useful={rl['useful_flops_ratio']:.3f};"
                   f"mfu_bound={rl['mfu_bound']:.3f}")
        print(f"{name},{us:.1f},{derived}")
    if not recs:
        print("roofline/none,0,run repro.launch.dryrun first")


if __name__ == "__main__":
    main()
