"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from
results/dryrun/*.json.  Run:  python -m benchmarks.report > /tmp/tables.md
"""

from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def _fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f} TB"
    if b >= 1e9:
        return f"{b/1e9:.2f} GB"
    return f"{b/1e6:.1f} MB"


def load(mesh):
    recs = {}
    for p in sorted(RESULTS.glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        recs[(r["arch"], r["shape"])] = r
    return recs


def dryrun_table():
    single = load("16x16")
    multi = load("2x16x16")
    print("| arch | shape | 16×16 | 2×16×16 | compile s (1pod) | "
          "HLO GB/dev | collectives (1pod) |")
    print("|---|---|---|---|---|---|---|")
    for key in sorted(single):
        s, m = single[key], multi.get(key, {})
        status_s = s["status"]
        status_m = m.get("status", "—")
        if status_s == "ok":
            mem = s.get("memory_analysis", {})
            dev_gb = (mem.get("temp_size_in_bytes", 0)
                      + mem.get("argument_size_in_bytes", 0)) / 256 / 1e9
            colls = ",".join(f"{k}:{v}" for k, v in
                             sorted(s["collectives"]["counts"].items()))
            extra = (f"{s['compile_seconds']:.1f} | {dev_gb:.2f} | {colls}")
        else:
            extra = "— | — | —"
        print(f"| {key[0]} | {key[1]} | {status_s} | {status_m} | {extra} |")


def roofline_table():
    single = load("16x16")
    print("| arch | shape | t_comp s | t_mem s | t_coll s | bottleneck | "
          "useful | MFU bound | fix for dominant term |")
    print("|---|---|---|---|---|---|---|---|---|")
    hints = {
        "memory": "fuse/remat attention blocks; bf16 intermediates",
        "collective": "reshard to cut all-to-alls; overlap with compute",
        "compute": "larger per-chip batch; MXU-aligned tiles",
    }
    for key in sorted(single, key=lambda k: (k[1], k[0])):
        r = single[key]
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        print(f"| {key[0]} | {key[1]} | {rl['t_compute']:.3f} | "
              f"{rl['t_memory']:.3f} | {rl['t_collective']:.3f} | "
              f"{rl['bottleneck']} | {rl['useful_flops_ratio']:.3f} | "
              f"{rl['mfu_bound']:.3f} | {hints[rl['bottleneck']]} |")


def main():
    print("### §Dry-run table (auto-generated)\n")
    dryrun_table()
    print("\n### §Roofline table (auto-generated, single-pod 16×16)\n")
    roofline_table()


if __name__ == "__main__":
    main()
