"""Benchmark harness — one module per paper table/figure.

Emits ``name,us_per_call,derived`` CSV.  Mapping (DESIGN.md §7):
  bench_reproducibility → Table 1 (run manifests, bit-exact replay)
  bench_pipeline        → Fig. 1–2 (DAG runs, persistence hierarchy)
  bench_runtime         → Fig. 3 (read/write path, run-id overhead)
  bench_branching       → Fig. 4 + §5.4 (CoW branching, time travel)
  bench_train           → training integration (checkpoint-as-commit)
  bench_roofline        → scale mandate (summarizes results/dryrun)
"""

from __future__ import annotations

import sys


def main() -> None:
    from . import (bench_branching, bench_pipeline, bench_reproducibility,
                   bench_runtime, bench_train)

    print("name,us_per_call,derived")
    bench_reproducibility.main()
    bench_pipeline.main()
    bench_runtime.main()
    bench_branching.main()
    bench_train.main()
    try:
        from . import bench_roofline
        bench_roofline.main()
    except Exception as e:  # dry-run results may not exist yet
        print(f"roofline/summary,0,skipped({type(e).__name__})",
              file=sys.stderr)


if __name__ == "__main__":
    main()
