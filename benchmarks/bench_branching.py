"""Paper Fig. 4 + §5.4 — debug branches and copy-on-write.

The paper's claim: "Nessie builds the debug branch through copy-on-write
semantics over the lake, avoiding slow and costly copies."  We verify the
claim structurally: branch-creation time and bytes-written must be CONSTANT
in table size (derived column shows both across 100× size range)."""

from __future__ import annotations

import tempfile
import threading
import time

import numpy as np

from repro.core import Lake, MergeConflict
from .common import emit, timeit


def _store_bytes(lake):
    return sum(lake.store.size(d) for d in lake.store.iter_objects())


def main():
    for n_rows in (10_000, 100_000, 1_000_000):
        with tempfile.TemporaryDirectory() as tmp:
            lake = Lake(tmp, protect_main=False)
            rng = np.random.default_rng(0)
            cols = {"x": rng.normal(size=n_rows).astype(np.float32)}
            lake.write_table("main", "big", cols)
            before = _store_bytes(lake)
            i = [0]

            def branch():
                i[0] += 1
                lake.catalog.create_branch(f"u.b{i[0]}", "main", author="u")

            us = timeit(branch, repeats=5)
            grew = _store_bytes(lake) - before
            emit(f"fig4/branch_{n_rows}rows", us,
                 f"bytes_copied={grew}")  # must be 0 at every size

    # time-travel + replay-debug loop of use case #2
    with tempfile.TemporaryDirectory() as tmp:
        lake = Lake(tmp, protect_main=False)
        rng = np.random.default_rng(0)
        for day in range(10):  # ten nightly "production" commits
            lake.write_table("main", "training_data",
                             {"x": rng.normal(size=1000).astype(np.float32)})
        monday = lake.catalog.resolve("main~5")

        def checkout_past():
            lake.catalog.resolve("main~5")
        emit("fig4/time_travel_resolve", timeit(checkout_past), "commits_back=5")

        k = [0]

        def debug_branch_at_past():
            k[0] += 1
            lake.catalog.create_branch(f"r.dbg{k[0]}", monday, author="r")
        emit("fig4/debug_branch_at_commit", timeit(debug_branch_at_past),
             "cow=True")

        def merge_ff():
            name = f"r.m{k[0]}"
            k[0] += 1
            lake.catalog.create_branch(name, "main", author="r")
            lake.write_table(name, f"t{k[0]}",
                             {"x": np.ones(10, np.float32)}, author="r")
            lake.catalog.merge(name, "main")
        emit("fig4/branch_write_merge", timeit(merge_ff), "")

    _multi_writer_leg()
    _churn_leg()


def _multi_writer_leg(n_writers: int = 6, commits_each: int = 20):
    """N concurrent writers committing to DISJOINT tables on one branch.

    The before/after of the transaction layer: at the ref level every one
    of these commits races every other, so the retry count ("rebases")
    shows the contention the catalog absorbs; the caller-visible conflict
    count must be ZERO — that is the spurious-conflict bugfix, measured.
    """
    with tempfile.TemporaryDirectory() as tmp:
        lake = Lake(tmp, protect_main=False)
        snaps = [lake.io.write_snapshot(
            {"x": np.full(64, float(j), np.float32)})
            for j in range(commits_each)]
        conflicts = [0]

        def writer(i):
            for j in range(commits_each):
                try:
                    lake.catalog.commit("main", {f"t{i}": snaps[j]},
                                        f"w{i} c{j}", author=f"w{i}")
                except MergeConflict:  # includes TransactionConflict
                    conflicts[0] += 1

        t0 = time.perf_counter()
        threads = [threading.Thread(target=writer, args=(i,))
                   for i in range(n_writers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        total = n_writers * commits_each
        stats = lake.catalog.txn_stats
        assert conflicts[0] == 0, (
            f"disjoint writers saw {conflicts[0]} spurious conflicts")
        emit(f"txn/multi_writer_{n_writers}x{commits_each}",
             wall / total * 1e6,
             f"commits_per_s={total / wall:.0f};rebases={stats['rebases']};"
             f"caller_visible_conflicts={conflicts[0]}")


def _churn_leg():
    """High-churn streaming tables over the manifest hierarchy (§4.2
    analogue): append cost must be O(delta) — flat as the table's file
    count grows 10× — zone-pruned selective scans must beat full scans,
    same-table append/append writers must merge conflict-free, and
    compaction must rewrite the fragment tail losslessly (digest-proved).
    """
    from repro.core import TableIO, col, compact_snapshot
    from repro.core.errors import MergeConflict

    # -- append cost vs accumulated file count (O(delta) claim) ----------
    with tempfile.TemporaryDirectory() as tmp:
        lake = Lake(tmp, protect_main=False)
        io = TableIO(lake.store, target_rows_per_file=256)
        head = [io.write_snapshot(
            {"ts": np.arange(256, dtype=np.int64),
             "x": np.zeros(256, np.float32)})]
        n = [256]

        def append_batch():
            a = np.arange(n[0], n[0] + 64, dtype=np.int64)
            n[0] += 64
            head[0] = io.append(head[0], {"ts": a,
                                          "x": np.zeros(64, np.float32)})

        us_small = timeit(append_batch, repeats=7)
        while io.load_snapshot(head[0]).nfiles < 100:  # grow the table 10x+
            append_batch()
        nfiles = io.load_snapshot(head[0]).nfiles
        us_large = timeit(append_batch, repeats=7)
        emit("churn/append_10files", us_small, "")
        emit(f"churn/append_{nfiles}files", us_large,
             f"ratio_vs_small={us_large / us_small:.2f}")  # ~1.0 = O(delta)

        # -- zone-pruned selective scan vs full-scan filter --------------
        final = head[0]

        def full_scan():
            frames = list(io.iter_files(final))
            return sum(f["ts"].shape[0] for f in frames)

        hi = n[0] - 32  # predicate selects only the newest fragment
        def pruned_scan():
            return io.read(final, where=col("ts") >= hi)

        us_full = timeit(full_scan, repeats=5)
        us_pruned = timeit(pruned_scan, repeats=5)
        emit("churn/scan_full", us_full, f"nfiles={nfiles}")
        emit("churn/scan_zone_pruned", us_pruned,
             f"speedup={us_full / us_pruned:.1f}x")  # >=3x on selective preds

        # -- compaction: lossless rewrite of the fragment tail -----------
        before = io.logical_digest(final)
        t0 = time.perf_counter()
        report = compact_snapshot(io, final)
        wall = (time.perf_counter() - t0) * 1e6
        assert report.logical_digest == before, "compaction changed contents"
        emit("churn/compact", wall,
             f"files={report.files_before}->{report.files_after};"
             f"write_amp={report.bytes_written / max(1, report.bytes_read):.2f};"
             "digest=verified")

        # metadata cost is O(#manifests) per append (the manifest-list is
        # rewritten); compaction collapses the manifests, so append cost
        # falls back to the small-table baseline — the two halves of the
        # streaming bargain, measured
        head[0] = report.new_snapshot
        us_after = timeit(append_batch, repeats=7)
        emit("churn/append_after_compact", us_after,
             f"ratio_vs_small={us_after / us_small:.2f}")

    # -- same-TABLE concurrent appends: zero caller-visible conflicts ----
    with tempfile.TemporaryDirectory() as tmp:
        lake = Lake(tmp, protect_main=False)
        lake.write_table("main", "events",
                         {"v": np.arange(64, dtype=np.int64)})
        conflicts = [0]
        batches_each = 15

        def appender(i):
            for j in range(batches_each):
                try:
                    txn = lake.catalog.transaction("main", author=f"w{i}")
                    txn.write("events",
                              {"v": np.arange(j * 8, j * 8 + 8,
                                              dtype=np.int64) + i * 10_000},
                              append=True)
                    txn.commit(f"w{i} b{j}")
                except MergeConflict:
                    conflicts[0] += 1

        t0 = time.perf_counter()
        threads = [threading.Thread(target=appender, args=(i,))
                   for i in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        total = 2 * batches_each
        stats = lake.catalog.txn_stats
        assert conflicts[0] == 0, (
            f"same-table appenders saw {conflicts[0]} conflicts")
        rows = lake.read_table("main", "events")["v"].shape[0]
        assert rows == 64 + total * 8, f"lost updates: {rows} rows"
        emit(f"txn/same_table_appenders_2x{batches_each}",
             wall / total * 1e6,
             f"commits_per_s={total / wall:.0f};"
             f"append_merges={stats['append_merges']};"
             f"caller_visible_conflicts={conflicts[0]}")


if __name__ == "__main__":
    main()
