"""Paper Fig. 4 + §5.4 — debug branches and copy-on-write.

The paper's claim: "Nessie builds the debug branch through copy-on-write
semantics over the lake, avoiding slow and costly copies."  We verify the
claim structurally: branch-creation time and bytes-written must be CONSTANT
in table size (derived column shows both across 100× size range)."""

from __future__ import annotations

import tempfile
import threading
import time

import numpy as np

from repro.core import Lake, MergeConflict
from .common import emit, timeit


def _store_bytes(lake):
    return sum(lake.store.size(d) for d in lake.store.iter_objects())


def main():
    for n_rows in (10_000, 100_000, 1_000_000):
        with tempfile.TemporaryDirectory() as tmp:
            lake = Lake(tmp, protect_main=False)
            rng = np.random.default_rng(0)
            cols = {"x": rng.normal(size=n_rows).astype(np.float32)}
            lake.write_table("main", "big", cols)
            before = _store_bytes(lake)
            i = [0]

            def branch():
                i[0] += 1
                lake.catalog.create_branch(f"u.b{i[0]}", "main", author="u")

            us = timeit(branch, repeats=5)
            grew = _store_bytes(lake) - before
            emit(f"fig4/branch_{n_rows}rows", us,
                 f"bytes_copied={grew}")  # must be 0 at every size

    # time-travel + replay-debug loop of use case #2
    with tempfile.TemporaryDirectory() as tmp:
        lake = Lake(tmp, protect_main=False)
        rng = np.random.default_rng(0)
        for day in range(10):  # ten nightly "production" commits
            lake.write_table("main", "training_data",
                             {"x": rng.normal(size=1000).astype(np.float32)})
        monday = lake.catalog.resolve("main~5")

        def checkout_past():
            lake.catalog.resolve("main~5")
        emit("fig4/time_travel_resolve", timeit(checkout_past), "commits_back=5")

        k = [0]

        def debug_branch_at_past():
            k[0] += 1
            lake.catalog.create_branch(f"r.dbg{k[0]}", monday, author="r")
        emit("fig4/debug_branch_at_commit", timeit(debug_branch_at_past),
             "cow=True")

        def merge_ff():
            name = f"r.m{k[0]}"
            k[0] += 1
            lake.catalog.create_branch(name, "main", author="r")
            lake.write_table(name, f"t{k[0]}",
                             {"x": np.ones(10, np.float32)}, author="r")
            lake.catalog.merge(name, "main")
        emit("fig4/branch_write_merge", timeit(merge_ff), "")

    _multi_writer_leg()


def _multi_writer_leg(n_writers: int = 6, commits_each: int = 20):
    """N concurrent writers committing to DISJOINT tables on one branch.

    The before/after of the transaction layer: at the ref level every one
    of these commits races every other, so the retry count ("rebases")
    shows the contention the catalog absorbs; the caller-visible conflict
    count must be ZERO — that is the spurious-conflict bugfix, measured.
    """
    with tempfile.TemporaryDirectory() as tmp:
        lake = Lake(tmp, protect_main=False)
        snaps = [lake.io.write_snapshot(
            {"x": np.full(64, float(j), np.float32)})
            for j in range(commits_each)]
        conflicts = [0]

        def writer(i):
            for j in range(commits_each):
                try:
                    lake.catalog.commit("main", {f"t{i}": snaps[j]},
                                        f"w{i} c{j}", author=f"w{i}")
                except MergeConflict:  # includes TransactionConflict
                    conflicts[0] += 1

        t0 = time.perf_counter()
        threads = [threading.Thread(target=writer, args=(i,))
                   for i in range(n_writers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        total = n_writers * commits_each
        stats = lake.catalog.txn_stats
        assert conflicts[0] == 0, (
            f"disjoint writers saw {conflicts[0]} spurious conflicts")
        emit(f"txn/multi_writer_{n_writers}x{commits_each}",
             wall / total * 1e6,
             f"commits_per_s={total / wall:.0f};rebases={stats['rebases']};"
             f"caller_visible_conflicts={conflicts[0]}")


if __name__ == "__main__":
    main()
