"""Paper Fig. 4 + §5.4 — debug branches and copy-on-write.

The paper's claim: "Nessie builds the debug branch through copy-on-write
semantics over the lake, avoiding slow and costly copies."  We verify the
claim structurally: branch-creation time and bytes-written must be CONSTANT
in table size (derived column shows both across 100× size range)."""

from __future__ import annotations

import tempfile

import numpy as np

from repro.core import Lake
from .common import emit, timeit


def _store_bytes(lake):
    return sum(lake.store.size(d) for d in lake.store.iter_objects())


def main():
    for n_rows in (10_000, 100_000, 1_000_000):
        with tempfile.TemporaryDirectory() as tmp:
            lake = Lake(tmp, protect_main=False)
            rng = np.random.default_rng(0)
            cols = {"x": rng.normal(size=n_rows).astype(np.float32)}
            lake.write_table("main", "big", cols)
            before = _store_bytes(lake)
            i = [0]

            def branch():
                i[0] += 1
                lake.catalog.create_branch(f"u.b{i[0]}", "main", author="u")

            us = timeit(branch, repeats=5)
            grew = _store_bytes(lake) - before
            emit(f"fig4/branch_{n_rows}rows", us,
                 f"bytes_copied={grew}")  # must be 0 at every size

    # time-travel + replay-debug loop of use case #2
    with tempfile.TemporaryDirectory() as tmp:
        lake = Lake(tmp, protect_main=False)
        rng = np.random.default_rng(0)
        for day in range(10):  # ten nightly "production" commits
            lake.write_table("main", "training_data",
                             {"x": rng.normal(size=1000).astype(np.float32)})
        monday = lake.catalog.resolve("main~5")

        def checkout_past():
            lake.catalog.resolve("main~5")
        emit("fig4/time_travel_resolve", timeit(checkout_past), "commits_back=5")

        k = [0]

        def debug_branch_at_past():
            k[0] += 1
            lake.catalog.create_branch(f"r.dbg{k[0]}", monday, author="r")
        emit("fig4/debug_branch_at_commit", timeit(debug_branch_at_past),
             "cow=True")

        def merge_ff():
            name = f"r.m{k[0]}"
            k[0] += 1
            lake.catalog.create_branch(name, "main", author="r")
            lake.write_table(name, f"t{k[0]}",
                             {"x": np.ones(10, np.float32)}, author="r")
            lake.catalog.merge(name, "main")
        emit("fig4/branch_write_merge", timeit(merge_ff), "")


if __name__ == "__main__":
    main()
