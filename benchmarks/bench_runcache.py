"""Incremental run cache — cold vs warm execution of the paper_demo pipeline.

The paper's complaint is that pipeline size makes iteration slow; the run
cache makes replaying an unchanged branch a pure cache lookup.  This
benchmark runs the paper-demo data pipeline (source_table -> filtered ->
features -> training_data, Listings 1-2 shape) cold, then warm, and checks:

  * warm replay >= 5x faster than the cold run;
  * the ledger manifests of both runs pin IDENTICAL output snapshot digests
    (the speedup cannot come at the cost of the reproducibility contract);
  * editing one node re-runs only its downstream cone (partial warm run).

Usage: PYTHONPATH=src python -m benchmarks.bench_runcache
"""

from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.core import Lake, Model, Pipeline, col, lit, model, sql_model
from .common import emit


def paper_demo_pipeline(feature_scale: float = 2.0) -> Pipeline:
    final_table = sql_model(
        "final_table", select=["c1", "c2", "c3"], frm="source_table",
        where=col("transaction_ts") >= lit(1000))

    @model()
    def features(data=Model("final_table")):
        # deliberately heavier than a lookup: a few dense passes
        x = data["c1"]
        acc = np.zeros_like(x)
        for k in range(1, 9):
            acc = acc + np.sin(x * k) / k
        return {"f0": acc * feature_scale,
                "f1": np.sqrt(np.abs(data["c2"]).astype(np.float64)),
                "c3": data["c3"]}

    @model()
    def training_data(data=Model("features")):
        return {"x": np.tanh(data["f0"] + data["f1"]),
                "y": (data["c3"] > 3).astype(np.float32)}

    @model()
    def data_stats(data=Model("features")):
        return {"mean_f0": np.array([data["f0"].mean()]),
                "n": np.array([data["f0"].shape[0]], np.int64)}

    return Pipeline([final_table, features, training_data, data_stats])


def main(n_rows: int = 400_000):
    rng = np.random.default_rng(0)
    src = {
        "c1": rng.normal(size=n_rows).astype(np.float32),
        "c2": rng.integers(-1000, 1000, n_rows).astype(np.int64),
        "c3": (np.arange(n_rows) % 7).astype(np.int32),
        "transaction_ts": np.arange(n_rows, dtype=np.int64),
    }
    with tempfile.TemporaryDirectory() as tmp:
        lake = Lake(tmp, protect_main=False)
        lake.write_table("main", "source_table", src)
        lake.catalog.create_branch("bench.run", "main", author="bench")
        pipe = paper_demo_pipeline()

        t0 = time.perf_counter()
        cold = lake.run(pipe, branch="bench.run", author="bench")
        cold_s = time.perf_counter() - t0
        assert cold.cache_misses == 4

        t0 = time.perf_counter()
        warm = lake.run(pipe, branch="bench.run", author="bench")
        warm_s = time.perf_counter() - t0
        assert warm.cache_hits == 4 and warm.cache_misses == 0

        m_cold = lake.ledger.get(cold.run_id)
        m_warm = lake.ledger.get(warm.run_id)
        assert m_cold["outputs"] == m_warm["outputs"], \
            "warm replay changed output snapshot digests"
        speedup = cold_s / warm_s
        emit("runcache/cold_run", cold_s * 1e6, f"rows={n_rows};misses=4")
        emit("runcache/warm_replay", warm_s * 1e6,
             f"speedup={speedup:.1f}x;hits=4;identical_outputs=True")
        assert speedup >= 5.0, f"warm replay only {speedup:.1f}x faster"

        # edit one node -> only its downstream cone re-runs
        edited = paper_demo_pipeline(feature_scale=3.0)
        t0 = time.perf_counter()
        part = lake.run(edited, branch="bench.run", author="bench")
        part_s = time.perf_counter() - t0
        assert part.cache_hits == 1 and part.cache_misses == 3  # final_table
        emit("runcache/edit_one_node", part_s * 1e6,
             f"hits={part.cache_hits};misses={part.cache_misses}")

        # --no-cache path: full re-execution for comparison
        t0 = time.perf_counter()
        nocache = lake.run(pipe, branch="bench.run", author="bench",
                           use_cache=False)
        emit("runcache/no_cache_run", (time.perf_counter() - t0) * 1e6,
             f"misses={nocache.cache_misses}")
        print(f"runcache: cold={cold_s*1e3:.1f}ms warm={warm_s*1e3:.1f}ms "
              f"speedup={speedup:.1f}x", flush=True)


if __name__ == "__main__":
    main()
