"""Training-integration benchmark (paper §5.5 extended to training):
tokens/s of the paper-demo model on CPU, the per-step overhead of
checkpoint-as-commit (sync vs async), and the catalog cost of a full
fault-tolerant resume."""

from __future__ import annotations

import tempfile

import jax
import numpy as np

from repro.checkpoint import CheckpointManager, save
from repro.configs import smoke_config
from repro.core import Lake
from repro.models import init_params
from repro.optim import adamw
from repro.runtime.steps import build_train_step, synthetic_batch
from .common import emit, timeit


def main():
    cfg = smoke_config("paper-demo")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig()
    opt_state = adamw.init(params, opt_cfg)
    step = jax.jit(build_train_step(cfg, opt_config=opt_cfg,
                                    schedule="constant",
                                    schedule_kw={"peak_lr": 1e-3}))
    batch = synthetic_batch(cfg, batch=8, seq=64)
    state = {"p": params, "o": opt_state}

    def train_step():
        state["p"], state["o"], m = step(state["p"], state["o"], batch)
        jax.block_until_ready(m["loss"])

    us = timeit(train_step, repeats=5, warmup=2)
    tokens = 8 * 64
    emit("train/step", us, f"tokens_per_s={tokens / (us / 1e6):.0f}")

    with tempfile.TemporaryDirectory() as tmp:
        lake = Lake(tmp, protect_main=False)

        i = [0]

        def sync_ckpt():
            i[0] += 1
            save(lake, "main", step=i[0], params=state["p"],
                 opt_state=state["o"])
        us_sync = timeit(sync_ckpt, repeats=3)
        emit("train/checkpoint_sync", us_sync, "")

        mgr = CheckpointManager(lake, "main")

        def async_ckpt():
            i[0] += 1
            mgr.submit(step=i[0], params=state["p"], opt_state=state["o"])
        us_async = timeit(async_ckpt, repeats=3)
        mgr.wait()
        emit("train/checkpoint_async_submit", us_async,
             f"hidden_ratio={us_sync / max(us_async, 1):.1f}x")

        from repro.checkpoint import restore, latest_checkpoint

        def do_restore():
            restore(lake, latest_checkpoint(lake, "main"))
        emit("train/restore", timeit(do_restore, repeats=3), "")


if __name__ == "__main__":
    main()
