"""Paper Fig. 1–2 — pipeline-as-DAG + the hierarchy of persistence.

Measures throughput at each reversible layer of Fig. 2:
in-memory columns ⇄ tensorfile bytes ⇄ table snapshot ⇄ catalog commit,
the full DAG execution rate (rows/s through transformation functions), and
thread- vs process-executor scaling on a GIL-bound DAG."""

from __future__ import annotations

import os
import tempfile

import numpy as np

from repro.core import Lake, Model, Pipeline, execute, model
from repro.core import tensorfile as tf
from .common import emit, timeit

#: pure-Python iterations per GIL-bound node (holds the GIL the whole time,
#: so N such nodes cannot overlap on the thread executor)
GIL_ITERS = 600_000


def _gil_node_fn(data=Model("source_table")):
    acc = 0.0
    for i in range(GIL_ITERS):  # pure-Python loop: never releases the GIL
        acc += (i * 1.000001) % 97.0
    return {"acc": np.array([acc, float(len(data["a"]))])}


def executor_scaling(cols, *, width: int = 4, repeats: int = 3):
    """Fan-out of ``width`` independent GIL-bound nodes: the thread
    executor serializes them on the interpreter lock, the process pool
    actually overlaps them.  Cache off so every run re-executes."""
    nodes = [model(name=f"gil{i}")(_gil_node_fn) for i in range(width)]
    pipe = Pipeline(nodes)
    with tempfile.TemporaryDirectory() as tmp:
        lake = Lake(tmp, protect_main=False)
        lake.catalog.commit(
            "main", {"source_table": lake.io.write_snapshot(cols)}, "seed")
        lake.catalog.create_branch("u.bench", "main", author="u")

        def run(executor):
            def body():
                execute(pipe, lake.catalog, lake.io, branch="u.bench",
                        author="u", use_cache=False, jobs=width,
                        executor=executor)
            return body

        cpus = os.cpu_count() or 1
        t_us = timeit(run("thread"), repeats=repeats, warmup=1)
        emit(f"executor/thread_jobs{width}_gil{width}", t_us,
             f"cpus={cpus}")
        p_us = timeit(run("process"), repeats=repeats, warmup=1)
        emit(f"executor/process_jobs{width}_gil{width}", p_us,
             f"cpus={cpus},speedup_vs_thread={t_us / p_us:.2f}x")


def main(n_rows: int = 200_000):
    rng = np.random.default_rng(0)
    cols = {"a": rng.normal(size=n_rows).astype(np.float32),
            "b": rng.integers(0, 1000, n_rows).astype(np.int64)}
    nbytes = sum(v.nbytes for v in cols.values())

    # layer 1: columns -> tensorfile bytes (Arrow -> Parquet analogue)
    blob_holder = {}

    def enc():
        blob_holder["blob"], _ = tf.encode(cols)
    us = timeit(enc)
    emit("fig2/encode_tensorfile", us,
         f"MBps={nbytes / us:.1f}")

    def dec():
        tf.decode(blob_holder["blob"])
    us = timeit(dec)
    emit("fig2/decode_tensorfile", us, f"MBps={nbytes / us:.1f}")

    with tempfile.TemporaryDirectory() as tmp:
        lake = Lake(tmp, protect_main=False)

        # layer 2: tensorfile -> snapshot in the object store (Iceberg)
        snap_holder = {}

        def write_snap():
            snap_holder["s"] = lake.io.write_snapshot(cols)
        us = timeit(write_snap, repeats=3)
        emit("fig2/write_snapshot", us, f"MBps={nbytes / us:.1f}")

        def read_snap():
            lake.io.read(snap_holder["s"])
        us = timeit(read_snap, repeats=3)
        emit("fig2/read_snapshot", us, f"MBps={nbytes / us:.1f}")

        # layer 3: snapshot -> commit (Nessie)
        i = [0]

        def commit():
            i[0] += 1
            lake.catalog.commit("main", {f"t{i[0]}": snap_holder["s"]}, "c")
        emit("fig2/commit", timeit(commit), "multi_table=True")

        # Fig. 1: full DAG run (two transformation functions)
        lake.catalog.commit("main", {"source_table":
                                     lake.io.write_snapshot(cols)}, "seed")

        @model()
        def mid(data=Model("source_table")):
            return {"a2": data["a"] * 2, "b": data["b"]}

        @model()
        def out(data=Model("mid")):
            return {"y": data["a2"] + data["b"]}

        pipe = Pipeline([mid, out])
        lake.catalog.create_branch("u.run", "main", author="u")

        def run():
            lake.run(pipe, branch="u.run", author="u")
        us = timeit(run, repeats=3)
        emit("fig1/dag_run_2nodes", us,
             f"rows_per_s={n_rows / (us / 1e6):.0f}")

    executor_scaling(cols)


if __name__ == "__main__":
    main()
