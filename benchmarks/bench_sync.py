"""Closure-transfer benchmarks: concurrency and wire-frame compression.

**Concurrency** (PR 3): PR 2's closure transfer paid one round-trip per
blob; the concurrent engine pipelines batched exists checks, blob gets and
puts across a worker pool, so a wide closure (many independent tensorfiles
under one commit) transfers in parallel.  The benchmark pushes the SAME
≥200-blob closure twice — once with ``jobs=1`` (the sequential path: one
object per round-trip, PR 2's exact wire pattern) and once with a worker
pool — through a loopback transport that charges a fixed per-request
latency, and checks:

  * concurrent push ≥ 3x faster than sequential;
  * the two remotes end **bit-identical**: same object digests (content
    addressing makes digest equality byte equality), same refs.

**Wire-frame compression** (PR 4): large tensorfile blobs cross the wire
as their framed at-rest payloads — compressed once at the original write,
decoded only for digest verification, never recompressed per hop.  The
benchmark pushes a tensorfile-heavy, compressible closure twice through a
byte-counting transport — once with compressed frames (the default), once
with ``compress_wire=False`` — and checks:

  * compressed frames move measurably fewer bytes on the wire;
  * the two remotes are bit-identical (no digest drift: every closure
    digest decodes to identical content on both).

**Delta frames** (wire-speed PR): a checkpoint-to-checkpoint push — v2
differs from v1 by a small contiguous slice of each weight table — ships
content-defined chunk recipes instead of whole frames.  The benchmark
pushes v1, mutates ~4% of each table, then pushes the v2 increment twice
(delta on / delta off) through byte-counting transports, and checks:

  * the delta push moves ≤ ``MAX_DELTA_RATIO`` (0.2x) of the whole-frame
    wire bytes for the same increment;
  * the destination stores are **bit-identical** (every closure digest
    decodes to the same bytes on both — recipes are rebuilt and
    digest-verified on the receiver, so delta can never drift).

**Multipart + ranged transfer** (same PR): large blobs cross the S3
dialect as part-sized pieces both ways.  The smoke leg pushes a blob well
over a toy ``multipart_threshold`` through the in-process stub, reads it
back through the ranged-GET path, and checks bit-identical round-trip with
zero orphaned multipart state.

Usage: PYTHONPATH=src python -m benchmarks.bench_sync
"""

from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import (Lake, LoopbackTransport, ObjectStore, RemoteServer,
                        RemoteStore, commit_closure, push)
from .common import emit

N_TABLES = 110          # 1 commit + N snapshots + N tensorfiles ≥ 200 blobs
LATENCY_S = 0.008       # per-request wire latency charged by the transport
JOBS_CONCURRENT = 4     # modest pool: the win must not need many cores
N_TENSOR_TABLES = 24    # wire-compression leg: fewer, fatter tensorfiles
TENSOR_ROWS = 8192      # compressible float32 payloads, ~32 KiB each
MAX_WIRE_RATIO = 0.8    # compressed wire bytes must be ≤ 80% of raw
N_CKPT_TABLES = 6       # delta leg: weight-checkpoint-shaped tables
CKPT_ROWS = 65536       # 256 KiB float32 each, incompressible random
MUTATE_FRAC = 0.04      # v2 touches a contiguous ~4% slice per table
MAX_DELTA_RATIO = 0.2   # delta push wire bytes vs whole-frame push
MP_BLOB_BYTES = 1 << 20      # multipart smoke: one 1 MiB random blob
MP_PART_BYTES = 96 << 10     # toy part size so several parts fly


class LatencyTransport:
    """Loopback plus a fixed per-request delay — models round-trip cost
    without needing a real network in the benchmark container."""

    def __init__(self, inner, delay_s: float):
        self.inner = inner
        self.delay_s = delay_s
        self.requests = 0

    def request(self, payload: bytes) -> bytes:
        self.requests += 1
        time.sleep(self.delay_s)
        return self.inner.request(payload)

    def close(self) -> None:
        self.inner.close()


def build_wide_lake(root: Path) -> Lake:
    """One commit pointing at many independent small tables: a wide, shallow
    closure — the shape where transfer concurrency pays most."""
    lake = Lake(root, protect_main=False)
    rng = np.random.default_rng(0)
    snaps = {}
    for i in range(N_TABLES):
        snaps[f"t{i:03d}"] = lake.io.write_snapshot(
            {"v": rng.normal(size=192).astype(np.float32)})
    lake.catalog.commit("main", snaps, "wide seed", _wap_token=True)
    lake.catalog.create_branch("bench.wide", "main", author="bench")
    return lake


class ByteCountingTransport:
    """Counts every byte crossing the wire, both directions."""

    def __init__(self, inner):
        self.inner = inner
        self.bytes_out = 0
        self.bytes_in = 0

    def request(self, payload: bytes) -> bytes:
        self.bytes_out += len(payload)
        reply = self.inner.request(payload)
        self.bytes_in += len(reply)
        return reply

    def close(self) -> None:
        self.inner.close()

    @property
    def total(self) -> int:
        return self.bytes_out + self.bytes_in


def build_tensor_lake(root: Path) -> Lake:
    """A tensorfile-heavy branch with *compressible* payloads (structured
    float32 ramps — the shape real activations/weights statistics take far
    more often than white noise)."""
    lake = Lake(root, protect_main=False)
    snaps = {}
    for i in range(N_TENSOR_TABLES):
        base = np.arange(TENSOR_ROWS, dtype=np.float32) * (0.01 * (i + 1))
        snaps[f"w{i:02d}"] = lake.io.write_snapshot(
            {"w": base, "b": np.repeat(np.float32(i), TENSOR_ROWS)})
    lake.catalog.commit("main", snaps, "tensor seed", _wap_token=True)
    lake.catalog.create_branch("bench.tensors", "main", author="bench")
    return lake


def counted_push(lake: Lake, remote_root: Path, *, compress_wire: bool):
    store = ObjectStore(remote_root)
    transport = ByteCountingTransport(
        LoopbackTransport(RemoteServer(store)))
    report = push(lake.store, RemoteStore(transport), "bench.tensors",
                  jobs=JOBS_CONCURRENT, cache_entries=False, runs=False,
                  compress_wire=compress_wire)
    return report, store, transport


def timed_push(lake: Lake, remote_root: Path, jobs: int):
    store = ObjectStore(remote_root)
    transport = LatencyTransport(
        LoopbackTransport(RemoteServer(store)), LATENCY_S)
    remote = RemoteStore(transport)
    t0 = time.perf_counter()
    report = push(lake.store, remote, "bench.wide", jobs=jobs,
                  cache_entries=False, runs=False)
    wall = time.perf_counter() - t0
    return wall, report, store, transport.requests


def build_ckpt_lake(root: Path) -> Lake:
    """Weight-checkpoint-shaped branch: incompressible random float32
    tables (white noise is the adversarial case for frame compression, so
    any wire win here is delta's alone)."""
    lake = Lake(root, protect_main=False)
    rng = np.random.default_rng(7)
    snaps = {}
    for i in range(N_CKPT_TABLES):
        snaps[f"w{i:02d}"] = lake.io.write_snapshot(
            {"w": rng.normal(size=CKPT_ROWS).astype(np.float32)})
    lake.catalog.commit("main", snaps, "ckpt v1", _wap_token=True)
    lake.catalog.create_branch("bench.ckpt", "main", author="bench")
    return lake


def mutate_ckpt(lake: Lake) -> None:
    """v2 checkpoint: a contiguous ~MUTATE_FRAC slice of each table moves
    (the optimizer-step shape: most weights drift little, a band changes)."""
    rng = np.random.default_rng(8)
    snaps = {}
    for name in sorted(lake.catalog.tables("bench.ckpt")):
        cols = lake.read_table("bench.ckpt", name)
        w = np.array(cols["w"])
        n = max(1, int(len(w) * MUTATE_FRAC))
        start = int(rng.integers(0, len(w) - n))
        w[start:start + n] = rng.normal(size=n).astype(np.float32)
        snaps[name] = lake.io.write_snapshot({"w": w})
    lake.catalog.commit("bench.ckpt", snaps, "ckpt v2", author="bench")


def delta_push_leg(tmp: Path) -> None:
    lake = build_ckpt_lake(tmp / "ckpt_lake")

    remotes = {}
    for mode, use_delta in (("delta", True), ("whole", False)):
        store = ObjectStore(tmp / f"ckpt_remote_{mode}")
        transport = ByteCountingTransport(
            LoopbackTransport(RemoteServer(store)))
        remote = RemoteStore(transport)
        remotes[mode] = (store, transport, remote, use_delta)
        # v1 lands whole either way (nothing to delta against)
        push(lake.store, remote, "bench.ckpt", jobs=JOBS_CONCURRENT,
             cache_entries=False, runs=False, delta_frames=use_delta)

    mutate_ckpt(lake)
    head = lake.catalog.head("bench.ckpt")
    closure = commit_closure(lake.store, head)

    v2_wire = {}
    reports = {}
    for mode, (store, transport, remote, use_delta) in remotes.items():
        before = transport.total
        reports[mode] = push(lake.store, remote, "bench.ckpt",
                             jobs=JOBS_CONCURRENT, cache_entries=False,
                             runs=False, delta_frames=use_delta)
        v2_wire[mode] = transport.total - before

    delta_store, whole_store = remotes["delta"][0], remotes["whole"][0]
    assert sorted(delta_store.iter_objects()) == \
        sorted(whole_store.iter_objects()), "remotes diverged"
    assert set(delta_store.iter_objects()) >= closure
    for digest in sorted(closure):
        assert delta_store.get(digest) == whole_store.get(digest)
    assert delta_store.get_ref("branch=bench.ckpt") == head
    assert reports["delta"].bytes_delta_saved > 0
    assert reports["whole"].bytes_delta_saved == 0

    ratio = v2_wire["delta"] / v2_wire["whole"]
    emit("sync/ckpt_whole_frame_bytes", v2_wire["whole"],
         f"tables={N_CKPT_TABLES};mutated={MUTATE_FRAC}")
    emit("sync/ckpt_delta_bytes", v2_wire["delta"],
         f"tables={N_CKPT_TABLES};mutated={MUTATE_FRAC};"
         f"ratio={ratio:.3f};saved={reports['delta'].bytes_delta_saved}")
    print(f"delta: ckpt v2 whole_wire={v2_wire['whole']} "
          f"delta_wire={v2_wire['delta']} ratio={ratio:.3f} "
          f"saved={reports['delta'].bytes_delta_saved}", flush=True)
    assert ratio <= MAX_DELTA_RATIO, \
        (f"delta push moved {ratio:.3f}x of whole-frame wire bytes "
         f"(need <= {MAX_DELTA_RATIO})")


def multipart_leg(tmp: Path) -> None:
    from repro.core import serve_s3, sha256_hex
    from repro.core.s3 import S3Backend

    httpd, url = serve_s3(tmp / "mp_bucket")
    try:
        backend = S3Backend.from_url(url, multipart_threshold=MP_PART_BYTES,
                                     part_size=MP_PART_BYTES)
        blob = np.random.default_rng(9).integers(
            0, 256, size=MP_BLOB_BYTES, dtype=np.uint8).tobytes()
        t0 = time.perf_counter()
        digest = backend.put(blob)          # multipart upload path
        up_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        back = backend.get(digest)          # ranged GET path
        down_s = time.perf_counter() - t0
        assert back == blob and digest == sha256_hex(blob)
        assert not httpd.uploads, "orphaned multipart upload state"
        backend.close()
        emit("sync/multipart_upload", up_s * 1e6,
             f"bytes={MP_BLOB_BYTES};part={MP_PART_BYTES}")
        emit("sync/ranged_get", down_s * 1e6,
             f"bytes={MP_BLOB_BYTES};part={MP_PART_BYTES}")
        print(f"multipart: {MP_BLOB_BYTES} bytes in {MP_PART_BYTES}-byte "
              f"parts up={up_s*1e3:.0f}ms down={down_s*1e3:.0f}ms "
              f"round-trip ok", flush=True)
    finally:
        httpd.shutdown()


def main():
    with tempfile.TemporaryDirectory() as tmp:
        tmp = Path(tmp)
        lake = build_wide_lake(tmp / "lake")
        head = lake.catalog.head("bench.wide")
        closure = commit_closure(lake.store, head)
        assert len(closure) >= 200, f"closure too narrow: {len(closure)}"

        seq_s, seq_rep, seq_store, seq_reqs = \
            timed_push(lake, tmp / "remote_seq", jobs=1)
        con_s, con_rep, con_store, con_reqs = \
            timed_push(lake, tmp / "remote_con", jobs=JOBS_CONCURRENT)

        # bit-identical remotes: identical digest sets (content addressing
        # makes that byte equality) and identical refs
        seq_objs = sorted(seq_store.iter_objects())
        con_objs = sorted(con_store.iter_objects())
        assert seq_objs == con_objs, "remotes diverged in object contents"
        assert sorted(seq_store.list_refs()[0]) == \
            sorted(con_store.list_refs()[0]), "remotes diverged in refs"
        assert set(seq_objs) >= closure, "closure incomplete on the remote"
        assert seq_rep.objects_sent == con_rep.objects_sent

        speedup = seq_s / con_s
        emit("sync/sequential_push", seq_s * 1e6,
             f"blobs={len(closure)};requests={seq_reqs};jobs=1")
        emit("sync/concurrent_push", con_s * 1e6,
             f"blobs={len(closure)};requests={con_reqs};"
             f"jobs={JOBS_CONCURRENT};speedup={speedup:.1f}x")
        print(f"sync: closure={len(closure)} blobs "
              f"seq={seq_s*1e3:.0f}ms ({seq_reqs} reqs) "
              f"conc={con_s*1e3:.0f}ms ({con_reqs} reqs) "
              f"speedup={speedup:.1f}x", flush=True)
        assert speedup >= 3.0, \
            f"concurrent push only {speedup:.1f}x faster (need >= 3x)"

        # ------------------------------------------ wire-frame compression
        tlake = build_tensor_lake(tmp / "tensor_lake")
        thead = tlake.catalog.head("bench.tensors")
        tclosure = commit_closure(tlake.store, thead)

        raw_rep, raw_store, raw_wire = counted_push(
            tlake, tmp / "remote_raw", compress_wire=False)
        comp_rep, comp_store, comp_wire = counted_push(
            tlake, tmp / "remote_comp", compress_wire=True)

        # no digest drift: both remotes hold the full closure, and every
        # closure digest decodes to identical bytes on both
        assert sorted(raw_store.iter_objects()) == \
            sorted(comp_store.iter_objects()), "remotes diverged"
        assert set(comp_store.iter_objects()) >= tclosure
        for digest in sorted(tclosure):
            assert comp_store.get(digest) == raw_store.get(digest)
        assert sorted(raw_store.list_refs()[0]) == \
            sorted(comp_store.list_refs()[0])
        assert comp_rep.objects_sent == raw_rep.objects_sent
        assert comp_rep.bytes_sent == raw_rep.bytes_sent  # logical bytes
        assert comp_rep.bytes_wire < comp_rep.bytes_sent  # per-object win

        ratio = comp_wire.total / raw_wire.total
        emit("sync/wire_raw_bytes", raw_wire.total,
             f"blobs={len(tclosure)};logical={raw_rep.bytes_sent}")
        emit("sync/wire_compressed_bytes", comp_wire.total,
             f"blobs={len(tclosure)};logical={comp_rep.bytes_sent};"
             f"ratio={ratio:.2f}")
        print(f"wire: closure={len(tclosure)} blobs "
              f"logical={comp_rep.bytes_sent} "
              f"raw_wire={raw_wire.total} comp_wire={comp_wire.total} "
              f"ratio={ratio:.2f}", flush=True)
        assert ratio <= MAX_WIRE_RATIO, \
            (f"compressed frames moved {ratio:.2f}x of raw wire bytes "
             f"(need <= {MAX_WIRE_RATIO})")

        # --------------------------------------------------- delta frames
        delta_push_leg(tmp)

        # ------------------------------------------ multipart + ranged GET
        multipart_leg(tmp)


if __name__ == "__main__":
    main()
