"""Concurrent vs sequential closure transfer over a latency-bearing wire.

PR 2's closure transfer paid one round-trip per blob; the concurrent engine
pipelines batched exists checks, blob gets and puts across a worker pool, so
a wide closure (many independent tensorfiles under one commit) transfers in
parallel.  This benchmark pushes the SAME ≥200-blob closure twice — once with
``jobs=1`` (the sequential path: one object per round-trip, PR 2's exact
wire pattern) and once with a worker pool — through a loopback transport
that charges a fixed per-request latency (the only cost a real network adds
that the loopback lacks), and checks:

  * concurrent push ≥ 3x faster than sequential;
  * the two remotes end **bit-identical**: same object digests (content
    addressing makes digest equality byte equality), same refs.

Usage: PYTHONPATH=src python -m benchmarks.bench_sync
"""

from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import (Lake, LoopbackTransport, ObjectStore, RemoteServer,
                        RemoteStore, commit_closure, push)
from .common import emit

N_TABLES = 110          # 1 commit + N snapshots + N tensorfiles ≥ 200 blobs
LATENCY_S = 0.008       # per-request wire latency charged by the transport
JOBS_CONCURRENT = 4     # modest pool: the win must not need many cores


class LatencyTransport:
    """Loopback plus a fixed per-request delay — models round-trip cost
    without needing a real network in the benchmark container."""

    def __init__(self, inner, delay_s: float):
        self.inner = inner
        self.delay_s = delay_s
        self.requests = 0

    def request(self, payload: bytes) -> bytes:
        self.requests += 1
        time.sleep(self.delay_s)
        return self.inner.request(payload)

    def close(self) -> None:
        self.inner.close()


def build_wide_lake(root: Path) -> Lake:
    """One commit pointing at many independent small tables: a wide, shallow
    closure — the shape where transfer concurrency pays most."""
    lake = Lake(root, protect_main=False)
    rng = np.random.default_rng(0)
    snaps = {}
    for i in range(N_TABLES):
        snaps[f"t{i:03d}"] = lake.io.write_snapshot(
            {"v": rng.normal(size=192).astype(np.float32)})
    lake.catalog.commit("main", snaps, "wide seed", _wap_token=True)
    lake.catalog.create_branch("bench.wide", "main", author="bench")
    return lake


def timed_push(lake: Lake, remote_root: Path, jobs: int):
    store = ObjectStore(remote_root)
    transport = LatencyTransport(
        LoopbackTransport(RemoteServer(store)), LATENCY_S)
    remote = RemoteStore(transport)
    t0 = time.perf_counter()
    report = push(lake.store, remote, "bench.wide", jobs=jobs,
                  cache_entries=False, runs=False)
    wall = time.perf_counter() - t0
    return wall, report, store, transport.requests


def main():
    with tempfile.TemporaryDirectory() as tmp:
        tmp = Path(tmp)
        lake = build_wide_lake(tmp / "lake")
        head = lake.catalog.head("bench.wide")
        closure = commit_closure(lake.store, head)
        assert len(closure) >= 200, f"closure too narrow: {len(closure)}"

        seq_s, seq_rep, seq_store, seq_reqs = \
            timed_push(lake, tmp / "remote_seq", jobs=1)
        con_s, con_rep, con_store, con_reqs = \
            timed_push(lake, tmp / "remote_con", jobs=JOBS_CONCURRENT)

        # bit-identical remotes: identical digest sets (content addressing
        # makes that byte equality) and identical refs
        seq_objs = sorted(seq_store.iter_objects())
        con_objs = sorted(con_store.iter_objects())
        assert seq_objs == con_objs, "remotes diverged in object contents"
        assert sorted(seq_store.list_refs()[0]) == \
            sorted(con_store.list_refs()[0]), "remotes diverged in refs"
        assert set(seq_objs) >= closure, "closure incomplete on the remote"
        assert seq_rep.objects_sent == con_rep.objects_sent

        speedup = seq_s / con_s
        emit("sync/sequential_push", seq_s * 1e6,
             f"blobs={len(closure)};requests={seq_reqs};jobs=1")
        emit("sync/concurrent_push", con_s * 1e6,
             f"blobs={len(closure)};requests={con_reqs};"
             f"jobs={JOBS_CONCURRENT};speedup={speedup:.1f}x")
        print(f"sync: closure={len(closure)} blobs "
              f"seq={seq_s*1e3:.0f}ms ({seq_reqs} reqs) "
              f"conc={con_s*1e3:.0f}ms ({con_reqs} reqs) "
              f"speedup={speedup:.1f}x", flush=True)
        assert speedup >= 3.0, \
            f"concurrent push only {speedup:.1f}x faster (need >= 3x)"


if __name__ == "__main__":
    main()
