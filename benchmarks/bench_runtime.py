"""Paper Fig. 3 — the run data flow: client → plan → catalog → storage →
execution → results.

Measures each hop of the read/write path as table size scales, plus the
per-run ledger overhead (run_id issuance + manifest persistence) — the cost
the paper's architecture adds on top of raw compute."""

from __future__ import annotations

import tempfile

import numpy as np

from repro.core import Lake, Model, Pipeline, model
from .common import emit, timeit


def main():
    for n_rows in (1_000, 100_000):
        with tempfile.TemporaryDirectory() as tmp:
            lake = Lake(tmp, protect_main=False)
            rng = np.random.default_rng(0)
            cols = {"x": rng.normal(size=n_rows).astype(np.float32)}
            snap = lake.io.write_snapshot(cols)
            lake.catalog.commit("main", {"t": snap}, "seed")

            # hop 3-4: catalog ref → snapshot → files → columns
            def read_path():
                lake.read_table("main", "t")
            us = timeit(read_path)
            emit(f"fig3/read_path_{n_rows}rows", us,
                 f"MBps={cols['x'].nbytes / us:.1f}")

            # hop 5: results committed back
            def write_path():
                lake.write_table("main", "t_out", cols)
            emit(f"fig3/write_path_{n_rows}rows", timeit(write_path, repeats=3),
                 "")

    # ledger overhead: run with 1 trivial node (≈ pure bookkeeping)
    with tempfile.TemporaryDirectory() as tmp:
        lake = Lake(tmp, protect_main=False)
        lake.write_table("main", "src", {"x": np.ones(8, np.float32)})

        @model()
        def out(data=Model("src")):
            return {"y": data["x"]}

        pipe = Pipeline([out])
        lake.catalog.create_branch("u.r", "main", author="u")

        def ledger_run():
            lake.run(pipe, branch="u.r", author="u")
        us = timeit(ledger_run)
        emit("fig3/run_id_overhead", us, "nodes=1")

        def resolve_run():
            lake.ledger.get(lake.ledger.runs()[0])
        emit("fig3/run_manifest_lookup", timeit(resolve_run),
             f"n_runs={len(lake.ledger.runs())}")


if __name__ == "__main__":
    main()
