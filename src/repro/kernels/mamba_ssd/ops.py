"""Jitted wrapper for the chunked SSD kernel (pads ragged sequence tails)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_chunked


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, Bmat, Cmat, *, chunk: int = 128, h0=None,
        interpret: bool = True):
    """Chunk-parallel SSD with identity-step padding for ragged tails.
    x: (B,S,nh,hd); dt: (B,S,nh); A: (nh,); B/C: (B,S,ns)."""
    S = x.shape[1]
    S_pad = ((S + chunk - 1) // chunk) * chunk
    if S_pad != S:
        pad = S_pad - S
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 ⇒ identity step
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    y, h = ssd_chunked(x, dt, A, Bmat, Cmat, chunk=chunk, h0=h0,
                       interpret=interpret)
    return y[:, :S], h
