"""Pure-jnp oracle for the SSD kernels: sequential state-space recurrence.

Deliberately the *naive O(S) sequential scan* — a different algorithm from
the chunked kernels — so kernel tests validate the chunk decomposition math
itself, not just a re-implementation of it.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def ssd_reference(
    x: jnp.ndarray,     # (B, S, nh, hd)
    dt: jnp.ndarray,    # (B, S, nh)  (already softplus'd)
    A: jnp.ndarray,     # (nh,) negative
    Bmat: jnp.ndarray,  # (B, S, ns)
    Cmat: jnp.ndarray,  # (B, S, ns)
    h0: Optional[jnp.ndarray] = None,  # (B, nh, hd, ns)
):
    Bsz, S, nh, hd = x.shape
    ns = Bmat.shape[-1]
    h = (jnp.zeros((Bsz, nh, hd, ns), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))

    def step(h, inp):
        xt, dtt, Bt, Ct = inp
        decay = jnp.exp(dtt.astype(jnp.float32) * A[None, :])  # (B, nh)
        upd = jnp.einsum("bhd,bs->bhds",
                         xt.astype(jnp.float32) * dtt[..., None],
                         Bt.astype(jnp.float32))
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bhds,bs->bhd", h, Ct.astype(jnp.float32))
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bmat, 1, 0), jnp.moveaxis(Cmat, 1, 0))
    h_final, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_final
