"""Chunked SSD (Mamba-2) — Pallas TPU kernels.

TPU adaptation of the SSD algorithm (DESIGN.md §6): the sequence is split
into chunks; all *intra-chunk* work (quadratic in chunk length, dense matmul
— MXU food) and the *per-chunk state contributions* run chunk-parallel in
kernel 1; a tiny O(n_chunks) associative recurrence over (nh, hd, ns) states
runs outside; kernel 2 folds the carried-in states back into the outputs.
Grid cell = (batch, head, chunk); one cell's working set (Q×Q decay matrix +
Q×hd inputs + Q×ns B/C tiles) is sized for VMEM at Q=128–256.

The CUDA version's warp-level scan has no TPU analogue — the two-pass
chunk-parallel decomposition + outer scan IS the TPU-idiomatic equivalent.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                  y_ref, state_ref, segtot_ref):
    """Per (batch, head, chunk): intra-chunk output + state contribution."""
    x = x_ref[...].astype(jnp.float32)        # (Q, hd)
    dt = dt_ref[...].astype(jnp.float32)      # (Q,)
    A = a_ref[0].astype(jnp.float32)          # scalar decay rate (this head)
    Bm = b_ref[...].astype(jnp.float32)       # (Q, ns)
    Cm = c_ref[...].astype(jnp.float32)       # (Q, ns)
    Q = x.shape[0]

    dA = dt * A                                # (Q,) log-decay per step
    seg = jnp.cumsum(dA)                       # (Q,)
    rel = seg[:, None] - seg[None, :]          # (Q, Q)
    causal = jax.lax.iota(jnp.int32, Q)[:, None] >= \
        jax.lax.iota(jnp.int32, Q)[None, :]
    L = jnp.exp(jnp.where(causal, rel, -1e30))  # mask pre-exp (no inf)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    W = cb * L
    xdt = x * dt[:, None]                      # (Q, hd)
    y_ref[...] = jax.lax.dot_general(
        W, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    seg_tot = seg[-1]
    decay_out = jnp.exp(seg_tot - seg)         # (Q,)
    state = jax.lax.dot_general(
        xdt * decay_out[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)    # (hd, ns)
    state_ref[...] = state.astype(state_ref.dtype)
    segtot_ref[0] = seg_tot.astype(segtot_ref.dtype)


def _carry_kernel(y_ref, c_ref, dt_ref, a_ref, hprev_ref, o_ref):
    """Per (batch, head, chunk): add the inter-chunk term C·h_prev·decay."""
    y = y_ref[...].astype(jnp.float32)         # (Q, hd)
    Cm = c_ref[...].astype(jnp.float32)        # (Q, ns)
    dt = dt_ref[...].astype(jnp.float32)       # (Q,)
    A = a_ref[0].astype(jnp.float32)
    h = hprev_ref[...].astype(jnp.float32)     # (hd, ns)
    seg = jnp.cumsum(dt * A)                   # (Q,)
    y_int = jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)    # (Q, hd)
    o_ref[...] = (y + y_int * jnp.exp(seg)[:, None]).astype(o_ref.dtype)


def ssd_chunked(
    x: jnp.ndarray,     # (B, S, nh, hd)
    dt: jnp.ndarray,    # (B, S, nh)
    A: jnp.ndarray,     # (nh,)
    Bmat: jnp.ndarray,  # (B, S, ns)
    Cmat: jnp.ndarray,  # (B, S, ns)
    *,
    chunk: int = 128,
    h0=None,
    interpret: bool = False,
):
    """Two-pass chunk-parallel SSD.  Returns (y (B,S,nh,hd), h_final)."""
    Bsz, S, nh, hd = x.shape
    ns = Bmat.shape[-1]
    assert S % chunk == 0, (S, chunk)
    N = S // chunk

    xc = x.reshape(Bsz, N, chunk, nh, hd)
    dtc = dt.reshape(Bsz, N, chunk, nh)
    Bc = Bmat.reshape(Bsz, N, chunk, ns)
    Cc = Cmat.reshape(Bsz, N, chunk, ns)

    grid = (Bsz, nh, N)
    y_intra, states, segtot = pl.pallas_call(
        _chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, chunk, None, hd),
                         lambda b, h, n: (b, n, 0, h, 0)),
            pl.BlockSpec((None, None, chunk, None),
                         lambda b, h, n: (b, n, 0, h)),
            pl.BlockSpec((1,), lambda b, h, n: (h,)),
            pl.BlockSpec((None, None, chunk, ns),
                         lambda b, h, n: (b, n, 0, 0)),
            pl.BlockSpec((None, None, chunk, ns),
                         lambda b, h, n: (b, n, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, chunk, None, hd),
                         lambda b, h, n: (b, n, 0, h, 0)),
            pl.BlockSpec((None, None, None, hd, ns),
                         lambda b, h, n: (b, n, h, 0, 0)),
            pl.BlockSpec((None, None, 1), lambda b, h, n: (b, n, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, N, chunk, nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, N, nh, hd, ns), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, N, nh), jnp.float32),
        ],
        interpret=interpret,
    )(xc, dtc, A.astype(jnp.float32), Bc, Cc)

    # ---- tiny outer recurrence over chunk states (O(N), off the kernel) ----
    def carry(h, inp):
        st, seg_tot = inp  # (B, nh, hd, ns), (B, nh)
        h_new = h * jnp.exp(seg_tot)[..., None, None] + st
        return h_new, h    # emit h_prev for each chunk

    h_init = (jnp.zeros((Bsz, nh, hd, ns), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_final, h_prevs = jax.lax.scan(
        carry, h_init, (jnp.moveaxis(states, 1, 0),
                        jnp.moveaxis(segtot, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (B, N, nh, hd, ns)

    y = pl.pallas_call(
        _carry_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, chunk, None, hd),
                         lambda b, h, n: (b, n, 0, h, 0)),
            pl.BlockSpec((None, None, chunk, ns),
                         lambda b, h, n: (b, n, 0, 0)),
            pl.BlockSpec((None, None, chunk, None),
                         lambda b, h, n: (b, n, 0, h)),
            pl.BlockSpec((1,), lambda b, h, n: (h,)),
            pl.BlockSpec((None, None, None, hd, ns),
                         lambda b, h, n: (b, n, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, chunk, None, hd),
                               lambda b, h, n: (b, n, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, N, chunk, nh, hd), x.dtype),
        interpret=interpret,
    )(y_intra, Cc, dtc, A.astype(jnp.float32), h_prevs)

    return y.reshape(Bsz, S, nh, hd), h_final
