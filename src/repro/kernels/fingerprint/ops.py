"""Public fingerprint API: digests for arrays and whole pytrees.

Used by the checkpoint layer to content-address device-resident tensors
(params, optimizer state) when committing to the catalog — the paper's
"immutable reference to data" without a device→host copy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .kernel import fingerprint


@functools.partial(jax.jit, static_argnames=("interpret",))
def tensor_digest(arr: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """(8,) uint32 digest of one array (kernel path)."""
    return fingerprint(arr, interpret=interpret)


def tensor_digest_hex(arr) -> str:
    return ref.digest_hex(tensor_digest(jnp.asarray(arr)))


def tree_digest_hex(tree) -> str:
    """Order-stable digest of a whole pytree: digest of leaf digests."""
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    parts = []
    for path, leaf in sorted(leaves, key=lambda kv: jax.tree_util.keystr(kv[0])):
        parts.append(np.asarray(tensor_digest(jnp.asarray(leaf))))
    stacked = jnp.asarray(np.concatenate(parts).astype(np.uint32))
    return ref.digest_hex(ref.fingerprint_ref(stacked))
