"""Tensor fingerprint — Pallas TPU kernel.

Computes the (8,) uint32 content digest of a flat uint32 word stream in
VMEM-sized blocks.  The combine is wrapping addition (commutative +
associative), so grid cells can run in any order; each cell accumulates into
the single shared output block (sequential-grid accumulation on TPU).

This makes catalog commits of device-resident tensors (params, activations)
possible without copying bytes to the host: the digest IS the content
address (see ``repro.checkpoint``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .ref import GOLDEN, LANES, MULT1, MULT2, _to_words, mix_words


def _fp_kernel(w_ref, o_ref, *, block: int):
    pid = pl.program_id(0).astype(jnp.uint32)
    words = w_ref[...]                       # (block,) uint32
    # all-uint32 arithmetic: int32 would sign-extend on >> (different digest)
    pos = (pid * np.uint32(block) +
           jax.lax.iota(jnp.uint32, block))  # global word positions
    h = words ^ (GOLDEN * (pos + np.uint32(1)))
    h = h * MULT1
    h = h ^ (h >> np.uint32(13))
    h = h * MULT2
    h = h ^ (h >> np.uint32(16))
    lanes = jnp.sum(h.reshape(-1, LANES), axis=0, dtype=jnp.uint32)

    @pl.when(pid == 0)
    def _init():
        o_ref[...] = lanes

    @pl.when(pid != 0)
    def _acc():
        o_ref[...] = o_ref[...] + lanes


def fingerprint_words(words: jnp.ndarray, *, block: int = 1024,
                      interpret: bool = False) -> jnp.ndarray:
    """(n,) uint32 → (8,) uint32 lane sums (before length mixing)."""
    n = words.shape[0]
    block = min(block, max(LANES, ((n + LANES - 1) // LANES) * LANES))
    pad = (-n) % block
    words = jnp.pad(words, (0, pad))
    nblocks = words.shape[0] // block
    return pl.pallas_call(
        functools.partial(_fp_kernel, block=block),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((LANES,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((LANES,), jnp.uint32),
        interpret=interpret,
    )(words)


def fingerprint(arr: jnp.ndarray, *, block: int = 1024,
                interpret: bool = False) -> jnp.ndarray:
    """(8,) uint32 digest — bit-identical to ``ref.fingerprint_ref``."""
    words = _to_words(arr)
    n = words.shape[0]
    # ref pads to a LANES multiple with zero words before mixing; the kernel
    # pads to a block multiple — both pads contribute mix(0, p) terms, so
    # equality requires the SAME padded length semantics: pad to LANES first.
    pad = (-n) % LANES
    words = jnp.pad(words, (0, pad))
    lanes = fingerprint_words(words, block=block, interpret=interpret)
    # ... minus the contributions of any extra block padding beyond LANES
    # (handled below by subtracting them analytically is avoidable: instead
    # the kernel-level pad words are mix(0, p) for p >= padded_n, which the
    # ref does NOT include).  Subtract them here.
    padded_n = words.shape[0]
    block_eff = min(block, max(LANES,
                               ((padded_n + LANES - 1) // LANES) * LANES))
    extra = (-padded_n) % block_eff
    if extra:
        pos = padded_n + jnp.arange(extra, dtype=jnp.uint32)
        surplus = mix_words(jnp.zeros((extra,), jnp.uint32), pos)
        surplus = jnp.sum(surplus.reshape(-1, LANES), axis=0,
                          dtype=jnp.uint32)
        lanes = lanes - surplus
    n_mix = mix_words(jnp.full((LANES,), np.uint32(n)),
                      jnp.arange(LANES, dtype=jnp.uint32))
    return (lanes + n_mix).astype(jnp.uint32)
