"""Pure-jnp oracle for the tensor fingerprint.

The digest must be *bit-exactly* reproducible across the kernel and this
reference (uint32 wraparound arithmetic only — no floats), because it is
used by the catalog as a content address for device-resident tensors
(DESIGN.md §6: the TPU-native replacement for hashing Parquet files on S3).

Digest: 8 uint32 lanes.  Each 32-bit word w at global position p contributes
    mix(w XOR rot(GOLDEN * (p+1)))
to lane p % 8, where mix is an xxhash-style avalanche; contributions combine
by wrapping addition (commutative ⇒ chunk-parallel kernel is exact).
Finally the total word count is mixed into every lane.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

LANES = 8
GOLDEN = np.uint32(0x9E3779B1)
MULT1 = np.uint32(0x85EBCA6B)
MULT2 = np.uint32(0xC2B2AE35)


def _to_words(arr: jnp.ndarray) -> jnp.ndarray:
    """Flatten any-dtype array to uint32 words (little-endian packing)."""
    flat = arr.reshape(-1)
    nbits = flat.dtype.itemsize * 8
    if flat.dtype == jnp.bool_:
        flat = flat.astype(jnp.uint8)
        nbits = 8
    uint = jnp.dtype(f"uint{nbits}")
    if flat.dtype.kind != "u":
        flat = jax.lax.bitcast_convert_type(flat, uint)
    if nbits < 32:
        per = 32 // nbits
        pad = (-flat.shape[0]) % per
        flat = jnp.pad(flat, (0, pad))
        w = flat.reshape(-1, per).astype(jnp.uint32)
        shifts = (jnp.arange(per, dtype=jnp.uint32) * nbits)
        return jnp.sum(w << shifts[None, :], axis=1, dtype=jnp.uint32)
    if nbits == 64:
        lo = flat.astype(jnp.uint32)
        hi = (flat >> np.uint64(32)).astype(jnp.uint32)
        return jnp.stack([lo, hi], axis=1).reshape(-1)
    return flat.astype(jnp.uint32)


def mix_words(words: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    """Per-word avalanche used by both ref and kernel. uint32 in/out."""
    h = words ^ (GOLDEN * (positions + np.uint32(1)))
    h = h * MULT1
    h = h ^ (h >> np.uint32(13))
    h = h * MULT2
    h = h ^ (h >> np.uint32(16))
    return h


def fingerprint_ref(arr: jnp.ndarray) -> jnp.ndarray:
    """(8,) uint32 digest of an arbitrary array."""
    words = _to_words(arr)
    n = words.shape[0]
    pad = (-n) % LANES
    words = jnp.pad(words, (0, pad))
    pos = jnp.arange(words.shape[0], dtype=jnp.uint32)
    # padded words contribute mix(0, p) — deterministic, length-mixed below
    contrib = mix_words(words, pos)
    lanes = jnp.sum(contrib.reshape(-1, LANES), axis=0, dtype=jnp.uint32)
    n_mix = mix_words(jnp.full((LANES,), np.uint32(n)),
                      jnp.arange(LANES, dtype=jnp.uint32))
    return (lanes + n_mix).astype(jnp.uint32)


def digest_hex(digest: jnp.ndarray) -> str:
    return "".join(f"{int(x):08x}" for x in np.asarray(digest))
