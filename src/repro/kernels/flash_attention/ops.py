"""Jitted public wrapper for the flash attention kernel.

``flash_gqa`` takes model-layout tensors (B, S, H, d) and handles GQA +
layout transposition; gradient support comes from a recompute-based
``jax.custom_vjp`` (forward kernel + reference backward), the standard
memory-saving pattern for attention backward on TPU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .kernel import flash_attention


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_gqa(q, k, v, causal: bool = True, window: Optional[int] = None,
              softcap: Optional[float] = None, interpret: bool = True):
    """q: (B, S, Hq, d); k/v: (B, T, Hkv, d) → (B, S, Hq, d).

    interpret=True by default: this container is CPU-only; on TPU the caller
    passes interpret=False.
    """
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          softcap=softcap, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)


def _fwd(q, k, v, causal, window, softcap, interpret):
    out = flash_gqa(q, k, v, causal, window, softcap, interpret)
    return out, (q, k, v)


def _bwd(causal, window, softcap, interpret, res, g):
    """Recompute-based backward via the reference implementation — the
    canonical flash-bwd trade (no O(S·T) tensor is saved from the fwd)."""
    q, k, v = res

    def f(q_, k_, v_):
        qt = jnp.swapaxes(q_, 1, 2)
        kt = jnp.swapaxes(k_, 1, 2)
        vt = jnp.swapaxes(v_, 1, 2)
        groups = qt.shape[1] // kt.shape[1]
        kr = jnp.repeat(kt, groups, axis=1)
        vr = jnp.repeat(vt, groups, axis=1)
        out = ref.mha_reference(qt, kr, vr, causal=causal, window=window,
                                softcap=softcap)
        return jnp.swapaxes(out, 1, 2)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_gqa.defvjp(_fwd, _bwd)
