"""Blockwise online-softmax (flash) attention — Pallas TPU kernel.

TPU adaptation of the flash-attention idea (DESIGN.md §6): instead of CUDA
shared-memory tiles and warp shuffles, blocks are BlockSpec-mapped VMEM tiles
sized for the MXU (128-multiples); the kv loop is a ``fori_loop`` whose trip
count is bounded per q-block so causal/windowed kernels skip fully-masked kv
blocks (the same work-skipping the CUDA kernel gets from early exit).

Grid: (batch, q_head, S // block_q).  GQA is handled in the index map — the
kv BlockSpec maps q-head h to kv-head h // group_size, so grouped K/V tiles
are fetched without materializing repeated heads in HBM.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.3819763e38


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float,
                 block_q: int, block_k: int, kv_len: int, q_len: int,
                 causal: bool, window: Optional[int],
                 softcap: Optional[float]):
    qi = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32) * scale           # (bq, d)
    d = q.shape[-1]
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q) + (kv_len - q_len)

    n_kv_blocks = pl.cdiv(kv_len, block_k)
    if causal:
        # highest kv block a query in this q-block can see
        hi = jax.lax.div(qi * block_q + block_q - 1 + (kv_len - q_len),
                         block_k) + 1
        hi = jnp.minimum(hi, n_kv_blocks)
    else:
        hi = n_kv_blocks
    if window is not None:
        lo = jax.lax.max(
            0, jax.lax.div(qi * block_q + (kv_len - q_len) - (window - 1),
                           block_k))
    else:
        lo = 0

    def body(j, carry):
        acc, m_prev, l_prev = carry
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, bk)
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        kv_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = kv_pos[None, :] < kv_len  # tail padding
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= (q_pos[:, None] - kv_pos[None, :]) < window
        logits = jnp.where(mask, logits, NEG_INF)

        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc0, m0, l0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = out.astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,  # (B, Hq, S, d)
    k: jnp.ndarray,  # (B, Hkv, T, d)
    v: jnp.ndarray,  # (B, Hkv, T, d)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, S, d = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    groups = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, T)

    orig_S = S
    if S % block_q:
        pad = block_q - S % block_q
        q = jnp.pad(q, ((0, 0), (0, 0), (pad, 0), (0, 0)))  # left-pad queries
        S = S + pad
    # kv tail padding handled by the in-kernel kv_pos < kv_len mask
    if T % block_k:
        padk = block_k - T % block_k
        k = jnp.pad(k, ((0, 0), (0, 0), (0, padk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, padk), (0, 0)))

    grid = (B, Hq, S // block_q)
    # NB: q_len is the PADDED length — with left-padded queries, row r maps to
    # absolute position r + (T - S_padded), which keeps the last real query
    # aligned to the last kv position; padded rows land at negative positions
    # and are fully masked (their l==0 is guarded in the kernel).
    kernel = functools.partial(
        _attn_kernel, scale=d ** -0.5, block_q=block_q, block_k=block_k,
        kv_len=T, q_len=S, causal=causal, window=window,
        softcap=softcap)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, d),
                         lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((None, None, k.shape[2], d),
                         lambda b, h, i, g=groups: (b, h // g, 0, 0)),
            pl.BlockSpec((None, None, v.shape[2], d),
                         lambda b, h, i, g=groups: (b, h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, d),
                               lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, S - orig_S:, :]
