"""Pure-jnp oracle for the flash attention kernel.

Identical math to ``repro.models.attention.gqa_attention`` but kept here as a
standalone, dependency-light reference so kernel tests compare kernel output
against exactly this function.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def mha_reference(
    q: jnp.ndarray,  # (B, H, S, d)
    k: jnp.ndarray,  # (B, H, T, d)
    v: jnp.ndarray,  # (B, H, T, d)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jnp.ndarray:
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap_ * jnp.tanh(logits / softcap_) \
            if (softcap_ := softcap) else logits
    S, T = q.shape[2], k.shape[2]
    q_pos = jnp.arange(S)[:, None] + (T - S)  # right-aligned queries
    kv_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= (q_pos - kv_pos) < window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def gqa_reference(q, k, v, **kw):
    """q: (B, Hq, S, d), k/v: (B, Hkv, T, d) with Hq % Hkv == 0."""
    B, Hq, S, d = q.shape
    Hkv = k.shape[1]
    groups = Hq // Hkv
    qg = q.reshape(B, Hkv, groups, S, d)
    out = jax.vmap(lambda qq, kk, vv: mha_reference(
        qq.reshape(B * Hkv, 1, S, d).reshape(B, Hkv, S, d), kk, vv, **kw),
        in_axes=(2, None, None), out_axes=2)(qg, k, v)
    return out.reshape(B, Hq, S, d)
