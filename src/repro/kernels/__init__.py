"""repro.kernels — Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships as <name>/kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), <name>/ops.py (jitted public wrapper) and <name>/ref.py (pure-jnp
oracle); tests sweep shapes/dtypes in interpret mode against the oracle.
"""
