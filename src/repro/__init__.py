"""repro — reproducible, replayable training & serving over a tensor lake.

JAX reproduction of "Reproducible data science over data lakes: replayable
data pipelines with Bauplan and Nessie" (DEEM @ SIGMOD 2024), extended into
a multi-pod training/serving framework: the catalog (Git semantics over
content-addressed tensor tables) versions data, code, runtime and hardware
for every run — training runs, checkpoints and serving deployments are all
replayable catalog objects.
"""

__version__ = "1.0.0"
