"""Multi-replica serving on immutable catalog refs.

Deployment is a catalog **tag flip**: replicas watch ``serving/prod`` and,
when the tag moves, roll one at a time onto the new checkpoint commit while
the rest keep serving — so a rollout is one CAS'd ref write and a rollback
is the reverse (time-travel as a deployment primitive, the paper's "few CLI
commands" promise applied to serving).  Rollouts can be gated by a
**canary**: a replica pinned to the candidate commit serves live traffic,
its metrics land in a table on a canary branch, and the tag flips only if
WAP expectations over that table pass (``core/wap.py`` — the
"Proof-Carrying AI Agents" gating idea).

The fleet is deliberately step-driven and single-threaded: ``submit`` routes
to the least-loaded live replica, each :meth:`ServingFleet.step` advances
every replica one decode interval and the rollout state machine one
transition.  That makes every schedule — including replica crashes injected
mid-rollout — deterministic and replayable, the same philosophy as
``core/exec``'s lease board.

Sync points (``on_event``): ``fleet:poll``, ``fleet:rollout:begin``,
``replica:<name>:swap:before`` / ``:after``, ``replica:<name>:crash`` —
``tests/fault_schedule.py`` schedules kills/delays at these names exactly
as it does for store operations.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import Lake
from ..core.errors import RefNotFound, ReproError
from ..core.sync import commit_closure
from ..core.wap import (AuditReport, Expectation, audit, audit_frames,
                        column_range, no_nans, not_empty)
from ..models.config import ModelConfig
from .batcher import ContinuousBatcher
from .engine import FixedBatchedServer, Request, ServeEngine

#: the production serving tag replicas watch — flipping it IS the rollout
PROD_TAG = "serving/prod"
#: where a flip records the previous production commit (rollback target)
PREV_TAG = "serving/prev"
#: default branch canary metrics are committed to (owner: ``canary``)
CANARY_BRANCH = "canary.rollout"
#: default metric table the canary audit runs over
CANARY_TABLE = "serve_metrics"


def _tag_ref(tag: str) -> str:
    return f"tag={tag}"


def read_tag(lake: Lake, tag: str = PROD_TAG) -> Optional[str]:
    """Commit digest the serving tag points at (None if unset)."""
    try:
        return lake.store.get_ref(_tag_ref(tag))
    except RefNotFound:
        return None


def prefetch_weights(lake: Lake, ref: str, *, chunk: int = 64) -> int:
    """Warm-pool prefetch: pull the checkpoint commit's whole closure
    through the tiered store's read-through BEFORE a replica takes traffic,
    so the swap itself never waits on the remote.  Returns blobs fetched
    (0 on a purely local store — nothing to warm)."""
    commit = lake.catalog.resolve(ref)
    local = getattr(lake.store, "local", None)
    if local is None:
        return 0
    closure = sorted(commit_closure(lake.store, commit))
    missing = [d for d in closure if not local.has(d)]
    for i in range(0, len(missing), chunk):
        lake.store.get_many(missing[i:i + chunk])  # read-through write-back
    return len(missing)


# ---------------------------------------------------------------- rollouts
@dataclass
class RolloutReport:
    tag: str
    old: Optional[str]          # previous production commit (None = first)
    new: str                    # candidate commit
    flipped: bool
    reason: str = ""
    audit: Optional[AuditReport] = None

    def to_obj(self) -> dict:
        return {"tag": self.tag, "old": self.old, "new": self.new,
                "flipped": self.flipped, "reason": self.reason,
                "audit": None if self.audit is None else
                {"passed": self.audit.passed,
                 "results": self.audit.results,
                 "errors": self.audit.errors}}


def flip_tag(lake: Lake, target_ref: str, *, tag: str = PROD_TAG,
             prev_tag: str = PREV_TAG) -> RolloutReport:
    """The rollout primitive: CAS the serving tag onto ``target_ref``.

    Compare-and-set against the currently observed tag value, so two
    concurrent rollouts cannot both win (the loser gets ``RefConflict``
    and must re-read — no partial flip is representable).  The displaced
    commit is recorded under ``prev_tag`` for :func:`rollback`."""
    new = lake.catalog.resolve(target_ref)
    old = read_tag(lake, tag)
    if old == new:
        return RolloutReport(tag, old, new, flipped=False,
                             reason="already current")
    lake.store.cas_ref(_tag_ref(tag), old, new)
    if old is not None:
        lake.store.set_ref(_tag_ref(prev_tag), old)
    return RolloutReport(tag, old, new, flipped=True)


def rollback(lake: Lake, *, tag: str = PROD_TAG,
             prev_tag: str = PREV_TAG) -> RolloutReport:
    """Time-travel the serving tag back to the pre-rollout commit.

    The flip re-records the displaced commit under ``prev_tag``, so two
    rollbacks in a row return to where you started."""
    prev = read_tag(lake, prev_tag)
    if prev is None:
        raise RefNotFound(
            f"no {prev_tag!r} tag — nothing to roll back to")
    return flip_tag(lake, prev, tag=tag, prev_tag=prev_tag)


def default_canary_expectations(
        table: str = CANARY_TABLE, *,
        max_latency_us: Optional[float] = None) -> List[Expectation]:
    """The baseline canary gate: metrics exist, are finite, every request
    completed fully (``ok``) and cited the candidate commit
    (``commit_ok``); optionally a hard latency ceiling."""
    exps = [not_empty(table), no_nans(table),
            column_range(table, "ok", 1.0, 1.0),
            column_range(table, "commit_ok", 1.0, 1.0)]
    if max_latency_us is not None:
        exps.append(column_range(table, "latency_us", 0.0, max_latency_us))
    return exps


def canary_rollout(lake: Lake, cfg: ModelConfig, candidate_ref: str,
                   requests: Sequence[Tuple[int, np.ndarray, int]],
                   expectations: Optional[Sequence[Expectation]] = None, *,
                   max_len: int = 128, slots: int = 4,
                   tag: str = PROD_TAG, prev_tag: str = PREV_TAG,
                   branch: Optional[str] = CANARY_BRANCH,
                   author: str = "canary", on_event=None,
                   clock: Callable[[], float] = time.perf_counter,
                   max_steps: int = 100_000) -> RolloutReport:
    """Gated rollout: serve ``requests`` from a canary replica pinned to
    ``candidate_ref``, audit WAP ``expectations`` over the live metric
    table, and flip ``tag`` ONLY if the audit passes.

    The tag is untouched until after the audit verdict — a failing canary
    cannot leave a partial flip.  With ``branch`` set (default), metrics
    are committed to that branch first and the authoritative audit runs
    over the committed table (so the verdict is itself replayable);
    ``branch=None`` audits the in-memory frames only
    (:func:`repro.core.wap.audit_frames`)."""
    candidate = lake.catalog.resolve(candidate_ref)
    old = read_tag(lake, tag)
    replica = Replica("canary", lake, cfg, max_len=max_len, slots=slots,
                      on_event=on_event)
    replica.load(candidate)

    t0: Dict[int, float] = {}
    lat: Dict[int, float] = {}
    results: Dict[int, "object"] = {}
    for rid, prompt, n_tokens in requests:
        t0[rid] = clock()
        replica.server.submit(rid, prompt, n_tokens)
    steps = 0
    while replica.server.pending:
        steps += 1
        if steps > max_steps:
            raise ReproError("canary did not drain (stuck server?)")
        replica.server.step()
        now = clock()
        for rid in list(replica.server.completed):
            results[rid] = replica.server.completed.pop(rid)
            lat[rid] = now - t0[rid]

    rids = sorted(t0)
    metrics = {
        "latency_us": np.asarray([lat.get(r, np.nan) * 1e6 for r in rids],
                                 np.float32),
        "n_tokens": np.asarray(
            [results[r].tokens.shape[1] if r in results else 0
             for r in rids], np.int32),
        "ok": np.asarray(
            [1.0 if r in results
             and results[r].tokens.shape[1] == dict(
                 (q, n) for q, _, n in requests)[r] else 0.0
             for r in rids], np.float32),
        "commit_ok": np.asarray(
            [1.0 if r in results and results[r].model_commit == candidate
             else 0.0 for r in rids], np.float32),
    }
    exps = list(expectations) if expectations is not None \
        else default_canary_expectations()
    if branch is None:
        report = audit_frames(exps, {CANARY_TABLE: metrics},
                              context="canary:live")
    else:
        if branch not in lake.catalog.branches():
            lake.catalog.create_branch(branch, "main", author=author)
        lake.write_table(branch, CANARY_TABLE, metrics, author=author,
                         message=f"canary metrics for {candidate[:12]}")
        report = audit(lake.catalog, lake.io, branch, exps)
    if not report.passed:
        return RolloutReport(tag, old, candidate, flipped=False,
                             reason="canary audit failed", audit=report)
    out = flip_tag(lake, candidate, tag=tag, prev_tag=prev_tag)
    out.audit = report
    return out


# ----------------------------------------------------------------- replicas
class Replica:
    """One serving replica: an engine pinned to a commit + its batcher."""

    def __init__(self, name: str, lake: Lake, cfg: ModelConfig, *,
                 max_len: int = 128, slots: int = 4,
                 mode: str = "continuous", on_event=None):
        assert mode in ("continuous", "fixed"), mode
        self.name = name
        self.lake = lake
        self.cfg = cfg
        self.max_len = max_len
        self.slots = slots
        self.mode = mode
        self.on_event = on_event
        self.server = None
        self.commit: Optional[str] = None
        self.alive = True
        self.draining = False
        self.swaps = 0
        self.prefetched = 0

    def _fire(self, point: str) -> None:
        if self.on_event is not None:
            self.on_event(point)

    def load(self, ref: str) -> None:
        """Prefetch weights, build the engine, take traffic — in that
        order: the replica serves nothing from the new commit until its
        closure is local (warm-pool contract)."""
        self._fire(f"replica:{self.name}:swap:before")
        self.prefetched += prefetch_weights(self.lake, ref)
        engine = ServeEngine.from_catalog(self.lake, ref, self.cfg,
                                          max_len=self.max_len,
                                          batch_size=self.slots)
        self.server = (ContinuousBatcher(engine, slots=self.slots)
                       if self.mode == "continuous"
                       else FixedBatchedServer(engine))
        self.commit = engine.model_commit
        self.swaps += 1
        self._fire(f"replica:{self.name}:swap:after")

    @property
    def pending(self) -> int:
        return self.server.pending if self.server is not None else 0

    @property
    def routable(self) -> bool:
        return self.alive and not self.draining and self.server is not None


class ServingFleet:
    """N replicas behind one front-end, watching a serving tag.

    ``submit`` routes to the least-loaded routable replica (requests wait
    at the fleet when none is routable — e.g. a 1-replica fleet mid-swap —
    and are dispatched as soon as one is, so a rollout delays requests but
    never fails them).  Each ``step``:

    1. every ``poll_every`` steps, re-read the watch tag (``poll``);
    2. advance the rolling update: at most ONE replica drains and swaps at
       a time, the rest keep serving the old commit — zero-downtime;
    3. dispatch waiting requests, run one decode interval per replica,
       collect completions (with submit→complete latency).

    A replica that crashes (any ``ReproError`` out of its server or swap —
    including injected faults) is marked dead and its queued AND in-flight
    requests are re-dispatched to the survivors; generation is
    deterministic, so the re-run produces identical tokens.
    """

    def __init__(self, lake: Lake, cfg: ModelConfig, *, replicas: int = 2,
                 slots: int = 4, max_len: int = 128,
                 watch_tag: str = PROD_TAG, poll_every: int = 4,
                 mode: str = "continuous", on_event=None,
                 clock: Callable[[], float] = time.perf_counter):
        self.lake = lake
        self.cfg = cfg
        self.watch_tag = watch_tag
        self.poll_every = max(1, poll_every)
        self.mode = mode
        self.on_event = on_event
        self.clock = clock
        target = read_tag(lake, watch_tag)
        if target is None:
            raise RefNotFound(
                f"serving tag {watch_tag!r} is unset — create it with "
                f"`repro rollout --to <checkpoint-ref>`")
        self.target = target
        self.replicas = [
            Replica(f"r{i}", lake, cfg, max_len=max_len, slots=slots,
                    mode=mode, on_event=on_event)
            for i in range(replicas)]
        for r in self.replicas:
            r.load(self.target)
        self.queue: List[Request] = []
        self.completed: Dict[int, "object"] = {}
        self.latency: Dict[int, float] = {}      # rid -> seconds
        self._t_submit: Dict[int, float] = {}
        self.steps = 0
        self.rollouts = 0
        self.events: List[Tuple[int, str]] = []

    # ------------------------------------------------------------- surface
    def _log(self, event: str) -> None:
        self.events.append((self.steps, event))
        if self.on_event is not None:
            self.on_event(event)

    def submit(self, request_id: int, prompt: np.ndarray, n_tokens: int):
        self.queue.append(Request(request_id, np.asarray(prompt, np.int32),
                                  int(n_tokens)))
        self._t_submit[request_id] = self.clock()

    @property
    def pending(self) -> int:
        return len(self.queue) + sum(r.pending for r in self.replicas
                                     if r.alive)

    @property
    def alive_count(self) -> int:
        return sum(1 for r in self.replicas if r.alive)

    def kill(self, name_or_index) -> None:
        """Simulate a replica crash (tests / operational drills)."""
        r = (self.replicas[name_or_index]
             if isinstance(name_or_index, int) else
             next(x for x in self.replicas if x.name == name_or_index))
        self._crash(r, "killed")

    # ------------------------------------------------------------ internals
    def _crash(self, replica: Replica, reason: str) -> None:
        replica.alive = False
        replica.draining = False
        self._log(f"replica:{replica.name}:crash:{reason}")
        if replica.server is not None:
            self.queue[:0] = replica.server.cancel_all()
            replica.server = None

    def poll(self) -> None:
        """Re-read the watch tag; a moved tag begins a rolling update."""
        self._log("fleet:poll")
        target = read_tag(self.lake, self.watch_tag)
        if target is not None and target != self.target:
            self.target = target
            self.rollouts += 1
            self._log(f"fleet:rollout:begin:{target[:12]}")

    def _advance_rollout(self) -> None:
        swapping = [r for r in self.replicas if r.alive and r.draining]
        if not swapping:
            stale = [r for r in self.replicas
                     if r.alive and not r.draining
                     and r.commit != self.target]
            if stale:
                r = stale[0]
                r.draining = True
                # queued-but-unadmitted work must not wait out the drain
                if r.server is not None:
                    moved, r.server.queue = r.server.queue, []
                    self.queue[:0] = moved
                swapping = [r]
        for r in swapping:
            if r.server is not None and r.server.pending:
                continue  # in-flight work finishes on the old commit
            try:
                r.load(self.target)
                r.draining = False
                self._log(f"replica:{r.name}:swapped:{self.target[:12]}")
            except ReproError as e:
                self._crash(r, f"swap failed: {e}")

    def _dispatch(self) -> None:
        targets = [r for r in self.replicas if r.routable]
        if not targets:
            return
        while self.queue:
            r = min(targets, key=lambda x: x.pending)
            req = self.queue.pop(0)
            r.server.submit(req.request_id, req.prompt, req.n_tokens)

    def step(self) -> int:
        """One fleet interval; returns requests completed this step."""
        self.steps += 1
        if self.steps % self.poll_every == 0:
            self.poll()
        self._advance_rollout()
        self._dispatch()
        done = 0
        for r in self.replicas:
            if not r.alive or r.server is None:
                continue
            try:
                r.server.step()
            except ReproError as e:
                self._crash(r, f"step failed: {e}")
                continue
            now = self.clock()
            for rid in list(r.server.completed):
                self.completed[rid] = r.server.completed.pop(rid)
                t0 = self._t_submit.pop(rid, None)
                if t0 is not None:
                    self.latency[rid] = now - t0
                done += 1
        return done

    def drain(self, *, max_steps: int = 100_000) -> int:
        """Step until nothing is pending; returns completions collected."""
        done = 0
        while self.pending:
            if self.alive_count == 0:
                raise ReproError(
                    f"fleet has no live replicas with {self.pending} "
                    "requests pending")
            if self.steps >= max_steps:
                raise ReproError(f"fleet did not drain in {max_steps} steps")
            done += self.step()
        return done
