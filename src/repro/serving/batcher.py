"""Continuous batching: admit new requests into in-flight decode batches.

The scheduler keeps a fixed pool of decode *slots* over one shared KV/state
cache.  Each :meth:`ContinuousBatcher.step` is one **decode interval**:

1. *admit* — every free slot pulls the next queued request: the prompt is
   prefilled alone at its exact length (one B=1 prefill, same computation as
   serving the request solo) and its cache rows are written into the slot;
2. *decode* — ONE vmapped decode call advances every slot a token, each row
   against its own cache at its own position (``ServeEngine.row_decode``);
3. *retire* — slots that produced their last token complete their request
   and free up, to be refilled at the next step's admit phase.

The decode loop never blocks on the device: retirement depends only on
token COUNTS, so each interval's token vector stays on device in an
interval log and dispatches queue asynchronously; values are materialized
(one host sync) when a request completes.  Free slots keep decoding
garbage rows — their outputs are never read and their cache rows are
overwritten at the next admit, which costs nothing extra because the
vmapped call advances all ``slots`` rows either way.

Two contracts distinguish this from the :class:`FixedBatchedServer` it
subsumes, both pinned by ``tests/serving_conformance.py``:

* **equivalence** — a request's token stream is bit-identical to generating
  it alone (per-slot isolation: exact-length prefill + per-row decode), for
  any arrival order / ``n_tokens`` mix;
* **no head-of-line blocking** — a long request occupies one slot; requests
  submitted later flow through the other slots and complete on their own
  schedule instead of waiting for the longest batch-mate.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from ..models import init_cache
from .engine import GenerationResult, Request, ServeEngine


@dataclass
class _InFlight:
    request: Request
    first_tok: jnp.ndarray  # device scalar until completion materializes it
    ivals: List[int] = field(default_factory=list)  # decode intervals used


class ContinuousBatcher:
    """Slot-scheduled continuous-batching server over a :class:`ServeEngine`.

    Same surface as the fixed server (``submit`` / ``step`` / ``queue`` /
    ``completed``) plus ``pending`` (queued + in-flight) — drive with
    ``while server.pending: server.step()``.
    """

    def __init__(self, engine: ServeEngine, *, slots: Optional[int] = None):
        self.engine = engine
        self.slots = int(slots or engine.batch_size)
        cfg = engine.cfg
        self.queue: List[Request] = []
        self.completed: Dict[int, GenerationResult] = {}
        self._active: Dict[int, _InFlight] = {}      # slot -> in-flight
        self._free: List[int] = list(range(self.slots))
        self._cache = init_cache(cfg, self.slots, engine.max_len,
                                 dtype=cfg.compute_dtype)
        self._cache["pos"] = jnp.zeros((self.slots,), jnp.int32)  # per-row
        self._tokens = jnp.zeros((self.slots, 1), jnp.int32)
        self._decode = engine.row_decode()
        self._log: Dict[int, jnp.ndarray] = {}       # interval -> (S,1) toks
        self._np_log: Dict[int, np.ndarray] = {}     # ...materialized
        #: decode intervals run so far (the bench's unit of rollout blip)
        self.intervals = 0

    # ------------------------------------------------------------- surface
    def submit(self, request_id: int, prompt: np.ndarray, n_tokens: int):
        assert n_tokens >= 1
        assert prompt.shape[0] + n_tokens <= self.engine.max_len
        self.queue.append(Request(request_id, np.asarray(prompt, np.int32),
                                  n_tokens))

    @property
    def pending(self) -> int:
        """Requests not yet completed (waiting + in a slot)."""
        return len(self.queue) + len(self._active)

    def cancel_all(self) -> List[Request]:
        """Abandon queued AND in-flight work, handing the requests back for
        re-dispatch (generation is deterministic, so a re-run elsewhere
        produces the identical tokens) — the fleet's crash path."""
        out = list(self.queue)
        self.queue = []
        for slot in sorted(self._active):
            out.append(self._active[slot].request)
        self._active.clear()
        self._free = list(range(self.slots))
        self._log.clear()
        self._np_log.clear()
        return out

    # ---------------------------------------------------------------- step
    _CHUNK = 16  # intervals materialized per host transfer

    def _tok(self, interval: int, slot: int) -> int:
        """Token decoded for ``slot`` at ``interval`` — materialized lazily
        on first read, a CHUNK of consecutive intervals per host transfer
        (per-interval np.asarray costs a dispatch each; one concatenated
        copy amortizes it across every slot retiring nearby)."""
        a = self._np_log.get(interval)
        if a is None:
            lo = interval - interval % self._CHUNK
            span = [j for j in range(lo, lo + self._CHUNK) if j in self._log]
            block = np.asarray(jnp.concatenate([self._log[j] for j in span],
                                               axis=1))
            for col, j in enumerate(span):
                self._np_log[j] = block[:, col:col + 1]
            a = self._np_log[interval]
        return int(a[slot, 0])

    def _complete(self, inflight: _InFlight, slot: Optional[int]) -> None:
        r = inflight.request
        toks = [int(inflight.first_tok)] + [self._tok(j, slot)
                                            for j in inflight.ivals]
        self.completed[r.request_id] = GenerationResult(
            tokens=np.asarray(toks, np.int32)[None, :],
            model_commit=self.engine.model_commit,
            prompt_len=r.prompt.shape[0])

    def _prune_log(self) -> None:
        if not self._log:
            return
        floor = (min(min(inf.ivals, default=self.intervals + 1)
                     for inf in self._active.values())
                 if self._active else self.intervals + 1)
        for j in [j for j in self._log if j < floor]:
            self._log.pop(j)
            self._np_log.pop(j, None)

    def _admit(self) -> int:
        done = 0
        while self._free and self.queue:
            r = self.queue.pop(0)
            first_tok, cache1 = self.engine.prefill_one(r.prompt)
            inflight = _InFlight(r, first_tok)
            if r.n_tokens == 1:  # completed at prefill; slot stays free
                self._complete(inflight, None)
                done += 1
                continue
            slot = self._free.pop(0)
            self._cache, self._tokens = self.engine.write_slot(
                self._cache, self._tokens, cache1, first_tok, slot)
            self._active[slot] = inflight
        return done

    def step(self) -> int:
        """One admit + decode interval; returns requests completed."""
        done = self._admit()
        if not self._active:
            return done
        self.intervals += 1
        self._tokens, self._cache = self._decode(
            self.engine.params, self._tokens, self._cache)
        self._log[self.intervals] = self._tokens
        for slot in sorted(self._active):
            inflight = self._active[slot]
            inflight.ivals.append(self.intervals)
            if 1 + len(inflight.ivals) >= inflight.request.n_tokens:
                self._complete(inflight, slot)
                del self._active[slot]
                self._free.append(slot)
                done += 1
        if done or not self.intervals % 64:
            self._prune_log()
        return done


class BatchedServer(ContinuousBatcher):
    """The request server, now continuously batched.

    The fixed-bucket scheduler this name used to denote (and its
    head-of-line blocking) lives on as
    :class:`~repro.serving.engine.FixedBatchedServer`, kept as the
    benchmark baseline; existing call sites get continuous batching."""
