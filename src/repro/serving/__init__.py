"""repro.serving — commit-pinned serving: continuous batching over replica
fleets that watch immutable catalog tags (rollout = tag flip)."""

from .batcher import BatchedServer, ContinuousBatcher
from .engine import (FixedBatchedServer, GenerationResult, Request,
                     ServeEngine)
from .fleet import (CANARY_BRANCH, CANARY_TABLE, PREV_TAG, PROD_TAG, Replica,
                    RolloutReport, ServingFleet, canary_rollout,
                    default_canary_expectations, flip_tag, prefetch_weights,
                    read_tag, rollback)

__all__ = [
    "ServeEngine", "GenerationResult", "Request",
    "ContinuousBatcher", "BatchedServer", "FixedBatchedServer",
    "ServingFleet", "Replica", "RolloutReport",
    "flip_tag", "rollback", "canary_rollout", "read_tag",
    "prefetch_weights", "default_canary_expectations",
    "PROD_TAG", "PREV_TAG", "CANARY_BRANCH", "CANARY_TABLE",
]
