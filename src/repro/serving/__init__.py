"""repro.serving — commit-pinned batched serving (prefill + KV-cache decode)."""

from .engine import BatchedServer, GenerationResult, Request, ServeEngine

__all__ = ["ServeEngine", "BatchedServer", "Request", "GenerationResult"]
