"""Serving engine: batched prefill + decode over a KV cache, pinned to a
catalog commit (the paper's read path, Fig. 3: ref → snapshot → files →
in-memory — here ref → checkpoint commit → params → device).

The engine records which commit its weights came from; every response can
therefore cite an immutable model identity — serving inherits the paper's
reproducibility story.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import checkpoint as ckpt
from ..core import Lake
from ..models import init_cache
from ..models.config import ModelConfig
from ..runtime.steps import build_decode_step, build_prefill_step


@dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, n_generated)
    model_commit: Optional[str]
    prompt_len: int


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int,
                 batch_size: int, model_commit: Optional[str] = None,
                 ac=None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.batch_size = batch_size
        self.model_commit = model_commit
        ac = ac if ac is not None else (lambda x, name=None: x)
        self._prefill = jax.jit(build_prefill_step(cfg, max_len=max_len,
                                                   ac=ac))
        self._decode = jax.jit(build_decode_step(cfg, ac=ac))

    @classmethod
    def from_catalog(cls, lake: Lake, ref: str, cfg: ModelConfig, *,
                     max_len: int, batch_size: int, mesh=None,
                     param_specs=None, ac=None) -> "ServeEngine":
        """Load weights from a checkpoint commit — the serving side of
        'immutable reference to code and input data'."""
        commit = lake.catalog.resolve(ref)
        params, _, _ = ckpt.restore(lake, commit, mesh=mesh,
                                    param_specs=param_specs)
        return cls(cfg, params, max_len=max_len, batch_size=batch_size,
                   model_commit=commit, ac=ac)

    # ------------------------------------------------------------- generate
    def generate(self, prompts: np.ndarray, *, n_tokens: int,
                 extra_embeds=None) -> GenerationResult:
        """Greedy batched generation. prompts: (B, P) int32."""
        B, P = prompts.shape
        assert B == self.batch_size, (B, self.batch_size)
        assert P + n_tokens <= self.max_len
        cache = init_cache(self.cfg, B, self.max_len,
                           dtype=self.cfg.compute_dtype)
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      cache, extra_embeds)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [np.asarray(tok)]
        for _ in range(n_tokens - 1):
            tok, _, cache = self._decode(self.params, tok, cache)
            out.append(np.asarray(tok))
        return GenerationResult(tokens=np.stack(out, axis=1),
                                model_commit=self.model_commit,
                                prompt_len=P)


@dataclass
class Request:
    request_id: int
    prompt: np.ndarray
    n_tokens: int


class BatchedServer:
    """Static-batching request server: queue requests, run bucketed batches.

    (Continuous batching is a decode-slot scheduler on top of the same
    decode step; static bucketing keeps the example deterministic.)"""

    def __init__(self, engine: ServeEngine):
        self.engine = engine
        self.queue: List[Request] = []
        self.completed: Dict[int, GenerationResult] = {}

    def submit(self, request_id: int, prompt: np.ndarray, n_tokens: int):
        self.queue.append(Request(request_id, prompt, n_tokens))

    def step(self) -> int:
        """Serve one batch; returns number of requests completed."""
        if not self.queue:
            return 0
        bs = self.engine.batch_size
        batch, self.queue = self.queue[:bs], self.queue[bs:]
        P = max(r.prompt.shape[0] for r in batch)
        n_gen = max(r.n_tokens for r in batch)
        prompts = np.zeros((bs, P), np.int32)
        for i, r in enumerate(batch):
            prompts[i, P - r.prompt.shape[0]:] = r.prompt  # left-pad
        while len(batch) < bs:  # pad the batch with copies of slot 0
            batch.append(batch[0])
        res = self.engine.generate(prompts, n_tokens=n_gen)
        done = 0
        for i, r in enumerate(batch[:bs]):
            if r.request_id not in self.completed:
                self.completed[r.request_id] = GenerationResult(
                    tokens=res.tokens[i:i + 1, :r.n_tokens],
                    model_commit=res.model_commit, prompt_len=P)
                done += 1
        return done
