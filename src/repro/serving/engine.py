"""Serving engine: batched prefill + decode over a KV cache, pinned to a
catalog commit (the paper's read path, Fig. 3: ref → snapshot → files →
in-memory — here ref → checkpoint commit → params → device).

The engine records which commit its weights came from; every response can
therefore cite an immutable model identity — serving inherits the paper's
reproducibility story.

Compiled steps are shared process-wide per ``(cfg, max_len)`` (weights are
*arguments*, never baked in), so a fleet of replicas — or a replica swapping
weights on a rollout — pays for each jit exactly once, and two engines
pinned to the same commit are bit-identical by construction.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import checkpoint as ckpt
from ..core import Lake
from ..models import init_cache
from ..models.config import ModelConfig
from ..runtime.steps import build_decode_step, build_prefill_step


def _cache_axes(cfg: ModelConfig) -> Dict[str, int]:
    """vmap axes of the per-slot cache: ``pos`` is per-row (axis 0), every
    other leaf is (L, B, ...) — batch on axis 1."""
    axes = {"pos": 0}
    if cfg.has_attention:
        axes.update(k=1, v=1)
    if cfg.has_ssm:
        axes.update(h=1, conv=1)
    return axes


@functools.lru_cache(maxsize=16)
def _shared_steps(cfg: ModelConfig, max_len: int):
    """(prefill, decode, row_decode) jitted once per (cfg, max_len).

    ``row_decode`` is the continuous-batching primitive: a vmap of the
    single-request decode step over the slot axis with a PER-ROW position,
    so every slot advances through its own sequence independently.  Because
    each row runs exactly the B=1 decode computation, a slot's token stream
    is bit-identical to generating that request alone — the equivalence the
    serving conformance suite pins.
    """
    prefill_raw = build_prefill_step(cfg, max_len=max_len)
    prefill = jax.jit(prefill_raw)
    decode = jax.jit(build_decode_step(cfg))
    step = build_decode_step(cfg)
    axes = _cache_axes(cfg)

    # prefill fused with greedy sampling: returns the first TOKEN (not the
    # logits), so the admit path never syncs on a host-side argmax — the
    # batcher keeps the scalar on device until the request completes
    @jax.jit
    def prefill_tok(params, tokens, cache):
        logits, cache = prefill_raw(params, tokens, cache, None)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)[0], cache

    def row(params, token1, cache_row):
        cache = {k: (v if k == "pos" else v[:, None])
                 for k, v in cache_row.items()}
        tok, _, cache = step(params, token1, cache)
        return tok, {k: (v if k == "pos" else v[:, 0])
                     for k, v in cache.items()}

    row_decode = jax.jit(jax.vmap(row, in_axes=(None, 0, axes),
                                  out_axes=(0, axes)))

    # one fused (donated) executable for the whole slot admit: write the
    # prefilled B=1 cache into the pool AND splice the first token into the
    # next-input vector — leaf-by-leaf .at[].set outside jit costs ~2 decode
    # intervals per admit in separate dispatches + full copies.  Only the
    # cache is donated: the tokens vector is tiny AND aliased by the
    # batcher's interval log, which must stay readable after the admit
    @functools.partial(jax.jit, donate_argnums=(0,))
    def write_slot(cache, tokens, cache1, first_tok, slot):
        cache = {k: (v.at[slot].set(cache1[k]) if k == "pos"
                     else v.at[:, slot].set(cache1[k][:, 0]))
                 for k, v in cache.items()}
        return cache, tokens.at[slot, 0].set(first_tok)

    return prefill, prefill_tok, decode, row_decode, write_slot


@dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, n_generated)
    model_commit: Optional[str]
    prompt_len: int


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int,
                 batch_size: int, model_commit: Optional[str] = None,
                 ac=None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.batch_size = batch_size
        self.model_commit = model_commit
        self._zero_cache = None  # lazy B=1 prefill template (see prefill_one)
        if ac is None:  # the common path: share compiles across engines
            (self._prefill, self._prefill_tok, self._decode,
             self._row_decode, self._write_slot) = _shared_steps(cfg, max_len)
        else:
            prefill_raw = build_prefill_step(cfg, max_len=max_len, ac=ac)
            self._prefill = jax.jit(prefill_raw)

            @jax.jit
            def prefill_tok(params, tokens, cache):
                logits, cache = prefill_raw(params, tokens, cache, None)
                return (jnp.argmax(logits, axis=-1).astype(jnp.int32)[0],
                        cache)

            self._prefill_tok = prefill_tok
            self._decode = jax.jit(build_decode_step(cfg, ac=ac))
            step = build_decode_step(cfg, ac=ac)
            axes = _cache_axes(cfg)

            def row(params, token1, cache_row):
                cache = {k: (v if k == "pos" else v[:, None])
                         for k, v in cache_row.items()}
                tok, _, cache = step(params, token1, cache)
                return tok, {k: (v if k == "pos" else v[:, 0])
                             for k, v in cache.items()}

            self._row_decode = jax.jit(jax.vmap(row, in_axes=(None, 0, axes),
                                                out_axes=(0, axes)))

            @functools.partial(jax.jit, donate_argnums=(0,))
            def write_slot(cache, tokens, cache1, first_tok, slot):
                cache = {k: (v.at[slot].set(cache1[k]) if k == "pos"
                             else v.at[:, slot].set(cache1[k][:, 0]))
                         for k, v in cache.items()}
                return cache, tokens.at[slot, 0].set(first_tok)

            self._write_slot = write_slot

    @classmethod
    def from_catalog(cls, lake: Lake, ref: str, cfg: ModelConfig, *,
                     max_len: int, batch_size: int, mesh=None,
                     param_specs=None, ac=None) -> "ServeEngine":
        """Load weights from a checkpoint commit — the serving side of
        'immutable reference to code and input data'."""
        commit = lake.catalog.resolve(ref)
        params, _, _ = ckpt.restore(lake, commit, mesh=mesh,
                                    param_specs=param_specs)
        return cls(cfg, params, max_len=max_len, batch_size=batch_size,
                   model_commit=commit, ac=ac)

    # ---------------------------------------------------------- primitives
    def prefill_one(self, prompt: np.ndarray):
        """Prefill ONE request at its exact length (no padding, so the
        computation — and therefore the token stream — matches generating
        the request alone).  Returns ``(first_token, cache with B=1)``
        where the token is a DEVICE scalar — argmax is fused into the
        prefill jit and nothing here blocks on the device, so admits queue
        asynchronously behind in-flight decode intervals.

        The zeroed input cache is a shared template: the step fns are
        functional (they return a NEW cache, never mutating the input), so
        one allocation serves every admit instead of re-paying
        ``init_cache``'s per-leaf dispatches on the request hot path."""
        if self._zero_cache is None:
            self._zero_cache = init_cache(self.cfg, 1, self.max_len,
                                          dtype=self.cfg.compute_dtype)
        return self._prefill_tok(self.params, jnp.asarray(prompt[None]),
                                 self._zero_cache)

    def row_decode(self):
        """The jitted vmapped per-row decode (see ``_shared_steps``)."""
        return self._row_decode

    def write_slot(self, cache, tokens, cache1, first_tok, slot: int):
        """Admit a prefilled request into ``slot`` of a pooled cache: write
        its B=1 cache rows and splice ``first_tok`` into the next-input
        token vector — one fused, donated dispatch (the admit hot path).
        Returns ``(cache, tokens)``."""
        return self._write_slot(cache, tokens, cache1, first_tok, slot)

    # ------------------------------------------------------------- generate
    def generate(self, prompts: np.ndarray, *, n_tokens: int,
                 extra_embeds=None) -> GenerationResult:
        """Greedy batched generation. prompts: (B, P) int32."""
        B, P = prompts.shape
        assert B == self.batch_size, (B, self.batch_size)
        assert P + n_tokens <= self.max_len
        cache = init_cache(self.cfg, B, self.max_len,
                           dtype=self.cfg.compute_dtype)
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      cache, extra_embeds)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [np.asarray(tok)]
        for _ in range(n_tokens - 1):
            tok, _, cache = self._decode(self.params, tok, cache)
            out.append(np.asarray(tok))
        return GenerationResult(tokens=np.stack(out, axis=1),
                                model_commit=self.model_commit,
                                prompt_len=P)


@dataclass
class Request:
    request_id: int
    prompt: np.ndarray
    n_tokens: int


class FixedBatchedServer:
    """Static-batching request server: queue requests, run bucketed batches.

    This is the PRE-continuous-batching baseline, kept as the reference
    point for ``benchmarks/bench_serve.py`` and the ``fixed`` leg of the
    serving conformance matrix.  It has two documented costs the
    continuous :class:`~repro.serving.batcher.ContinuousBatcher` removes:

    * **head-of-line blocking** — every request in a batch decodes for
      ``max(n_tokens)`` steps, and nothing submitted later starts until the
      whole batch drains;
    * **left-pad contamination** — prompts are left-padded to the batch
      max, so a request's tokens depend on its batch-mates (not equal to
      generating it alone).
    """

    def __init__(self, engine: ServeEngine):
        self.engine = engine
        self.queue: List[Request] = []
        self.completed: Dict[int, GenerationResult] = {}

    def submit(self, request_id: int, prompt: np.ndarray, n_tokens: int):
        self.queue.append(Request(request_id, prompt, n_tokens))

    @property
    def pending(self) -> int:
        return len(self.queue)

    def cancel_all(self) -> List[Request]:
        """Drop queued work and hand it back (fleet re-dispatch on crash)."""
        out, self.queue = self.queue, []
        return out

    def step(self) -> int:
        """Serve one batch; returns number of requests completed."""
        if not self.queue:
            return 0
        bs = self.engine.batch_size
        batch, self.queue = self.queue[:bs], self.queue[bs:]
        P = max(r.prompt.shape[0] for r in batch)
        n_gen = max(r.n_tokens for r in batch)
        prompts = np.zeros((bs, P), np.int32)
        for i, r in enumerate(batch):
            prompts[i, P - r.prompt.shape[0]:] = r.prompt  # left-pad
        while len(batch) < bs:  # pad the batch with copies of slot 0
            batch.append(batch[0])
        res = self.engine.generate(prompts, n_tokens=n_gen)
        done = 0
        for i, r in enumerate(batch[:bs]):
            if r.request_id not in self.completed:
                self.completed[r.request_id] = GenerationResult(
                    tokens=res.tokens[i:i + 1, :r.n_tokens],
                    model_commit=res.model_commit, prompt_len=P)
                done += 1
        return done
