"""LR schedules.  WSD (warmup-stable-decay) is a first-class citizen because
minicpm-2b (assigned arch) was trained with it; cosine covers the rest.

WSD's stable phase is also what makes mid-run branching cheap to reason
about: any checkpoint in the stable phase is a valid branch point with the
same LR (the catalog's branch-from-commit semantics pair naturally with it).
"""

from __future__ import annotations

import jax.numpy as jnp


def wsd(step, *, peak_lr: float, warmup_steps: int, stable_steps: int,
        decay_steps: int, floor: float = 0.0):
    """Warmup-Stable-Decay (minicpm): linear warmup → flat → 1-sqrt decay."""
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * (step + 1.0) / max(warmup_steps, 1)
    t = (step - warmup_steps - stable_steps) / max(decay_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    decay = peak_lr * (1.0 - jnp.sqrt(t)) + floor * jnp.sqrt(t)
    return jnp.where(step < warmup_steps, warm,
                     jnp.where(step < warmup_steps + stable_steps,
                               peak_lr, decay))


def cosine(step, *, peak_lr: float, warmup_steps: int, total_steps: int,
           floor_ratio: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * (step + 1.0) / max(warmup_steps, 1)
    t = jnp.clip((step - warmup_steps)
                 / max(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = peak_lr * (floor_ratio + (1 - floor_ratio)
                     * 0.5 * (1.0 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup_steps, warm, cos)


def constant(step, *, peak_lr: float, **_):
    return jnp.full_like(jnp.asarray(step, jnp.float32), peak_lr)


SCHEDULES = {"wsd": wsd, "cosine": cosine, "constant": constant}
