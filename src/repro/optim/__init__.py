"""repro.optim — from-scratch AdamW (+ WSD/cosine schedules, grad clipping,
int8 error-feedback gradient compression)."""

from .adamw import AdamWConfig, AdamWState, apply, compress_int8, init
from .schedules import SCHEDULES, constant, cosine, wsd

__all__ = ["AdamWConfig", "AdamWState", "init", "apply", "compress_int8",
           "SCHEDULES", "wsd", "cosine", "constant"]
