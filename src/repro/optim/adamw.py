"""AdamW with fp32 master weights + optional int8 error-feedback gradient
compression (the distributed-optimization trick for cross-pod all-reduce).

Implemented from scratch (no optax dependency) as pure pytree functions so
the optimizer state is an ordinary pytree: it shards with the same
PartitionSpecs as the parameters (ZeRO-style) and checkpoints as catalog
tables like everything else.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray            # () int32
    mu: Any                      # fp32 pytree
    nu: Any                      # fp32 pytree
    master: Any                  # fp32 master params (None if params are fp32)
    ef: Any                      # error-feedback residual (None if no compression)


class AdamWConfig(NamedTuple):
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    compress_grads: bool = False   # int8 EF compression of the grad tree


def _zeros_like_f32(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def init(params, config: AdamWConfig) -> AdamWState:
    needs_master = any(x.dtype != jnp.float32 for x in jax.tree.leaves(params))
    master = (jax.tree.map(lambda x: x.astype(jnp.float32), params)
              if needs_master else None)
    ef = _zeros_like_f32(params) if config.compress_grads else None
    return AdamWState(jnp.zeros((), jnp.int32), _zeros_like_f32(params),
                      _zeros_like_f32(params), master, ef)


def _global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def compress_int8(g: jnp.ndarray, residual: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Error-feedback int8 quantization: returns (q, scale, new_residual).
    The all-reduce then moves 1 byte/grad instead of 4 — the classic
    bandwidth-term optimization for slow cross-pod links."""
    gf = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(gf)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def apply(grads, state: AdamWState, params, *, lr, config: AdamWConfig):
    """One optimizer step. Returns (new_params, new_state, metrics)."""
    gnorm = _global_norm(grads)
    clip_coef = jnp.minimum(1.0, config.grad_clip / (gnorm + 1e-12)) \
        if config.grad_clip else 1.0

    if config.compress_grads:
        def comp(g, r):
            q, scale, new_r = compress_int8(g, r)
            return q.astype(jnp.float32) * scale, new_r
        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = treedef.flatten_up_to(state.ef)
        pairs = [comp(g, r) for g, r in zip(flat_g, flat_r)]
        grads = treedef.unflatten([p[0] for p in pairs])
        new_ef = treedef.unflatten([p[1] for p in pairs])
    else:
        new_ef = state.ef

    step = state.step + 1
    b1, b2 = config.b1, config.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    masters = state.master if state.master is not None else params

    def upd(p_master, p, g, m, v):
        g = g.astype(jnp.float32) * clip_coef
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + config.eps)
        pm = p_master.astype(jnp.float32)
        pm = pm - lr * (delta + config.weight_decay * pm)
        return pm, pm.astype(p.dtype), m, v

    out = jax.tree.map(upd, masters, params, grads, state.mu, state.nu)
    # unzip the 4-tuples
    new_master = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[3], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_state = AdamWState(
        step, new_mu, new_nu,
        new_master if state.master is not None else None, new_ef)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
