"""Unified decoder LM covering all assigned families.

One scan-over-layers apply function serves dense / ssm / hybrid / moe:
per-layer differences are either static config (family branches) or *scanned
per-layer flags* (e.g. gemma-2's alternating local/global attention and
hymba's three full-attention layers become a boolean vector threaded through
``lax.scan``), so the traced HLO contains exactly ONE layer body regardless
of depth — compact HLO is what makes 94-layer dry-runs compile quickly and
keeps TPU compile times sane at scale.

Multimodal archs (musicgen/internvl2) take precomputed frontend embeddings
(the assignment's "modality frontend is a STUB") which pass through a trained
connector and replace the first ``n_frontend_embeds`` sequence positions.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_lib
from . import ssm as ssm_lib
from .config import ModelConfig
from .layers import (apply_rope, embed_tokens, mlp_swiglu, rms_norm,
                     rope_angles, softcap, unembed)
from .moe import moe_layer

Params = Dict[str, Any]
Identity = lambda x, name=None: x  # noqa: E731  (activation-sharding hook)


def _dtype(name: str):
    return jnp.dtype(name)


# =============================================================== init params
def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    """Real initialization (smoke tests / examples).  The dry-run never calls
    this — it uses ``jax.eval_shape(init_params, ...)`` stand-ins."""
    pd = _dtype(cfg.param_dtype)
    D, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    keys = iter(jax.random.split(key, 64))

    def dense(k, *shape, scale=None):
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        s = scale if scale is not None else fan_in ** -0.5
        return (jax.random.normal(k, shape, jnp.float32) * s).astype(pd)

    p: Params = {
        "embed": dense(next(keys), V, D, scale=0.02),
        "final_norm": jnp.zeros((D,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = dense(next(keys), V, D, scale=0.02)
    if cfg.n_frontend_embeds:
        p["connector"] = dense(next(keys), D, D)

    layers: Params = {"ln1": jnp.zeros((L, D), jnp.float32)}
    if cfg.is_moe or cfg.d_ff:
        layers["ln2"] = jnp.zeros((L, D), jnp.float32)
    if cfg.has_attention:
        H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        layers["attn"] = {
            "wq": dense(next(keys), L, D, H * dh),
            "wk": dense(next(keys), L, D, KV * dh),
            "wv": dense(next(keys), L, D, KV * dh),
            "wo": dense(next(keys), L, H * dh, D),
        }
        if cfg.qkv_bias:
            layers["attn"]["bq"] = jnp.zeros((L, H * dh), pd)
            layers["attn"]["bk"] = jnp.zeros((L, KV * dh), pd)
            layers["attn"]["bv"] = jnp.zeros((L, KV * dh), pd)
    if cfg.has_ssm:
        di, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
        proj_in = 2 * di + 2 * ns + nh
        conv_dim = di + 2 * ns
        layers["ssm"] = {
            "in_proj": dense(next(keys), L, D, proj_in),
            "conv_w": dense(next(keys), L, cfg.ssm_conv, conv_dim, scale=0.5),
            "A_log": jnp.log(jnp.broadcast_to(
                jnp.linspace(1.0, 16.0, nh), (L, nh))).astype(jnp.float32),
            "D": jnp.ones((L, nh), jnp.float32),
            "dt_bias": jnp.zeros((L, nh), jnp.float32),
            "norm": jnp.zeros((L, di), jnp.float32),
            "out_proj": dense(next(keys), L, di, D),
        }
    if cfg.is_moe:
        E, F = cfg.n_experts, cfg.expert_d_ff
        layers["moe"] = {
            "router": dense(next(keys), L, D, E, scale=0.02),
            "w_gate": dense(next(keys), L, E, D, F),
            "w_up": dense(next(keys), L, E, D, F),
            "w_down": dense(next(keys), L, E, F, D),
        }
        if cfg.n_shared_experts:
            Fs = cfg.n_shared_experts * F
            layers["moe"]["shared_gate"] = dense(next(keys), L, D, scale=0.02)
            layers["moe"]["shared_w_gate"] = dense(next(keys), L, D, Fs)
            layers["moe"]["shared_w_up"] = dense(next(keys), L, D, Fs)
            layers["moe"]["shared_w_down"] = dense(next(keys), L, Fs, D)
    elif cfg.d_ff:
        layers["mlp"] = {
            "w_gate": dense(next(keys), L, D, cfg.d_ff),
            "w_up": dense(next(keys), L, D, cfg.d_ff),
            "w_down": dense(next(keys), L, cfg.d_ff, D),
        }
    p["layers"] = layers
    return p


def layer_flags(cfg: ModelConfig) -> jnp.ndarray:
    """(L,) bool — True where the layer attends globally (scanned)."""
    return jnp.asarray([cfg.layer_is_global(i) for i in range(cfg.n_layers)])


# ================================================================ layer body
def _attn_branch(cfg: ModelConfig, lp: Params, h: jnp.ndarray,
                 is_global, cos, sin, ac: Callable,
                 cache: Optional[dict], pos) -> Tuple[jnp.ndarray, dict]:
    B, S, D = h.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    a = lp["attn"]
    q = jnp.einsum("bsd,dk->bsk", h, a["wq"])
    k = jnp.einsum("bsd,dk->bsk", h, a["wk"])
    v = jnp.einsum("bsd,dk->bsk", h, a["wv"])
    if cfg.qkv_bias:
        q, k, v = q + a["bq"], k + a["bk"], v + a["bv"]
    q = ac(q.reshape(B, S, H, dh), "q")
    k = k.reshape(B, S, KV, dh)
    v = v.reshape(B, S, KV, dh)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    new_cache: dict = {}
    if cfg.attention_impl == "pallas":
        from ..kernels.flash_attention.ops import flash_gqa
        attn_full = lambda q_, k_, v_: flash_gqa(  # noqa: E731
            q_, k_, v_, True, cfg.sliding_window, cfg.attn_softcap)
    else:
        attn_full = lambda q_, k_, v_: attn_lib.gqa_attention(  # noqa: E731
            q_, k_, v_, is_global=is_global, window=cfg.sliding_window,
            attn_softcap=cfg.attn_softcap, impl=cfg.attention_impl,
            block=cfg.attn_block, block_remat=cfg.attn_block_remat)
    if cache is None:  # training: full self-attention
        out = attn_full(q, k, v)
    elif S > 1:  # prefill: attend within prompt, emit cache
        out = attn_full(q, k, v)
        T = cache["k"].shape[1]
        pad = [(0, 0), (0, T - S), (0, 0), (0, 0)]
        new_cache = {"k": jnp.pad(k.astype(cache["k"].dtype), pad),
                     "v": jnp.pad(v.astype(cache["v"].dtype), pad)}
    else:  # decode: one token against the cache
        out, k_c, v_c = attn_lib.decode_attention(
            q, k, v, cache["k"], cache["v"], pos,
            is_global=is_global, window=cfg.sliding_window,
            attn_softcap=cfg.attn_softcap)
        new_cache = {"k": k_c, "v": v_c}
    out = ac(out, "attn_out")
    return jnp.einsum("bsk,kd->bsd", out.reshape(B, S, H * dh), a["wo"]), \
        new_cache


def _ssm_branch(cfg: ModelConfig, lp: Params, h: jnp.ndarray, ac: Callable,
                cache: Optional[dict]) -> Tuple[jnp.ndarray, dict]:
    B, S, D = h.shape
    di, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    hd = cfg.ssm_head_dim
    s = lp["ssm"]
    zxbcdt = jnp.einsum("bsd,dp->bsp", h, s["in_proj"])
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + ns, 2 * di + 2 * ns], axis=-1)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    A = -jnp.exp(s["A_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + s["dt_bias"][None, None, :])

    new_cache: dict = {}
    if cache is None or S > 1:  # train / prefill: chunked SSD
        tail = None if cache is None else cache["conv"]
        conv, new_tail = ssm_lib.causal_conv1d(conv_in, s["conv_w"], tail)
        conv = jax.nn.silu(conv.astype(jnp.float32)).astype(h.dtype)
        xs, Bc, Cc = jnp.split(conv, [di, di + ns], axis=-1)
        chunk = min(cfg.ssm_chunk, S)
        y, h_fin = ssm_lib.ssd_scan(
            xs.reshape(B, S, nh, hd), dt, A, Bc, Cc, chunk=chunk,
            h0=None if cache is None else cache["h"])
        y = y + xs.reshape(B, S, nh, hd) * s["D"][None, None, :, None]
        if cache is not None:
            new_cache = {"h": h_fin, "conv": new_tail}
    else:  # decode: O(1) state update
        tail = cache["conv"]
        full = jnp.concatenate([tail, conv_in], axis=1)  # (B, K, convdim)
        conv = jnp.einsum("bkc,kc->bc", full, s["conv_w"])[:, None, :]
        conv = jax.nn.silu(conv.astype(jnp.float32)).astype(h.dtype)
        xs, Bc, Cc = jnp.split(conv, [di, di + ns], axis=-1)
        y, h_new = ssm_lib.ssd_decode_step(
            xs.reshape(B, nh, hd), dt[:, 0], A, Bc[:, 0], Cc[:, 0],
            cache["h"])
        y = y[:, None] + xs.reshape(B, 1, nh, hd) * s["D"][None, None, :, None]
        new_cache = {"h": h_new, "conv": full[:, 1:, :]}
    y = ssm_lib.gated_rms_norm(y.reshape(B, S, di), z, s["norm"],
                               cfg.norm_eps)
    return jnp.einsum("bsi,id->bsd", y, s["out_proj"]), new_cache


def _mlp_branch(cfg: ModelConfig, lp: Params, h: jnp.ndarray, ac: Callable
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe:
        m = lp["moe"]
        mesh = None
        if cfg.moe_impl == "ep":
            from ..distributed.context import current_mesh
            mesh = current_mesh()
        if (mesh is not None and "model" in mesh.axis_names
                and cfg.n_experts % int(mesh.shape["model"]) == 0):
            from .moe import moe_layer_ep
            y, metrics = moe_layer_ep(
                h, m["router"], m["w_gate"], m["w_up"], m["w_down"],
                mesh=mesh, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, act=cfg.mlp_act,
                dp_axes=("pod", "data"))
        else:
            y, metrics = moe_layer(
                h, m["router"], m["w_gate"], m["w_up"], m["w_down"],
                top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                act=cfg.mlp_act, ac=ac,
                combine_dtype=cfg.moe_combine_dtype)
        aux = metrics.aux_loss
        if cfg.n_shared_experts:
            sh = mlp_swiglu(h, m["shared_w_gate"], m["shared_w_up"],
                            m["shared_w_down"], cfg.mlp_act)
            g = jax.nn.sigmoid(jnp.einsum(
                "bsd,d->bs", h.astype(jnp.float32),
                m["shared_gate"].astype(jnp.float32)))
            y = y + sh * g[..., None].astype(h.dtype)
        return y, aux
    mlpp = lp["mlp"]
    y = mlp_swiglu(h, mlpp["w_gate"], mlpp["w_up"], mlpp["w_down"],
                   cfg.mlp_act)
    y = ac(y, "mlp_out")
    return y, aux


def _layer(cfg: ModelConfig, lp: Params, x: jnp.ndarray, is_global,
           cos, sin, ac: Callable, cache: Optional[dict], pos
           ) -> Tuple[jnp.ndarray, dict, jnp.ndarray]:
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    new_cache: dict = {}
    mix = jnp.zeros_like(x)
    if cfg.has_attention:
        a_cache = None
        if cache is not None and "k" in cache:
            a_cache = {"k": cache["k"], "v": cache["v"]}
        a_out, a_new = _attn_branch(cfg, lp, h, is_global, cos, sin, ac,
                                    a_cache, pos)
        mix = mix + a_out
        new_cache.update(a_new)
    if cfg.has_ssm:
        s_cache = None
        if cache is not None:
            s_cache = {"h": cache["h"], "conv": cache["conv"]}
        s_out, s_new = _ssm_branch(cfg, lp, h, ac, s_cache)
        mix = mix + s_out
        new_cache.update(s_new)
    if cfg.has_attention and cfg.has_ssm:  # hybrid: mean-combine branches
        mix = mix * 0.5
    x = x + ac(mix.astype(x.dtype), "residual")
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe or cfg.d_ff:  # mamba2 layers are mixer-only (no MLP)
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        mlp_out, aux = _mlp_branch(cfg, lp, h2, ac)
        x = x + mlp_out.astype(x.dtype)
    return ac(x, "hidden"), new_cache, aux


# ================================================================== forward
def forward(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
            extra_embeds: Optional[jnp.ndarray] = None,
            *, ac: Callable = Identity, cache: Optional[dict] = None,
            pos=None, remat: bool = True):
    """Full-sequence forward (training / prefill).

    Returns (logits, new_cache_stack, aux_loss).  ``cache``, if given, is the
    stacked (L, ...) cache pytree to fill during prefill.
    """
    cd = _dtype(cfg.compute_dtype)
    x = embed_tokens(tokens, params["embed"],
                     scale_by_dim=cfg.final_softcap is not None).astype(cd)
    if cfg.n_frontend_embeds:
        fe = jnp.einsum("bpd,de->bpe", extra_embeds.astype(cd),
                        params["connector"])
        x = jnp.concatenate([fe, x[:, cfg.n_frontend_embeds:, :]], axis=1) \
            if x.shape[1] > cfg.n_frontend_embeds else fe[:, :x.shape[1]]
    x = ac(x, "hidden")
    S = x.shape[1]
    positions = jnp.arange(S) + (0 if pos is None else pos)
    cos, sin = (rope_angles(positions, cfg.d_head, cfg.rope_theta)
                if cfg.has_attention else (None, None))
    flags = layer_flags(cfg)

    def body(carry, xs):
        lp, flag, cache_l = xs
        x, aux = carry
        x, new_cache_l, aux_l = _layer(cfg, lp, x, flag, cos, sin, ac,
                                       cache_l, pos)
        return (x, aux + aux_l), new_cache_l

    body_fn = jax.checkpoint(body) if remat else body
    layer_cache = None
    if cache is not None:
        layer_cache = {k: v for k, v in cache.items() if k != "pos"}
    xs = (params["layers"], flags, layer_cache)
    (x, aux), new_cache = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                       xs)
    if cache is not None:
        new_cache["pos"] = jnp.asarray(S if pos is None else pos + S,
                                       jnp.int32)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    emb = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(x, emb, cfg.final_softcap)
    return logits, new_cache, aux


def decode_step(cfg: ModelConfig, params: Params, token: jnp.ndarray,
                cache: dict, *, ac: Callable = Identity):
    """One-token decode: token (B,), cache pytree with leading L dims and a
    scalar ``pos``.  Returns (logits (B, V), new_cache)."""
    cd = _dtype(cfg.compute_dtype)
    pos = cache["pos"]
    x = embed_tokens(token[:, None], params["embed"],
                     scale_by_dim=cfg.final_softcap is not None).astype(cd)
    x = ac(x, "hidden")
    cos, sin = (rope_angles(pos[None], cfg.d_head, cfg.rope_theta)
                if cfg.has_attention else (None, None))
    if cos is not None:
        cos, sin = cos[None], sin[None]  # (B=1 broadcast, 1, half)
    flags = layer_flags(cfg)
    layer_cache = {k: v for k, v in cache.items() if k != "pos"}

    def body(x, xs):
        lp, flag, cache_l = xs
        x, new_cache_l, _ = _layer(cfg, lp, x, flag, cos, sin, ac, cache_l,
                                   pos)
        return x, new_cache_l

    x, new_cache = jax.lax.scan(body, x, (params["layers"], flags,
                                          layer_cache))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    emb = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(x, emb, cfg.final_softcap)
    new_cache["pos"] = pos + 1
    return logits[:, 0, :], new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype: str = "bfloat16") -> dict:
    """Stacked (L, ...) KV/state cache + scalar position."""
    L = cfg.n_layers
    cd = _dtype(dtype)
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.has_attention:
        KV, dh = cfg.n_kv_heads, cfg.d_head
        cache["k"] = jnp.zeros((L, batch, max_len, KV, dh), cd)
        cache["v"] = jnp.zeros((L, batch, max_len, KV, dh), cd)
    if cfg.has_ssm:
        nh, hd, ns = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        conv_dim = cfg.ssm_d_inner + 2 * ns
        cache["h"] = jnp.zeros((L, batch, nh, hd, ns), jnp.float32)
        cache["conv"] = jnp.zeros((L, batch, cfg.ssm_conv - 1, conv_dim), cd)
    return cache


# ===================================================================== loss
def lm_loss(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
            extra_embeds: Optional[jnp.ndarray] = None,
            *, ac: Callable = Identity, remat: bool = True):
    """Next-token cross-entropy (fp32 log-softmax), masking frontend slots.
    Returns (loss, metrics dict)."""
    logits, _, aux = forward(cfg, params, tokens, extra_embeds, ac=ac,
                             remat=remat)
    targets = tokens[:, 1:]
    lg = logits[:, :-1, :].astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = jnp.ones_like(nll)
    if cfg.n_frontend_embeds:
        keep = jnp.arange(nll.shape[1]) >= cfg.n_frontend_embeds
        mask = mask * keep[None, :]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + cfg.router_aux_coef * aux
    return total, {"loss": loss, "aux_loss": aux,
                   "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}
