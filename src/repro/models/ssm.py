"""Mamba-2 (SSD — state-space duality) mixer, pure-jnp model path.

The chunked SSD algorithm: within-chunk terms are dense matmuls (MXU-friendly
"attention-like" quadratic-in-chunk work), across-chunk terms are a scan over
a small recurrent state (B, nh, hd, ns).  The Pallas kernel in
``repro.kernels.mamba_ssd`` implements the same chunk body with explicit VMEM
tiling; this module is the oracle and the CPU/dry-run path.

Decode is O(1): a single state update per token — this is why the SSM/hybrid
archs are the ones that run the ``long_500k`` shape (DESIGN.md).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class SSMState(NamedTuple):
    h: jnp.ndarray          # (B, nh, hd, ns) recurrent state
    conv: jnp.ndarray       # (B, d_conv-1, conv_dim) causal-conv tail


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray,
                  tail: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over (B, S, C) with kernel (K, C).
    Returns (y, new_tail) where new_tail carries the last K-1 inputs."""
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(K))
    new_tail = xp[:, -(K - 1):, :] if K > 1 else tail
    return y, new_tail


def ssd_scan(
    x: jnp.ndarray,     # (B, S, nh, hd)  inputs per head
    dt: jnp.ndarray,    # (B, S, nh)      softplus'd step sizes
    A: jnp.ndarray,     # (nh,)           negative decay rates
    Bmat: jnp.ndarray,  # (B, S, ns)      input projection (n_groups=1)
    Cmat: jnp.ndarray,  # (B, S, ns)      output projection
    *,
    chunk: int = 256,
    h0: Optional[jnp.ndarray] = None,
):
    """Chunked SSD: returns (y (B,S,nh,hd), h_final (B,nh,hd,ns)).

    Recurrence (per head):  h_t = exp(dt_t A) h_{t-1} + dt_t B_t xᵀ_t
                            y_t = C_t · h_t
    """
    Bsz, S, nh, hd = x.shape
    ns = Bmat.shape[-1]
    S_orig = S
    if S % chunk:  # pad with dt=0 steps: decay=1, contribution=0 ⇒ identity
        pad = chunk - (S % chunk)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nchunks = S // chunk

    xc = x.reshape(Bsz, nchunks, chunk, nh, hd)
    dtc = dt.reshape(Bsz, nchunks, chunk, nh).astype(jnp.float32)
    Bc = Bmat.reshape(Bsz, nchunks, chunk, ns)
    Cc = Cmat.reshape(Bsz, nchunks, chunk, ns)

    dA = dtc * A.astype(jnp.float32)[None, None, None, :]  # log-decay per step
    seg = jnp.cumsum(dA, axis=2)                           # (B,N,Q,nh)
    seg_total = seg[:, :, -1:, :]                          # (B,N,1,nh)

    # within-chunk "attention": L[t,k] = exp(seg_t - seg_k) for t >= k.
    # Mask BEFORE exp: masked entries have rel > 0 (cumsum decreases), and
    # exp(+big)=inf under a where() poisons the backward with inf·0 = NaN.
    rel = seg[:, :, :, None, :] - seg[:, :, None, :, :]    # (B,N,Q,Q,nh)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    rel = jnp.where(causal[None, None, :, :, None], rel, -1e30)
    L = jnp.exp(rel)
    cb = jnp.einsum("bnts,bnks->bntk", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))                # (B,N,Q,Q)
    W = cb[..., None] * L                                  # (B,N,Q,Q,nh)
    xdt = xc.astype(jnp.float32) * dtc[..., None]          # (B,N,Q,nh,hd)
    y_intra = jnp.einsum("bntkh,bnkhd->bnthd", W, xdt)

    # chunk -> carried state contribution: decay-to-end ⊗ (B x dt)
    decay_out = jnp.exp(seg_total - seg)                   # (B,N,Q,nh)
    chunk_state = jnp.einsum("bnks,bnkhd->bnhds",
                             Bc.astype(jnp.float32),
                             xdt * decay_out[..., None])   # (B,N,nh,hd,ns)

    def body(h, inputs):
        cs, st, c_chunk, seg_chunk = inputs
        # inter-chunk output: read previous state through C with decay-in
        decay_in = jnp.exp(seg_chunk)                      # (B,Q,nh)
        y_int = jnp.einsum("bts,bhds->bthd", c_chunk.astype(jnp.float32), h)
        y_int = y_int * decay_in[..., None]
        h_new = h * jnp.exp(st)[:, 0, :, None, None] + cs
        return h_new, y_int

    h0 = (jnp.zeros((Bsz, nh, hd, ns), jnp.float32) if h0 is None
          else h0.astype(jnp.float32))
    xs = (
        jnp.moveaxis(chunk_state, 1, 0),
        jnp.moveaxis(seg_total, 1, 0),
        jnp.moveaxis(Cc, 1, 0),
        jnp.moveaxis(seg, 1, 0),
    )
    h_final, y_inter = jax.lax.scan(body, h0, xs)
    y_inter = jnp.moveaxis(y_inter, 0, 1).reshape(Bsz, nchunks, chunk, nh, hd)
    y = (y_intra + y_inter).reshape(Bsz, S, nh, hd)[:, :S_orig]
    return y.astype(x.dtype), h_final


def ssd_decode_step(
    x: jnp.ndarray,     # (B, nh, hd)
    dt: jnp.ndarray,    # (B, nh)
    A: jnp.ndarray,     # (nh,)
    Bvec: jnp.ndarray,  # (B, ns)
    Cvec: jnp.ndarray,  # (B, ns)
    h: jnp.ndarray,     # (B, nh, hd, ns) fp32
):
    """O(1) per-token state update (long-context decode path)."""
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A.astype(jnp.float32)[None, :])  # (B, nh)
    upd = jnp.einsum("bhd,bs->bhds", x.astype(jnp.float32) * dtf[..., None],
                     Bvec.astype(jnp.float32))
    h_new = h * decay[..., None, None] + upd
    y = jnp.einsum("bhds,bs->bhd", h_new, Cvec.astype(jnp.float32))
    return y.astype(x.dtype), h_new


def gated_rms_norm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray,
                   eps: float = 1e-6) -> jnp.ndarray:
    """Mamba-2 output gate: RMSNorm(y * silu(z)) * (1+scale)."""
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    out = g * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(y.dtype)
