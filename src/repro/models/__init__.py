"""repro.models — unified model zoo for the assigned architectures."""

from .config import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from .lm import (decode_step, forward, init_cache, init_params, layer_flags,
                 lm_loss)

__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "shape_applicable",
    "init_params", "forward", "decode_step", "init_cache", "lm_loss",
    "layer_flags",
]
