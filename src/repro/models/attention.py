"""Attention: GQA with causal / sliding-window masks, full-sequence (train /
prefill) and single-token decode over a KV cache.

The XLA einsum path is the default (and the dry-run path); the Pallas flash
kernel (``repro.kernels.flash_attention``) is selected with
``attention_impl="pallas"`` and is validated against this code in tests.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import softcap

NEG_INF = -2.3819763e38  # large negative for masked logits (bf16-safe)


def _causal_window_mask(q_len: int, kv_len: int, *, q_offset: int,
                        window: Optional[int]) -> jnp.ndarray:
    """(q_len, kv_len) boolean mask. q position i attends kv position j iff
    j <= i+q_offset and (window is None or i+q_offset - j < window)."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    mask = kv_pos <= q_pos
    if window is not None:
        mask &= (q_pos - kv_pos) < window
    return mask


def gqa_attention(
    q: jnp.ndarray,          # (B, S, H, dh)
    k: jnp.ndarray,          # (B, T, KV, dh)
    v: jnp.ndarray,          # (B, T, KV, dh)
    *,
    q_offset: int = 0,
    is_global: jnp.ndarray | bool = True,  # scalar flag (scanned per layer)
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    kv_valid_len: Optional[jnp.ndarray] = None,  # decode: cache fill level
    impl: str = "xla",
    block: int = 1024,
    block_remat: bool = False,
) -> jnp.ndarray:
    if impl == "chunked" and kv_valid_len is None:
        return chunked_gqa_attention(
            q, k, v, q_offset=q_offset, is_global=is_global, window=window,
            attn_softcap=attn_softcap, block=block, block_remat=block_remat)
    return _dense_gqa_attention(
        q, k, v, q_offset=q_offset, is_global=is_global, window=window,
        attn_softcap=attn_softcap, kv_valid_len=kv_valid_len)


def _dense_gqa_attention(
    q, k, v, *, q_offset=0, is_global=True, window=None,
    attn_softcap=None, kv_valid_len=None,
) -> jnp.ndarray:
    """Grouped-query attention with optional sliding window + logit softcap.

    ``is_global`` may be a traced scalar bool: when False the sliding-window
    constraint is applied — this lets one scanned layer body serve both the
    local and global layers of e.g. gemma-2 with uniform stacked params.
    """
    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    groups = H // KV
    scale = dh ** -0.5

    qg = q.reshape(B, S, KV, groups, dh)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k) * scale
    logits = softcap(logits, attn_softcap)

    causal = _causal_window_mask(S, T, q_offset=q_offset, window=None)
    if window is not None:
        local = _causal_window_mask(S, T, q_offset=q_offset, window=window)
        glob = jnp.asarray(is_global, bool)
        mask = jnp.where(glob, causal, local)
    else:
        mask = causal
    if kv_valid_len is not None:
        mask = mask & (jnp.arange(T)[None, :] < kv_valid_len)
    logits = jnp.where(mask[None, None, None, :, :], logits.astype(
        jnp.float32), NEG_INF)

    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, H, dh)


def chunked_gqa_attention(
    q: jnp.ndarray,          # (B, S, H, dh)
    k: jnp.ndarray,          # (B, T, KV, dh)
    v: jnp.ndarray,          # (B, T, KV, dh)
    *,
    q_offset: int = 0,
    is_global: jnp.ndarray | bool = True,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    block: int = 1024,
    block_remat: bool = False,
) -> jnp.ndarray:
    """Flash-style attention in pure jnp: online softmax over KV blocks via
    ``lax.scan`` — never materializes the (S, T) logits.  This is the
    XLA-lowerable twin of the Pallas kernel (same algorithm, same memory
    behavior: O(S·block) temporaries instead of O(S·T)) and the default
    impl for long-context shapes (DESIGN.md §6, EXPERIMENTS.md §Perf).

    ``block_remat=True`` additionally checkpoints the per-block body:
    without it, autodiff saves each block's logits/probs for the backward
    (an O(S·T) stack — exactly what flash-attention-backward avoids by
    in-kernel recompute); with it, blocks are recomputed during the
    backward, trading ~1 extra block forward for O(S·T) saved bytes."""
    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    groups = H // KV
    scale = dh ** -0.5
    blk = min(block, T)
    padT = (-T) % blk
    if padT:
        k = jnp.pad(k, ((0, 0), (0, padT), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, padT), (0, 0), (0, 0)))
    nblk = (T + padT) // blk

    qg = (q.reshape(B, S, KV, groups, dh) * scale)
    q_pos = jnp.arange(S) + q_offset
    glob = jnp.asarray(is_global, bool)

    kb = jnp.moveaxis(k.reshape(B, nblk, blk, KV, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nblk, blk, KV, dh), 1, 0)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        j, k_j, v_j = xs
        logits = jnp.einsum("bskgd,btkd->bkgst", qg, k_j).astype(jnp.float32)
        logits = softcap(logits, attn_softcap)
        kv_pos = j * blk + jnp.arange(blk)
        mask = (kv_pos[None, :] <= q_pos[:, None]) & (kv_pos[None, :] < T)
        if window is not None:
            local = mask & ((q_pos[:, None] - kv_pos[None, :]) < window)
            mask = jnp.where(glob, mask, local)
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(q.dtype), v_j).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, KV, groups, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, groups, S), jnp.float32)
    acc0 = jnp.zeros((B, KV, groups, S, dh), jnp.float32)
    body_fn = jax.checkpoint(body) if block_remat else body
    (m, l, acc), _ = jax.lax.scan(
        body_fn, (m0, l0, acc0), (jnp.arange(nblk), kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,KV,G,S,dh)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, S, H, dh)
    return out.astype(q.dtype)


# ---------------------------------------------------------------- KV cache
def decode_attention(
    q: jnp.ndarray,            # (B, 1, H, dh)
    k_new: jnp.ndarray,        # (B, 1, KV, dh)
    v_new: jnp.ndarray,        # (B, 1, KV, dh)
    k_cache: jnp.ndarray,      # (B, T, KV, dh)
    v_cache: jnp.ndarray,      # (B, T, KV, dh)
    pos: jnp.ndarray,          # scalar int32: index to write / current length
    *,
    is_global: jnp.ndarray | bool = True,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
):
    """One decode step: insert k/v at ``pos`` and attend over the cache.

    Returns (attn_out (B,1,H,dh), new_k_cache, new_v_cache).
    Sliding-window layers may use a ring cache of size ``window`` — handled
    by the caller choosing T = window and pos % window (see serving/).
    """
    T = k_cache.shape[1]
    write_idx = pos % T
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), write_idx, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), write_idx, axis=1)

    B, _, H, dh = q.shape
    KV = k_cache.shape[2]
    groups = H // KV
    scale = dh ** -0.5
    qg = q.reshape(B, KV, groups, dh)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache) * scale
    logits = softcap(logits, attn_softcap)

    kv_idx = jnp.arange(T)
    # absolute position of each slot in a ring cache
    abs_pos = jnp.where(kv_idx <= write_idx, pos - write_idx + kv_idx,
                        pos - T - write_idx + kv_idx)
    valid = (abs_pos >= 0) & (abs_pos <= pos)
    if window is not None:
        local = valid & ((pos - abs_pos) < window)
        valid = jnp.where(jnp.asarray(is_global, bool), valid, local)
    logits = jnp.where(valid[None, None, None, :],
                       logits.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v_cache)
    return out.reshape(B, 1, H, dh), k_cache, v_cache
