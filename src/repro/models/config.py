"""Model configuration for every assigned architecture family.

One dataclass covers dense GQA transformers, SSMs (Mamba-2/SSD), hybrids
(parallel attn+SSM heads) and MoE — families differ only in per-layer branch
flags, so a single scan-over-layers apply fn serves all ten architectures.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | ssm | hybrid | moe
    n_layers: int
    d_model: int
    vocab_size: int

    # attention (ignored for family == "ssm")
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None   # window size for local layers
    global_every: int = 1                  # 1 = all global; 2 = alternate l/g
    global_layers: Tuple[int, ...] = ()    # explicit extra global layers
    attn_softcap: Optional[float] = None   # gemma2: 50.0
    final_softcap: Optional[float] = None  # gemma2: 30.0

    # dense mlp
    d_ff: int = 0
    mlp_act: str = "silu"  # silu (swiglu) | gelu

    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    # §Perf: dtype of the EP combine payload (None = fp32 baseline)
    moe_combine_dtype: Optional[str] = None
    # §Perf: "dense" = pjit-propagated dispatch (baseline);
    # "ep" = hand-scheduled shard_map expert parallelism (one psum/layer)
    moe_impl: str = "dense"

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # modality frontend stub (audio / vision): number of prepended
    # precomputed embeddings supplied by input_specs()
    frontend: str = "none"  # none | audio | vision
    n_frontend_embeds: int = 0

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # attention implementation: "chunked" (flash-style streaming, default —
    # the XLA twin of the Pallas kernel), "xla" (dense logits; ablation),
    # "pallas" (TPU kernel; interpret-validated on CPU)
    attention_impl: str = "chunked"
    attn_block: int = 1024
    # remat the per-kv-block attention body (flash-bwd-style recompute);
    # beyond-paper §Perf optimization — off in the paper-faithful baseline
    attn_block_remat: bool = False

    # ------------------------------------------------------------- derived
    def __post_init__(self):
        if self.d_head == 0 and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def has_attention(self) -> bool:
        return self.family in ("dense", "hybrid", "moe")

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.family == "moe"

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context without a full-attention
        layer attending over the whole cache?  (DESIGN.md shape-skip rule:
        SSM yes; hybrid with only sliding-window globals yes; anything with
        a full-attention layer no.)"""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return True  # sliding-window attn + SSM state (see configs)
        return False

    def layer_is_global(self, i: int) -> bool:
        """Per-layer attention span flag (scanned through the layer stack)."""
        if self.sliding_window is None:
            return True
        if i in self.global_layers:
            return True
        if self.global_every > 1:
            return (i % self.global_every) == (self.global_every - 1)
        return not self.global_layers  # window-only unless listed

    # ---------------------------------------------------------- accounting
    def param_count(self) -> int:
        """Analytic parameter count (matches init_params within ties)."""
        D, L, V = self.d_model, self.n_layers, self.vocab_size
        n = V * D  # embed
        if not self.tie_embeddings:
            n += V * D
        n += D  # final norm
        if self.n_frontend_embeds:
            n += D * D  # modality connector
        has_mlp_block = self.is_moe or self.d_ff > 0
        per_layer = D * (2 if has_mlp_block else 1)  # pre-mixer (+ pre-mlp)
        if self.has_attention:
            H, KV, dh = self.n_heads, self.n_kv_heads, self.d_head
            per_layer += D * H * dh + 2 * D * KV * dh + H * dh * D
            if self.qkv_bias:
                per_layer += (H + 2 * KV) * dh
        if self.has_ssm:
            di, ns, nh = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            proj_in = 2 * di + 2 * ns + nh  # z, x, B, C, dt
            per_layer += D * proj_in
            per_layer += self.ssm_conv * (di + 2 * ns)  # conv over x,B,C
            per_layer += 2 * nh + nh  # A_log, D, dt_bias
            per_layer += di * D  # out proj
            per_layer += di  # gated rmsnorm
        if self.is_moe:
            E, F = self.n_experts, self.expert_d_ff
            per_layer += D * E  # router
            per_layer += E * (3 * D * F)
            if self.n_shared_experts:
                per_layer += 3 * D * (self.n_shared_experts * F)
                per_layer += D  # shared-expert gate
        elif self.d_ff:
            per_layer += 3 * D * self.d_ff
        return n + L * per_layer

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top-k routed + shared)."""
        if not self.is_moe:
            return self.param_count()
        D, L, F = self.d_model, self.n_layers, self.expert_d_ff
        inactive = (self.n_experts - self.top_k) * 3 * D * F
        return self.param_count() - L * inactive

    def model_flops_per_token(self, *, training: bool = True) -> float:
        """6·N_active (fwd+bwd) or 2·N_active (fwd) — the §Roofline MODEL_FLOPS."""
        mult = 6.0 if training else 2.0
        return mult * self.active_param_count()

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One cell of the (arch × shape) grid."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """DESIGN.md §Arch-applicability shape-skip rule."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True
