"""Mixture-of-Experts layer: top-k routing with capacity, scatter dispatch.

TPU-native design notes (DESIGN.md §2): instead of the quadratic GShard
one-hot dispatch einsum, tokens are placed into per-expert capacity buffers
with a scatter (memory-bound, not FLOP-bound) and combined back with a
gather.  Expert positions come from a cumsum over one-hot assignments — no
sort — which partitions cleanly under SPMD (per-shard cumsum + offset
all-reduce).  Experts are sharded over the ``model`` ("expert-parallel")
axis; the scatter/gather across data→expert shards lowers to all-to-alls.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class MoEMetrics(NamedTuple):
    aux_loss: jnp.ndarray       # load-balancing loss (scalar fp32)
    dropped_frac: jnp.ndarray   # fraction of (token,k) slots over capacity


def capacity_of(n_tokens: int, n_experts: int, top_k: int,
                capacity_factor: float) -> int:
    if capacity_factor <= 0:  # no-drop mode (serving): worst case = T
        cap = n_tokens
    else:
        cap = int(math.ceil(n_tokens * top_k / n_experts * capacity_factor))
    return max(8, ((cap + 7) // 8) * 8)  # pad to lane multiple


def moe_layer(
    x: jnp.ndarray,          # (B, S, D)
    router_w: jnp.ndarray,   # (D, E)
    w_gate: jnp.ndarray,     # (E, D, F)
    w_up: jnp.ndarray,       # (E, D, F)
    w_down: jnp.ndarray,     # (E, F, D)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    act: str = "silu",
    normalize_gates: bool = True,
    ac=lambda x, name=None: x,   # activation-sharding hook (EP layouts)
    combine_dtype: str | None = None,  # bf16 combine payloads (§Perf)
) -> tuple[jnp.ndarray, MoEMetrics]:
    B, S, D = x.shape
    E, _, F = w_gate.shape
    T = B * S
    C = capacity_of(T, E, top_k, capacity_factor)
    xf = x.reshape(T, D)

    # ---- routing (fp32 for numerical stability of the softmax) ----
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)      # (T, K)
    if normalize_gates:
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # ---- load-balancing aux loss (Switch-style) ----
    me = jnp.mean(probs, axis=0)                             # (E,)
    onehot_top1 = jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(onehot_top1, axis=0)
    aux = jnp.sum(me * ce) * E

    # ---- position of each (t, k) inside its expert's capacity buffer ----
    flat_ids = expert_ids.reshape(-1)                        # (T*K,) k-major? t-major
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)    # (T*K, E)
    pos_in_expert = jnp.cumsum(onehot, axis=0) - onehot      # exclusive
    pos = jnp.take_along_axis(pos_in_expert, flat_ids[:, None],
                              axis=1)[:, 0]                  # (T*K,)
    within_cap = pos < C
    slot = jnp.where(within_cap, flat_ids * C + pos, E * C)  # OOB → dropped

    # ---- dispatch: scatter tokens into (E*C, D) expert buffers ----
    xk = jnp.repeat(xf, top_k, axis=0) if top_k > 1 else xf  # (T*K, D)
    xk = ac(xk, "moe_tokens")
    # NB: jnp.repeat(t-major) matches expert_ids.reshape(-1) (t-major, k minor)
    buf = jnp.zeros((E * C, D), x.dtype)
    buf = buf.at[slot].set(xk.astype(x.dtype), mode="drop")
    buf = ac(buf.reshape(E, C, D), "moe_buf")  # EP layout: experts sharded

    # ---- expert computation: batched matmuls over the expert axis ----
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
         if act == "silu"
         else jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * u)
    out = jnp.einsum("ecf,efd->ecd", h, w_down)
    out = ac(out, "moe_buf").reshape(E * C, D)

    # ---- combine: gather back, weight by gates, sum over k ----
    # The gather moves (T*K, D) across the EP boundary; keeping the payload
    # in the model dtype (bf16) instead of letting the fp32 gate multiply
    # upcast it halves the all-to-all bytes (§Perf iteration).
    cdt = jnp.dtype(combine_dtype) if combine_dtype else jnp.float32
    out_padded = jnp.concatenate([out, jnp.zeros((1, D), out.dtype)], axis=0)
    safe_slot = jnp.where(within_cap, slot, E * C)
    yk = out_padded[safe_slot].astype(cdt)                   # (T*K, D)
    yk = ac(yk, "moe_tokens")
    yk = yk * gate_vals.reshape(-1)[:, None].astype(cdt)
    y = jnp.sum(yk.reshape(T, top_k, D), axis=1)

    dropped = 1.0 - jnp.mean(within_cap.astype(jnp.float32))
    return y.reshape(B, S, D), MoEMetrics(aux.astype(jnp.float32), dropped)


# ======================================================================
# Hand-scheduled expert parallelism (shard_map) — §Perf beyond-paper.
#
# Observation: in this framework's layout, activations are sharded over the
# data axes and REPLICATED over "model" — every model-rank already holds all
# tokens of its data shard.  So EP needs NO token all-to-all at all:
#   1. each rank routes its (replicated) tokens locally,
#   2. keeps only the (token, k) pairs destined for ITS expert slice,
#   3. runs its experts locally,
#   4. one bf16 psum over "model" combines the per-rank partial outputs.
# The pjit baseline instead lowers the same computation to
# scatter-by-all-reduce + f32 all-to-alls (~20 GB/layer measured for
# qwen3-moe prefill); this path moves ~1 GB/layer.
# ======================================================================
def moe_layer_ep(
    x: jnp.ndarray,          # (B, S, D) — sharded (dp, None, None)
    router_w: jnp.ndarray,   # (D, E)    — replicated
    w_gate: jnp.ndarray,     # (E, D, F) — E sharded over "model"
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,     # (E, F, D)
    *,
    mesh,
    top_k: int,
    capacity_factor: float = 1.25,
    act: str = "silu",
    normalize_gates: bool = True,
    dp_axes: tuple = ("data",),
    ep_axis: str = "model",
) -> tuple[jnp.ndarray, MoEMetrics]:
    from jax.sharding import PartitionSpec as P

    E = w_gate.shape[0]
    ep_n = int(mesh.shape[ep_axis])
    assert E % ep_n == 0, (E, ep_n)
    dp = tuple(a for a in dp_axes if a in mesh.axis_names)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)

    def local(x_loc, rw, wg, wu, wd):
        B_loc, S, D = x_loc.shape
        E_loc, _, F = wg.shape
        T = B_loc * S
        xf = x_loc.reshape(T, D)
        my_rank = jax.lax.axis_index(ep_axis)
        my_lo = my_rank * E_loc

        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                            rw.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, top_k)
        if normalize_gates:
            gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], E,
                                     dtype=jnp.float32), axis=0)
        aux = jnp.sum(me * ce) * E

        # (token, k) pairs destined for MY expert slice
        flat_ids = expert_ids.reshape(-1)
        mine = (flat_ids >= my_lo) & (flat_ids < my_lo + E_loc)
        local_ids = jnp.where(mine, flat_ids - my_lo, E_loc)  # E_loc = drop
        C = capacity_of(T, E, top_k, capacity_factor)
        onehot = jax.nn.one_hot(local_ids, E_loc, dtype=jnp.int32)
        pos = jnp.take_along_axis(
            jnp.cumsum(onehot, axis=0) - onehot,
            jnp.minimum(local_ids, E_loc - 1)[:, None], axis=1)[:, 0]
        within = mine & (pos < C)
        slot = jnp.where(within, local_ids * C + pos, E_loc * C)

        xk = jnp.repeat(xf, top_k, axis=0) if top_k > 1 else xf
        buf = jnp.zeros((E_loc * C, D), x_loc.dtype)
        buf = buf.at[slot].set(xk.astype(x_loc.dtype), mode="drop")
        buf = buf.reshape(E_loc, C, D)

        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        h = (jax.nn.silu(g.astype(jnp.float32)).astype(x_loc.dtype) * u
             if act == "silu"
             else jax.nn.gelu(g.astype(jnp.float32)).astype(x_loc.dtype) * u)
        out = jnp.einsum("ecf,efd->ecd", h, wd).reshape(E_loc * C, D)

        out_padded = jnp.concatenate(
            [out, jnp.zeros((1, D), out.dtype)], axis=0)
        yk = out_padded[jnp.where(within, slot, E_loc * C)]
        yk = yk * gate_vals.reshape(-1)[:, None].astype(yk.dtype)
        y_partial = jnp.sum(yk.reshape(T, top_k, D), axis=1)

        # THE one collective: combine partial expert outputs across ranks
        y = jax.lax.psum(y_partial, ep_axis)
        dropped = 1.0 - jnp.mean(
            jax.lax.psum(within.astype(jnp.float32), ep_axis))
        return (y.reshape(B_loc, S, D), aux.reshape(1),
                dropped.reshape(1))

    from ..distributed.compat import shard_map
    y, aux, dropped = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp_spec, None, None), P(None, None),
                  P(ep_axis, None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None)),
        out_specs=(P(dp_spec, None, None), P(dp_spec), P(dp_spec)),
        check_vma=False,
    )(x, router_w, w_gate, w_up, w_down)
    # per-dp-shard scalars (each shard routed different tokens) → average
    return y, MoEMetrics(jnp.mean(aux), jnp.mean(dropped))

