"""Shared neural building blocks (pure jnp, shape-polymorphic, scan-friendly)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6,
             *, zero_centered: bool = True) -> jnp.ndarray:
    """RMSNorm in fp32 with (1+scale) gemma-style parametrization."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale.astype(jnp.float32)) if zero_centered \
        else scale.astype(jnp.float32)
    return (y * w).astype(dtype)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def rope_angles(positions: jnp.ndarray, d_head: int,
                theta: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for given integer positions: (..., d_head/2)."""
    half = d_head // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray
               ) -> jnp.ndarray:
    """x: (..., n_heads, d_head); cos/sin broadcast over the head axis."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)


def mlp_swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
               w_down: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    """Gated MLP: act(x @ w_gate) * (x @ w_up) @ w_down."""
    g = jnp.einsum("bsd,df->bsf", x, w_gate)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    if act == "silu":
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    elif act == "gelu":
        h = jax.nn.gelu(g.astype(jnp.float32), approximate=True).astype(
            x.dtype) * u
    else:
        raise ValueError(f"unknown act {act!r}")
    return jnp.einsum("bsf,fd->bsd", h, w_down)


def embed_tokens(tokens: jnp.ndarray, embedding: jnp.ndarray,
                 *, scale_by_dim: bool = False) -> jnp.ndarray:
    out = jnp.take(embedding, tokens, axis=0)
    if scale_by_dim:  # gemma convention
        out = out * jnp.asarray(out.shape[-1] ** 0.5, out.dtype)
    return out


def unembed(x: jnp.ndarray, embedding: jnp.ndarray,
            cap: Optional[float] = None) -> jnp.ndarray:
    logits = jnp.einsum("bsd,vd->bsv", x, embedding)
    return softcap(logits, cap)
