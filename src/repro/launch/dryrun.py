import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell:
  1. abstract params / optimizer state / inputs (ShapeDtypeStruct — zero
     allocation; the FULL configs are exercised only here);
  2. jit(train_step | prefill_step | decode_step) with explicit
     in_/out_shardings from the sharding policy;
  3. .lower().compile() — success proves the distribution config is
     coherent (shardings consistent, collectives supported, HLO sound);
  4. memory_analysis() + cost_analysis() + collective parse → one JSON
     per cell under results/dryrun/ (resumable across invocations).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
      [--skip-existing] [--no-cache] [--jobs N]

The per-cell JSON under results/dryrun/ doubles as the sweep's cache:
``--skip-existing`` reuses it, ``--no-cache`` forces recompute even when a
record exists, and ``--jobs N`` compiles independent cells on N threads
(XLA compilation releases the GIL for most of its wall time).
"""

import argparse
import json
import time
import traceback
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, full_config
from repro.distributed import (analysis, batch_specs, cache_specs, named,
                               opt_state_specs, param_specs,
                               make_activation_constraint)
from repro.models import config as mcfg
from repro.models import init_cache, init_params
from repro.models.config import SHAPES, shape_applicable
from repro.optim import adamw
from repro.runtime.steps import (build_decode_step, build_prefill_step,
                                 build_train_step, input_specs)
from repro.launch.mesh import make_production_mesh

from jax.sharding import NamedSharding, PartitionSpec as P

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

DRY_ARCHS = [a for a in ARCH_IDS if a != "paper-demo"]


def _abstract(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


def _mem_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _analytic_memory(cfg, shape, mesh, *, training: bool) -> dict:
    """Bytes/device from the sharding policy (CPU memory_analysis is often
    unavailable — this is the 'proves it fits' accounting)."""
    n_dev = mesh.devices.size
    pbytes = cfg.param_count() * jnp.dtype(cfg.param_dtype).itemsize
    out = {"params_bytes_per_device": pbytes / n_dev}
    if training:
        master = 0 if cfg.param_dtype == "float32" else 4 * cfg.param_count()
        opt = 8 * cfg.param_count() + master
        out["opt_bytes_per_device"] = opt / n_dev
    else:
        kvb = 0
        if cfg.has_attention:
            kvb += (2 * cfg.n_layers * shape.global_batch * shape.seq_len
                    * cfg.n_kv_heads * cfg.d_head
                    * jnp.dtype(cfg.compute_dtype).itemsize)
        if cfg.has_ssm:
            kvb += (4 * cfg.n_layers * shape.global_batch * cfg.ssm_heads
                    * cfg.ssm_head_dim * cfg.ssm_state)
        out["cache_bytes_per_device"] = kvb / n_dev
    out["total_known_bytes_per_device"] = sum(out.values())
    return out


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               attention_impl: str = "chunked",
               remat: bool = True,
               fsdp: bool = True, tp: bool = True,
               donate: bool = True,
               cfg_overrides: dict | None = None,
               moe_constraints: bool = False,
               serving_layout: bool = False,
               pure_fsdp: bool = False):
    """Returns (lowered, compiled, context dict)."""
    cfg = full_config(arch).with_(attention_impl=attention_impl,
                                  **(cfg_overrides or {}))
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        return None, None, {"status": "skipped",
                            "reason": "shape inapplicable (DESIGN.md)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    # pure_fsdp (§Perf): no TP; parameters + batch shard over EVERY mesh
    # axis — per-layer weight all-gathers replace activation all-reduces.
    fsdp_axes = ("pod", "data", "model") if pure_fsdp else None
    if pure_fsdp:
        tp = False
    pspecs = param_specs(cfg, mesh, fsdp=fsdp, tp=tp,
                         serving=serving_layout and shape.kind != "train",
                         fsdp_axes=fsdp_axes)
    ac = make_activation_constraint(cfg, mesh,
                                    moe_constraints=moe_constraints,
                                    fsdp_axes=fsdp_axes)
    params_abs = _abstract(lambda: init_params(cfg, jax.random.PRNGKey(0)))

    from repro.distributed.context import use_mesh
    with mesh, use_mesh(mesh):
        if shape.kind == "train":
            opt_cfg = adamw.AdamWConfig()
            opt_abs = _abstract(lambda p: adamw.init(p, opt_cfg), params_abs)
            ospecs = opt_state_specs(pspecs, has_master=(
                cfg.param_dtype != "float32"), compress=False)
            bspecs = batch_specs(cfg, mesh, global_batch=shape.global_batch,
                                 fsdp_axes=fsdp_axes)
            step = build_train_step(cfg, opt_config=opt_cfg, ac=ac,
                                    remat=remat)
            in_sh = (named(mesh, pspecs), named(mesh, ospecs),
                     named(mesh, bspecs))
            out_sh = (named(mesh, pspecs), named(mesh, ospecs), None)
            jfn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=(0, 1) if donate else ())
            batch_abs = {k: v for k, v in
                         input_specs(cfg, shape).items()}
            lowered = jfn.lower(params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            cspecs = cache_specs(cfg, mesh, batch=shape.global_batch,
                                 max_len=shape.seq_len)
            cache_abs = _abstract(lambda: init_cache(
                cfg, shape.global_batch, shape.seq_len, cfg.compute_dtype))
            step = build_prefill_step(cfg, max_len=shape.seq_len, ac=ac)
            ins = input_specs(cfg, shape)
            bspecs = batch_specs(cfg, mesh, global_batch=shape.global_batch,
                                 fsdp_axes=fsdp_axes)
            in_sh = (named(mesh, pspecs),
                     named(mesh, bspecs["tokens"]),
                     named(mesh, cspecs))
            args = [params_abs, ins["tokens"], cache_abs]
            if cfg.n_frontend_embeds:
                in_sh = in_sh + (named(mesh, bspecs["extra_embeds"]),)
                args.append(ins["extra_embeds"])
            jfn = jax.jit(step, in_shardings=in_sh,
                          donate_argnums=(2,) if donate else ())
            lowered = jfn.lower(*args)
        else:  # decode
            cspecs = cache_specs(cfg, mesh, batch=shape.global_batch,
                                 max_len=shape.seq_len)
            cache_abs = _abstract(lambda: init_cache(
                cfg, shape.global_batch, shape.seq_len, cfg.compute_dtype))
            step = build_decode_step(cfg, ac=ac)
            ins = input_specs(cfg, shape)
            in_sh = (named(mesh, pspecs),
                     named(mesh, P(None)),
                     named(mesh, cspecs))
            jfn = jax.jit(step, in_shardings=in_sh,
                          donate_argnums=(2,) if donate else ())
            lowered = jfn.lower(params_abs, ins["token"], cache_abs)

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    training = shape.kind == "train"
    model_flops = (cfg.model_flops_per_token(training=training)
                   * shape.global_batch
                   * (shape.seq_len if not shape.is_decode else 1))
    ctx = {
        "status": "ok",
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "kind": shape.kind,
        "attention_impl": attention_impl,
        "compile_seconds": compile_s,
        "model_flops_total": model_flops,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    return lowered, compiled, ctx


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: Path = RESULTS, skip_existing: bool = False,
             no_cache: bool = False, **lower_kw) -> dict:
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_tag}.json"
    if skip_existing and not no_cache and out_path.exists():
        return json.loads(out_path.read_text())
    t_start = time.time()
    try:
        lowered, compiled, ctx = lower_cell(arch, shape_name,
                                            multi_pod=multi_pod, **lower_kw)
        if compiled is None:  # skipped
            record = {**ctx, "arch": arch, "shape": shape_name,
                      "mesh": mesh_tag}
        else:
            n_dev = ctx["n_devices"]
            hlo_text = compiled.as_text()
            roof, coll = analysis.roofline_from_compiled(
                compiled, n_devices=n_dev,
                model_flops_total=ctx["model_flops_total"],
                hlo_text=hlo_text)
            try:  # raw XLA cost analysis (loop-body-once; for reference)
                ca = compiled.cost_analysis()
                ca = ca[0] if isinstance(ca, list) else ca
                ctx["xla_cost_analysis"] = {
                    "flops": float(ca.get("flops", 0.0)),
                    "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
                }
            except Exception:
                pass
            cfg = full_config(arch)
            shape = SHAPES[shape_name]
            record = {
                **ctx,
                "memory_analysis": _mem_dict(compiled),
                "analytic_memory": _analytic_memory(
                    cfg, shape, make_production_mesh(multi_pod=multi_pod),
                    training=shape.kind == "train"),
                "roofline": roof.to_dict(),
                "collectives": {
                    "counts": coll.counts,
                    "result_bytes": coll.result_bytes,
                    "link_bytes_per_device": coll.link_bytes,
                },
                "wall_seconds": time.time() - t_start,
            }
    except Exception as e:
        record = {"status": "error", "arch": arch, "shape": shape_name,
                  "mesh": mesh_tag, "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-2000:],
                  "wall_seconds": time.time() - t_start}
    out_path.write_text(json.dumps(record, indent=2, default=float))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=DRY_ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-cache", action="store_true",
                    help="recompute cells even when their JSON record exists")
    ap.add_argument("--jobs", type=int, default=1, metavar="N",
                    help="compile N independent cells concurrently")
    ap.add_argument("--attention-impl", default="chunked",
                    choices=["chunked", "xla"])
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = []
    if args.all:
        for a in DRY_ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    def one(mp, a, s):
        rec = run_cell(a, s, multi_pod=mp,
                       skip_existing=args.skip_existing,
                       no_cache=args.no_cache,
                       attention_impl=args.attention_impl)
        status = rec.get("status")
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" compile={rec['compile_seconds']:.1f}s"
                     f" bottleneck={r['bottleneck']}"
                     f" t=({r['t_compute']:.3f},{r['t_memory']:.3f},"
                     f"{r['t_collective']:.3f})s")
        elif status == "error":
            extra = " " + rec["error"][:120]
        print(f"[{rec.get('mesh')}] {a} × {s}: {status}{extra}", flush=True)

    grid = [(mp, a, s) for mp in meshes for a, s in cells]
    if args.jobs > 1:
        # chunk the grid so jax caches are cleared between batches (from the
        # main thread, with no compile in flight): peak cache memory is
        # bounded by the args.jobs cells of one chunk, vs one cell when
        # sequential
        chunk = args.jobs
        with ThreadPoolExecutor(max_workers=args.jobs) as pool:
            for start in range(0, len(grid), chunk):
                list(pool.map(lambda cell: one(*cell),
                              grid[start:start + chunk]))
                jax.clear_caches()
    else:
        for cell in grid:
            one(*cell)
            jax.clear_caches()  # keep the sweep's memory bounded


if __name__ == "__main__":
    main()
