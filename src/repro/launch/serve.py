"""Serving driver: load checkpoint commits from the lake and serve batched
requests (weights pinned to immutable catalog refs).

Single engine against one ref (legacy surface):

  PYTHONPATH=src python -m repro.launch.serve --lake /tmp/lake \
      --ref trainer.run-run0 --arch paper-demo --smoke --requests 8

Replica fleet watching the production serving tag (deployment = tag flip,
see docs/serving.md):

  PYTHONPATH=src python -m repro.launch.serve --lake /tmp/lake \
      --replicas 2 --watch-tag serving/prod --arch paper-demo --smoke

Also reachable as `repro serve --replicas N` / `repro rollout` /
`repro rollback`.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs import full_config, smoke_config
from repro.core import Lake
from repro.serving import (BatchedServer, FixedBatchedServer, ServeEngine,
                           ServingFleet)


def run_single(lake: Lake, cfg, ref: str, *, batch_size: int = 4,
               max_len: int = 128, requests: int = 8, gen_tokens: int = 16,
               mode: str = "continuous", seed: int = 0) -> dict:
    """Serve a synthetic workload from one engine pinned to ``ref``."""
    from repro.checkpoint import latest_checkpoint

    commit = latest_checkpoint(lake, ref) or ref
    engine = ServeEngine.from_catalog(lake, commit, cfg, max_len=max_len,
                                      batch_size=batch_size)
    server = (BatchedServer(engine) if mode == "continuous"
              else FixedBatchedServer(engine))
    rng = np.random.default_rng(seed)
    for rid in range(requests):
        plen = int(rng.integers(4, max_len - gen_tokens))
        prompt = rng.integers(3, cfg.vocab_size, size=plen).astype(np.int32)
        server.submit(rid, prompt, gen_tokens)
    served = 0
    while server.pending:
        served += server.step()
    return {"served": served, "commit": engine.model_commit,
            "completed": server.completed}


def run_fleet(lake: Lake, cfg, *, replicas: int = 2, slots: int = 4,
              max_len: int = 128, watch_tag: str = "serving/prod",
              poll_every: int = 4, mode: str = "continuous",
              requests: int = 16, gen_tokens: int = 8,
              seed: int = 0) -> ServingFleet:
    """Serve a synthetic workload from a tag-watching replica fleet."""
    fleet = ServingFleet(lake, cfg, replicas=replicas, slots=slots,
                         max_len=max_len, watch_tag=watch_tag,
                         poll_every=poll_every, mode=mode)
    rng = np.random.default_rng(seed)
    for rid in range(requests):
        plen = int(rng.integers(4, max_len - gen_tokens))
        prompt = rng.integers(3, cfg.vocab_size, size=plen).astype(np.int32)
        fleet.submit(rid, prompt, int(rng.integers(1, gen_tokens + 1)))
    fleet.drain()
    return fleet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lake", required=True)
    ap.add_argument("--ref", default=None,
                    help="branch / tag / commit with a checkpoint "
                         "(single-engine mode)")
    ap.add_argument("--arch", default="paper-demo")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--mode", choices=["continuous", "fixed"],
                    default="continuous")
    ap.add_argument("--replicas", type=int, default=None,
                    help="fleet mode: number of replicas watching the tag")
    ap.add_argument("--watch-tag", default="serving/prod")
    ap.add_argument("--poll-every", type=int, default=4)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else full_config(args.arch)
    lake = Lake(args.lake)
    if args.replicas:
        fleet = run_fleet(lake, cfg, replicas=args.replicas,
                          slots=args.batch_size, max_len=args.max_len,
                          watch_tag=args.watch_tag,
                          poll_every=args.poll_every, mode=args.mode,
                          requests=args.requests,
                          gen_tokens=args.gen_tokens)
        print(f"fleet of {args.replicas} served {len(fleet.completed)} "
              f"requests from tag {args.watch_tag!r} "
              f"(target {fleet.target[:12]}, {fleet.steps} steps, "
              f"{fleet.rollouts} rollouts)")
        return
    if not args.ref:
        raise SystemExit("--ref is required without --replicas")
    out = run_single(lake, cfg, args.ref, batch_size=args.batch_size,
                     max_len=args.max_len, requests=args.requests,
                     gen_tokens=args.gen_tokens, mode=args.mode)
    print(f"served {out['served']} requests from model commit "
          f"{out['commit'][:12]}")
    for rid in sorted(out["completed"])[:3]:
        res = out["completed"][rid]
        print(f"  req {rid}: {res.tokens[0][:8].tolist()}...")


if __name__ == "__main__":
    main()
