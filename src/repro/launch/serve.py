"""Serving driver: load a checkpoint commit from the lake and serve batched
requests (weights pinned to an immutable catalog ref).

  PYTHONPATH=src python -m repro.launch.serve --lake /tmp/lake \
      --ref trainer.run-run0 --arch paper-demo --smoke --requests 8
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs import full_config, smoke_config
from repro.core import Lake
from repro.serving import BatchedServer, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lake", required=True)
    ap.add_argument("--ref", required=True,
                    help="branch / tag / commit with a checkpoint")
    ap.add_argument("--arch", default="paper-demo")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else full_config(args.arch)
    lake = Lake(args.lake)
    from repro.checkpoint import latest_checkpoint
    commit = latest_checkpoint(lake, args.ref) or args.ref
    engine = ServeEngine.from_catalog(
        lake, commit, cfg, max_len=args.max_len, batch_size=args.batch_size)
    server = BatchedServer(engine)

    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        plen = int(rng.integers(4, args.max_len - args.gen_tokens))
        prompt = rng.integers(3, cfg.vocab_size, size=plen).astype(np.int32)
        server.submit(rid, prompt, args.gen_tokens)
    served = 0
    while server.queue:
        served += server.step()
    print(f"served {served} requests from model commit "
          f"{engine.model_commit[:12]}")
    for rid in sorted(server.completed)[:3]:
        res = server.completed[rid]
        print(f"  req {rid}: {res.tokens[0][:8].tolist()}...")


if __name__ == "__main__":
    main()
