"""Production mesh construction.

A FUNCTION (never a module-level constant) so importing this module touches
no jax device state — the dry-run sets XLA_FLAGS before first jax init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi-pod adds the 'pod' axis (2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(*, model: int = 1):
    """Whatever devices exist now (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_cells(mesh) -> int:
    return int(mesh.devices.size)
