"""``repro`` CLI — the paper's bauplan-style command surface (§4–5):

  repro branch <user.branch> [--from REF]      create a CoW branch
  repro checkout <ref>                         resolve + print a ref
  repro run --pipeline data --branch B         run a pipeline, get a run_id
  repro run --id RUN_ID --branch B             REPLAY a past run (Listing 3)
  repro run ... --executor process             local process pool (GIL-bound
                                               nodes; bit-identical commits)
  repro run ... --executor remote              lease nodes to `repro worker`
                                               processes sharing the store
  repro worker [--once|--max-idle SEC]         pull-based worker loop
  repro status <run-id>                        live per-node lease/heartbeat/
                                               cache state (docs/executor.md)
  repro query "SELECT COUNT(*) FROM t" --ref R tiny read-path query
  repro log <ref> / branches / runs            inspect the catalog
  repro contract add T not_empty no_nans       attach catalog-enforced data
                                               contracts to a table (see
                                               docs/catalog.md)
  repro contract list [--ref R] / drop T       inspect / detach contracts

Multi-host (git-remote semantics over the object store — see
docs/remote_store.md):

  repro remote add origin URL                  name a remote (http://, s3://
                                               or a path)
  repro push --branch B [--remote origin]      publish closure + cache + runs
  repro push main 'exp/*' --tags 'v*'          atomic multi-ref push (globs;
                                               all refs land or none do)
  repro pull --branch B [--remote origin]      fetch + fast-forward
  repro clone URL DEST [--branch B]            new lake from a remote (+tags)
  repro serve --root DIR --port P              loopback object-store server
  repro serve --root DIR --s3 [--bucket B]     stub S3 server (same tree,
                                               S3 REST dialect)

Model serving on immutable refs (docs/serving.md — deployment is a
catalog tag flip, rollback is time-travel):

  repro serve --replicas 2 --watch-tag serving/prod --smoke
                                               replica fleet; each replica
                                               pins an engine to the tag's
                                               checkpoint commit
  repro rollout --to <ckpt-ref>                CAS-flip serving/prod
  repro rollout --to <ckpt-ref> --canary 8     ...gated: flip only if WAP
                                               expectations over live canary
                                               metrics pass
  repro rollback                               flip back to serving/prev
  repro gc [--dry-run] [--drop-cache]          mark-and-sweep the local lake
  repro gc --remote origin                     remote-side GC: server-side
                                               mark from the REMOTE's refs,
                                               sweep there
  repro gc --prune-age 3600                    upload-age grace window —
                                               with the GC generation token
                                               this makes gc safe to run
                                               concurrently with pushes

Transfers are concurrent (--jobs N workers; --jobs 1 = sequential) and
move large blobs as compressed wire frames (paid for once, at write time).

"CLI is all you need": no catalog service to provision, no client API to
learn — the same ergonomics claim the paper demonstrates, over the tensor
lake.  Example session in examples/quickstart.py.
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

import numpy as np

from repro.core import Lake, ObjectStore, SyncError, connect, serve_http
from repro.core import sync as sync_mod
from repro.data import build_data_pipeline


def _pipeline(name: str, seq_len: int):
    if name == "data":
        return build_data_pipeline(seq_len)
    raise SystemExit(f"unknown pipeline {name!r} (built-in: data)")


_QUERY_RE = re.compile(
    r"^\s*select\s+(count\(\*\)|[\w,\s*]+)\s+from\s+(\w+)\s*"
    r"(?:where\s+(\w+)\s*(=|>|<|>=|<=)\s*([-\d.]+))?\s*$", re.I)


def _query(lake: Lake, sql: str, ref: str):
    """Minimal SELECT over one table — the paper's Listing 3 read path.
    (Full engines read the same snapshots via the Iceberg-like manifests.)"""
    m = _QUERY_RE.match(sql)
    if not m:
        raise SystemExit(
            "supported: SELECT count(*)|cols FROM table [WHERE col OP num]")
    proj, table, wcol, wop, wval = m.groups()
    cols = lake.read_table(ref, table)
    if wcol:
        import operator
        ops = {"=": operator.eq, ">": operator.gt, "<": operator.lt,
               ">=": operator.ge, "<=": operator.le}
        mask = ops[wop](cols[wcol], float(wval))
        cols = {k: v[mask] for k, v in cols.items()}
    n = next(iter(cols.values())).shape[0] if cols else 0
    if proj.strip().lower() == "count(*)":
        print(n)
        return
    names = [c.strip() for c in proj.split(",") if c.strip() != "*"] \
        or list(cols)
    for i in range(min(n, 20)):
        print({k: np.asarray(cols[k][i]).tolist() for k in names})
    if n > 20:
        print(f"... ({n} rows)")


def _remotes_dir(lake: Lake) -> Path:
    return Path(lake.store.root) / "remotes"


def _resolve_remote(lake: Lake, spec: str, *, allow_delete: bool = False):
    """A remote spec is a configured name (``repro remote add``) or a
    URL/path used directly.  A bare name that is neither configured nor an
    existing directory is an error — silently creating an empty store named
    after a typo'd remote would make a push look published when nothing
    left the machine.  ``allow_delete`` opens the remote-side GC sweep path
    (only ``repro gc --remote`` passes it)."""
    if "://" in spec:
        return connect(spec, allow_delete=allow_delete)
    if "/" not in spec and "\\" not in spec:
        cfg = _remotes_dir(lake) / spec
        if cfg.exists():
            return connect(cfg.read_text().strip(),
                           allow_delete=allow_delete)
        if not Path(spec).is_dir():
            raise SystemExit(
                f"unknown remote {spec!r}: configure it with "
                f"`repro remote add {spec} URL` or pass a URL/path")
    return connect(spec, allow_delete=allow_delete)


def _add_sync_args(p):
    p.add_argument("refspecs", nargs="*", metavar="BRANCH",
                   help="branch names or globs; several move as ONE atomic "
                        "multi-ref operation (all refs update or none do)")
    p.add_argument("--branch", default=None,
                   help="single branch (kept for scripts; same as one "
                        "positional BRANCH)")
    p.add_argument("--tags", action="append", default=None, metavar="PATTERN",
                   help="also sync tags matching PATTERN (glob; repeatable)")
    p.add_argument("--remote", action="append", default=None,
                   help="configured remote name, or a URL/path (default: "
                        "origin; repeatable on push — one closure walk "
                        "fans out to every destination)")
    p.add_argument("--force", action="store_true",
                   help="allow a non-fast-forward ref update / tag clobber")
    p.add_argument("--jobs", type=int, default=None, metavar="N",
                   help="concurrent transfer workers (default: 8; 1 = "
                        "sequential)")
    p.add_argument("--no-cache-entries", action="store_true",
                   help="skip run-cache entry transfer (see the trust "
                        "model in docs/remote_store.md)")
    p.add_argument("--no-runs", action="store_true",
                   help="skip run-ledger manifest transfer")


def main(argv=None):
    ap = argparse.ArgumentParser(prog="repro")
    ap.add_argument("--lake", default=".lake")
    sub = ap.add_subparsers(dest="cmd", required=True)

    b = sub.add_parser("branch")
    b.add_argument("name")
    b.add_argument("--from", dest="from_ref", default="main")
    b.add_argument("--author", default=None)

    c = sub.add_parser("checkout")
    c.add_argument("ref")

    r = sub.add_parser("run")
    r.add_argument("--pipeline", default="data")
    r.add_argument("--seq-len", type=int, default=256)
    r.add_argument("--branch", required=True)
    r.add_argument("--id", dest="run_id", default=None,
                   help="replay this run id instead of a fresh run")
    r.add_argument("--author", default="cli")
    r.add_argument("--no-cache", action="store_true",
                   help="ignore the run cache: re-execute every node")
    r.add_argument("--jobs", type=int, default=None, metavar="N",
                   help="max concurrent DAG nodes (default: auto)")
    r.add_argument("--executor", choices=["thread", "process", "remote"],
                   default="thread",
                   help="worker backend: thread (default), process (local "
                        "process pool for GIL-bound nodes), remote (lease "
                        "nodes to `repro worker` processes sharing the "
                        "store)")
    r.add_argument("--lease-ttl", type=float, default=30.0, metavar="SEC",
                   help="worker heartbeat deadline; an expired lease means "
                        "the worker is presumed dead and the node is "
                        "re-leased (default: 30)")
    r.add_argument("--max-attempts", type=int, default=3, metavar="N",
                   help="poison pill: fail the run after N lease claims of "
                        "one node (default: 3)")
    r.add_argument("--wait-timeout", type=float, default=None,
                   metavar="SEC",
                   help="--executor remote: fail if no node makes progress "
                        "for this long (default: wait forever)")

    st = sub.add_parser("status",
                        help="live per-node lease/heartbeat/cache state of "
                             "a run (exec id or ledger run id, prefixes ok)")
    st.add_argument("run_id")

    w = sub.add_parser("worker",
                       help="execute leased nodes for runs started with "
                            "--executor remote (shares the lake store; "
                            "code is matched by pipeline hash, never "
                            "shipped)")
    w.add_argument("--pipeline", default="data",
                   help="pipeline(s) this worker can execute (comma-"
                        "separated; built-in: data)")
    w.add_argument("--seq-len", type=int, default=256)
    w.add_argument("--name", default=None,
                   help="lease owner name (default: worker-<pid>)")
    w.add_argument("--ttl", type=float, default=10.0,
                   help="heartbeat lease ttl in seconds (default: 10)")
    w.add_argument("--poll", type=float, default=0.05,
                   help="idle poll interval in seconds (default: 0.05)")
    w.add_argument("--once", action="store_true",
                   help="claim and execute at most one node, then exit")
    w.add_argument("--max-idle", type=float, default=None, metavar="SEC",
                   help="exit after this long with no claimable work "
                        "(default: poll forever)")

    cc = sub.add_parser("cache", help="inspect / clear the run cache")
    cc.add_argument("action", choices=["stats", "clear"])

    g = sub.add_parser("gc", help="mark-and-sweep unreachable objects")
    g.add_argument("--dry-run", action="store_true",
                   help="report what would be swept without deleting")
    g.add_argument("--drop-cache", action="store_true",
                   help="drop run-cache entries first and sweep what only "
                        "the cache kept alive")
    g.add_argument("--remote", default=None, metavar="NAME",
                   help="collect the named remote instead of the local "
                        "lake: mark from the REMOTE's own refs, sweep via "
                        "its delete_object — local state is never trusted")
    g.add_argument("--prune-age", type=float, default=None,
                   metavar="SECONDS",
                   help="upload-age grace window: never sweep an object "
                        "younger than this (default: 3600 — the safety "
                        "margin that lets gc run concurrently with "
                        "pushes; 0 sweeps everything unreachable)")

    cp = sub.add_parser(
        "compact",
        help="rewrite a table's small tensorfile fragments into "
             "target-sized files as a new snapshot with digest-provably "
             "identical logical contents (the maintenance half of "
             "streaming ingestion)")
    cp.add_argument("table")
    cp.add_argument("--branch", default="main")
    cp.add_argument("--author", default="compactor")
    cp.add_argument("--target-rows", type=int, default=None, metavar="N",
                    help="rows per output file (default: the lake's "
                         "target_rows_per_file)")
    cp.add_argument("--no-history", action="store_true",
                    help="start a fresh snapshot chain instead of keeping "
                         "the compacted snapshot as parent — the old "
                         "fragments become GC-collectable once the grace "
                         "window passes")
    cp.add_argument("--max-attempts", type=int, default=4,
                    help="retries when concurrent ingestion keeps moving "
                         "the table (ingestion always wins the race)")

    q = sub.add_parser("query")
    q.add_argument("sql")
    q.add_argument("--ref", default="main")

    lg = sub.add_parser("log")
    lg.add_argument("ref")

    sub.add_parser("branches")
    sub.add_parser("runs")

    ct = sub.add_parser("contract",
                        help="catalog-enforced data contracts: rules the "
                             "ref update itself checks on every commit/"
                             "merge/publish touching the table")
    ct_sub = ct.add_subparsers(dest="contract_cmd", required=True)
    ct_add = ct_sub.add_parser(
        "add", help="attach rules to a table (current data is validated "
                    "first: a contract is never in force over data that "
                    "fails it)")
    ct_add.add_argument("table")
    ct_add.add_argument("rules", nargs="+",
                        help="rule specs: not_empty | no_nans[:cols] | "
                             "column_range:col,lo,hi | "
                             "columns_required:cols")
    ct_add.add_argument("--branch", default="main")
    ct_add.add_argument("--author", default="cli")
    ct_list = ct_sub.add_parser("list")
    ct_list.add_argument("--ref", default="main")
    ct_drop = ct_sub.add_parser("drop")
    ct_drop.add_argument("table")
    ct_drop.add_argument("--branch", default="main")
    ct_drop.add_argument("--author", default="cli")

    rm = sub.add_parser("remote", help="manage named remotes")
    rm_sub = rm.add_subparsers(dest="remote_cmd", required=True)
    rm_add = rm_sub.add_parser("add")
    rm_add.add_argument("name")
    rm_add.add_argument("url", help="http(s)://host:port or a store path")
    rm_sub.add_parser("list")

    _add_sync_args(sub.add_parser(
        "push", help="publish a branch closure to a remote"))
    _add_sync_args(sub.add_parser(
        "pull", help="fetch a branch closure from a remote"))

    cl = sub.add_parser("clone", help="materialize a lake from a remote")
    cl.add_argument("url")
    cl.add_argument("dest")
    cl.add_argument("--branch", default=None,
                    help="single branch (default: every remote branch)")
    cl.add_argument("--no-tags", action="store_true",
                    help="skip pulling remote tags")
    cl.add_argument("--jobs", type=int, default=None, metavar="N",
                    help="concurrent transfer workers")

    sv = sub.add_parser("serve", help="serve a store over loopback HTTP")
    sv.add_argument("--root", default=None,
                    help="store directory (default: the --lake store)")
    sv.add_argument("--host", default="127.0.0.1")
    sv.add_argument("--port", type=int, default=8750)
    sv.add_argument("--s3", action="store_true",
                    help="serve the S3-compatible REST dialect instead of "
                         "the msgpack protocol (clients connect with "
                         "s3://host:port/BUCKET)")
    sv.add_argument("--bucket", default="lake",
                    help="bucket name for --s3 (default: lake)")
    sv.add_argument("--replicas", type=int, default=None, metavar="N",
                    help="model-fleet mode: serve N tag-watching replica "
                         "engines instead of the object store")
    sv.add_argument("--watch-tag", default="serving/prod",
                    help="catalog tag the fleet deploys from "
                         "(default: serving/prod)")
    sv.add_argument("--arch", default="paper-demo")
    sv.add_argument("--smoke", action="store_true",
                    help="smoke-sized model config")
    sv.add_argument("--slots", type=int, default=4,
                    help="decode slots per replica (continuous batching)")
    sv.add_argument("--max-len", type=int, default=128)
    sv.add_argument("--mode", choices=["continuous", "fixed"],
                    default="continuous")
    sv.add_argument("--requests", type=int, default=16,
                    help="synthetic requests to serve before exiting")
    sv.add_argument("--gen-tokens", type=int, default=8)
    sv.add_argument("--poll-every", type=int, default=4,
                    help="fleet steps between tag polls")

    ro = sub.add_parser(
        "rollout", help="deploy a checkpoint: CAS-flip the serving tag "
                        "(optionally canary-gated by WAP expectations)")
    ro.add_argument("--to", dest="to_ref", required=True,
                    help="checkpoint ref to deploy (branch/tag/commit; a "
                         "branch resolves to its latest checkpoint)")
    ro.add_argument("--tag", default="serving/prod")
    ro.add_argument("--canary", type=int, default=None, metavar="N",
                    help="serve N live requests from a canary replica "
                         "pinned to the candidate and flip only if the "
                         "WAP audit over its metric table passes")
    ro.add_argument("--arch", default="paper-demo")
    ro.add_argument("--smoke", action="store_true")
    ro.add_argument("--max-len", type=int, default=128)
    ro.add_argument("--slots", type=int, default=4)
    ro.add_argument("--gen-tokens", type=int, default=8)

    rb = sub.add_parser(
        "rollback", help="flip the serving tag back to serving/prev")
    rb.add_argument("--tag", default="serving/prod")

    args = ap.parse_args(argv)

    if args.cmd == "clone":  # no existing lake needed
        remote = connect(args.url)
        _local, reports = sync_mod.clone(
            remote, args.dest, branch=args.branch,
            tags=() if args.no_tags else ("*",), jobs=args.jobs)
        dest_remotes = Path(args.dest) / "remotes"
        dest_remotes.mkdir(parents=True, exist_ok=True)
        (dest_remotes / "origin").write_text(args.url)
        for rep in reports:
            print(rep.summary())
        return
    if args.cmd == "serve" and args.replicas:
        from repro.configs import full_config, smoke_config
        from repro.launch.serve import run_fleet

        cfg = (smoke_config(args.arch) if args.smoke
               else full_config(args.arch))
        fleet = run_fleet(Lake(args.lake), cfg, replicas=args.replicas,
                          slots=args.slots, max_len=args.max_len,
                          watch_tag=args.watch_tag,
                          poll_every=args.poll_every, mode=args.mode,
                          requests=args.requests,
                          gen_tokens=args.gen_tokens)
        print(json.dumps({
            "replicas": args.replicas, "watch_tag": args.watch_tag,
            "target": fleet.target[:12], "served": len(fleet.completed),
            "steps": fleet.steps, "rollouts": fleet.rollouts}))
        return
    if args.cmd == "serve":
        import time as _time

        root = args.root or args.lake
        if args.s3:
            from repro.core.s3stub import serve_s3

            httpd, url = serve_s3(root, host=args.host, port=args.port,
                                  bucket=args.bucket)
        else:
            httpd, url = serve_http(ObjectStore(root), host=args.host,
                                    port=args.port)
        print(f"serving {root} at {url}", flush=True)
        try:  # the serve_http daemon thread accepts requests; just block
            while True:
                _time.sleep(3600)
        except KeyboardInterrupt:
            httpd.shutdown()
        return

    lake = Lake(args.lake)

    if args.cmd == "branch":
        author = args.author or args.name.split(".")[0]
        digest = lake.catalog.create_branch(args.name, args.from_ref,
                                            author=author)
        print(f"{args.name} -> {digest[:12]} (copy-on-write)")
    elif args.cmd == "checkout":
        print(lake.catalog.resolve(args.ref))
    elif args.cmd == "run":
        from repro.core.errors import ContractViolation, TransactionConflict

        pipe = _pipeline(args.pipeline, args.seq_len)
        exec_kw = dict(executor=args.executor, lease_ttl=args.lease_ttl,
                       max_attempts=args.max_attempts,
                       wait_timeout=args.wait_timeout)
        try:
            if args.run_id:
                rep = lake.replay(args.run_id, pipe, branch=args.branch,
                                  author=args.author,
                                  use_cache=not args.no_cache,
                                  jobs=args.jobs, **exec_kw)
                print(json.dumps({"replayed": args.run_id,
                                  "replay_run_id": rep.replay_run_id,
                                  "branch": rep.branch,
                                  "bit_exact": rep.bit_exact}))
            else:
                res = lake.run(pipe, branch=args.branch, author=args.author,
                               use_cache=not args.no_cache, jobs=args.jobs,
                               **exec_kw)
                out = {"run_id": res.run_id,
                       "commit": res.commit[:12],
                       "outputs": list(res.outputs),
                       "cache_hits": res.cache_hits,
                       "cache_misses": res.cache_misses}
                rebases = lake.catalog.txn_stats["rebases"]
                if rebases:  # concurrent writers absorbed transparently
                    out["txn_rebases"] = rebases
                print(json.dumps(out))
        except ContractViolation as e:
            raise SystemExit(
                f"commit rejected by data contract: {e}") from None
        except TransactionConflict as e:
            raise SystemExit(
                f"commit lost to concurrent writers on the same tables: "
                f"{e} (rerun to retry from the new head)") from None
    elif args.cmd == "status":
        from repro.core.errors import ReproError

        try:
            print(json.dumps(lake.run_status(args.run_id), indent=2,
                             sort_keys=True, default=str))
        except ReproError as e:
            raise SystemExit(str(e)) from None
    elif args.cmd == "worker":
        import os as _os

        pipelines = [_pipeline(name.strip(), args.seq_len)
                     for name in args.pipeline.split(",") if name.strip()]
        svc = lake.worker(pipelines,
                          name=args.name or f"worker-{_os.getpid()}",
                          ttl=args.ttl, poll=args.poll)
        if args.once:
            did = svc.run_once()
            print(json.dumps({"worker": svc.name, "nodes_done": int(did)}))
        else:
            try:
                done = svc.serve_forever(max_idle=args.max_idle)
            except KeyboardInterrupt:
                done = svc.nodes_done
            print(json.dumps({"worker": svc.name, "nodes_done": done}))
    elif args.cmd == "cache":
        if args.action == "stats":
            print(json.dumps({"entries": len(lake.run_cache)}))
        else:
            print(json.dumps({"cleared": lake.run_cache.clear()}))
    elif args.cmd == "gc":
        from repro.core.gc import DEFAULT_PRUNE_AGE, collect

        if args.remote:
            # remote-side GC: every read and delete goes through the
            # remote itself — a stale local mirror can neither protect
            # nor doom a remote object
            store = _resolve_remote(lake, args.remote, allow_delete=True)
        else:
            store = lake.store
        prune_age = (DEFAULT_PRUNE_AGE if args.prune_age is None
                     else max(0.0, args.prune_age))
        rep = collect(store, dry_run=args.dry_run,
                      drop_cache=args.drop_cache, prune_age=prune_age)
        print(json.dumps({"target": args.remote or "local",
                          "live": rep.live, "swept": rep.swept,
                          "bytes_freed": rep.bytes_freed,
                          "skipped_young": rep.skipped_young,
                          "prune_age": prune_age,
                          "generation": rep.generation,
                          "mode": rep.mode,
                          "dry_run": args.dry_run}))
    elif args.cmd == "compact":
        from repro.core.compact import compact_table
        from repro.core.errors import ReproError

        # compaction is an operator/maintenance action like contract
        # administration: it may touch a WAP-protected main directly —
        # losslessness is enforced internally by the digest check
        try:
            rep = compact_table(
                lake.catalog, args.table, branch=args.branch,
                author=args.author,
                target_rows_per_file=args.target_rows,
                keep_history=not args.no_history,
                max_attempts=args.max_attempts, _wap_token=True)
        except ReproError as e:
            raise SystemExit(str(e)) from None
        print(json.dumps({"table": args.table, "branch": args.branch,
                          "files_before": rep.files_before,
                          "files_after": rep.files_after,
                          "rows": rep.rows,
                          "bytes_read": rep.bytes_read,
                          "bytes_written": rep.bytes_written,
                          "snapshot": rep.new_snapshot[:12],
                          "logical_digest": rep.logical_digest[:12]}))
    elif args.cmd == "query":
        _query(lake, args.sql, args.ref)
    elif args.cmd == "log":
        for d in lake.catalog.log(args.ref):
            info = lake.catalog.commit_info(d)
            print(f"{d[:12]} {info.author:12s} {info.message}")
    elif args.cmd == "branches":
        for name in sorted(lake.catalog.branches()):
            print(name)
    elif args.cmd == "contract":
        from repro.core import parse_rule_spec
        from repro.core.errors import ContractViolation, ReproError

        # contract administration is an operator action: it may touch a
        # WAP-protected main directly (the attach itself is still gated
        # by the new rules against the current data)
        try:
            if args.contract_cmd == "add":
                rules = [parse_rule_spec(s) for s in args.rules]
                digest = lake.catalog.add_contract(
                    args.table, rules, branch=args.branch,
                    author=args.author, _wap_token=True)
                print(json.dumps({"table": args.table,
                                  "branch": args.branch,
                                  "rules": [r.name for r in rules],
                                  "commit": digest[:12]}))
            elif args.contract_cmd == "drop":
                digest = lake.catalog.drop_contract(
                    args.table, branch=args.branch, author=args.author,
                    _wap_token=True)
                print(json.dumps({"dropped": args.table,
                                  "branch": args.branch,
                                  "commit": digest[:12]}))
            else:  # list
                specs = lake.catalog.contracts(args.ref)
                print(json.dumps(
                    {t: [r.name for r in c.rules]
                     for t, c in sorted(specs.items())}, indent=2))
        except ContractViolation as e:
            raise SystemExit(
                f"refused: {e} (fix the data or adjust the rules)"
            ) from None
        except ReproError as e:
            raise SystemExit(str(e)) from None
    elif args.cmd == "runs":
        for rid in lake.ledger.runs():
            print(rid)
    elif args.cmd == "remote":
        if args.remote_cmd == "add":
            if "/" in args.name or "\\" in args.name or \
                    args.name.startswith("."):
                raise SystemExit(f"bad remote name {args.name!r}")
            _remotes_dir(lake).mkdir(parents=True, exist_ok=True)
            (_remotes_dir(lake) / args.name).write_text(args.url)
            print(f"{args.name} -> {args.url}")
        else:
            d = _remotes_dir(lake)
            if d.is_dir():
                for cfg in sorted(d.iterdir()):
                    print(f"{cfg.name}\t{cfg.read_text().strip()}")
    elif args.cmd == "rollout":
        from repro.checkpoint import latest_checkpoint
        from repro.serving import canary_rollout, flip_tag

        target = latest_checkpoint(lake, args.to_ref) or args.to_ref
        if args.canary:
            from repro.configs import full_config, smoke_config

            cfg = (smoke_config(args.arch) if args.smoke
                   else full_config(args.arch))
            rng = np.random.default_rng(0)
            reqs = [(rid,
                     rng.integers(3, cfg.vocab_size,
                                  size=int(rng.integers(
                                      4, args.max_len - args.gen_tokens))
                                  ).astype(np.int32),
                     args.gen_tokens)
                    for rid in range(args.canary)]
            rep = canary_rollout(lake, cfg, target, reqs, tag=args.tag,
                                 slots=args.slots, max_len=args.max_len)
        else:
            rep = flip_tag(lake, target, tag=args.tag)
        print(json.dumps(rep.to_obj()))
        if not rep.flipped and rep.reason != "already current":
            raise SystemExit(1)
    elif args.cmd == "rollback":
        from repro.core.errors import RefNotFound
        from repro.serving import rollback as _rollback

        try:
            print(json.dumps(_rollback(lake, tag=args.tag).to_obj()))
        except RefNotFound as e:
            raise SystemExit(str(e)) from None
    elif args.cmd in ("push", "pull"):
        remote_specs = args.remote or ["origin"]
        if len(remote_specs) > 1 and args.cmd == "pull":
            raise SystemExit("pull: --remote may be given once (fan-out "
                             "is a push concept; pull merges ONE remote's "
                             "view)")
        branches = ([args.branch] if args.branch else []) + args.refspecs
        tags = args.tags or []
        if not branches and not tags:
            raise SystemExit(f"{args.cmd}: name at least one branch "
                             "(--branch or positional) or --tags")

        def _tracking_name(spec):
            return spec if "/" not in spec else "origin"

        try:
            if len(remote_specs) > 1:
                # multi-remote push: shared fetch side, N destinations
                remotes = [(_tracking_name(spec),
                            _resolve_remote(lake, spec))
                           for spec in remote_specs]
                reports = sync_mod.push_fanout(
                    lake.store, remotes, branches, tags=tags,
                    force=args.force,
                    cache_entries=not args.no_cache_entries,
                    runs=not args.no_runs, jobs=args.jobs)
                for name, rep in reports:
                    print(f"{name}: {rep.summary()}")
                return
            spec = remote_specs[0]
            remote = _resolve_remote(lake, spec)
            kw = dict(remote_name=_tracking_name(spec), force=args.force,
                      cache_entries=not args.no_cache_entries,
                      runs=not args.no_runs, jobs=args.jobs)
            if (len(branches) == 1 and not tags
                    and not any(ch in branches[0] for ch in "*?[")):
                # single literal branch: the PR-2 surface, unchanged output
                fn = sync_mod.push if args.cmd == "push" else sync_mod.pull
                rep = fn(lake.store, remote, branches[0], **kw)
            else:
                fn = (sync_mod.push_refs if args.cmd == "push"
                      else sync_mod.pull_refs)
                rep = fn(lake.store, remote, branches, tags=tags, **kw)
        except SyncError as e:
            raise SystemExit(str(e)) from None
        print(rep.summary())


if __name__ == "__main__":
    main()
