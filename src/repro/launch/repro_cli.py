"""``repro`` CLI — the paper's bauplan-style command surface (§4–5):

  repro branch <user.branch> [--from REF]      create a CoW branch
  repro checkout <ref>                         resolve + print a ref
  repro run --pipeline data --branch B         run a pipeline, get a run_id
  repro run --id RUN_ID --branch B             REPLAY a past run (Listing 3)
  repro query "SELECT COUNT(*) FROM t" --ref R tiny read-path query
  repro log <ref> / branches / runs            inspect the catalog

"CLI is all you need": no catalog service to provision, no client API to
learn — the same ergonomics claim the paper demonstrates, over the tensor
lake.  Example session in examples/quickstart.py.
"""

from __future__ import annotations

import argparse
import json
import re
import sys

import numpy as np

from repro.core import Lake
from repro.data import build_data_pipeline


def _pipeline(name: str, seq_len: int):
    if name == "data":
        return build_data_pipeline(seq_len)
    raise SystemExit(f"unknown pipeline {name!r} (built-in: data)")


_QUERY_RE = re.compile(
    r"^\s*select\s+(count\(\*\)|[\w,\s*]+)\s+from\s+(\w+)\s*"
    r"(?:where\s+(\w+)\s*(=|>|<|>=|<=)\s*([-\d.]+))?\s*$", re.I)


def _query(lake: Lake, sql: str, ref: str):
    """Minimal SELECT over one table — the paper's Listing 3 read path.
    (Full engines read the same snapshots via the Iceberg-like manifests.)"""
    m = _QUERY_RE.match(sql)
    if not m:
        raise SystemExit(
            "supported: SELECT count(*)|cols FROM table [WHERE col OP num]")
    proj, table, wcol, wop, wval = m.groups()
    cols = lake.read_table(ref, table)
    if wcol:
        import operator
        ops = {"=": operator.eq, ">": operator.gt, "<": operator.lt,
               ">=": operator.ge, "<=": operator.le}
        mask = ops[wop](cols[wcol], float(wval))
        cols = {k: v[mask] for k, v in cols.items()}
    n = next(iter(cols.values())).shape[0] if cols else 0
    if proj.strip().lower() == "count(*)":
        print(n)
        return
    names = [c.strip() for c in proj.split(",") if c.strip() != "*"] \
        or list(cols)
    for i in range(min(n, 20)):
        print({k: np.asarray(cols[k][i]).tolist() for k in names})
    if n > 20:
        print(f"... ({n} rows)")


def main(argv=None):
    ap = argparse.ArgumentParser(prog="repro")
    ap.add_argument("--lake", default=".lake")
    sub = ap.add_subparsers(dest="cmd", required=True)

    b = sub.add_parser("branch")
    b.add_argument("name")
    b.add_argument("--from", dest="from_ref", default="main")
    b.add_argument("--author", default=None)

    c = sub.add_parser("checkout")
    c.add_argument("ref")

    r = sub.add_parser("run")
    r.add_argument("--pipeline", default="data")
    r.add_argument("--seq-len", type=int, default=256)
    r.add_argument("--branch", required=True)
    r.add_argument("--id", dest="run_id", default=None,
                   help="replay this run id instead of a fresh run")
    r.add_argument("--author", default="cli")
    r.add_argument("--no-cache", action="store_true",
                   help="ignore the run cache: re-execute every node")
    r.add_argument("--jobs", type=int, default=None, metavar="N",
                   help="max concurrent DAG nodes (default: auto)")

    cc = sub.add_parser("cache", help="inspect / clear the run cache")
    cc.add_argument("action", choices=["stats", "clear"])

    q = sub.add_parser("query")
    q.add_argument("sql")
    q.add_argument("--ref", default="main")

    lg = sub.add_parser("log")
    lg.add_argument("ref")

    sub.add_parser("branches")
    sub.add_parser("runs")

    args = ap.parse_args(argv)
    lake = Lake(args.lake)

    if args.cmd == "branch":
        author = args.author or args.name.split(".")[0]
        digest = lake.catalog.create_branch(args.name, args.from_ref,
                                            author=author)
        print(f"{args.name} -> {digest[:12]} (copy-on-write)")
    elif args.cmd == "checkout":
        print(lake.catalog.resolve(args.ref))
    elif args.cmd == "run":
        pipe = _pipeline(args.pipeline, args.seq_len)
        if args.run_id:
            rep = lake.replay(args.run_id, pipe, branch=args.branch,
                              author=args.author,
                              use_cache=not args.no_cache, jobs=args.jobs)
            print(json.dumps({"replayed": args.run_id,
                              "replay_run_id": rep.replay_run_id,
                              "branch": rep.branch,
                              "bit_exact": rep.bit_exact}))
        else:
            res = lake.run(pipe, branch=args.branch, author=args.author,
                           use_cache=not args.no_cache, jobs=args.jobs)
            print(json.dumps({"run_id": res.run_id,
                              "commit": res.commit[:12],
                              "outputs": list(res.outputs),
                              "cache_hits": res.cache_hits,
                              "cache_misses": res.cache_misses}))
    elif args.cmd == "cache":
        if args.action == "stats":
            print(json.dumps({"entries": len(lake.run_cache)}))
        else:
            print(json.dumps({"cleared": lake.run_cache.clear()}))
    elif args.cmd == "query":
        _query(lake, args.sql, args.ref)
    elif args.cmd == "log":
        for d in lake.catalog.log(args.ref):
            info = lake.catalog.commit_info(d)
            print(f"{d[:12]} {info.author:12s} {info.message}")
    elif args.cmd == "branches":
        for name in sorted(lake.catalog.branches()):
            print(name)
    elif args.cmd == "runs":
        for rid in lake.ledger.runs():
            print(rid)


if __name__ == "__main__":
    main()
