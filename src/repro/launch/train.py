"""End-to-end training driver: corpus → data pipeline → fault-tolerant
trainer → WAP publish, all catalog-backed.

  PYTHONPATH=src python -m repro.launch.train --arch paper-demo \
      --lake /tmp/lake --steps 200 --seq-len 256 --batch 8
"""

from __future__ import annotations

import argparse

from repro.configs import full_config, smoke_config
from repro.core import Lake
from repro.data import build_data_pipeline, seed_corpus
from repro.runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-demo")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--lake", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--n-docs", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--run-name", default="run0")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--publish", action="store_true")
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "constant"])
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else full_config(args.arch)
    lake = Lake(args.lake)
    if "data.main" not in lake.catalog.branches():
        lake.catalog.create_branch("data.main", "main", author="data")
        seed_corpus(lake, "data.main", n_docs=args.n_docs, seed=args.seed,
                    vocab_size=cfg.vocab_size, author="data")
        lake.run(build_data_pipeline(args.seq_len), branch="data.main",
                 author="data")

    tcfg = TrainerConfig(
        arch=args.arch, seq_len=args.seq_len, global_batch=args.batch,
        n_steps=args.steps, ckpt_every=args.ckpt_every, seed=args.seed,
        schedule=args.schedule,
        schedule_kw={"peak_lr": 3e-4, "warmup_steps": max(args.steps // 10, 1),
                     "total_steps": args.steps}
        if args.schedule == "cosine" else
        {"peak_lr": 3e-4, "warmup_steps": max(args.steps // 10, 1),
         "stable_steps": args.steps // 2, "decay_steps": args.steps // 2},
        author="trainer")
    trainer = Trainer(lake, cfg, tcfg, data_branch="data.main",
                      run_name=args.run_name)
    out = trainer.run(resume=args.resume)
    print(f"final loss: {out['losses'][-1]:.4f} "
          f"(first: {out['losses'][0]:.4f}), "
          f"stragglers: {trainer.straggler_events}")
    if args.publish:
        head = trainer.publish("main")
        print(f"published run branch to main @ {head[:12]}")


if __name__ == "__main__":
    main()
