"""jax version compatibility shims for the distributed layer."""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on new jax; ``jax.experimental.shard_map`` on
    jax < 0.5 (where ``check_vma`` was spelled ``check_rep``)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as legacy_sm

    return legacy_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check_vma)


def axis_size(name: str) -> int:
    """``jax.lax.axis_size`` on new jax; on jax < 0.5 ``psum(1, axis)`` is
    constant-folded to the (static) mapped axis size."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(name)
    return jax.lax.psum(1, name)
