"""Hierarchical multi-pod collectives (shard_map level).

Cross-pod ICI/DCN links are the scarce resource at 2+ pods.  The standard
trick: reduce-scatter INSIDE the pod (fast links), all-reduce the shards
ACROSS pods (slow links carry 1/|pod-size| of the bytes), all-gather back
inside the pod.  Optionally the cross-pod hop runs int8 with error feedback
(``repro.optim.compress_int8``), cutting slow-link bytes another 4×.

These run under ``jax.shard_map`` with explicit axis names, so the collective
schedule is deterministic rather than left to SPMD propagation — the
building block for the multi-pod gradient path (EXPERIMENTS.md §Perf,
"beyond-paper").
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .compat import axis_size, shard_map


def hierarchical_psum(x: jnp.ndarray, *, intra_axis: str = "data",
                      inter_axis: str = "pod") -> jnp.ndarray:
    """psum(x) over (inter × intra) via RS(intra) → AR(inter) → AG(intra).

    Byte-equivalent result to a flat psum, but the ``inter_axis`` (cross-pod)
    hop moves only 1/|intra| of the tensor per device.
    Call INSIDE shard_map with both axes bound.
    """
    n_intra = axis_size(intra_axis)
    pad = (-x.shape[0]) % n_intra
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)) if pad else x
    # reduce-scatter within the pod
    shard = jax.lax.psum_scatter(xp, intra_axis, scatter_dimension=0,
                                 tiled=True)
    # all-reduce the 1/n shard across pods (the slow hop)
    shard = jax.lax.psum(shard, inter_axis)
    # all-gather within the pod
    full = jax.lax.all_gather(shard, intra_axis, axis=0, tiled=True)
    return full[: x.shape[0]] if pad else full


def hierarchical_psum_int8(x: jnp.ndarray, residual: jnp.ndarray, *,
                           intra_axis: str = "data",
                           inter_axis: str = "pod"):
    """Like ``hierarchical_psum`` but the cross-pod hop is int8 with error
    feedback: returns (psum_approx, new_residual).

    The intra-pod reduce-scatter stays full precision (fast links); only the
    scattered shard is quantized for the inter-pod all-reduce.  The
    quantization error is fed back into ``residual`` so it is re-applied on
    the next step (convergence-preserving — standard EF-SGD argument).
    """
    n_intra = axis_size(intra_axis)
    n_inter = axis_size(inter_axis)
    pad = (-x.shape[0]) % n_intra
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)) if pad else x
    shard = jax.lax.psum_scatter(xp, intra_axis, scatter_dimension=0,
                                 tiled=True)

    # residual is stored per-device over the SCATTERED shard
    g = shard.astype(jnp.float32) + residual
    # pods must agree on ONE scale BEFORE quantizing — otherwise the summed
    # int8 values have no common dequantization (a scalar pmax across pods
    # is the only extra traffic)
    scale = jnp.maximum(jnp.max(jnp.abs(g)) / 127.0, 1e-12)
    scale = jax.lax.pmax(scale, inter_axis)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    n_inter = axis_size(inter_axis)
    if n_inter == 2:
        # pairwise exchange: the wire carries TRUE int8 payloads (psum
        # would upcast before transfer); sum locally after the swap
        other = jax.lax.ppermute(q, inter_axis, perm=[(0, 1), (1, 0)])
        q32 = q.astype(jnp.int32) + other.astype(jnp.int32)
    else:
        # ≥3 pods: int16 wire (sum of P int8 fits while P ≤ 128) — still
        # 2× under f32; a byte-packed ring AR would need per-hop requant
        q32 = jax.lax.psum(q.astype(jnp.int16), inter_axis).astype(jnp.int32)
    deq = q32.astype(jnp.float32) * scale
    new_residual = g - (q.astype(jnp.float32) * scale)

    full = jax.lax.all_gather(deq.astype(x.dtype), intra_axis, axis=0,
                              tiled=True)
    return (full[: x.shape[0]] if pad else full), new_residual


def make_hierarchical_grad_reducer(mesh: Mesh, *, compress: bool = False):
    """shard_map-wrapped reducer for a gradient pytree laid out with batch
    over ("pod","data").  Used by the multi-pod training path when SPMD's
    flat all-reduce schedule is the bottleneck."""
    if "pod" not in mesh.axis_names:
        raise ValueError("hierarchical reduction needs a 'pod' axis")

    def reduce_tree(grads):
        def one(g):
            flat = g.reshape(-1)
            out = hierarchical_psum(flat, intra_axis="data",
                                    inter_axis="pod")
            return out.reshape(g.shape)

        return jax.tree.map(one, grads)

    in_specs = P(("pod", "data"))
    return shard_map(reduce_tree, mesh=mesh,
                     in_specs=in_specs, out_specs=in_specs,
                     check_vma=False)
