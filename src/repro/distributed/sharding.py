"""Sharding rules: map parameter/batch/cache pytrees → PartitionSpecs.

Mesh axes (launch/mesh.py): ("pod", "data", "model") multi-pod, or
("data", "model") single-pod.  Policy (DESIGN.md §5):

- FSDP (ZeRO-3): parameters shard their *non-TP* matrix dim over
  ("pod","data"); XLA SPMD inserts all-gathers on use / reduce-scatters on
  grads.
- TP: attention heads (q/o), FFN hidden, vocab shard over "model".
- EP: MoE experts shard over "model" when E % |model| == 0, otherwise the
  per-expert FFN dim shards over "model" (TP-in-expert fallback — e.g.
  qwen2-moe's 60 experts on a 16-wide model axis).
- SSM mixers: FSDP only (the fused z|x|B|C|dt projection does not split
  cleanly across "model"; real Mamba TP would split the projections —
  recorded as a known deviation).
- Batch/activations: batch over ("pod","data"); long-context decode with
  batch < |data| shards the KV cache sequence dim over "data" instead
  (context parallelism).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig

FSDP = ("pod", "data")  # logical data axes (present subset used at runtime)


def _axes(mesh: Mesh, *names):
    """Keep only axes present in the mesh; None if none survive."""
    present = [n for n in names if n in mesh.axis_names]
    if not present:
        return None
    return tuple(present) if len(present) > 1 else present[0]


def data_axis_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names
                        if a in FSDP]))


def model_axis_size(mesh: Mesh) -> int:
    return int(mesh.shape.get("model", 1))


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def param_specs(cfg: ModelConfig, mesh: Mesh, *,
                fsdp: bool = True, tp: bool = True,
                serving: bool = False,
                fsdp_axes: Optional[tuple] = None) -> Dict[str, Any]:
    """PartitionSpec tree matching ``models.lm.init_params`` structure.

    ``serving=True`` switches to an inference layout (§Perf): weights keep
    their TP sharding but the FSDP axis moves OFF contracting dims (D) onto
    output dims (F / heads) — ZeRO-3-style gathering of weights every layer
    is a training trade; at serve time it shows up as a per-layer
    partial-sum all-reduce of activations, which this layout removes."""
    fa = fsdp_axes if fsdp_axes is not None else FSDP
    dp = _axes(mesh, *fa) if fsdp else None
    mp = _axes(mesh, "model") if tp else None
    dpn = (int(np.prod([mesh.shape[a] for a in mesh.axis_names if a in fa]))
           if fsdp else 0)
    mpn = model_axis_size(mesh) if tp else 0

    def _d_any(n):
        return dp if _div(n, max(dpn, 1)) and dpn > 1 else None

    def d(n):
        """fsdp axis for a CONTRACTING/feature dim — dropped in the serving
        layout (it would force per-layer weight gathers / partial-sum
        all-reduces with no optimizer-state payoff at inference)."""
        return None if serving else _d_any(n)

    def m(n):  # model axis if divisible
        return mp if _div(n, max(mpn, 1)) and mpn > 1 else None

    D, V = cfg.d_model, cfg.vocab_size
    specs: Dict[str, Any] = {
        "embed": P(m(V), d(D)),
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = P(m(V), d(D))
    if cfg.n_frontend_embeds:
        specs["connector"] = P(d(D), m(D))

    L = cfg.n_layers
    layers: Dict[str, Any] = {"ln1": P(None, None)}
    if cfg.is_moe or cfg.d_ff:
        layers["ln2"] = P(None, None)
    if cfg.has_attention:
        H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        layers["attn"] = {
            "wq": P(None, d(D), m(H * dh)),
            "wk": P(None, d(D), m(KV * dh)),
            "wv": P(None, d(D), m(KV * dh)),
            "wo": P(None, m(H * dh), d(D)),
        }
        if cfg.qkv_bias:
            layers["attn"]["bq"] = P(None, m(H * dh))
            layers["attn"]["bk"] = P(None, m(KV * dh))
            layers["attn"]["bv"] = P(None, m(KV * dh))
    if cfg.has_ssm:
        di, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
        proj_in = 2 * di + 2 * ns + nh
        layers["ssm"] = {
            "in_proj": P(None, d(D), None),
            "conv_w": P(None, None, None),
            "A_log": P(None, None),
            "D": P(None, None),
            "dt_bias": P(None, None),
            "norm": P(None, None),
            "out_proj": P(None, None, d(D)),
        }
    if cfg.is_moe:
        E, F = cfg.n_experts, cfg.expert_d_ff
        if _div(E, max(mpn, 1)) and mpn > 1:  # expert parallelism
            e_ax, f_ax = mp, None
        else:                                  # TP-in-expert fallback
            e_ax, f_ax = None, m(F)
        # serving: expert tensors are the memory heavyweight (no optimizer
        # state to amortize) — shard F over the data axes instead of D so
        # weights still fit per-chip without contracting-dim partial sums
        # on the gate/up matmuls.
        fd = _d_any(F) if serving and e_ax is not None else f_ax
        layers["moe"] = {
            "router": P(None, d(D), None),
            "w_gate": P(None, e_ax, d(D), fd),
            "w_up": P(None, e_ax, d(D), fd),
            "w_down": P(None, e_ax, fd, d(D)),
        }
        if cfg.n_shared_experts:
            Fs = cfg.n_shared_experts * F
            layers["moe"]["shared_gate"] = P(None, None)
            layers["moe"]["shared_w_gate"] = P(None, d(D), m(Fs))
            layers["moe"]["shared_w_up"] = P(None, d(D), m(Fs))
            layers["moe"]["shared_w_down"] = P(None, m(Fs), d(D))
    elif cfg.d_ff:
        layers["mlp"] = {
            "w_gate": P(None, d(D), m(cfg.d_ff)),
            "w_up": P(None, d(D), m(cfg.d_ff)),
            "w_down": P(None, m(cfg.d_ff), d(D)),
        }
    specs["layers"] = layers
    return specs


def batch_specs(cfg: ModelConfig, mesh: Mesh, *, global_batch: int,
                fsdp_axes: Optional[tuple] = None) -> Dict[str, Any]:
    fa = fsdp_axes if fsdp_axes is not None else FSDP
    dp = _axes(mesh, *fa)
    dpn = int(np.prod([mesh.shape[a] for a in mesh.axis_names if a in fa]))
    b = dp if _div(global_batch, dpn) and dpn > 1 else None
    out = {"tokens": P(b, None)}
    if cfg.n_frontend_embeds:
        out["extra_embeds"] = P(b, None, None)
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, *, batch: int,
                max_len: int = 0) -> Dict[str, Any]:
    """KV/state cache sharding policy:

    - batch divisible by |data axes| → shard batch over them; otherwise
      shard the cache SEQUENCE over "data" (context parallelism — the
      long_500k batch=1 case).
    - kv heads shard over "model" when divisible; otherwise the cache
      sequence shards over "model" (sequence-parallel decode — partial
      softmax + all-reduce, the standard TPU serving layout for GQA models
      whose few kv heads can't fill the TP axis)."""
    dp = _axes(mesh, *FSDP)
    dpn = data_axis_size(mesh)
    mp = _axes(mesh, "model")
    mpn = model_axis_size(mesh)
    batch_sharded = _div(batch, dpn) and dpn > 1
    b = dp if batch_sharded else None
    seq_data = None if batch_sharded else (
        _axes(mesh, "data") if "data" in mesh.axis_names else None)
    specs: Dict[str, Any] = {"pos": P()}
    if cfg.has_attention:
        kv_heads_fit = _div(cfg.n_kv_heads, max(mpn, 1)) and mpn > 1
        kv_ax = mp if kv_heads_fit else None
        seq_model = None if kv_heads_fit else (
            mp if _div(max_len, max(mpn, 1)) and mpn > 1 else None)
        seq_axes = []
        for a in (seq_data, seq_model):
            if a is None:
                continue
            seq_axes.extend(a if isinstance(a, tuple) else (a,))
        seq = (tuple(seq_axes) if len(seq_axes) > 1
               else seq_axes[0] if seq_axes else None)
        specs["k"] = P(None, b, seq, kv_ax, None)
        specs["v"] = P(None, b, seq, kv_ax, None)
    if cfg.has_ssm:
        nh_ax = mp if _div(cfg.ssm_heads, max(mpn, 1)) and mpn > 1 else None
        specs["h"] = P(None, b, nh_ax, None, None)
        specs["conv"] = P(None, b, None, None)
    return specs


def opt_state_specs(param_spec_tree, has_master: bool, compress: bool):
    """AdamWState spec: mu/nu/master mirror the param specs."""
    from ..optim.adamw import AdamWState

    return AdamWState(
        step=P(),
        mu=param_spec_tree,
        nu=param_spec_tree,
        master=param_spec_tree if has_master else None,
        ef=param_spec_tree if compress else None,
    )


def make_activation_constraint(cfg: ModelConfig, mesh: Mesh, *,
                               moe_constraints: bool = False,
                               fsdp_axes: Optional[tuple] = None):
    """The ``ac`` hook threaded through the model: named activation points →
    with_sharding_constraint.  This is where sequence-parallel / TP activation
    layouts are pinned so XLA doesn't invent pathological reshards.

    ``moe_constraints`` pins the MoE dispatch buffers to the EP layout
    (experts over "model", capacity over data) — a §Perf optimization: the
    unconstrained baseline lets SPMD propagation replicate the (E, C, D)
    buffer."""
    fa = fsdp_axes if fsdp_axes is not None else FSDP
    dp = _axes(mesh, *fa)
    mp = _axes(mesh, "model") if fa == FSDP else None
    dpn = int(np.prod([mesh.shape[a] for a in mesh.axis_names if a in fa]))
    mpn = model_axis_size(mesh)

    table = {
        "hidden": P(dp, None, None),
        "residual": P(dp, None, None),
        "q": P(dp, None, mp, None),
        "attn_out": P(dp, None, mp, None),
        "mlp_out": P(dp, None, None),
    }
    if moe_constraints and cfg.is_moe:
        ep = _div(cfg.n_experts, max(mpn, 1)) and mpn > 1
        if ep:
            # experts over model ONLY: the scatter from dp-sharded tokens
            # to mp-sharded expert rows lowers to an all-to-all.  Sharding
            # capacity over data as well was measured to force a massive
            # redistribution (§Perf iteration log) — don't.
            table["moe_buf"] = P(mp, None, None)
        table["moe_tokens"] = P(dp, None)

    def ac(x, name=None):
        spec = table.get(name)
        if spec is None or len(spec) != x.ndim:
            return x
        # NB: internal constraints may be uneven (GSPMD pads) — and padded
        # head sharding measurably beats dropping the constraint (§Perf:
        # removing the uneven q/attn_out pin nearly doubled yi-34b's
        # collective term).  Only jit INPUTS require even shards.
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    return ac


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))
