"""repro.distributed — mesh/sharding policy + compiled-HLO roofline analysis."""

from .analysis import (CollectiveStats, Roofline, parse_collectives,
                       roofline_from_compiled, HBM_BW, ICI_BW, PEAK_FLOPS)
from .sharding import (batch_specs, cache_specs, data_axis_size,
                       make_activation_constraint, model_axis_size, named,
                       opt_state_specs, param_specs)

__all__ = [
    "param_specs", "batch_specs", "cache_specs", "opt_state_specs",
    "make_activation_constraint", "named", "data_axis_size",
    "model_axis_size", "Roofline", "CollectiveStats", "parse_collectives",
    "roofline_from_compiled", "PEAK_FLOPS", "HBM_BW", "ICI_BW",
]
