"""Ambient mesh context: lets deep model code (e.g. the shard_map MoE
dispatch) find the active mesh without threading it through every call."""

from __future__ import annotations

import contextlib
from typing import Optional

from jax.sharding import Mesh

_CURRENT: list = []


def current_mesh() -> Optional[Mesh]:
    return _CURRENT[-1] if _CURRENT else None


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    _CURRENT.append(mesh)
    try:
        yield mesh
    finally:
        _CURRENT.pop()
