"""Roofline analysis from compiled HLO (the dry-run "profiler").

No hardware timers exist in the dry-run; the three roofline terms are
derived from the compiled artifact (EXPERIMENTS.md §Roofline):

    compute    = HLO_FLOPs / peak_FLOPs
    memory     = HLO_bytes / HBM_bw
    collective = Σ per-collective link-bytes / ICI_bw

``cost_analysis()`` provides FLOPs/bytes of the *per-device* partitioned
module; collective bytes are parsed out of the optimized HLO text with the
standard per-algorithm link-byte formulas (ring all-gather moves
out_bytes·(g-1)/g per device, all-reduce twice that, etc.).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

# TPU v5e-like hardware model (per assignment)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (assume 1 link per hop here)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "%all-gather.3 = bf16[8,128,2048]{2,1,0} all-gather(..."
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")(?:-start)?\(")
_TUPLE_OP_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*(" + "|".join(_COLLECTIVES) + r")(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    result_bytes: Dict[str, int] = field(default_factory=dict)
    link_bytes: float = 0.0  # per-device bytes over ICI (algorithm-weighted)

    def total_result_bytes(self) -> int:
        return sum(self.result_bytes.values())


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_V2_RE.search(line)
    if m:  # replica_groups=[G,S]<=[N]: G groups of size S
        return int(m.group(2))
    return default


# A computation header starts at column 0: "%name (" or "ENTRY %name ("
# (ops are indented; params may be nested tuples, so don't match the arrow)
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s+\(")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=(%[\w\.\-]+), body=(%[\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo_text.splitlines():
        if line[:1] in ("%", "E"):  # column-0 header (%name / ENTRY %name)
            m = _COMP_HEADER_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _computation_multipliers(comps: Dict[str, List[str]]) -> Dict[str, float]:
    """Dynamic execution count per computation: while bodies run trip-count
    times (nested whiles multiply).  Trip counts are recovered from the
    largest integer literal in the loop condition (XLA inlines static
    bounds); a body with no recoverable bound gets ×1 (conservative)."""
    # while edges: (enclosing computation) -> (cond, body) with trip count;
    # call edges (fusion bodies, reduce to_apply, conditional branches,
    # calls) propagate the caller's multiplier unchanged.
    while_edges: Dict[str, List[tuple]] = {}
    call_edges: Dict[str, List[str]] = {}
    for name, lines in comps.items():
        for line in lines:
            for cond, body in _WHILE_RE.findall(line):
                while_edges.setdefault(name, []).append((cond, body))
            for callee in _CALLS_RE.findall(line):
                call_edges.setdefault(name, []).append(callee)
            mb = _BRANCHES_RE.search(line)
            if mb:
                for callee in re.findall(r"%[\w\.\-]+", mb.group(1)):
                    call_edges.setdefault(name, []).append(callee)

    def trip_count(cond: str) -> float:
        consts = [int(c) for c in _CONST_RE.findall(
            "\n".join(comps.get(cond, [])))]
        consts = [c for c in consts if c > 1]
        return float(max(consts)) if consts else 1.0

    mult: Dict[str, float] = {name: 1.0 for name in comps}
    # iterate to fixpoint (the computation graph is a DAG; a few passes)
    for _ in range(16):
        changed = False
        for caller, pairs in while_edges.items():
            for cond, body in pairs:
                m = mult.get(caller, 1.0) * trip_count(cond)
                for target in (body, cond):
                    if target in mult and mult[target] < m:
                        mult[target] = m
                        changed = True
        for caller, callees in call_edges.items():
            m = mult.get(caller, 1.0)
            for target in callees:
                if target in mult and mult[target] < m:
                    mult[target] = m
                    changed = True
        if not changed:
            break
    return mult


_CALLS_RE = re.compile(r"(?:calls=|to_apply=)(%[\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branches=\{([^}]*)\}")
_DEF_RE = re.compile(r"^\s*(%[\w\.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"\((%[\w\.\-]+(?:,\s*%[\w\.\-]+)*)\)")
_DIMS_ATTR_RE = {
    "lhs_c": re.compile(r"lhs_contracting_dims=\{([\d,]*)\}"),
    "lhs_b": re.compile(r"lhs_batch_dims=\{([\d,]*)\}"),
}
_OPCODE_RE = re.compile(
    r"\}\s*([a-z][a-z0-9\-]*)\(|\s([a-z][a-z0-9\-]*)\(%")

# ops that do not touch HBM (metadata / layout only)
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota"}


def _parse_shapes(text_after_eq: str):
    """All shapes on the RHS of an '=' (tuple results give several)."""
    head = text_after_eq.split("(", 1)[0]
    return [( d, s) for d, s in _SHAPE_RE.findall(head)]


def _bytes_of(shapes) -> int:
    return sum(_shape_bytes(d, s) for d, s in shapes)


class HloProgram:
    """While-aware FLOP/byte accounting parsed from optimized HLO text.

    ``cost_analysis()`` visits while bodies ONCE (verified empirically), so a
    scanned L-layer model under-reports by ~L×.  This analyzer multiplies
    every op by its computation's dynamic execution count (trip counts
    recovered from loop-condition constants) and resolves operand shapes for
    dot FLOPs.  Fusion-body internals are excluded from byte accounting (the
    fusion call line carries the HBM traffic)."""

    def __init__(self, hlo_text: str):
        self.comps = _split_computations(hlo_text)
        self.mults = _computation_multipliers(self.comps)
        # name → shapes, name → opcode line index
        self.shapes: Dict[str, list] = {}
        self.fusion_bodies: set = set()
        self.slicing_fusions: set = set()  # callees containing dyn-slice/DUS
        for name, lines in self.comps.items():
            for line in lines:
                m = _DEF_RE.match(line)
                if not m:
                    continue
                self.shapes[m.group(1)] = _parse_shapes(m.group(2))
                if "fusion(" in line or "custom-call" in line:
                    for callee in _CALLS_RE.findall(line):
                        self.fusion_bodies.add(callee)
        for name, lines in self.comps.items():
            body = "\n".join(lines)
            if "dynamic-slice(" in body or "dynamic-update-slice(" in body:
                self.slicing_fusions.add(name)
        # trip count of the innermost enclosing while loop per computation
        # (for slice-aware byte accounting of stacked scan buffers):
        # while bodies get their own trip; fusions called from a body
        # inherit it.
        self.trips: Dict[str, float] = {}
        call_edges: Dict[str, List[str]] = {}
        for name, lines in self.comps.items():
            for line in lines:
                for cond, body in _WHILE_RE.findall(line):
                    consts = [int(c) for c in _CONST_RE.findall(
                        "\n".join(self.comps.get(cond, [])))]
                    consts = [c for c in consts if c > 1]
                    if consts:
                        t = float(max(consts))
                        self.trips[body] = t
                        self.trips[cond] = t
                for callee in _CALLS_RE.findall(line):
                    call_edges.setdefault(name, []).append(callee)
        for _ in range(8):  # propagate caller trips to callees (fixpoint)
            changed = False
            for caller, callees in call_edges.items():
                t = self.trips.get(caller)
                if t is None:
                    continue
                for c in callees:
                    if c not in self.trips:
                        self.trips[c] = t
                        changed = True
            if not changed:
                break
        # computation params: "%name (p: f32[..], q: (s32[], ...)) -> ..."
        # parameters also appear as "parameter(N)" op lines inside bodies,
        # which the loop above already captured.

    # ------------------------------------------------------------- flops
    def _dot_flops(self, line: str) -> float:
        m = _DEF_RE.match(line)
        if not m:
            return 0.0
        result = _parse_shapes(m.group(2))
        out_elems = 1
        for d, s in result:
            for x in s.split(","):
                if x:
                    out_elems *= int(x)
        ops = _OPND_RE.search(line)
        k = 1
        if ops:
            lhs = ops.group(1).split(",")[0].strip()
            lhs_shapes = self.shapes.get(lhs)
            mc = _DIMS_ATTR_RE["lhs_c"].search(line)
            if lhs_shapes and mc and mc.group(1):
                dims = [int(x) for x in mc.group(1).split(",") if x]
                lhs_dims = [int(x) for x in lhs_shapes[0][1].split(",") if x]
                for d in dims:
                    if d < len(lhs_dims):
                        k *= lhs_dims[d]
        return 2.0 * out_elems * k

    def flops_bytes(self) -> tuple:
        """(flops, hbm_bytes) per device, while-aware."""
        flops = 0.0
        nbytes = 0.0
        for comp, lines in self.comps.items():
            w = self.mults.get(comp, 1.0)
            in_fusion = comp in self.fusion_bodies
            for line in lines:
                m = _DEF_RE.match(line)
                if not m:
                    continue
                rest = m.group(2)
                if " dot(" in rest or rest.startswith("dot("):
                    flops += w * self._dot_flops(line)
                elif "convolution(" in rest:
                    # approximate: 2 × out × (kernel elems) — convs here are
                    # tiny depthwise causal convs
                    out_shapes = _parse_shapes(rest)
                    flops += w * 2.0 * _bytes_of(out_shapes)
                if in_fusion:
                    continue  # fusion internals: no HBM traffic
                mop = re.search(r"(?:^|\s|\))([a-z][a-z0-9\-]+)\(", rest)
                opcode = mop.group(1) if mop else ""
                if opcode in _FREE_OPS or opcode.endswith("-done"):
                    continue
                # slice-aware charging: an op (or fusion) that dynamic-
                # slices/updates a stacked scan buffer touches one slice per
                # iteration, not the whole (trip, ...) stack.
                trip = self.trips.get(comp, 1.0)
                slicing = ("dynamic-slice" in rest
                           or "dynamic-update-slice" in rest)
                if not slicing and opcode == "fusion":
                    for callee in _CALLS_RE.findall(rest):
                        if callee in self.slicing_fusions:
                            slicing = True
                            break

                def charge(shapes_list) -> float:
                    b = 0.0
                    for d, s in shapes_list:
                        sz = _shape_bytes(d, s)
                        lead = int(s.split(",")[0]) if s else 0
                        if slicing and trip > 1 and lead == int(trip):
                            sz = sz / trip  # one slice of the stack
                        b += sz
                    return b

                rbytes2 = charge(_parse_shapes(rest))
                obytes = 0.0
                opnds = _OPND_RE.search(rest)
                if opnds:
                    for nm in opnds.group(1).split(","):
                        obytes += charge(self.shapes.get(nm.strip(), []))
                nbytes += w * (rbytes2 + obytes)
        return flops, nbytes


def parse_collectives(hlo_text: str, *, n_devices: int) -> CollectiveStats:
    """Sum result bytes + per-device link bytes of every collective op,
    weighted by dynamic execution count (while-loop trip counts) — a
    collective inside the layer scan counts n_layers times."""
    comps = _split_computations(hlo_text)
    mults = _computation_multipliers(comps)
    stats = CollectiveStats()
    for comp_name, lines in comps.items():
        weight = mults.get(comp_name, 1.0)
        for line in lines:
            kind: Optional[str] = None
            rbytes = 0
            # tuple results FIRST — _OP_RE would otherwise match only the
            # first tuple element and undercount bundled collectives
            mt = _TUPLE_OP_RE.search(line)
            if mt:
                kind = mt.group(2)
                rbytes = sum(_shape_bytes(d, s)
                             for d, s in _SHAPE_RE.findall(mt.group(1)))
            else:
                m = _OP_RE.search(line)
                if m:
                    kind = m.group(3)
                    rbytes = _shape_bytes(m.group(1), m.group(2))
            if kind is None or "-done" in line:
                continue
            g = _group_size(line, n_devices)
            frac = (g - 1) / g if g > 1 else 0.0
            if kind == "all-reduce":
                link = 2.0 * rbytes * frac  # reduce-scatter + all-gather
            elif kind == "all-gather":
                link = rbytes * frac        # rbytes = gathered output
            elif kind == "reduce-scatter":
                link = rbytes * (g - 1)     # rbytes = scattered shard
            elif kind == "all-to-all":
                link = rbytes * frac
            else:  # collective-permute
                link = float(rbytes)
            stats.counts[kind] = stats.counts.get(kind, 0) + int(weight)
            stats.result_bytes[kind] = (stats.result_bytes.get(kind, 0)
                                        + int(rbytes * weight))
            stats.link_bytes += link * weight
    return stats


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_link_bytes: float
    n_devices: int
    model_flops_total: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_link_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lb(self) -> float:
        """Roofline lower bound: no overlap assumption → max of terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips) — remat/dispatch waste detector."""
        total_hlo = self.flops_per_device * self.n_devices
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def mfu_bound(self) -> float:
        """Upper bound on achievable MFU: useful flops / (peak × step LB)."""
        denom = self.step_time_lb * PEAK_FLOPS * self.n_devices
        return self.model_flops_total / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_link_bytes": self.collective_link_bytes,
            "n_devices": self.n_devices,
            "model_flops_total": self.model_flops_total,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_lb": self.step_time_lb,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def roofline_from_compiled(compiled, *, n_devices: int,
                           model_flops_total: float = 0.0,
                           hlo_text: Optional[str] = None) -> Roofline:
    """Three roofline terms from the compiled per-device module.

    FLOPs/bytes come from the while-aware text analyzer (``HloProgram``) —
    ``cost_analysis()`` visits loop bodies once and under-reports scanned
    models by ~n_layers× (verified; raw values still recorded upstream)."""
    text = hlo_text if hlo_text is not None else compiled.as_text()
    prog = HloProgram(text)
    flops, nbytes = prog.flops_bytes()
    stats = parse_collectives(text, n_devices=n_devices)
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=nbytes,
        collective_link_bytes=stats.link_bytes,
        n_devices=n_devices,
        model_flops_total=model_flops_total,
    ), stats
