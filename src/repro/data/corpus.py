"""Synthetic corpus generation — deterministic from a seed.

The framework must own its whole data substrate (no external downloads);
documents are drawn from a seeded Zipfian vocabulary with paragraph
structure, enough statistical texture for LM training examples and fully
reproducible: the same seed always yields byte-identical tables, so corpus
regeneration and catalog content addressing agree.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

EOS = 0  # reserved token ids
BOS = 1
PAD = 2
FIRST_WORD = 3


def generate_documents(*, n_docs: int, seed: int, vocab_size: int,
                       mean_len: int = 512) -> Dict[str, np.ndarray]:
    """Token-id documents with Zipf unigram stats + Markov bigram structure.
    Returns columns {doc_id, tokens (ragged → fixed width with PAD), length}.
    """
    rng = np.random.default_rng(seed)
    n_words = vocab_size - FIRST_WORD
    # Zipf over the word portion of the vocab
    ranks = np.arange(1, n_words + 1, dtype=np.float64)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()

    lengths = np.clip(rng.poisson(mean_len, size=n_docs), 16,
                      4 * mean_len).astype(np.int32)
    width = int(lengths.max())
    tokens = np.full((n_docs, width), PAD, dtype=np.int32)
    # cheap bigram structure: next ~ 0.7 fresh zipf, 0.3 (prev*7+3) mod words
    for i in range(n_docs):
        L = lengths[i]
        fresh = rng.choice(n_words, size=L, p=probs)
        mix = rng.random(L) < 0.3
        toks = fresh.copy()
        toks[1:][mix[1:]] = (toks[:-1][mix[1:]] * 7 + 3) % n_words
        tokens[i, :L] = toks + FIRST_WORD
    return {
        "doc_id": np.arange(n_docs, dtype=np.int64),
        "tokens": tokens,
        "length": lengths,
    }
