"""repro.data — deterministic, catalog-backed data pipeline."""

from .corpus import BOS, EOS, PAD, generate_documents
from .loader import DeterministicLoader, batch_rows, permuted_index
from .pipeline import (build_data_pipeline, packing_node, seed_corpus,
                       stats_node)

__all__ = ["generate_documents", "EOS", "BOS", "PAD",
           "DeterministicLoader", "batch_rows", "permuted_index",
           "build_data_pipeline", "packing_node", "stats_node",
           "seed_corpus"]
