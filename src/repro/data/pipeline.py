"""The training data pipeline AS a catalog pipeline (the paper's technique
applied to the training substrate).

Stages are ``@model`` nodes — raw_docs → packed_{seq} — materialized as
tables on a branch, so every training run's input data is an immutable
commit: replaying a training run replays its exact token stream.
"""

from __future__ import annotations

import numpy as np

from ..core import Model, Pipeline, model
from . import corpus


def packing_node(seq_len: int, *, name: str = "packed"):
    """Pack ragged documents into dense (rows, seq_len) training sequences:
    concat with EOS separators, chunk, drop the ragged tail."""

    @model(name=name)
    def packed(docs=Model("raw_docs")):
        toks, lengths = docs["tokens"], docs["length"]
        flat = np.empty(int(lengths.sum()) + len(lengths), np.int32)
        off = 0
        for row, L in zip(toks, lengths):
            flat[off:off + L] = row[:L]
            flat[off + L] = corpus.EOS
            off += L + 1
        n_rows = off // seq_len
        seqs = flat[:n_rows * seq_len].reshape(n_rows, seq_len)
        return {"tokens": seqs,
                "seq_id": np.arange(n_rows, dtype=np.int64)}

    return packed


def stats_node(src: str = "packed"):
    """Data-quality stats table consumed by WAP expectations."""

    @model(name="data_stats")
    def data_stats(packed=Model(src)):
        t = packed["tokens"]
        return {
            "n_rows": np.array([t.shape[0]], np.int64),
            "seq_len": np.array([t.shape[1]], np.int64),
            "min_token": np.array([t.min()], np.int64),
            "max_token": np.array([t.max()], np.int64),
            "eos_frac": np.array([(t == corpus.EOS).mean()], np.float64),
        }

    return data_stats


def build_data_pipeline(seq_len: int) -> Pipeline:
    return Pipeline([packing_node(seq_len), stats_node()])


def seed_corpus(lake, branch: str, *, n_docs: int, seed: int,
                vocab_size: int, mean_len: int = 512, author="system"):
    """Land the raw corpus on a branch (the 'source_table' of Fig. 1)."""
    docs = corpus.generate_documents(n_docs=n_docs, seed=seed,
                                     vocab_size=vocab_size,
                                     mean_len=mean_len)
    return lake.write_table(branch, "raw_docs", docs, author=author,
                            message=f"raw corpus seed={seed} n={n_docs}")
