"""Stateless deterministic batch indexing.

Iterator state is ONE integer (the step): batch membership is a pure
function of (seed, epoch, step), via a Feistel permutation of row indices.
This is what lets a checkpoint commit capture the data-iterator state as a
single number and resume bit-exactly — and lets any worker (or a restarted
one) compute its shard of any batch without coordination.
"""

from __future__ import annotations

from typing import Dict, Iterator, Tuple

import numpy as np


def _feistel(x: np.ndarray, n_rounds: int, k0: int,
             half_bits: int, mask: np.uint32) -> np.ndarray:
    """Format-preserving permutation over [0, 2^(2*half_bits))."""
    l = (x >> np.uint32(half_bits)) & mask
    r = x & mask
    for i in range(n_rounds):
        key = np.uint32((k0 + i * 0x9E3779B1) & 0xFFFFFFFF)
        f = r * np.uint32(0x85EBCA6B) + key
        f ^= f >> np.uint32(13)
        f = (f * np.uint32(0xC2B2AE35)) & mask
        l, r = r, (l ^ f) & mask
    return (l << np.uint32(half_bits)) | r


def permuted_index(i: np.ndarray, n: int, seed: int,
                   epoch: int) -> np.ndarray:
    """Pseudorandom permutation of [0, n), evaluated pointwise.

    Cycle-walking a Feistel network: ONLY out-of-range values are
    re-encrypted, so the restriction to [0, n) is a true bijection.
    Domain size is < 4n ⇒ expected walk length < 4.
    """
    bits = max(2, int(np.ceil(np.log2(max(n, 2)))))
    half = (bits + 1) // 2
    mask = np.uint32((1 << half) - 1)
    k0 = (seed * 1_000_003 + epoch) & 0xFFFFFFFF
    out = _feistel(np.asarray(i, np.uint32), 4, k0, half, mask)
    for _ in range(256):
        oor = out >= n
        if not oor.any():
            break
        out = np.where(oor, _feistel(out, 4, k0, half, mask), out)
    else:  # pragma: no cover — walk lengths this long are impossible
        raise RuntimeError("cycle walk did not terminate")
    return out.astype(np.int64)


def batch_rows(step: int, *, n_rows: int, global_batch: int,
               seed: int) -> Tuple[np.ndarray, int]:
    """Row ids of batch ``step`` (+ the epoch it falls in)."""
    batches_per_epoch = max(n_rows // global_batch, 1)
    epoch = step // batches_per_epoch
    within = step % batches_per_epoch
    base = within * global_batch + np.arange(global_batch)
    rows = permuted_index(base % n_rows, n_rows, seed, epoch)
    return rows, epoch


class DeterministicLoader:
    """Batches from a materialized packed table (host → device feed)."""

    def __init__(self, tokens: np.ndarray, *, global_batch: int, seed: int):
        self.tokens = tokens
        self.global_batch = global_batch
        self.seed = seed

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rows, epoch = batch_rows(step, n_rows=self.tokens.shape[0],
                                 global_batch=self.global_batch,
                                 seed=self.seed)
        return {"tokens": self.tokens[rows], "rows": rows,
                "epoch": np.int64(epoch)}

    def iterate(self, start_step: int, n_steps: int
                ) -> Iterator[Dict[str, np.ndarray]]:
        for s in range(start_step, start_step + n_steps):
            yield self.batch(s)
