"""Fault-tolerant trainer: the paper's replayable-pipeline semantics wrapped
around a JAX training loop.

Run anatomy (Fig. 4 of the paper, applied to training):
  - every run gets its own branch ``<user>.run-<name>`` forked from the data
    branch (copy-on-write — the corpus is never copied);
  - checkpoints are commits on that branch (step + iterator state + digest);
  - a crash (or injected failure) resumes by checking out the latest
    checkpoint commit — bit-exact thanks to the stateless loader;
  - at the end, metrics tables go through write-audit-publish before the
    run branch is merged into the target branch;
  - the run manifest (code/config/data/hardware) lands in the ledger so
    ``replay(run_id)`` can reproduce the whole run later.

Straggler mitigation: a host-side watchdog tracks step wall-times; steps
slower than ``straggler_factor ×`` the running median are counted and logged
to the metrics table (on a real pod the same hook triggers re-dispatch /
slice exclusion; in simulation it is observability + a tested interface).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from .. import checkpoint as ckpt
from ..core import Lake, Pipeline, not_empty, no_nans, publish
from ..core.wap import Expectation
from ..data.loader import DeterministicLoader
from ..models.config import ModelConfig
from ..optim import adamw
from .steps import build_train_step


@dataclass
class TrainerConfig:
    arch: str
    seq_len: int
    global_batch: int
    n_steps: int
    ckpt_every: int = 50
    seed: int = 0
    schedule: str = "cosine"
    schedule_kw: Optional[dict] = None
    opt: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)
    straggler_factor: float = 3.0
    author: str = "trainer"


@dataclass
class StepRecord:
    step: int
    loss: float
    wall_ms: float
    straggler: bool


class Trainer:
    def __init__(self, lake: Lake, cfg: ModelConfig, tcfg: TrainerConfig,
                 *, data_branch: str, run_name: str,
                 mesh=None, ac=None, failure_at: Optional[int] = None):
        self.lake = lake
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.run_branch = f"{tcfg.author}.run-{run_name}"
        self.data_branch = data_branch
        self.failure_at = failure_at  # fault-injection hook (tests)
        self.records: List[StepRecord] = []
        self.straggler_events = 0

        if self.run_branch not in lake.catalog.branches():
            lake.catalog.create_branch(self.run_branch, data_branch,
                                       author=tcfg.author)
        packed = lake.read_table(self.run_branch, "packed")
        self.loader = DeterministicLoader(
            packed["tokens"], global_batch=tcfg.global_batch, seed=tcfg.seed)
        self.train_step = jax.jit(build_train_step(
            cfg, opt_config=tcfg.opt, schedule=tcfg.schedule,
            schedule_kw=tcfg.schedule_kw,
            ac=ac if ac is not None else (lambda x, name=None: x)))

    # ---------------------------------------------------------------- state
    def init_state(self):
        from ..models import init_params

        key = jax.random.PRNGKey(self.tcfg.seed)
        params = init_params(self.cfg, key)
        opt_state = adamw.init(params, self.tcfg.opt)
        return params, opt_state, 0

    def restore_state(self):
        """Resume from the newest checkpoint commit on the run branch."""
        commit = ckpt.latest_checkpoint(self.lake, self.run_branch)
        if commit is None:
            return self.init_state()
        params, opt_cols, meta = ckpt.restore(self.lake, commit)
        template = adamw.init(params, self.tcfg.opt)
        tables = self.lake.catalog.tables(commit)
        opt_state = template
        if "ckpt_opt" in tables:
            cols = self.lake.io.read(tables["ckpt_opt"])
            opt_state = ckpt.restore_into(template, cols)
        return params, opt_state, int(meta["step"])

    # ----------------------------------------------------------------- loop
    def run(self, *, resume: bool = False) -> Dict[str, Any]:
        params, opt_state, start_step = (self.restore_state() if resume
                                         else self.init_state())
        median_tracker: List[float] = []
        for step in range(start_step, self.tcfg.n_steps):
            if self.failure_at is not None and step == self.failure_at:
                self.failure_at = None  # next attempt survives
                raise RuntimeError(f"injected node failure at step {step}")
            t0 = time.perf_counter()
            batch = {"tokens": jax.numpy.asarray(
                self.loader.batch(step)["tokens"])}
            params, opt_state, metrics = self.train_step(params, opt_state,
                                                         batch)
            loss = float(metrics["loss"])
            wall = (time.perf_counter() - t0) * 1e3
            median_tracker.append(wall)
            med = float(np.median(median_tracker[-32:]))
            straggler = len(median_tracker) > 4 and \
                wall > self.tcfg.straggler_factor * med
            if straggler:
                self.straggler_events += 1
            self.records.append(StepRecord(step, loss, wall, straggler))

            if (step + 1) % self.tcfg.ckpt_every == 0 \
                    or step + 1 == self.tcfg.n_steps:
                ckpt.save(self.lake, self.run_branch, step=step + 1,
                          params=params, opt_state=opt_state,
                          author=self.tcfg.author,
                          extra_meta={"loader_seed": self.tcfg.seed,
                                      "straggler_events":
                                          self.straggler_events})
        self._write_metrics()
        return {"params": params, "opt_state": opt_state,
                "final_step": self.tcfg.n_steps,
                "losses": [r.loss for r in self.records]}

    def _write_metrics(self):
        recs = self.records
        if not recs:
            return
        self.lake.write_table(
            self.run_branch, "train_metrics",
            {
                "step": np.array([r.step for r in recs], np.int64),
                "loss": np.array([r.loss for r in recs], np.float64),
                "wall_ms": np.array([r.wall_ms for r in recs], np.float64),
                "straggler": np.array([r.straggler for r in recs], np.bool_),
            },
            author=self.tcfg.author, message="training metrics")

    # ------------------------------------------------------------------ WAP
    def default_expectations(self) -> List[Expectation]:
        from ..core import expectation

        @expectation("train_metrics", name="loss_finite")
        def loss_finite(f):
            return bool(np.isfinite(f["loss"]).all())

        @expectation("train_metrics", name="loss_decreased")
        def loss_decreased(f):
            loss = f["loss"]
            k = max(len(loss) // 5, 1)
            return float(loss[-k:].mean()) < float(loss[:k].mean())

        return [not_empty("train_metrics"), loss_finite, loss_decreased]

    def publish(self, dst_branch: str = "main",
                expectations: Optional[List[Expectation]] = None) -> str:
        """Write-Audit-Publish the run branch (checkpoints + metrics)."""
        return publish(self.lake.catalog, self.lake.io, self.run_branch,
                       expectations or self.default_expectations(),
                       dst_branch=dst_branch, author=self.tcfg.author)
