"""repro.runtime — step builders + fault-tolerant trainer."""

from .steps import (build_decode_step, build_prefill_step, build_train_step,
                    input_specs, synthetic_batch)
from .trainer import StepRecord, Trainer, TrainerConfig

__all__ = ["build_train_step", "build_prefill_step", "build_decode_step",
           "input_specs", "synthetic_batch", "Trainer", "TrainerConfig",
           "StepRecord"]
