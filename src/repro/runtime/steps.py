"""Jit-ready train / prefill / decode step builders.

These are the terminal DAG nodes of a training/serving pipeline (paper §2:
"running P is the composition of transformations") — and exactly what the
multi-pod dry-run lowers and compiles.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..models import config as mcfg
from ..models import lm
from ..optim import adamw
from ..optim.schedules import SCHEDULES


def build_train_step(cfg: mcfg.ModelConfig, *,
                     opt_config: adamw.AdamWConfig = adamw.AdamWConfig(),
                     schedule: str = "cosine",
                     schedule_kw: Optional[dict] = None,
                     ac: Callable = lm.Identity,
                     remat: bool = True):
    """(params, opt_state, batch) → (params, opt_state, metrics)."""
    skw = dict(schedule_kw or {"peak_lr": 3e-4, "warmup_steps": 100,
                               "total_steps": 10_000})
    sched = SCHEDULES[schedule]

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return lm.lm_loss(cfg, p, batch["tokens"],
                              batch.get("extra_embeds"), ac=ac, remat=remat)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        lr = sched(opt_state.step, **skw)
        params, opt_state, opt_metrics = adamw.apply(
            grads, opt_state, params, lr=lr, config=opt_config)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["total_loss"] = loss
        return params, opt_state, metrics

    return train_step


def build_prefill_step(cfg: mcfg.ModelConfig, *, max_len: int,
                       ac: Callable = lm.Identity):
    """(params, tokens, cache) → (last_logits, cache)."""

    def prefill_step(params, tokens, cache, extra_embeds=None):
        logits, cache, _ = lm.forward(cfg, params, tokens, extra_embeds,
                                      ac=ac, cache=cache, pos=0, remat=True)
        return logits[:, -1, :], cache

    return prefill_step


def build_decode_step(cfg: mcfg.ModelConfig, *, ac: Callable = lm.Identity,
                      greedy: bool = True):
    """(params, token, cache) → (next_token, logits, cache)."""
    serve_cfg = cfg.with_(capacity_factor=-1.0) if cfg.is_moe else cfg

    def decode_one(params, token, cache):
        logits, cache = lm.decode_step(serve_cfg, params, token, cache, ac=ac)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, cache

    return decode_one


def synthetic_batch(cfg: mcfg.ModelConfig, *, batch: int, seq: int,
                    key=None) -> Dict[str, Any]:
    """Materialized random batch (smoke tests / examples)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    out = {"tokens": jax.random.randint(k1, (batch, seq), 0,
                                        cfg.vocab_size, dtype=jnp.int32)}
    if cfg.n_frontend_embeds:
        out["extra_embeds"] = jax.random.normal(
            k2, (batch, cfg.n_frontend_embeds, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    return out


def input_specs(cfg: mcfg.ModelConfig, shape: mcfg.ShapeConfig
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a grid cell —
    weak-type-correct, shardable, zero allocation (dry-run contract)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.is_decode:
        out = {"token": jax.ShapeDtypeStruct((B,), jnp.int32)}
        return out
    out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.n_frontend_embeds:
        out["extra_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frontend_embeds, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    return out
