"""repro.checkpoint — checkpoints ARE catalog commits (the paper's technique
applied to model state).

A checkpoint is a multi-table transaction on the run branch:
  ``ckpt_params``    one row per leaf (leaf path → (1, *shape) column)
  ``ckpt_opt``       optimizer state the same way
plus commit metadata {step, data iterator state, mesh fingerprint, digest}.

Consequences inherited from the catalog (DESIGN.md §2):
 - restart = checkout: restore the branch head (or ANY historical commit);
 - unchanged leaves dedup by content address (free CoW across checkpoints);
 - a training run's checkpoints, metrics and input data live in one ref
   graph — `replay(run_id)` pins all of them at once;
 - async save: serialization + commit happen on a host thread off the
   critical path (the device→host copy is the only sync part).
"""

from .saver import (CheckpointManager, columns_to_tree, latest_checkpoint,
                    leaves_to_columns, restore, restore_into, save)

__all__ = ["save", "restore", "restore_into", "latest_checkpoint",
           "CheckpointManager", "leaves_to_columns", "columns_to_tree"]
