"""Checkpoint save/restore through the catalog + elastic resharding."""

from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from ..core import Lake
from ..kernels.fingerprint.ops import tree_digest_hex

_SEP = "/"


def _path_str(path) -> str:
    try:
        return jax.tree_util.keystr(path, simple=True, separator=_SEP)
    except TypeError:  # jax < 0.5: keystr has no simple/separator kwargs
        parts = []
        for k in path:
            for attr in ("key", "idx", "name"):
                if hasattr(k, attr):
                    parts.append(str(getattr(k, attr)))
                    break
            else:
                parts.append(str(k))
        return _SEP.join(parts)


def leaves_to_columns(tree) -> Dict[str, np.ndarray]:
    """Pytree → single-row columns: leaf path → (1, *shape) array."""
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        arr = np.asarray(leaf)
        out[_path_str(path)] = arr[None, ...]
    return out


def columns_to_tree(cols: Dict[str, np.ndarray]) -> Dict[str, Any]:
    """Inverse of ``leaves_to_columns`` for dict-of-dict trees."""
    root: Dict[str, Any] = {}
    for name, arr in cols.items():
        parts = name.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr[0]
    return root


def restore_into(template, cols: Dict[str, np.ndarray]):
    """Rebuild a TYPED pytree (NamedTuples etc.) from saved columns using the
    template's structure: each template leaf is replaced by the column at the
    same keypath.  Template leaf values are never read — only structure."""
    paths = [_path_str(p)
             for p, _ in jax.tree_util.tree_leaves_with_path(template)]
    missing = [p for p in paths if p not in cols]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
    leaves = [cols[p][0] for p in paths]
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(lake: Lake, branch: str, *, step: int, params, opt_state=None,
         author: str = "system", extra_meta: Optional[dict] = None,
         digest: bool = True) -> str:
    """Commit a checkpoint (one multi-table transaction). Returns commit."""
    updates = {"ckpt_params": lake.io.write_snapshot(
        leaves_to_columns(params))}
    if opt_state is not None:
        updates["ckpt_opt"] = lake.io.write_snapshot(
            leaves_to_columns(opt_state))
    meta = {"step": int(step), **(extra_meta or {})}
    if digest:
        # device-side content digest (fingerprint kernel) — integrity check
        meta["params_digest"] = tree_digest_hex(params)
    return lake.catalog.commit(branch, updates, f"checkpoint step={step}",
                               author=author, meta={"checkpoint": meta})


def restore(lake: Lake, ref: str, *, mesh=None, param_specs=None,
            opt_specs=None, verify: bool = False
            ) -> Tuple[dict, Optional[Any], dict]:
    """Load (params, opt_state, meta) from a commit.

    Elastic resharding: arrays are stored layout-free; passing
    ``mesh``+``param_specs`` lays them onto WHATEVER mesh is alive now
    (restore after scaling from 512 → 256 chips is the same code path).
    """
    commit = lake.catalog.commit_info(ref)
    meta = commit.meta.get("checkpoint", {})
    tables = lake.catalog.tables(ref)
    params = columns_to_tree(lake.io.read(tables["ckpt_params"]))
    opt_state = None
    if "ckpt_opt" in tables:
        opt_state = columns_to_tree(lake.io.read(tables["ckpt_opt"]))
    if mesh is not None and param_specs is not None:
        from ..distributed.sharding import named

        shardings = named(mesh, param_specs)
        params = jax.tree.map(jax.device_put, params, shardings)
        if opt_state is not None and opt_specs is not None:
            opt_state = jax.tree.map(jax.device_put, opt_state,
                                     named(mesh, opt_specs))
    if verify and "params_digest" in meta:
        actual = tree_digest_hex(params)
        if actual != meta["params_digest"]:
            raise ValueError(
                f"checkpoint digest mismatch: {actual} != "
                f"{meta['params_digest']}")
    return params, opt_state, meta


def latest_checkpoint(lake: Lake, branch: str) -> Optional[str]:
    """Newest commit on the branch that carries checkpoint metadata."""
    for digest in lake.catalog.log(branch):
        if "checkpoint" in lake.catalog.commit_info(digest).meta:
            return digest
    return None


class CheckpointManager:
    """Async checkpointing: the device→host copy happens on the caller
    thread (cheap, one HBM read), serialization + commit on a worker thread
    — the distributed-training "don't stall the step loop" optimization."""

    def __init__(self, lake: Lake, branch: str, *, author: str = "system",
                 keep_last: int = 0):
        self.lake = lake
        self.branch = branch
        self.author = author
        self._queue: "queue.Queue" = queue.Queue()
        self._errors: list = []
        self._commits: list = []
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    def _run(self):
        while True:
            item = self._queue.get()
            if item is None:
                self._queue.task_done()
                return
            step, params_host, opt_host, extra = item
            try:
                c = save(self.lake, self.branch, step=step,
                         params=params_host, opt_state=opt_host,
                         author=self.author, extra_meta=extra)
                self._commits.append((step, c))
            except Exception as e:  # surfaced on wait()
                self._errors.append(e)
            finally:
                self._queue.task_done()

    def submit(self, *, step: int, params, opt_state=None,
               extra_meta: Optional[dict] = None):
        # synchronous part: pull to host memory (jax arrays → np)
        params_host = jax.tree.map(np.asarray, params)
        opt_host = (jax.tree.map(np.asarray, opt_state)
                    if opt_state is not None else None)
        self._queue.put((step, params_host, opt_host, extra_meta or {}))

    def wait(self):
        """Block until every submitted checkpoint is committed."""
        self._queue.join()
        if self._errors:
            raise self._errors[0]
        return list(self._commits)

    def close(self):
        self._queue.put(None)
        self._worker.join()
        if self._errors:
            raise self._errors[0]
