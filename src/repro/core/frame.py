"""Minimal columnar-frame expression layer for declarative (SQL-style) nodes.

The paper's Listing 1 is a SQL node; the point it demonstrates is *declarative
multi-language nodes with implicit parents*, not SQL parsing.  We keep the
declarative power (projection + row filter over named columns) as a small
expression tree whose canonical form is hashable — so SQL-style nodes get the
same code-versioning guarantees as Python nodes.
"""

from __future__ import annotations

from typing import Any, Dict, List, Mapping

import numpy as np

from .errors import SchemaError

Frame = Dict[str, np.ndarray]


class Expr:
    """Tiny expression tree over columns: ``col('x') > 5 & col('y') == 0``."""

    def __init__(self, op: str, args: tuple):
        self.op = op
        self.args = args

    # -- construction sugar -------------------------------------------------
    def _bin(self, op: str, other) -> "Expr":
        return Expr(op, (self, _lift(other)))

    def __add__(self, o):
        return self._bin("add", o)

    def __sub__(self, o):
        return self._bin("sub", o)

    def __mul__(self, o):
        return self._bin("mul", o)

    def __truediv__(self, o):
        return self._bin("div", o)

    def __gt__(self, o):
        return self._bin("gt", o)

    def __ge__(self, o):
        return self._bin("ge", o)

    def __lt__(self, o):
        return self._bin("lt", o)

    def __le__(self, o):
        return self._bin("le", o)

    def __eq__(self, o):  # type: ignore[override]
        return self._bin("eq", o)

    def __ne__(self, o):  # type: ignore[override]
        return self._bin("ne", o)

    def __and__(self, o):
        return self._bin("and", o)

    def __or__(self, o):
        return self._bin("or", o)

    def __invert__(self):
        return Expr("not", (self,))

    def __hash__(self):  # Exprs go into canonical specs
        return hash(self.canonical())

    # -- evaluation / canonicalization --------------------------------------
    def evaluate(self, frame: Mapping[str, np.ndarray]) -> np.ndarray:
        return _eval(self, frame)

    def canonical(self) -> str:
        return _canon(self)


def col(name: str) -> Expr:
    return Expr("col", (name,))


def lit(value: Any) -> Expr:
    return Expr("lit", (value,))


def _lift(x) -> Expr:
    return x if isinstance(x, Expr) else lit(x)


_BINOPS = {
    "add": np.add, "sub": np.subtract, "mul": np.multiply,
    "div": np.divide, "gt": np.greater, "ge": np.greater_equal,
    "lt": np.less, "le": np.less_equal, "eq": np.equal, "ne": np.not_equal,
    "and": np.logical_and, "or": np.logical_or,
}


def _eval(e: Expr, frame: Mapping[str, np.ndarray]) -> np.ndarray:
    if e.op == "col":
        name = e.args[0]
        if name not in frame:
            raise SchemaError(f"unknown column {name!r}")
        return np.asarray(frame[name])
    if e.op == "lit":
        return np.asarray(e.args[0])
    if e.op == "not":
        return np.logical_not(_eval(e.args[0], frame))
    if e.op in _BINOPS:
        return _BINOPS[e.op](_eval(e.args[0], frame), _eval(e.args[1], frame))
    raise SchemaError(f"unknown expr op {e.op!r}")


def _canon(e: Expr) -> str:
    if e.op in ("col", "lit"):
        return f"{e.op}({e.args[0]!r})"
    return f"{e.op}({','.join(_canon(a) for a in e.args)})"


def select(frame: Frame, columns: List[str]) -> Frame:
    missing = [c for c in columns if c not in frame]
    if missing:
        raise SchemaError(f"missing columns {missing}")
    return {c: frame[c] for c in columns}


def where(frame: Frame, predicate: Expr) -> Frame:
    mask = predicate.evaluate(frame)
    if mask.dtype != np.bool_ or mask.ndim != 1:
        raise SchemaError("predicate must evaluate to a 1-D boolean mask")
    return {k: v[mask] for k, v in frame.items()}


def nrows(frame: Frame) -> int:
    if not frame:
        return 0
    return next(iter(frame.values())).shape[0]
