"""Functional DAG pipelines (paper §2): nodes are dataframes, edges are pure
transformation functions, parents are declared *implicitly* by referencing the
parent's name — exactly the ergonomics of Listings 1–2:

    @model()
    def training_data(data=Model("final_table")):
        ...
        return {"x": ..., "y": ...}

    final_table = sql_model(
        "final_table", select=["c1", "c2", "c3"], frm="source_table",
        where=col("transaction_ts") >= lit(CUTOFF))

Each node's *code version* is hashed (Python source / canonical SQL spec) and
recorded per run, which is half of the paper's reproducibility contract (the
other half, the data commit, comes from the catalog).
"""

from __future__ import annotations

import hashlib
import inspect
import textwrap
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np

from . import frame as F
from .catalog import Catalog
from .errors import CycleError, ReproError, SchemaError, TableNotFound
from .frame import Expr
from .table import TableIO


class Model:
    """A named reference to a parent DAG node / source table (bauplan.Model)."""

    def __init__(self, name: str, columns: Optional[Sequence[str]] = None):
        self.name = name
        self.columns = list(columns) if columns else None

    def __repr__(self):
        return f"Model({self.name!r})"


def _hash_text(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()


def code_hash_of(fn: Callable) -> str:
    """Stable hash of a node's transformation code."""
    try:
        src = textwrap.dedent(inspect.getsource(fn))
    except (OSError, TypeError):  # dynamically built fn — hash its repr chain
        src = repr(fn)
    return _hash_text(src)


@dataclass
class Node:
    name: str
    fn: Callable[..., Mapping[str, np.ndarray]]
    deps: List[str]
    dep_params: Dict[str, Model]
    code_hash: str
    materialize: bool = True
    runtime: Dict[str, Any] = field(default_factory=dict)  # pinned deps (Listing 2)

    def __call__(self, *args, **kwargs):
        return self.fn(*args, **kwargs)


def model(name: Optional[str] = None, *, materialize: bool = True,
          python: Optional[str] = None, pip: Optional[Dict[str, str]] = None):
    """Decorator turning a function into a DAG node.

    ``python=``/``pip=`` mirror Listing 2's runtime pinning: the values are
    recorded in the node's runtime manifest (on TPU the actual enforcement is
    the jaxpr/HLO fingerprint — see DESIGN.md §2.2)."""

    def deco(fn: Callable) -> Node:
        sig = inspect.signature(fn)
        dep_params: Dict[str, Model] = {}
        for pname, p in sig.parameters.items():
            if isinstance(p.default, Model):
                dep_params[pname] = p.default
        node_name = name or fn.__name__
        runtime = {}
        if python:
            runtime["python"] = python
        if pip:
            runtime["pip"] = dict(pip)
        return Node(
            name=node_name,
            fn=fn,
            deps=[m.name for m in dep_params.values()],
            dep_params=dep_params,
            code_hash=code_hash_of(fn),
            materialize=materialize,
            runtime=runtime,
        )

    return deco


def sql_model(name: str, *, select: Sequence[str], frm: str,
              where: Optional[Expr] = None, materialize: bool = True) -> Node:
    """Declarative (SQL-style) node: projection + row filter (Listing 1)."""
    spec = (f"SELECT {','.join(select)} FROM {frm}"
            + (f" WHERE {where.canonical()}" if where is not None else ""))

    def fn(**inputs):
        parent = inputs["data"]
        out = parent if where is None else F.where(parent, where)
        return F.select(out, list(select))

    node = Node(
        name=name, fn=lambda data: fn(data=data), deps=[frm],
        dep_params={"data": Model(frm)}, code_hash=_hash_text(spec),
        materialize=materialize, runtime={"lang": "sql", "spec": spec},
    )
    return node


class Pipeline:
    """A DAG of nodes.  ``run()`` is in ``runtime/executor.py`` — the pipeline
    itself only knows structure (names, edges, code hashes)."""

    def __init__(self, nodes: Sequence[Node]):
        self.nodes: Dict[str, Node] = {}
        for n in nodes:
            if n.name in self.nodes:
                raise ReproError(f"duplicate node {n.name!r}")
            self.nodes[n.name] = n
        self.order = self._topo_sort()

    def _topo_sort(self) -> List[str]:
        internal = set(self.nodes)
        indeg = {n: 0 for n in internal}
        children: Dict[str, List[str]] = {n: [] for n in internal}
        for n in self.nodes.values():
            for d in n.deps:
                if d in internal:
                    indeg[n.name] += 1
                    children[d].append(n.name)
        ready = sorted(n for n, k in indeg.items() if k == 0)
        order: List[str] = []
        while ready:
            cur = ready.pop(0)
            order.append(cur)
            for ch in sorted(children[cur]):
                indeg[ch] -= 1
                if indeg[ch] == 0:
                    ready.append(ch)
            ready.sort()
        if len(order) != len(internal):
            stuck = sorted(internal - set(order))
            raise CycleError(f"cycle through {stuck}")
        return order

    def source_tables(self) -> List[str]:
        """External tables the DAG reads (must exist on the branch)."""
        internal = set(self.nodes)
        out: List[str] = []
        for n in self.nodes.values():
            out.extend(d for d in n.deps if d not in internal)
        return sorted(set(out))

    def code_manifest(self) -> Dict[str, str]:
        return {name: self.nodes[name].code_hash for name in self.order}

    def code_hash(self) -> str:
        return _hash_text(repr(sorted(self.code_manifest().items())))


@dataclass
class RunResult:
    run_id: str
    commit: str
    branch: str
    outputs: Dict[str, str]  # node name -> snapshot digest
    metrics: Dict[str, Any] = field(default_factory=dict)


def execute(
    pipeline: Pipeline,
    catalog: Catalog,
    io: TableIO,
    *,
    branch: str,
    author: str = "system",
    params: Optional[Dict[str, Any]] = None,
    read_ref: Optional[str] = None,
) -> Dict[str, str]:
    """Run the DAG against a branch: read parents from ``read_ref`` (defaults
    to the branch head), evaluate nodes in topological order, materialize
    outputs and commit them as ONE multi-table transaction (paper §3:
    multi-table transactions are crucial for pipelines).

    Returns {node name -> snapshot digest}.  Ledger bookkeeping (run ids,
    replay) lives in ``ledger.py`` on top of this primitive.
    """
    params = params or {}
    read_ref = read_ref or branch
    head_tables = catalog.tables(read_ref)
    cache: Dict[str, Dict[str, np.ndarray]] = {}

    def fetch(table: str) -> Dict[str, np.ndarray]:
        if table in cache:
            return cache[table]
        if table not in head_tables:
            raise TableNotFound(f"source table {table!r} not on {read_ref!r}")
        cols = io.read(head_tables[table])
        cache[table] = cols
        return cols

    outputs: Dict[str, str] = {}
    for name in pipeline.order:
        node = pipeline.nodes[name]
        kwargs: Dict[str, Any] = {}
        for pname, mref in node.dep_params.items():
            data = fetch(mref.name)
            if mref.columns:
                data = F.select(data, mref.columns)
            kwargs[pname] = data
        sig = inspect.signature(node.fn)
        for pname in sig.parameters:
            if pname in params and pname not in kwargs:
                kwargs[pname] = params[pname]
        result = node.fn(**kwargs)
        if not isinstance(result, Mapping) or not result:
            raise SchemaError(
                f"node {name!r} must return a non-empty column mapping")
        result = {k: np.asarray(v) for k, v in result.items()}
        cache[name] = result
        if node.materialize:
            outputs[name] = io.write_snapshot(result)

    if outputs:
        catalog.commit(
            branch, outputs,
            f"pipeline run: {', '.join(pipeline.order)}",
            author=author,
            meta={"pipeline_code": pipeline.code_hash()},
        )
    return outputs
