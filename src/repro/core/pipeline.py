"""Functional DAG pipelines (paper §2): nodes are dataframes, edges are pure
transformation functions, parents are declared *implicitly* by referencing the
parent's name — exactly the ergonomics of Listings 1–2:

    @model()
    def training_data(data=Model("final_table")):
        ...
        return {"x": ..., "y": ...}

    final_table = sql_model(
        "final_table", select=["c1", "c2", "c3"], frm="source_table",
        where=col("transaction_ts") >= lit(CUTOFF))

Each node's *code version* is hashed (Python source / canonical SQL spec) and
recorded per run, which is half of the paper's reproducibility contract (the
other half, the data commit, comes from the catalog).
"""

from __future__ import annotations

import dis
import hashlib
import inspect
import os
import textwrap
import types
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np

from . import frame as F
from .catalog import Catalog
from .errors import CycleError, ReproError
from .frame import Expr
from .runcache import RunCache
from .table import TableIO


class Model:
    """A named reference to a parent DAG node / source table (bauplan.Model)."""

    def __init__(self, name: str, columns: Optional[Sequence[str]] = None):
        self.name = name
        self.columns = list(columns) if columns else None

    def __repr__(self):
        if self.columns:
            return f"Model({self.name!r}, columns={self.columns!r})"
        return f"Model({self.name!r})"


def _hash_text(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()


def _stable_const(v: Any) -> Optional[str]:
    """Canonical string for values safe to fold into a code hash: immutable
    scalars, Model refs, and tuples thereof.  Mutable objects (dicts, arrays,
    counters) return None — their reprs drift between otherwise identical
    runs, which would defeat warm caching."""
    if isinstance(v, (bool, int, float, str, bytes, type(None))):
        return repr(v)
    if isinstance(v, Model):
        return repr(v)
    if isinstance(v, np.generic):  # numpy scalar: immutable, dtype matters
        return f"npscalar:{v.dtype.str}:{v!r}"
    if isinstance(v, tuple):
        parts = [_stable_const(x) for x in v]
        if all(p is not None for p in parts):
            return "(" + ",".join(parts) + ")"
    return None


def _captured_values(fn: Callable):
    """(label, value) pairs for everything a function captures beyond its
    source text: closure cells and positional + keyword-only defaults."""
    out = []
    code = getattr(fn, "__code__", None)
    cells = getattr(fn, "__closure__", None) or ()
    for name, cell in zip(getattr(code, "co_freevars", ()), cells):
        try:
            out.append((f"closure:{name}", cell.cell_contents))
        except ValueError:  # unfilled cell
            continue
    for i, default in enumerate(getattr(fn, "__defaults__", None) or ()):
        out.append((f"default:{i}", default))
    for name, default in sorted(
            (getattr(fn, "__kwdefaults__", None) or {}).items()):
        out.append((f"kwdefault:{name}", default))
    return out


def _referenced_globals(fn: Callable):
    """``(name, value, stored)`` for every module-level name the function's
    bytecode loads or stores (LOAD_GLOBAL / STORE_GLOBAL / DELETE_GLOBAL),
    including inside nested functions and comprehensions.

    Only names that resolve in ``fn.__globals__`` are reported — an
    unresolved LOAD_GLOBAL is a builtin.  These references used to be
    invisible to the cache key: a node reading a module constant that
    changed between runs kept its old key and served a silently stale
    snapshot (the bug docs/run_cache.md used to document as a
    limitation)."""
    code = getattr(fn, "__code__", None)
    g = getattr(fn, "__globals__", None)
    if code is None or g is None:
        return []
    loaded: List[str] = []
    stored: List[str] = []
    stack = [code]
    while stack:
        c = stack.pop()
        for ins in dis.get_instructions(c):
            if ins.opname == "LOAD_GLOBAL":
                loaded.append(ins.argval)
            elif ins.opname in ("STORE_GLOBAL", "DELETE_GLOBAL"):
                stored.append(ins.argval)
        for const in c.co_consts:
            if isinstance(const, types.CodeType):
                stack.append(const)
    stored_set = set(stored)
    out = []
    for name in dict.fromkeys(loaded + stored):
        if name in stored_set:
            out.append((name, g.get(name), True))
        elif name in g:
            out.append((name, g[name], False))
    return out


def _opaque_global(v: Any) -> bool:
    """Globals the code hash deliberately does NOT cover: modules and
    callables (functions, types).  Hashing their behavior would mean
    hashing the transitive program; referencing them stays cache-safe and
    is the documented blind spot (docs/run_cache.md)."""
    return isinstance(v, types.ModuleType) or callable(v)


def is_cache_safe(fn: Callable) -> bool:
    """True iff every value ``fn`` captures — closure cells, argument
    defaults, AND module-level globals it references by name — is either a
    stable constant the code hash covers or a module/callable (the
    documented blind spot).  A node depending on something unstable — a
    mutable container, an arbitrary object, a global it *writes* — cannot
    be soundly keyed: its code hash cannot see that state, so two runs with
    identical keys could produce different outputs.  Such nodes are
    UNCACHEABLE (always re-executed) rather than silently wrong."""
    if not all(_stable_const(v) is not None
               for _, v in _captured_values(fn)):
        return False
    for _name, value, stored in _referenced_globals(fn):
        if stored:
            return False  # the node mutates module state
        if _opaque_global(value):
            continue
        if _stable_const(value) is None:
            return False  # mutable global (dict, list, array, object)
    return True


def code_hash_of(fn: Callable) -> str:
    """Stable hash of a node's transformation code.

    Factory-built nodes (``packing_node(seq_len)``) share identical source
    but differ through closure cells / argument defaults, so hashable
    constants from both are folded in — two factory instances with different
    parameters must NOT collide on one code version (they'd cross-hit the
    run cache and evade code-drift detection).  Module-level constants the
    function references by name are folded the same way: editing
    ``CUTOFF = 50`` to ``CUTOFF = 60`` is a code change and must invalidate
    the node's cone exactly like editing its source.  Unstable captured
    values are excluded here; ``is_cache_safe`` gates such nodes out of the
    cache."""
    try:
        src = textwrap.dedent(inspect.getsource(fn))
    except (OSError, TypeError):  # dynamically built fn — hash its repr chain
        src = repr(fn)
    extras = []
    for label, value in _captured_values(fn):
        const = _stable_const(value)
        if const is not None:
            extras.append(f"{label}={const}")
    for name, value, stored in _referenced_globals(fn):
        if stored or _opaque_global(value):
            continue
        const = _stable_const(value)
        if const is not None:
            extras.append(f"global:{name}={const}")
    return _hash_text(src + "\n" + "\n".join(extras))


@dataclass
class Node:
    name: str
    fn: Callable[..., Mapping[str, np.ndarray]]
    deps: List[str]
    dep_params: Dict[str, Model]
    code_hash: str
    materialize: bool = True
    runtime: Dict[str, Any] = field(default_factory=dict)  # pinned deps (Listing 2)
    cache_safe: bool = True  # False: captured state the code hash can't cover

    def __call__(self, *args, **kwargs):
        return self.fn(*args, **kwargs)


def model(name: Optional[str] = None, *, materialize: bool = True,
          python: Optional[str] = None, pip: Optional[Dict[str, str]] = None):
    """Decorator turning a function into a DAG node.

    ``python=``/``pip=`` mirror Listing 2's runtime pinning: the values are
    recorded in the node's runtime manifest (on TPU the actual enforcement is
    the jaxpr/HLO fingerprint — see DESIGN.md §2.2)."""

    def deco(fn: Callable) -> Node:
        sig = inspect.signature(fn)
        dep_params: Dict[str, Model] = {}
        for pname, p in sig.parameters.items():
            if isinstance(p.default, Model):
                dep_params[pname] = p.default
        node_name = name or fn.__name__
        runtime = {}
        if python:
            runtime["python"] = python
        if pip:
            runtime["pip"] = dict(pip)
        return Node(
            name=node_name,
            fn=fn,
            deps=[m.name for m in dep_params.values()],
            dep_params=dep_params,
            code_hash=code_hash_of(fn),
            materialize=materialize,
            runtime=runtime,
            cache_safe=is_cache_safe(fn),
        )

    return deco


def sql_model(name: str, *, select: Sequence[str], frm: str,
              where: Optional[Expr] = None, materialize: bool = True) -> Node:
    """Declarative (SQL-style) node: projection + row filter (Listing 1)."""
    spec = (f"SELECT {','.join(select)} FROM {frm}"
            + (f" WHERE {where.canonical()}" if where is not None else ""))

    def fn(**inputs):
        parent = inputs["data"]
        out = parent if where is None else F.where(parent, where)
        return F.select(out, list(select))

    node = Node(
        name=name, fn=lambda data: fn(data=data), deps=[frm],
        dep_params={"data": Model(frm)}, code_hash=_hash_text(spec),
        materialize=materialize, runtime={"lang": "sql", "spec": spec},
    )
    return node


class Pipeline:
    """A DAG of nodes.  ``run()`` is in ``runtime/executor.py`` — the pipeline
    itself only knows structure (names, edges, code hashes)."""

    def __init__(self, nodes: Sequence[Node]):
        self.nodes: Dict[str, Node] = {}
        for n in nodes:
            if n.name in self.nodes:
                raise ReproError(f"duplicate node {n.name!r}")
            self.nodes[n.name] = n
        self.order = self._topo_sort()

    def _topo_sort(self) -> List[str]:
        internal = set(self.nodes)
        indeg = {n: 0 for n in internal}
        children: Dict[str, List[str]] = {n: [] for n in internal}
        for n in self.nodes.values():
            for d in n.deps:
                if d in internal:
                    indeg[n.name] += 1
                    children[d].append(n.name)
        # kept for the executor: internal-edge adjacency + pristine indegrees
        # (Kahn's loop below consumes ``indeg`` destructively)
        self.children: Dict[str, List[str]] = children
        self.indegree: Dict[str, int] = dict(indeg)
        ready = sorted(n for n, k in indeg.items() if k == 0)
        order: List[str] = []
        while ready:
            cur = ready.pop(0)
            order.append(cur)
            for ch in sorted(children[cur]):
                indeg[ch] -= 1
                if indeg[ch] == 0:
                    ready.append(ch)
            ready.sort()
        if len(order) != len(internal):
            stuck = sorted(internal - set(order))
            raise CycleError(f"cycle through {stuck}")
        return order

    def source_tables(self) -> List[str]:
        """External tables the DAG reads (must exist on the branch)."""
        internal = set(self.nodes)
        out: List[str] = []
        for n in self.nodes.values():
            out.extend(d for d in n.deps if d not in internal)
        return sorted(set(out))

    def code_manifest(self) -> Dict[str, str]:
        return {name: self.nodes[name].code_hash for name in self.order}

    def code_hash(self) -> str:
        return _hash_text(repr(sorted(self.code_manifest().items())))


@dataclass
class RunResult:
    run_id: str
    commit: str
    branch: str
    outputs: Dict[str, str]  # node name -> snapshot digest
    metrics: Dict[str, Any] = field(default_factory=dict)
    node_stats: Dict[str, "NodeStat"] = field(default_factory=dict)

    @property
    def cache_hits(self) -> int:
        return sum(1 for s in self.node_stats.values() if s.cache_hit)

    @property
    def cache_misses(self) -> int:
        return sum(1 for s in self.node_stats.values() if not s.cache_hit)


@dataclass
class NodeStat:
    """Per-node execution record kept in the run manifest (Ledger)."""
    name: str
    cache_hit: bool
    wall_s: float
    snapshot: Optional[str]  # None only for materialize=False with no cache
    cache_key: Optional[str]  # None when the cache is disabled
    #: why the node was NOT cached this run (None = it was cacheable):
    #: "unstable-capture" (mutable closure/global the code hash can't
    #: cover) or "unhashable-param" (injected param with no stable cache
    #: encoding — the once-silent TypeError demotion, now surfaced)
    cache_skip_reason: Optional[str] = None
    #: lease claims on the node (1 = first try; >1 = re-leased after a
    #: worker crash)
    attempts: int = 1

    def to_obj(self) -> Dict[str, Any]:
        return {"cache_hit": self.cache_hit, "wall_s": self.wall_s,
                "snapshot": self.snapshot, "cache_key": self.cache_key,
                "cache_skip_reason": self.cache_skip_reason,
                "attempts": self.attempts}


@dataclass
class ExecutionReport:
    """What ``execute`` returns: committed outputs + per-node cache/timing."""
    outputs: Dict[str, str]  # materialized node -> snapshot digest
    commit: Optional[str]  # new commit digest, or None if nothing changed
    node_stats: Dict[str, NodeStat] = field(default_factory=dict)
    jobs: int = 1
    cache_enabled: bool = True
    executor: str = "thread"  # thread | process | remote
    exec_id: Optional[str] = None  # refs-keyspace run id (`repro status`)

    @property
    def cache_hits(self) -> int:
        return sum(1 for s in self.node_stats.values() if s.cache_hit)

    @property
    def cache_misses(self) -> int:
        return sum(1 for s in self.node_stats.values() if not s.cache_hit)


def default_jobs() -> int:
    return max(1, min(8, os.cpu_count() or 1))


def execute(
    pipeline: Pipeline,
    catalog: Catalog,
    io: TableIO,
    *,
    branch: str,
    author: str = "system",
    params: Optional[Dict[str, Any]] = None,
    read_ref: Optional[str] = None,
    cache: Optional[RunCache] = None,
    use_cache: bool = True,
    jobs: Optional[int] = None,
    executor: str = "thread",
    exec_id: Optional[str] = None,
    lease_ttl: float = 30.0,
    max_attempts: int = 3,
    poll: float = 0.05,
    wait_timeout: Optional[float] = None,
) -> ExecutionReport:
    """Run the DAG against a branch: read parents from ``read_ref`` (defaults
    to the branch head), evaluate nodes as their parents finish, materialize
    outputs and commit them as ONE multi-table transaction (paper §3:
    multi-table transactions are crucial for pipelines).

    Scheduling lives in :mod:`repro.core.exec`: a coordinator leases ready
    nodes to workers, with leases + heartbeats kept in the refs keyspace
    (``exec/<run-id>/...``, same CAS primitives as the GC generation token)
    so ``repro status`` can watch a live run and crashed workers are
    detected by lease expiry.  ``executor`` picks the worker backend:

    * ``"thread"`` (default) — in-process thread pool, outputs flow in
      memory;
    * ``"process"`` — local process pool for GIL-bound nodes; the shared
      run cache is the cross-process memo table;
    * ``"remote"`` — publish node leases for external ``repro worker``
      processes (any host sharing the store) and poll for results; a dead
      worker's node is re-leased after ``lease_ttl`` and the run fails
      with a poison pill after ``max_attempts`` claims of one node.

    Incremental execution: with ``use_cache`` (default), each node's output
    is memoized in a :class:`RunCache` under ``(code_hash, sorted input
    snapshot digests, injected params)`` — see docs/run_cache.md.  A node
    failure raises :class:`~repro.core.errors.NodeExecutionError` carrying
    the failing node's name and the stats of every node that completed
    first; in-flight siblings are drained (they finish but publish no
    snapshots or cache entries) before the error propagates.

    Outputs are content-addressed, so the result commit is bit-identical
    for any ``jobs`` value, any executor, and hit vs. miss paths.  Ledger
    bookkeeping (run ids, replay) lives in ``ledger.py``.
    """
    from .exec.coordinator import run_dag

    return run_dag(pipeline, catalog, io, branch=branch, author=author,
                   params=params, read_ref=read_ref, cache=cache,
                   use_cache=use_cache, jobs=jobs, executor=executor,
                   exec_id=exec_id, lease_ttl=lease_ttl,
                   max_attempts=max_attempts, poll=poll,
                   wait_timeout=wait_timeout)
