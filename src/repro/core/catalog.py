"""Nessie-style catalog: Git semantics over tables (paper §3.3, Fig. 4).

A *commit* is an immutable, content-addressed, multi-table transaction:

    { parents: [digest...], tables: {name: snapshot_digest}, message,
      author, ts, meta }

Branches are mutable refs (name → commit digest) updated with compare-and-set,
which gives the catalog the transactional behavior the paper needs for data
pipelines.  Branching is **copy-on-write**: creating a branch writes one ref —
no table data is copied regardless of size (benchmarked in
``benchmarks/bench_branching.py``).

Namespacing follows the paper's ``user.branch`` convention: everyone can read
any branch, only ``user`` can write ``user.*``; ``main`` accepts only merges
that went through write-audit-publish (see ``wap.py``) unless the catalog is
created with ``protect_main=False``.

Writes are **optimistic table-level transactions** (``txn.py``): a commit
declares its read/write table set, and a ref-level CAS miss triggers a
rebase — re-read the moved head, verify no declared table changed
snapshot since the transaction's base, retry — so concurrent writers on
*disjoint* tables never see a conflict; only genuinely overlapping
snapshot movement raises :class:`~.errors.TransactionConflict`.  **Data
contracts** (``contracts.py``) attached to tables ride the commit object
itself and are enforced here, at the ref update, on every ``commit`` and
``merge`` path — see docs/catalog.md for the conflict matrix and
enforcement points.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Set

import msgpack

from .contracts import (CONTRACTS_TABLE, Contract, Rule, evaluate,
                        pack_contracts, unpack_contracts)
from .errors import (ContractViolation, MergeConflict, ObjectNotFound,
                     PermissionDenied, RefConflict, RefNotFound, ReproError,
                     TransactionConflict)
from .store import ObjectStore, try_cas_ref
from .txn import DEFAULT_MAX_ATTEMPTS, Transaction, changed_tables

_BRANCH_PREFIX = "branch="
_TAG_PREFIX = "tag="
#: namespace for remote-tracking refs: ``remote/<name>/branch=<b>`` (and
#: ``remote/<name>/tag=<t>`` for synced tags) records where the ref pointed
#: on remote ``<name>`` at the last push/pull.  These are GC roots (see
#: ``gc.collect``) — objects reachable only through a remote-tracking ref
#: must survive a local sweep or the next replay of a pulled branch/tag
#: would break.
REMOTE_REF_PREFIX = "remote/"


def remote_tracking_ref(remote_name: str, branch: str) -> str:
    return f"{REMOTE_REF_PREFIX}{remote_name}/{_BRANCH_PREFIX}{branch}"


def remote_tracking_tag_ref(remote_name: str, tag: str) -> str:
    return f"{REMOTE_REF_PREFIX}{remote_name}/{_TAG_PREFIX}{tag}"


def _pack(obj) -> bytes:
    return msgpack.packb(obj, use_bin_type=True)


def _unpack(blob: bytes):
    return msgpack.unpackb(blob, raw=False)


@dataclass(frozen=True)
class Commit:
    parents: tuple
    tables: Dict[str, str]  # table name -> snapshot digest
    message: str
    author: str
    ts: float
    meta: Dict[str, Any] = field(default_factory=dict)

    def to_obj(self):
        return {
            "parents": list(self.parents),
            "tables": dict(sorted(self.tables.items())),
            "message": self.message,
            "author": self.author,
            "ts": self.ts,
            "meta": self.meta,
        }

    @staticmethod
    def from_obj(o) -> "Commit":
        return Commit(tuple(o["parents"]), dict(o["tables"]), o["message"],
                      o["author"], o["ts"], o.get("meta", {}))


class Catalog:
    def __init__(self, store: ObjectStore, *, protect_main: bool = True,
                 clock: Callable[[], float] = time.time):
        self.store = store
        self.protect_main = protect_main
        self.clock = clock
        self._io = None  # lazy TableIO for contract enforcement
        self._contracts_cache: Dict[str, Dict[str, Contract]] = {}
        #: (contracts digest, table, snapshot) -> failures; rebases re-check
        #: the same snapshot under the same contracts for free
        self._contract_results: Dict[tuple, Dict[str, str]] = {}
        self._stats_lock = threading.Lock()
        #: transaction accounting: ``rebases`` counts ref-CAS misses
        #: absorbed internally — before the transaction layer each one was
        #: a caller-visible conflict and a full retry (bench_branching's
        #: multi-writer leg reports these)
        #: ``append_merges`` counts same-table append/append races the
        #: manifest-diff rebase absorbed (two writers extending one table)
        self.txn_stats = {"commits": 0, "merges": 0, "append_merges": 0,
                          "rebases": 0, "conflicts": 0,
                          "contract_rejections": 0}
        try:
            self.store.get_ref(_BRANCH_PREFIX + "main")
        except RefNotFound:
            root = Commit((), {}, "repository root", "system", self.clock())
            try:  # create-exclusive: a concurrent init's root is as good
                self.store.cas_ref(_BRANCH_PREFIX + "main", None,
                                   self.store.put(_pack(root.to_obj())))
            except RefConflict:
                pass

    def _bump_stat(self, key: str, n: int = 1) -> None:
        with self._stats_lock:
            self.txn_stats[key] += n

    # -------------------------------------------------------------- plumbing
    def _load_commit(self, digest: str) -> Commit:
        return Commit.from_obj(_unpack(self.store.get(digest)))

    def _store_commit(self, commit: Commit) -> str:
        return self.store.put(_pack(commit.to_obj()))

    def head(self, branch: str) -> str:
        return self.store.get_ref(_BRANCH_PREFIX + branch)

    def branches(self) -> List[str]:
        return [r[len(_BRANCH_PREFIX):] for r in self.store.iter_refs()
                if r.startswith(_BRANCH_PREFIX)]

    def tags(self) -> List[str]:
        return [r[len(_TAG_PREFIX):] for r in self.store.iter_refs()
                if r.startswith(_TAG_PREFIX)]

    # --------------------------------------------------------------- resolve
    def resolve(self, ref: str) -> str:
        """Resolve branch / tag / commit digest / time-travel spec.

        Time travel (paper §5 "travels back in time"):
          ``main@1718000000``  — last commit on main at/before unix ts
          ``main~3``           — 3 first-parent steps back from main head
        """
        if "@" in ref:
            base, ts = ref.split("@", 1)
            return self._at_time(self.resolve(base), float(ts))
        if "~" in ref:
            base, n = ref.split("~", 1)
            digest = self.resolve(base)
            for _ in range(int(n)):
                parents = self._load_commit(digest).parents
                if not parents:
                    raise RefNotFound(f"{ref}: ran out of history")
                digest = parents[0]
            return digest
        if ref.startswith((_BRANCH_PREFIX, _TAG_PREFIX)):
            # fully-qualified spelling (``tag=v1.0`` / ``branch=main``) —
            # the exact names sync reports and ref listings print, so they
            # round-trip straight back into resolve
            return self.store.get_ref(ref)
        try:
            return self.head(ref)
        except RefNotFound:
            pass
        try:
            return self.store.get_ref(_TAG_PREFIX + ref)
        except RefNotFound:
            pass
        if "/" in ref:  # remote-tracking: ``origin/main`` (git spelling)
            rname, _, leaf = ref.partition("/")
            for tracking in (remote_tracking_ref(rname, leaf),
                             remote_tracking_tag_ref(rname, leaf)):
                try:
                    return self.store.get_ref(tracking)
                except RefNotFound:
                    pass
        if self.store.has(ref):
            return ref
        # commit digest prefix
        matches = [d for d in self.store.iter_objects() if d.startswith(ref)]
        if len(matches) == 1:
            return matches[0]
        raise RefNotFound(ref)

    def _at_time(self, digest: str, ts: float) -> str:
        cur: Optional[str] = digest
        while cur is not None:
            c = self._load_commit(cur)
            if c.ts <= ts:
                return cur
            cur = c.parents[0] if c.parents else None
        raise RefNotFound(f"no commit at/before ts={ts}")

    # ---------------------------------------------------------------- policy
    @staticmethod
    def branch_owner(branch: str) -> Optional[str]:
        return branch.split(".", 1)[0] if "." in branch else None

    def _check_write(self, branch: str, author: str, *, wap_token: bool):
        if branch == "main":
            if self.protect_main and not wap_token:
                raise PermissionDenied(
                    "main is write-audit-publish protected; use wap.publish()")
            return
        owner = self.branch_owner(branch)
        if owner is not None and owner != author:
            raise PermissionDenied(
                f"{author!r} cannot write to {branch!r} (owner {owner!r})")

    # ---------------------------------------------------------------- writes
    def create_branch(self, name: str, from_ref: str = "main", *,
                      author: str = "system") -> str:
        """Copy-on-write branch: one ref write, zero data copies (§5.4).

        Creation is create-exclusive: the ref is CAS'd *from absent*, so
        two concurrent creates of the same name have exactly one winner —
        the loser raises :class:`ReproError` and can never overwrite the
        winner's ref (the old check-then-set did exactly that)."""
        if name != "main" and self.branch_owner(name) not in (None, author):
            raise PermissionDenied(f"{author!r} cannot create {name!r}")
        if name in self.branches():
            raise ReproError(f"branch {name!r} exists")
        digest = self.resolve(from_ref)
        try:
            self.store.cas_ref(_BRANCH_PREFIX + name, None, digest)
        except RefConflict:
            raise ReproError(
                f"branch {name!r} exists (lost a concurrent create race)"
            ) from None
        return digest

    def delete_branch(self, name: str) -> None:
        if name == "main":
            raise PermissionDenied("cannot delete main")
        self.store.delete_ref(_BRANCH_PREFIX + name)

    def create_tag(self, name: str, ref: str) -> str:
        digest = self.resolve(ref)
        self.store.set_ref(_TAG_PREFIX + name, digest)
        return digest

    def delete_tag(self, name: str) -> None:
        self.store.delete_ref(_TAG_PREFIX + name)

    def commit(
        self,
        branch: str,
        table_updates: Mapping[str, Optional[str]],
        message: str,
        *,
        author: str = "system",
        meta: Optional[Dict[str, Any]] = None,
        read_tables: Optional[Sequence[str]] = None,
        base: Optional[str] = None,
        expected_head: Optional[str] = None,
        max_attempts: Optional[int] = None,
        _wap_token: bool = False,
        _contracts_update: bool = False,
    ) -> str:
        """Multi-table transaction: atomically update snapshot pointers on a
        branch.  ``None`` as snapshot digest deletes the table.

        The commit is an **optimistic table-level transaction**: its
        declared set is ``table_updates`` keys ∪ ``read_tables``, checked
        against ``base`` (the head the caller computed its writes from;
        defaults to the head read here).  On a ref-level CAS miss the
        commit *rebases* — re-reads the moved head, verifies no declared
        table changed snapshot since ``base``, rebuilds on the new head,
        retries (up to ``max_attempts``) — so concurrent commits to
        disjoint tables all land without any caller-visible conflict.
        Genuine overlap raises :class:`~.errors.TransactionConflict`.

        ``expected_head=`` pins the commit: exactly one CAS attempt
        against that digest, any movement raises ``TransactionConflict``
        with ``pinned=True`` — WAP publish uses this to guarantee the
        branch it stamps is byte-identical to the branch it audited.

        Data contracts on any written table are enforced here, before the
        ref moves, regardless of which path produced the commit."""
        self._check_write(branch, author, wap_token=_wap_token)
        if CONTRACTS_TABLE in table_updates and not _contracts_update:
            raise PermissionDenied(
                f"{CONTRACTS_TABLE!r} is reserved; use "
                "Catalog.add_contract()/drop_contract()")
        declared = set(table_updates) | set(read_tables or ())
        attempts_cap = (1 if expected_head is not None
                        else (max_attempts or DEFAULT_MAX_ATTEMPTS))
        head = expected_head if expected_head is not None else self.head(branch)
        if base is None:
            base = head
        base_tables = self._load_commit(base).tables
        attempts = 0
        while True:
            attempts += 1
            head_commit = self._load_commit(head)
            updates = dict(table_updates)
            if head != base:
                overlap = changed_tables(base_tables, head_commit.tables,
                                         declared)
                if overlap and expected_head is None:
                    # Manifest-diff escape hatch: when every overlapping
                    # table is an append/append race (both sides extended
                    # the base snapshot's manifest list verbatim), the
                    # file sets are disjoint by construction and the
                    # appends merge — same-table concurrent ingest lands
                    # with no caller-visible conflict.  Anything else
                    # (overwrite, compact, delete, declared read) stays a
                    # TransactionConflict.  Never under expected_head=:
                    # WAP publish pins byte-identical state.
                    merged = self._merge_table_appends(
                        overlap, updates, base_tables, head_commit.tables)
                    if merged is not None:
                        updates.update(merged)
                        overlap = []
                if overlap:
                    self._bump_stat("conflicts")
                    raise TransactionConflict(branch, overlap,
                                              attempts=attempts, base=base,
                                              pinned=expected_head is not None)
            tables = dict(head_commit.tables)
            for name, snap in updates.items():
                if snap is None:
                    tables.pop(name, None)
                else:
                    tables[name] = snap
            self._enforce_contracts(branch, head_commit.tables, tables)
            digest = self._store_commit(
                Commit((head,), tables, message, author, self.clock(),
                       meta or {}))
            try:
                self.store.cas_ref(_BRANCH_PREFIX + branch, head, digest)
            except RefConflict:
                if expected_head is not None:
                    self._bump_stat("conflicts")
                    raise TransactionConflict(
                        branch, [], attempts=attempts, base=base,
                        pinned=True) from None
                if attempts >= attempts_cap:
                    self._bump_stat("conflicts")
                    raise TransactionConflict(
                        branch, [], attempts=attempts, base=base,
                        exhausted=True) from None
                self._bump_stat("rebases")
                head = self.head(branch)
                continue
            self._bump_stat("commits")
            return digest

    def _merge_table_appends(
        self,
        overlap: Sequence[str],
        updates: Mapping[str, Optional[str]],
        base_tables: Mapping[str, str],
        head_tables: Mapping[str, str],
    ) -> Optional[Dict[str, str]]:
        """Try to absorb an overlapping head movement as append merges.

        Returns ``{table: merged snapshot digest}`` when EVERY overlapping
        table is a same-table append/append race resolvable by
        :func:`~.txn.rebase_append`; None if any single one is not — the
        merge is all-or-nothing so a commit never lands half its declared
        set rebased one way and half another."""
        from .txn import rebase_append

        io = self._table_io()
        merged: Dict[str, str] = {}
        for table in overlap:
            ours = updates.get(table)
            if table not in updates or ours is None:
                return None  # declared read or delete: genuine conflict
            rebased = rebase_append(io, base_tables.get(table),
                                    head_tables.get(table), ours)
            if rebased is None:
                return None
            merged[table] = rebased
        self._bump_stat("append_merges", len(merged))
        return merged

    # ----------------------------------------------------------------- reads
    def tables(self, ref: str) -> Dict[str, str]:
        return dict(self._load_commit(self.resolve(ref)).tables)

    def input_digests(self, ref: str,
                      names: Optional[Sequence[str]] = None) -> Dict[str, str]:
        """Snapshot digests of (a subset of) tables at ``ref`` — the data half
        of a node's run-cache key: a pipeline reading these tables is
        re-executed iff one of these digests (or its code) changes."""
        tables = self.tables(ref)
        if names is None:
            return tables
        return {n: tables[n] for n in names if n in tables}

    def snapshot_of(self, ref: str, table: str) -> str:
        tables = self.tables(ref)
        if table not in tables:
            from .errors import TableNotFound
            raise TableNotFound(f"{table!r} not in {ref!r}")
        return tables[table]

    def log(self, ref: str, *, first_parent: bool = True) -> List[str]:
        out, cur = [], self.resolve(ref)
        seen: Set[str] = set()
        stack = [cur]
        while stack:
            digest = stack.pop(0)
            if digest in seen:
                continue
            seen.add(digest)
            out.append(digest)
            parents = self._load_commit(digest).parents
            if first_parent:
                stack.extend(parents[:1])
            else:
                stack.extend(parents)
        return out

    def commit_info(self, ref: str) -> Commit:
        return self._load_commit(self.resolve(ref))

    # ----------------------------------------------------------------- merge
    def _ancestors(self, digest: str) -> Set[str]:
        seen: Set[str] = set()
        stack = [digest]
        while stack:
            d = stack.pop()
            if d in seen:
                continue
            seen.add(d)
            stack.extend(self._load_commit(d).parents)
        return seen

    def merge_base(self, a: str, b: str) -> Optional[str]:
        """Lowest common ancestor (first found walking a's history by ts)."""
        anc_b = self._ancestors(b)
        best, best_ts = None, -1.0
        for d in self._ancestors(a):
            if d in anc_b:
                ts = self._load_commit(d).ts
                if ts > best_ts:
                    best, best_ts = d, ts
        return best

    def merge(self, src_ref: str, dst_branch: str, *, author: str = "system",
              message: Optional[str] = None, _wap_token: bool = False,
              max_attempts: Optional[int] = None) -> str:
        """Fast-forward when possible, else 3-way at table granularity.

        Conflict rule (Nessie semantics): a table changed on *both* sides
        since the merge base conflicts unless both sides reached the same
        snapshot.

        The merge is itself an optimistic transaction: a ref-level CAS
        miss (the destination moved while we computed the merge) triggers
        a full recompute against the new head and a retry — the 3-way
        table comparison re-run per attempt *is* the semantic conflict
        check, so a concurrent commit to tables the source didn't touch
        never aborts the merge.  A fast-forward whose destination moves
        degrades gracefully into a 3-way merge on retry.  Contracts on
        every table the merge changes are enforced before the ref moves —
        on the fast-forward path too (a branch can fast-forward past a
        contract added after it forked)."""
        self._check_write(dst_branch, author, wap_token=_wap_token)
        src = self.resolve(src_ref)
        attempts_cap = max_attempts or DEFAULT_MAX_ATTEMPTS
        attempts = 0
        dst = self.head(dst_branch)
        while True:
            attempts += 1
            if src == dst or src in self._ancestors(dst):
                # already merged (a retry can observe its own landed work
                # or a concurrent identical merge) — idempotent success
                return dst
            dst_tables = self._load_commit(dst).tables
            if dst in self._ancestors(src):  # fast-forward
                src_tables = self._load_commit(src).tables
                self._enforce_contracts(dst_branch, dst_tables, src_tables)
                if try_cas_ref(self.store, _BRANCH_PREFIX + dst_branch,
                               dst, src):
                    self._bump_stat("merges")
                    return src
                dst = self._rebase_or_exhaust(dst_branch, attempts,
                                              attempts_cap)
                continue
            base = self.merge_base(src, dst)
            base_tables = self._load_commit(base).tables if base else {}
            src_tables = self._load_commit(src).tables
            conflicts, merged = [], dict(dst_tables)
            for name in sorted(set(base_tables) | set(src_tables)
                               | set(dst_tables)):
                b = base_tables.get(name)
                s = src_tables.get(name)
                d = dst_tables.get(name)
                if s == d:
                    continue
                src_changed, dst_changed = (s != b), (d != b)
                if src_changed and dst_changed:
                    conflicts.append(name)
                elif src_changed:
                    if s is None:
                        merged.pop(name, None)
                    else:
                        merged[name] = s
            if conflicts:
                self._bump_stat("conflicts")
                raise MergeConflict(conflicts)
            self._enforce_contracts(dst_branch, dst_tables, merged)
            commit = Commit(
                (dst, src), merged,
                message or f"merge {src_ref} into {dst_branch}",
                author, self.clock(), {"merge_base": base},
            )
            digest = self._store_commit(commit)
            if try_cas_ref(self.store, _BRANCH_PREFIX + dst_branch,
                           dst, digest):
                self._bump_stat("merges")
                return digest
            dst = self._rebase_or_exhaust(dst_branch, attempts, attempts_cap)

    def _rebase_or_exhaust(self, dst_branch: str, attempts: int,
                           attempts_cap: int) -> str:
        """CAS miss bookkeeping for merge: either hand back the moved head
        for another attempt or give up loudly."""
        if attempts >= attempts_cap:
            self._bump_stat("conflicts")
            raise TransactionConflict(dst_branch, [], attempts=attempts,
                                      exhausted=True)
        self._bump_stat("rebases")
        return self.head(dst_branch)

    def diff(self, ref_a: str, ref_b: str) -> Dict[str, tuple]:
        """Tables whose snapshot differs between two refs."""
        ta, tb = self.tables(ref_a), self.tables(ref_b)
        out = {}
        for name in sorted(set(ta) | set(tb)):
            if ta.get(name) != tb.get(name):
                out[name] = (ta.get(name), tb.get(name))
        return out

    # ---------------------------------------------------------- transactions
    def transaction(self, branch: str, *, author: str = "system",
                    io=None) -> Transaction:
        """Open an optimistic read/write transaction against ``branch``
        (see :class:`~.txn.Transaction`)."""
        return Transaction(self, branch, author=author, io=io)

    # ------------------------------------------------------------- contracts
    def _table_io(self):
        if self._io is None:
            from .table import TableIO
            self._io = TableIO(self.store)
        return self._io

    def _load_contract_specs(self, contracts_digest: Optional[str]
                             ) -> Dict[str, Contract]:
        if contracts_digest is None:
            return {}
        cached = self._contracts_cache.get(contracts_digest)
        if cached is None:
            cached = unpack_contracts(self.store.get(contracts_digest))
            self._contracts_cache[contracts_digest] = cached
        return cached

    def contracts(self, ref: str = "main") -> Dict[str, Contract]:
        """Contracts in force at ``ref`` (table name → contract)."""
        tables = self.tables(ref)
        return dict(self._load_contract_specs(tables.get(CONTRACTS_TABLE)))

    def _enforce_contracts(self, branch: str,
                           old_tables: Mapping[str, str],
                           new_tables: Mapping[str, str]) -> None:
        """Gate a prospective commit's tables against the contracts *it*
        carries.  Checked for every table whose snapshot OR contract
        changed relative to the current head — so attaching a contract
        over already-bad data is rejected at attach time, and unchanged
        tables never cost a data read.  Evaluation is memoized by
        (contracts blob, table, snapshot): a rebase retry re-checks the
        same snapshots for free."""
        new_cdig = new_tables.get(CONTRACTS_TABLE)
        if new_cdig is None:
            return
        new_specs = self._load_contract_specs(new_cdig)
        if not new_specs:
            return
        old_specs = self._load_contract_specs(old_tables.get(CONTRACTS_TABLE))
        for table, contract in new_specs.items():
            snap = new_tables.get(table)
            if snap is None:
                continue  # contracted table absent: nothing to validate
            if (snap == old_tables.get(table)
                    and contract == old_specs.get(table)):
                continue  # neither data nor contract moved past the head
            key = (new_cdig, table, snap)
            failures = self._contract_results.get(key)
            if failures is None:
                frame = self._table_io().read(snap)
                failures = evaluate(contract, frame)
                self._contract_results[key] = failures
            if failures:
                self._bump_stat("contract_rejections")
                raise ContractViolation(branch, table, failures)

    def add_contract(self, table: str, rules: Sequence[Rule], *,
                     branch: str = "main", author: str = "system",
                     _wap_token: bool = False) -> str:
        """Attach (or replace) the contract on ``table`` at ``branch``.

        The attach is itself a contract-gated commit: if the table's
        current snapshot violates the new rules, the attach is rejected —
        a contract can never be in force over data that fails it."""
        if table == CONTRACTS_TABLE:
            raise PermissionDenied(f"cannot contract {CONTRACTS_TABLE!r}")
        specs = dict(self.contracts(branch))
        specs[table] = Contract(table, tuple(rules), author)
        digest = self.store.put(pack_contracts(specs))
        return self.commit(
            branch, {CONTRACTS_TABLE: digest},
            f"contract: {table} ({len(rules)} rule(s))", author=author,
            _wap_token=_wap_token, _contracts_update=True)

    def drop_contract(self, table: str, *, branch: str = "main",
                      author: str = "system",
                      _wap_token: bool = False) -> str:
        specs = dict(self.contracts(branch))
        if table not in specs:
            raise ReproError(f"no contract on {table!r} at {branch!r}")
        del specs[table]
        digest = self.store.put(pack_contracts(specs))
        return self.commit(
            branch, {CONTRACTS_TABLE: digest},
            f"contract: drop {table}", author=author,
            _wap_token=_wap_token, _contracts_update=True)
