"""Nessie-style catalog: Git semantics over tables (paper §3.3, Fig. 4).

A *commit* is an immutable, content-addressed, multi-table transaction:

    { parents: [digest...], tables: {name: snapshot_digest}, message,
      author, ts, meta }

Branches are mutable refs (name → commit digest) updated with compare-and-set,
which gives the catalog the transactional behavior the paper needs for data
pipelines.  Branching is **copy-on-write**: creating a branch writes one ref —
no table data is copied regardless of size (benchmarked in
``benchmarks/bench_branching.py``).

Namespacing follows the paper's ``user.branch`` convention: everyone can read
any branch, only ``user`` can write ``user.*``; ``main`` accepts only merges
that went through write-audit-publish (see ``wap.py``) unless the catalog is
created with ``protect_main=False``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Set

import msgpack

from .errors import (MergeConflict, ObjectNotFound, PermissionDenied,
                     RefNotFound, ReproError)
from .store import ObjectStore

_BRANCH_PREFIX = "branch="
_TAG_PREFIX = "tag="
#: namespace for remote-tracking refs: ``remote/<name>/branch=<b>`` (and
#: ``remote/<name>/tag=<t>`` for synced tags) records where the ref pointed
#: on remote ``<name>`` at the last push/pull.  These are GC roots (see
#: ``gc.collect``) — objects reachable only through a remote-tracking ref
#: must survive a local sweep or the next replay of a pulled branch/tag
#: would break.
REMOTE_REF_PREFIX = "remote/"


def remote_tracking_ref(remote_name: str, branch: str) -> str:
    return f"{REMOTE_REF_PREFIX}{remote_name}/{_BRANCH_PREFIX}{branch}"


def remote_tracking_tag_ref(remote_name: str, tag: str) -> str:
    return f"{REMOTE_REF_PREFIX}{remote_name}/{_TAG_PREFIX}{tag}"


def _pack(obj) -> bytes:
    return msgpack.packb(obj, use_bin_type=True)


def _unpack(blob: bytes):
    return msgpack.unpackb(blob, raw=False)


@dataclass(frozen=True)
class Commit:
    parents: tuple
    tables: Dict[str, str]  # table name -> snapshot digest
    message: str
    author: str
    ts: float
    meta: Dict[str, Any] = field(default_factory=dict)

    def to_obj(self):
        return {
            "parents": list(self.parents),
            "tables": dict(sorted(self.tables.items())),
            "message": self.message,
            "author": self.author,
            "ts": self.ts,
            "meta": self.meta,
        }

    @staticmethod
    def from_obj(o) -> "Commit":
        return Commit(tuple(o["parents"]), dict(o["tables"]), o["message"],
                      o["author"], o["ts"], o.get("meta", {}))


class Catalog:
    def __init__(self, store: ObjectStore, *, protect_main: bool = True,
                 clock: Callable[[], float] = time.time):
        self.store = store
        self.protect_main = protect_main
        self.clock = clock
        try:
            self.store.get_ref(_BRANCH_PREFIX + "main")
        except RefNotFound:
            root = Commit((), {}, "repository root", "system", self.clock())
            self.store.set_ref(_BRANCH_PREFIX + "main",
                               self.store.put(_pack(root.to_obj())))

    # -------------------------------------------------------------- plumbing
    def _load_commit(self, digest: str) -> Commit:
        return Commit.from_obj(_unpack(self.store.get(digest)))

    def _store_commit(self, commit: Commit) -> str:
        return self.store.put(_pack(commit.to_obj()))

    def head(self, branch: str) -> str:
        return self.store.get_ref(_BRANCH_PREFIX + branch)

    def branches(self) -> List[str]:
        return [r[len(_BRANCH_PREFIX):] for r in self.store.iter_refs()
                if r.startswith(_BRANCH_PREFIX)]

    def tags(self) -> List[str]:
        return [r[len(_TAG_PREFIX):] for r in self.store.iter_refs()
                if r.startswith(_TAG_PREFIX)]

    # --------------------------------------------------------------- resolve
    def resolve(self, ref: str) -> str:
        """Resolve branch / tag / commit digest / time-travel spec.

        Time travel (paper §5 "travels back in time"):
          ``main@1718000000``  — last commit on main at/before unix ts
          ``main~3``           — 3 first-parent steps back from main head
        """
        if "@" in ref:
            base, ts = ref.split("@", 1)
            return self._at_time(self.resolve(base), float(ts))
        if "~" in ref:
            base, n = ref.split("~", 1)
            digest = self.resolve(base)
            for _ in range(int(n)):
                parents = self._load_commit(digest).parents
                if not parents:
                    raise RefNotFound(f"{ref}: ran out of history")
                digest = parents[0]
            return digest
        if ref.startswith((_BRANCH_PREFIX, _TAG_PREFIX)):
            # fully-qualified spelling (``tag=v1.0`` / ``branch=main``) —
            # the exact names sync reports and ref listings print, so they
            # round-trip straight back into resolve
            return self.store.get_ref(ref)
        try:
            return self.head(ref)
        except RefNotFound:
            pass
        try:
            return self.store.get_ref(_TAG_PREFIX + ref)
        except RefNotFound:
            pass
        if "/" in ref:  # remote-tracking: ``origin/main`` (git spelling)
            rname, _, leaf = ref.partition("/")
            for tracking in (remote_tracking_ref(rname, leaf),
                             remote_tracking_tag_ref(rname, leaf)):
                try:
                    return self.store.get_ref(tracking)
                except RefNotFound:
                    pass
        if self.store.has(ref):
            return ref
        # commit digest prefix
        matches = [d for d in self.store.iter_objects() if d.startswith(ref)]
        if len(matches) == 1:
            return matches[0]
        raise RefNotFound(ref)

    def _at_time(self, digest: str, ts: float) -> str:
        cur: Optional[str] = digest
        while cur is not None:
            c = self._load_commit(cur)
            if c.ts <= ts:
                return cur
            cur = c.parents[0] if c.parents else None
        raise RefNotFound(f"no commit at/before ts={ts}")

    # ---------------------------------------------------------------- policy
    @staticmethod
    def branch_owner(branch: str) -> Optional[str]:
        return branch.split(".", 1)[0] if "." in branch else None

    def _check_write(self, branch: str, author: str, *, wap_token: bool):
        if branch == "main":
            if self.protect_main and not wap_token:
                raise PermissionDenied(
                    "main is write-audit-publish protected; use wap.publish()")
            return
        owner = self.branch_owner(branch)
        if owner is not None and owner != author:
            raise PermissionDenied(
                f"{author!r} cannot write to {branch!r} (owner {owner!r})")

    # ---------------------------------------------------------------- writes
    def create_branch(self, name: str, from_ref: str = "main", *,
                      author: str = "system") -> str:
        """Copy-on-write branch: one ref write, zero data copies (§5.4)."""
        if name != "main" and self.branch_owner(name) not in (None, author):
            raise PermissionDenied(f"{author!r} cannot create {name!r}")
        if name in self.branches():
            raise ReproError(f"branch {name!r} exists")
        digest = self.resolve(from_ref)
        self.store.set_ref(_BRANCH_PREFIX + name, digest)
        return digest

    def delete_branch(self, name: str) -> None:
        if name == "main":
            raise PermissionDenied("cannot delete main")
        self.store.delete_ref(_BRANCH_PREFIX + name)

    def create_tag(self, name: str, ref: str) -> str:
        digest = self.resolve(ref)
        self.store.set_ref(_TAG_PREFIX + name, digest)
        return digest

    def delete_tag(self, name: str) -> None:
        self.store.delete_ref(_TAG_PREFIX + name)

    def commit(
        self,
        branch: str,
        table_updates: Mapping[str, Optional[str]],
        message: str,
        *,
        author: str = "system",
        meta: Optional[Dict[str, Any]] = None,
        _wap_token: bool = False,
    ) -> str:
        """Multi-table transaction: atomically update snapshot pointers on a
        branch.  ``None`` as snapshot digest deletes the table."""
        self._check_write(branch, author, wap_token=_wap_token)
        old_head = self.head(branch)
        tables = dict(self._load_commit(old_head).tables)
        for name, snap in table_updates.items():
            if snap is None:
                tables.pop(name, None)
            else:
                tables[name] = snap
        commit = Commit((old_head,), tables, message, author, self.clock(),
                        meta or {})
        digest = self._store_commit(commit)
        self.store.cas_ref(_BRANCH_PREFIX + branch, old_head, digest)
        return digest

    # ----------------------------------------------------------------- reads
    def tables(self, ref: str) -> Dict[str, str]:
        return dict(self._load_commit(self.resolve(ref)).tables)

    def input_digests(self, ref: str,
                      names: Optional[Sequence[str]] = None) -> Dict[str, str]:
        """Snapshot digests of (a subset of) tables at ``ref`` — the data half
        of a node's run-cache key: a pipeline reading these tables is
        re-executed iff one of these digests (or its code) changes."""
        tables = self.tables(ref)
        if names is None:
            return tables
        return {n: tables[n] for n in names if n in tables}

    def snapshot_of(self, ref: str, table: str) -> str:
        tables = self.tables(ref)
        if table not in tables:
            from .errors import TableNotFound
            raise TableNotFound(f"{table!r} not in {ref!r}")
        return tables[table]

    def log(self, ref: str, *, first_parent: bool = True) -> List[str]:
        out, cur = [], self.resolve(ref)
        seen: Set[str] = set()
        stack = [cur]
        while stack:
            digest = stack.pop(0)
            if digest in seen:
                continue
            seen.add(digest)
            out.append(digest)
            parents = self._load_commit(digest).parents
            if first_parent:
                stack.extend(parents[:1])
            else:
                stack.extend(parents)
        return out

    def commit_info(self, ref: str) -> Commit:
        return self._load_commit(self.resolve(ref))

    # ----------------------------------------------------------------- merge
    def _ancestors(self, digest: str) -> Set[str]:
        seen: Set[str] = set()
        stack = [digest]
        while stack:
            d = stack.pop()
            if d in seen:
                continue
            seen.add(d)
            stack.extend(self._load_commit(d).parents)
        return seen

    def merge_base(self, a: str, b: str) -> Optional[str]:
        """Lowest common ancestor (first found walking a's history by ts)."""
        anc_b = self._ancestors(b)
        best, best_ts = None, -1.0
        for d in self._ancestors(a):
            if d in anc_b:
                ts = self._load_commit(d).ts
                if ts > best_ts:
                    best, best_ts = d, ts
        return best

    def merge(self, src_ref: str, dst_branch: str, *, author: str = "system",
              message: Optional[str] = None, _wap_token: bool = False) -> str:
        """Fast-forward when possible, else 3-way at table granularity.

        Conflict rule (Nessie semantics): a table changed on *both* sides
        since the merge base conflicts unless both sides reached the same
        snapshot.
        """
        self._check_write(dst_branch, author, wap_token=_wap_token)
        src = self.resolve(src_ref)
        dst = self.head(dst_branch)
        if src == dst:
            return dst
        if dst in self._ancestors(src):  # fast-forward
            self.store.cas_ref(_BRANCH_PREFIX + dst_branch, dst, src)
            return src
        base = self.merge_base(src, dst)
        base_tables = self._load_commit(base).tables if base else {}
        src_tables = self._load_commit(src).tables
        dst_tables = self._load_commit(dst).tables
        conflicts, merged = [], dict(dst_tables)
        for name in sorted(set(base_tables) | set(src_tables) | set(dst_tables)):
            b = base_tables.get(name)
            s = src_tables.get(name)
            d = dst_tables.get(name)
            if s == d:
                continue
            src_changed, dst_changed = (s != b), (d != b)
            if src_changed and dst_changed:
                conflicts.append(name)
            elif src_changed:
                if s is None:
                    merged.pop(name, None)
                else:
                    merged[name] = s
        if conflicts:
            raise MergeConflict(conflicts)
        commit = Commit(
            (dst, src), merged,
            message or f"merge {src_ref} into {dst_branch}",
            author, self.clock(), {"merge_base": base},
        )
        digest = self._store_commit(commit)
        self.store.cas_ref(_BRANCH_PREFIX + dst_branch, dst, digest)
        return digest

    def diff(self, ref_a: str, ref_b: str) -> Dict[str, tuple]:
        """Tables whose snapshot differs between two refs."""
        ta, tb = self.tables(ref_a), self.tables(ref_b)
        out = {}
        for name in sorted(set(ta) | set(tb)):
            if ta.get(name) != tb.get(name):
                out[name] = (ta.get(name), tb.get(name))
        return out
