"""Git-remote semantics for the catalog: ``push`` / ``pull`` / ``clone``.

What moves when refs sync (the paper's "full pipeline reproducibility with a
few CLI commands", made multi-host):

1. the **commit closure** of every pushed/pulled branch and tag — ancestor
   commits, the table snapshots those commits reference, the tensorfiles
   those snapshots manifest;
2. the **run-cache closure** — cache entries whose input snapshot digests
   are satisfied by the commit closure (computed to a fixpoint so a chain of
   hits through unmaterialized intermediates transfers whole), plus the
   output snapshots those entries point at;
3. the **run manifests** — ledger entries recorded on a synced branch whose
   data/result commits are inside the closure, grafted onto the
   destination's own chain under their original run ids (so
   ``repro run --id`` replays cross-host).

Transfer rules that make this safe over a flaky wire:

* objects are copied **dependencies-first**, so any object present on the
  destination has its full closure present — an interrupted transfer leaves
  orphans at worst, never a torn closure, and a rerun resumes by skipping
  completed subtrees (dedup via batched ``has_many``);
* refs move **last** and via **all-or-nothing compare-and-set**
  (``cas_refs``): a multi-ref push either lands every branch and tag or none
  of them — one stale branch rolls back the entire ref update — and readers
  never observe a head without its objects;
* the ``cas_refs`` batch carries the destination's **GC generation token**
  (:data:`~repro.core.store.GC_GENERATION_REF`, captured before the first
  byte moved) as an extra guard: a concurrent sweep bumps the token before
  marking, so a sync whose uploads could predate that mark fails its ref
  update cleanly and **retries with a fresh transfer** (re-uploading
  whatever the sweep removed) instead of publishing refs to deleted blobs;
* non-fast-forward branch updates (and tag clobbers) are refused unless
  ``force`` (the freshly initialized empty root commit every new catalog
  starts with is exempt, so cloning/pulling ``main`` into a new lake just
  works).

Transfers are **concurrent**: a coordinator/worker engine
(:class:`_TransferEngine`, same shape as the parallel DAG executor in
``pipeline.execute``) walks the closure graph deps-first and pipelines
batched exists checks, blob gets and content-addressed puts across a bounded
worker pool, so independent subtrees move in parallel.  ``jobs=1`` degrades
to the sequential behavior; every invariant above holds for any ``jobs``
(pinned by ``tests/sync_conformance.py``, which runs the same contract suite
over every backend × transport × concurrency combination).
"""

from __future__ import annotations

import os
import queue
import threading
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from fnmatch import fnmatchcase
from typing import (Dict, Iterable, List, Optional, Sequence, Set, Tuple)

import msgpack

from .catalog import (_BRANCH_PREFIX, _TAG_PREFIX, remote_tracking_ref,
                      remote_tracking_tag_ref)
from .errors import (AmbiguousRefUpdate, CodecUnavailable, ObjectNotFound,
                     RefConflict, RefNotFound, RemoteError, ReproError,
                     SyncError)
from .ledger import RunLedger
from .runcache import RunCache
from .store import (GC_GENERATION_REF, ObjectStore, StoreBackend,
                    decode_frame, ensure_generation, sha256_hex)

_HAS_CHUNK = 256  # digests per batched-exists request
_BLOB_CHUNK = 8   # leaf blobs per batched get/put request
_GC_RETRIES = 3   # fresh-transfer retries after a raced GC sweep


def _default_jobs() -> int:
    """Transfer workers: I/O bound, so not tied to core count."""
    env = os.environ.get("REPRO_SYNC_JOBS")
    return max(1, int(env)) if env else 8


def _pack(obj) -> bytes:
    return msgpack.packb(obj, use_bin_type=True)


def _unpack(blob: bytes):
    return msgpack.unpackb(blob, raw=False)


# ------------------------------------------------------------------- reports
@dataclass
class SyncReport:
    direction: str  # "push" | "pull"
    branch: str
    head: str
    objects_sent: int = 0
    objects_skipped: int = 0
    #: dedup hits whose destination mtime was refreshed so the GC grace
    #: window can't age them out while the rest of the push is in flight
    objects_touched: int = 0
    bytes_sent: int = 0
    bytes_wire: int = 0  # framed/compressed bytes actually sent per object
    #: wire bytes delta frames avoided sending (whole-frame size minus
    #: recipe size, summed over blobs that shipped as deltas)
    bytes_delta_saved: int = 0
    cache_entries: int = 0
    runs: int = 0
    ref_updated: bool = False
    #: how the final ref update landed: "atomic" (one all-or-nothing
    #: cas_refs), "resolved" (a transport fault left the CAS ambiguous and
    #: a re-read confirmed it applied), "fallback" (per-ref CAS with
    #: rollback against a server predating cas_refs)
    ref_update_mode: str = "atomic"
    #: times a concurrent GC sweep bumped the generation token mid-sync,
    #: forcing a fresh transfer + ref-update retry
    gc_retries: int = 0

    def summary(self) -> str:
        wire = (f" (wire={self.bytes_wire})"
                if self.bytes_wire != self.bytes_sent else "")
        delta = (f" delta_saved={self.bytes_delta_saved}"
                 if self.bytes_delta_saved else "")
        return (f"{self.direction} {self.branch}: head={self.head[:12]} "
                f"objects={self.objects_sent} (+{self.objects_skipped} "
                f"deduped) bytes={self.bytes_sent}{wire}{delta} "
                f"cache_entries={self.cache_entries} runs={self.runs} "
                f"ref_updated={self.ref_updated}")


@dataclass
class MultiSyncReport:
    """Accounting for one atomic multi-ref push/pull.

    Byte/object counts are **exact and dedup-aware**: every transferred
    object is counted once in ``objects_sent`` (with its uncompressed size
    in ``bytes_sent``) no matter how many branches/tags reach it, and every
    closure digest the destination already had is counted once in
    ``objects_skipped``."""

    direction: str  # "push" | "pull"
    branches: Dict[str, str]  # branch name -> head digest synced
    tags: Dict[str, str] = field(default_factory=dict)  # tag -> digest
    updated_refs: List[str] = field(default_factory=list)
    objects_sent: int = 0
    objects_skipped: int = 0
    objects_touched: int = 0  # see SyncReport.objects_touched
    bytes_sent: int = 0
    bytes_wire: int = 0  # framed/compressed bytes actually sent per object
    bytes_delta_saved: int = 0  # see SyncReport.bytes_delta_saved
    cache_entries: int = 0
    runs: int = 0
    ref_update_mode: str = "atomic"  # see SyncReport.ref_update_mode
    gc_retries: int = 0  # see SyncReport.gc_retries

    def summary(self) -> str:
        names = sorted(self.branches)
        names += [f"tag:{t}" for t in sorted(self.tags)]
        wire = (f" (wire={self.bytes_wire})"
                if self.bytes_wire != self.bytes_sent else "")
        delta = (f" delta_saved={self.bytes_delta_saved}"
                 if self.bytes_delta_saved else "")
        return (f"{self.direction} [{', '.join(names)}]: "
                f"objects={self.objects_sent} (+{self.objects_skipped} "
                f"deduped) bytes={self.bytes_sent}{wire}{delta} "
                f"cache_entries={self.cache_entries} runs={self.runs} "
                f"refs_updated={len(self.updated_refs)}")


# ------------------------------------------------------------------ transfer
def _get_many(store: StoreBackend, digests: Sequence[str]
              ) -> Dict[str, bytes]:
    if len(digests) > 1:
        return store.get_many(digests)
    return {d: store.get(d) for d in digests}


def _put_many(store: StoreBackend, blobs: Sequence[bytes]) -> List[str]:
    if len(blobs) > 1:
        return store.put_many(blobs)
    return [store.put(b) for b in blobs]


class _TransferEngine:
    """Concurrent deps-first closure copier src → dst.

    Coordinator/worker split, mirroring the parallel DAG executor
    (``pipeline.execute``): worker threads do ONLY store I/O — batched
    exists checks, blob gets, content-addressed puts — while all graph
    bookkeeping (discovery, dependency counts, put eligibility) happens on
    the coordinating thread, so the deps-first invariant needs no locks.

    Invariant: a blob is written to dst strictly after everything it
    references is on dst, for any worker count — so a crash at any point
    leaves orphans at worst, never a torn closure, and a re-run resumes by
    pruning subtrees the destination already has (batched ``has_many``).
    ``done`` (digests known on dst) persists across :meth:`run` calls, so
    later phases (cache entries, run manifests) dedup against everything a
    previous phase already moved.
    """

    _COMMIT, _SNAPSHOT, _MLIST, _MANIFEST, _BLOB = "c", "s", "l", "m", "b"

    def __init__(self, src: StoreBackend, dst: StoreBackend, report,
                 *, jobs: Optional[int] = None, compress_wire: bool = True,
                 delta_frames: bool = True):
        self.src = src
        self.dst = dst
        self.report = report  # any object with the Sync*Report counters
        # touch-on-dedup: refresh dst mtimes of already-present objects so
        # a long push's dedup hits can't age past the GC grace window
        # mid-transfer (ROADMAP item 3).  Best-effort capability — absent
        # on backends without cheap mtime updates.
        self._touch = getattr(dst, "touch_many", None)
        self._to_touch: List[str] = []
        self.jobs = max(1, jobs) if jobs is not None else _default_jobs()
        # leaf blobs move as framed at-rest payloads when both sides speak
        # the encoded contract: compressed ONCE (at the source's original
        # put), verified at every hop, never recompressed
        self._encoded = (compress_wire
                         and hasattr(src, "get_many_encoded")
                         and hasattr(dst, "put_many_encoded"))
        # delta frames: large blobs ship as chunk recipes against what the
        # destination already holds (checkpoint-to-checkpoint pushes share
        # most of their bytes under new digests).  Requires the encoded
        # path (the recipes are built from the decoded payloads it already
        # verifies) and a destination speaking the delta wire ops;
        # negotiation is per hop — one "unknown op" downgrades the rest of
        # the transfer to whole frames, silently.
        self._delta = (delta_frames and self._encoded
                       and hasattr(dst, "has_chunks")
                       and hasattr(dst, "put_objects_delta"))
        # jobs=1 preserves the PR-2 wire pattern — one blob per round-trip,
        # the finest resume granularity; with a pool, gets/puts pipeline in
        # chunks (one wire frame per chunk, one coordinator wakeup per
        # chunk — per-object events made the coordinator the bottleneck)
        self._chunk = 1 if self.jobs == 1 else _BLOB_CHUNK
        self.done: Set[str] = set()       # digests known to be on dst
        self._seen: Dict[str, str] = {}   # digest -> kind, once discovered
        self._waiters: Dict[str, List[str]] = {}  # child -> parent digests
        self._npending: Dict[str, int] = {}  # parent -> children not done
        self._payload: Dict[str, bytes] = {}  # expanded, awaiting children
        self._to_check: List[str] = []
        self._to_fetch: List[Tuple[str, str]] = []   # (kind, digest)
        self._to_copy: List[str] = []
        self._to_put: List[Tuple[str, bytes]] = []   # deps done, write now

    # ------------------------------------------------------------ plumbing
    def _children(self, kind: str, blob: bytes) -> List[Tuple[str, str]]:
        if kind == self._COMMIT:
            obj = _unpack(blob)
            return ([(self._COMMIT, p) for p in obj.get("parents", [])]
                    + [(self._SNAPSHOT, s)
                       for s in sorted(obj.get("tables", {}).values())])
        if kind == self._SNAPSHOT:
            obj = _unpack(blob)
            if obj.get("manifest_list") is not None:  # v1 hierarchy
                out = [(self._MLIST, obj["manifest_list"])]
            else:  # legacy v0: flat entry list inline
                out = [(self._BLOB, entry[0])
                       for entry in obj.get("manifest", [])]
            if obj.get("parent"):
                out.append((self._SNAPSHOT, obj["parent"]))
            return out
        if kind == self._MLIST:
            obj = _unpack(blob)
            return [(self._MANIFEST, row[0])
                    for row in obj.get("manifests", [])]
        if kind == self._MANIFEST:
            obj = _unpack(blob)
            return [(self._BLOB, entry[0])
                    for entry in obj.get("entries", [])]
        return []  # leaf tensorfile

    def _want(self, kind: str, digest: str, parent: Optional[str]) -> bool:
        """Record that ``parent`` needs ``digest`` on dst; True iff the
        parent has to wait for it (i.e. it is not already known there)."""
        if digest in self.done:
            return False
        if parent is not None:
            self._waiters.setdefault(digest, []).append(parent)
        if digest not in self._seen:
            self._seen[digest] = kind
            self._to_check.append(digest)
        return True

    # ------------------------------------------------------- worker tasks
    def _task_check(self, chunk: List[str]):
        return ("checked", chunk, self.dst.has_many(chunk))

    def _task_fetch(self, items: List[Tuple[str, str]]):
        blobs = _get_many(self.src, [d for _k, d in items])
        return ("fetched", [(k, d, blobs[d]) for k, d in items])

    def _task_copy(self, digests: List[str]):
        if self._encoded:
            try:
                return self._task_copy_encoded(digests)
            except CodecUnavailable:
                # a payload needs a compressor one side lacks (e.g. zstd
                # blob, zlib-only host): re-send this chunk raw — the
                # destination re-encodes with its own codec.  When a SIDE
                # (not a payload) is the problem — a server predating the
                # encoded ops — stop trying for the rest of the transfer,
                # or every later chunk would fetch and decode its payloads
                # twice.  (Benign race: workers flip a monotonic bool.)
                for side in (self.src, self.dst):
                    supports = getattr(side, "_supports_encoded", None)
                    if supports is not None and not supports():
                        self._encoded = False
        blobs = _get_many(self.src, digests)
        written = _put_many(self.dst, [blobs[d] for d in digests])
        for digest, got in zip(digests, written):
            if got != digest:  # defensive: src handed us corrupt bytes
                raise SyncError(f"transfer of {digest} produced {got}")
        return ("copied", [(d, len(blobs[d]), len(blobs[d]), 0)
                           for d in digests])

    def _task_copy_encoded(self, digests: List[str]):
        """Leaf copy in framed form: fetch the source's at-rest payloads,
        verify them here (never trust the wire — and learn the uncompressed
        size the report counts), forward the ORIGINAL payloads to the
        destination, which decodes and verifies again before storing them
        as-is.  With a delta-capable destination, large payloads try to
        ship as chunk recipes first (:meth:`_copy_delta`)."""
        payloads = self.src.get_many_encoded(digests)
        sizes: Dict[str, int] = {}
        datas: Dict[str, bytes] = {}
        for d in digests:
            data = decode_frame(payloads[d], what=f"object {d}")
            if sha256_hex(data) != d:
                raise SyncError(f"transfer of {d}: payload digest mismatch")
            sizes[d] = len(data)
            datas[d] = data
        if self._delta:
            events = self._copy_delta(digests, payloads, sizes, datas)
            if events is not None:
                return ("copied", events)
        # digests ride along as a verified hint so a wire destination can
        # skip re-decoding what this loop just checked
        written = self.dst.put_many_encoded([payloads[d] for d in digests],
                                            digests=digests)
        for digest, got in zip(digests, written):
            if got != digest:
                raise SyncError(f"transfer of {digest} produced {got}")
        return ("copied", [(d, sizes[d], len(payloads[d]), 0)
                           for d in digests])

    def _copy_delta(self, digests: List[str], payloads: Dict[str, bytes],
                    sizes: Dict[str, int], datas: Dict[str, bytes]):
        """Delta leg of a leaf chunk: chunk the large blobs, ask the
        destination which chunk hashes it already resolves (ONE round-trip
        for the whole chunk), ship recipes where they beat the whole frame
        and whole frames for the rest.  Returns the ``copied`` event list,
        or ``None`` to let the caller run the plain encoded path (nothing
        eligible, or the destination downgraded)."""
        from . import delta as delta_mod

        chunked = {d: delta_mod.chunk_blob(datas[d]) for d in digests
                   if sizes[d] >= delta_mod.DELTA_MIN_BYTES}
        if not chunked:
            return None
        hashes = sorted({h for chunks in chunked.values()
                         for h, _o, _l in chunks})
        have = self.dst.has_chunks(hashes)
        supports = getattr(self.dst, "_supports_delta", None)
        if supports is not None and not supports():
            # old server: stop chunking for the rest of the transfer
            # (benign race: workers flip a monotonic bool, same pattern as
            # the encoded-path kill switch)
            self._delta = False
            return None
        recipes: List[Tuple[str, list]] = []
        recipe_cost: Dict[str, int] = {}
        whole: List[str] = []
        for d in digests:
            chunks = chunked.get(d)
            if chunks and have:
                recipe, cost = delta_mod.build_recipe(datas[d], chunks, have)
                # a recipe's literals are uncompressed, the whole frame is
                # not — only ship the delta when it clearly wins
                if cost < 0.9 * len(payloads[d]):
                    recipes.append((d, recipe))
                    recipe_cost[d] = cost
                    continue
            whole.append(d)
        events: List[Tuple[str, int, int, int]] = []
        if recipes:
            stored, stale = self.dst.put_objects_delta(recipes)
            stored_set = set(stored)
            for d, _recipe in recipes:
                if d in stored_set:
                    events.append((d, sizes[d], recipe_cost[d],
                                   len(payloads[d]) - recipe_cost[d]))
                else:
                    # stale reference (index eviction / raced GC) or a
                    # downgrading server: this blob goes whole-frame
                    whole.append(d)
        if whole:
            written = self.dst.put_many_encoded(
                [payloads[d] for d in whole], digests=whole)
            for digest, got in zip(whole, written):
                if got != digest:
                    raise SyncError(f"transfer of {digest} produced {got}")
            events.extend((d, sizes[d], len(payloads[d]), 0) for d in whole)
        return events

    def _task_put(self, items: List[Tuple[str, bytes]]):
        written = _put_many(self.dst, [b for _d, b in items])
        for (digest, blob), got in zip(items, written):
            if got != digest:
                raise SyncError(f"transfer of {digest} produced {got}")
        return ("put", [(d, len(b), len(b), 0) for d, b in items])

    def _task_touch(self, digests: List[str]):
        return ("touched", self._touch(digests))

    # -------------------------------------------------------- coordinator
    def _finish(self, digest: str) -> None:
        """``digest`` is now on dst: release parents whose last missing
        child this was (their put becomes eligible only now — deps-first)."""
        self.done.add(digest)
        for parent in self._waiters.pop(digest, ()):
            self._npending[parent] -= 1
            if self._npending[parent] == 0:
                del self._npending[parent]
                self._to_put.append((parent, self._payload.pop(parent)))

    def _flush(self, submit) -> None:
        for i in range(0, len(self._to_check), _HAS_CHUNK):
            submit(self._task_check, self._to_check[i:i + _HAS_CHUNK])
        self._to_check = []
        for i in range(0, len(self._to_fetch), self._chunk):
            submit(self._task_fetch, self._to_fetch[i:i + self._chunk])
        self._to_fetch = []
        for i in range(0, len(self._to_copy), self._chunk):
            submit(self._task_copy, self._to_copy[i:i + self._chunk])
        self._to_copy = []
        for i in range(0, len(self._to_put), self._chunk):
            submit(self._task_put, self._to_put[i:i + self._chunk])
        self._to_put = []
        for i in range(0, len(self._to_touch), _HAS_CHUNK):
            submit(self._task_touch, self._to_touch[i:i + _HAS_CHUNK])
        self._to_touch = []

    def _handle(self, event) -> None:
        if event[0] == "checked":
            _tag, chunk, present = event
            for digest in chunk:
                if digest in present:
                    self.report.objects_skipped += 1
                    if self._touch is not None:
                        self._to_touch.append(digest)
                    self._finish(digest)
                elif self._seen[digest] == self._BLOB:
                    self._to_copy.append(digest)  # leaf: fetch+put, batched
                else:
                    self._to_fetch.append((self._seen[digest], digest))
        elif event[0] == "fetched":
            for kind, digest, blob in event[1]:
                children = dict.fromkeys(self._children(kind, blob))
                pending = sum(1 for ck, cd in children
                              if self._want(ck, cd, digest))
                if pending == 0:
                    self._to_put.append((digest, blob))
                else:
                    self._npending[digest] = pending
                    self._payload[digest] = blob
        elif event[0] == "touched":
            self.report.objects_touched += event[1]
        else:  # "copied" | "put" — objects landed on dst
            for digest, nbytes, wire_bytes, saved in event[1]:
                self.report.objects_sent += 1
                self.report.bytes_sent += nbytes
                self.report.bytes_wire += wire_bytes
                self.report.bytes_delta_saved += saved
                self._finish(digest)

    @staticmethod
    def _worker(events: "queue.Queue", fn, args) -> None:
        try:
            events.put(("ok", fn(*args)))
        except BaseException as e:  # noqa: BLE001 - re-raised by coordinator
            events.put(("err", e))

    def run(self, roots: Iterable[Tuple[str, str]]) -> None:
        """Transfer the closures of ``(kind, digest)`` roots, concurrently,
        deps-first.  Blocks until every reachable missing object is on dst
        (or raises, leaving only complete sub-closures behind)."""
        for kind, digest in dict.fromkeys(roots):
            self._want(kind, digest, None)
        if not self._to_check:
            return
        if self.jobs == 1:
            self._run_inline()
        else:
            self._run_pool()

    def _run_inline(self) -> None:
        """Sequential mode: a plain task loop on the calling thread — the
        PR-2 wire pattern (one object per round-trip, deps-first) with zero
        thread handoffs.  The reference behavior the conformance harness
        holds the pool path to."""
        tasks: "deque" = deque()

        def submit(fn, *args):
            tasks.append((fn, args))

        self._flush(submit)
        while tasks:
            fn, args = tasks.popleft()
            self._handle(fn(*args))
            self._flush(submit)

    def _run_pool(self) -> None:
        """Concurrent mode.  Workers report through one event queue rather
        than ``futures.wait(FIRST_COMPLETED)``: a completion wakes the
        coordinator through a single condition variable instead of
        re-registering a waiter with every pending future each round, and
        bursts of completions drain in one pass before the next flush —
        thread wakeups are the scarce resource on small hosts, so the
        engine pays one per *chunk*, never one per object."""
        events: "queue.Queue" = queue.Queue()
        inflight = 0
        with ThreadPoolExecutor(max_workers=self.jobs) as pool:
            def submit(fn, *args):
                nonlocal inflight
                inflight += 1
                pool.submit(self._worker, events, fn, args)

            self._flush(submit)
            try:
                while inflight:
                    batch = [events.get()]
                    while True:  # drain the burst, then flush once
                        try:
                            batch.append(events.get_nowait())
                        except queue.Empty:
                            break
                    inflight -= len(batch)
                    for status, payload in batch:
                        if status == "err":
                            raise payload
                        self._handle(payload)
                    self._flush(submit)
            except BaseException:
                pool.shutdown(wait=False, cancel_futures=True)
                raise

    def put_blobs(self, items: Sequence[Tuple[str, bytes]]) -> None:
        """Write already-held blobs (cache entries, run manifests) to dst,
        batched and dedup-aware, with the same exact accounting as
        :meth:`run`.  Call only after the blobs' own dependencies landed."""
        fresh = [(d, b) for d, b in dict(items).items() if d not in self.done]
        present: Set[str] = set()
        for i in range(0, len(fresh), _HAS_CHUNK):
            present |= self.dst.has_many([d for d, _b in
                                          fresh[i:i + _HAS_CHUNK]])
        self.report.objects_skipped += len(present)
        if self._touch is not None and present:
            self.report.objects_touched += self._touch(sorted(present))
        self.done.update(present)
        todo = [(d, b) for d, b in fresh if d not in present]
        for i in range(0, len(todo), _BLOB_CHUNK):
            chunk = todo[i:i + _BLOB_CHUNK]
            written = _put_many(self.dst, [b for _d, b in chunk])
            for (digest, blob), got in zip(chunk, written):
                if got != digest:
                    raise SyncError(f"transfer of {digest} produced {got}")
                self.report.objects_sent += 1
                self.report.bytes_sent += len(blob)
                self.report.bytes_wire += len(blob)
                self.done.add(digest)


# ------------------------------------------------------------------ closures
def commit_closure(store: StoreBackend, head: str) -> Set[str]:
    """Every digest reachable from ``head``: commits, snapshots,
    manifest-lists, manifests, tensorfiles.  Walks ``store`` directly, so
    call it on the side that has the objects locally (push: before
    transfer; pull: after)."""
    closure: Set[str] = set()
    stack: List[Tuple[str, str]] = [("c", head)]
    while stack:
        kind, digest = stack.pop()
        if digest in closure:
            continue
        closure.add(digest)
        if kind == "b":
            continue
        obj = _unpack(store.get(digest))
        if kind == "c":
            stack.extend(("c", p) for p in obj.get("parents", []))
            stack.extend(("s", s) for s in obj.get("tables", {}).values())
        elif kind == "s":
            if obj.get("manifest_list") is not None:  # v1 hierarchy
                stack.append(("l", obj["manifest_list"]))
            else:  # legacy v0: flat entry list inline
                stack.extend(("b", e[0]) for e in obj.get("manifest", []))
            if obj.get("parent"):
                stack.append(("s", obj["parent"]))
        elif kind == "l":  # manifest list
            stack.extend(("m", row[0]) for row in obj.get("manifests", []))
        else:  # manifest
            stack.extend(("b", e[0]) for e in obj.get("entries", []))
    return closure


def _is_empty_root(store: StoreBackend, digest: str) -> bool:
    """The parentless zero-table commit a fresh catalog initializes ``main``
    with.  Histories on two hosts always diverge at this commit (it embeds a
    timestamp), so fast-forward checks treat it as replaceable."""
    try:
        obj = _unpack(store.get(digest))
    except ObjectNotFound:
        return False
    return not obj.get("parents") and not obj.get("tables")


def _select_cache_entries(
    cache: RunCache, store: StoreBackend, closure: Set[str]
) -> List[Tuple[str, str, bytes, Optional[str]]]:
    """Cache entries shippable with a branch: entries whose input digests
    are all inside the branch closure — iterated to a fixpoint so an entry
    keyed on another entry's (possibly unmaterialized) output snapshot
    qualifies once that entry is selected.  Returns
    ``(key, entry_digest, entry_blob, output_snapshot)`` tuples."""
    entries = []
    for key, entry_digest in cache.entry_refs():
        try:
            blob = store.get(entry_digest)
        except ObjectNotFound:  # dangling ref (concurrent GC)
            continue
        entries.append((key, entry_digest, blob, _unpack(blob)))
    available = set(closure)
    selected: List[Tuple[str, str, bytes, Optional[str]]] = []
    picked: Set[str] = set()
    changed = True
    while changed:
        changed = False
        for key, entry_digest, blob, entry in entries:
            if key in picked:
                continue
            inputs = [d for _name, d in entry.get("inputs", [])]
            if all(d in available for d in inputs):
                snapshot = entry.get("snapshot")
                selected.append((key, entry_digest, blob, snapshot))
                picked.add(key)
                if snapshot:
                    available.add(snapshot)
                changed = True
    return selected


def _sync_cache(src: StoreBackend, dst: StoreBackend,
                engine: _TransferEngine, closure: Set[str], report) -> None:
    src_cache, dst_cache = RunCache(src), RunCache(dst)
    selected = _select_cache_entries(src_cache, src, closure)
    # output-snapshot closures first (one concurrent pass, deduped against
    # everything already transferred), entry blobs strictly after: an
    # adopted ref must be warm, never dangling
    engine.run((engine._SNAPSHOT, snapshot)
               for _k, _d, _b, snapshot in selected if snapshot)
    engine.put_blobs([(entry_digest, blob)
                      for _k, entry_digest, blob, _s in selected])
    for key, entry_digest, _blob, _snapshot in selected:
        if dst_cache.adopt(key, entry_digest):
            report.cache_entries += 1


def _sync_runs(src: StoreBackend, dst: StoreBackend,
               engine: _TransferEngine, closure: Set[str],
               branches: Set[str], report) -> None:
    src_ledger, dst_ledger = RunLedger(src), RunLedger(dst)
    have = set(dst_ledger.runs())
    picked = []
    grafted: List[str] = []  # manifests of runs dst already grafted
    for link in src_ledger.links():
        run_id, manifest_digest = link["run_id"], link["manifest"]
        if run_id in have:
            grafted.append(manifest_digest)
            continue
        try:
            blob = src.get(manifest_digest)
        except ObjectNotFound:
            continue
        manifest = _unpack(blob)
        # only runs recorded on a synced branch whose pinned commits made
        # the trip — a manifest must never reference objects the
        # destination cannot resolve
        if manifest.get("branch") not in branches:
            continue
        if manifest.get("data_commit") not in closure:
            continue
        if manifest.get("result_commit") not in closure:
            continue
        picked.append((run_id, manifest_digest, blob))
    # Presence-ensure already-grafted manifests WITHOUT fetching them
    # (batched exists, normally zero missing): a GC-retry re-transfer
    # must heal a manifest a raced sweep removed after its graft, but an
    # ordinary sync must not re-read the whole run history off src.
    grafted = list(dict.fromkeys(grafted))
    missing: Set[str] = set(grafted)
    for i in range(0, len(grafted), _HAS_CHUNK):
        missing -= engine.dst.has_many(grafted[i:i + _HAS_CHUNK])
    ensure: List[Tuple[str, bytes]] = []
    for manifest_digest in missing:
        try:
            blob = src.get(manifest_digest)
        except ObjectNotFound:
            continue
        manifest = _unpack(blob)
        if manifest.get("branch") not in branches:
            continue
        if (manifest.get("data_commit") not in closure
                or manifest.get("result_commit") not in closure):
            continue
        ensure.append((manifest_digest, blob))
    engine.put_blobs(ensure + [(digest, blob) for _r, digest, blob in picked])
    for run_id, manifest_digest, _blob in reversed(picked):  # oldest first
        dst_ledger.graft(run_id, manifest_digest)
        report.runs += 1


# --------------------------------------------------------------- ref helpers
def _list_ref_names(store: StoreBackend, prefix: str) -> List[str]:
    names: List[str] = []
    token: Optional[str] = None
    while True:
        page, token = store.list_refs(prefix, page_token=token)
        names.extend(name[len(prefix):] for name, _d in page)
        if token is None:
            return names


def _match_refs(store: StoreBackend, prefix: str,
                patterns: Iterable[str]) -> List[str]:
    """Expand branch/tag patterns against ``store``'s refs: a pattern with
    glob characters matches every existing name (zero matches is fine, like
    git); a literal name passes through untouched (existence is checked by
    the caller, which can say *which* side is missing it)."""
    out: List[str] = []
    names: Optional[List[str]] = None
    for pat in patterns:
        if any(ch in pat for ch in "*?["):
            if names is None:
                names = _list_ref_names(store, prefix)
            out.extend(n for n in names if fnmatchcase(n, pat))
        else:
            out.append(pat)
    return list(dict.fromkeys(out))


def _refs_match(store: StoreBackend,
                updates: Sequence[Tuple[str, Optional[str], str]]) -> bool:
    """True iff every ref in ``updates`` currently holds its NEW value —
    how an ambiguous CAS is resolved by re-reading the authoritative side."""
    for name, _expected, new in updates:
        try:
            if store.get_ref(name) != new:
                return False
        except RefNotFound:
            return False
    return True


def _cas_refs(store: StoreBackend,
              updates: Sequence[Tuple[str, Optional[str], str]]) -> str:
    """All-or-nothing ref update.  Returns how it landed (recorded in the
    sync report): ``"atomic"`` — one native ``cas_refs`` batch;
    ``"resolved"`` — the batch was interrupted by a transport fault
    (:class:`AmbiguousRefUpdate`) and a re-read of the refs confirmed it
    had in fact applied; ``"fallback"`` — per-ref CAS against a store that
    only speaks the PR-2 contract (no ``cas_refs``; the server refuses the
    unknown op *before* touching any ref, so falling back is safe).

    The fallback rolls already-applied refs back on ANY mid-batch failure
    — conflict, transport fault, crash-in-flight — never just on a clean
    ``RefConflict``: a fault between two per-ref CAS calls must not leave
    some refs updated and others stale (the torn state native ``cas_refs``
    exists to prevent).  An ambiguous per-ref CAS is resolved by re-read
    before deciding whether it belongs to the applied set.  The window
    between a failure and its rollback is visible to concurrent readers,
    which native ``cas_refs`` never exposes."""
    native = getattr(store, "cas_refs", None)
    if native is not None:
        try:
            native(updates)
            return "atomic"
        except AmbiguousRefUpdate as ambiguous:
            # the batch may have landed before the fault: re-read the refs
            # to resolve before reporting a failure that silently succeeded
            try:
                applied = _refs_match(store, updates)
            except ReproError:
                raise ambiguous  # cannot re-read either: stay ambiguous
            if applied:
                return "resolved"
            raise RemoteError(
                "ref update interrupted by a transport fault; the refs "
                "were re-read and verified unchanged — retry the "
                "operation") from ambiguous
        except RemoteError as e:
            if not ("bad_request" in str(e) and "unknown op" in str(e)):
                raise
    applied_refs: List[Tuple[str, Optional[str], str]] = []
    try:
        for name, expected, new in updates:
            try:
                store.cas_ref(name, expected, new)
            except AmbiguousRefUpdate as ambiguous:
                try:
                    current: Optional[str] = store.get_ref(name)
                except RefNotFound:
                    current = None
                except ReproError:
                    raise ambiguous from None
                if current != new:
                    # verified not applied → clean failure; the outer
                    # handler rolls back the refs applied before this one
                    raise RemoteError(
                        f"ref update for {name!r} interrupted by a "
                        "transport fault; the ref was re-read and "
                        "verified unchanged") from ambiguous
            applied_refs.append((name, expected, new))
    except BaseException as failure:
        torn: List[str] = []
        for name, expected, new in reversed(applied_refs):
            try:
                if expected is None:
                    store.delete_ref(name)
                else:
                    store.cas_ref(name, new, expected)
            except ReproError:
                torn.append(name)  # racer moved it / wire died again
        if torn:
            raise SyncError(
                f"ref update failed mid-batch AND rollback could not "
                f"restore {torn}; inspect the remote refs") from failure
        raise
    return "fallback"


# ----------------------------------------------------------------- push/pull
def push_refs(local: StoreBackend, remote: StoreBackend,
              branches: Sequence[str], *, tags: Sequence[str] = (),
              remote_name: str = "origin", force: bool = False,
              cache_entries: bool = True, runs: bool = True,
              jobs: Optional[int] = None,
              compress_wire: bool = True,
              delta_frames: bool = True) -> MultiSyncReport:
    """Atomic multi-ref push: several branches plus tags move in ONE
    deps-first transfer (shared subtrees dedup across refs), then every ref
    lands via one all-or-nothing ``cas_refs`` — a fast-forward conflict on
    any branch, or a tag clobber, leaves every ref on both sides unchanged.

    ``branches``/``tags`` accept glob patterns, expanded against the local
    refs.  Fast-forward and tag-immutability preflights run before any byte
    moves; the CAS re-validates at commit time, so a racing pusher loses
    with a conflict instead of splitting the ref set.
    """
    branch_names = _match_refs(local, _BRANCH_PREFIX, branches)
    tag_names = _match_refs(local, _TAG_PREFIX, tags)
    if not branch_names and not tag_names:
        raise SyncError("push: no branches or tags matched")

    heads: Dict[str, str] = {}
    for branch in branch_names:
        try:
            heads[branch] = local.get_ref(_BRANCH_PREFIX + branch)
        except RefNotFound:
            raise SyncError(
                f"local branch {branch!r} does not exist") from None
    tag_digests: Dict[str, str] = {}
    for tag in tag_names:
        try:
            tag_digests[tag] = local.get_ref(_TAG_PREFIX + tag)
        except RefNotFound:
            raise SyncError(f"local tag {tag!r} does not exist") from None

    report = MultiSyncReport("push", dict(heads), dict(tag_digests))
    closures = {b: commit_closure(local, h) for b, h in heads.items()}
    closure: Set[str] = set().union(
        *closures.values(),
        *(commit_closure(local, d) for d in tag_digests.values())) \
        if (closures or tag_digests) else set()

    # preflight every ref before moving a single byte
    updates: List[Tuple[str, Optional[str], str]] = []
    for branch, head in heads.items():
        ref = _BRANCH_PREFIX + branch
        try:
            current: Optional[str] = remote.get_ref(ref)
        except RefNotFound:
            current = None
        if current == head:
            continue
        if (current is not None and current not in closures[branch]
                and not force and not _is_empty_root(remote, current)):
            raise SyncError(
                f"push {branch!r}: remote head {current[:12]} is not an "
                "ancestor of the pushed head (non-fast-forward); pull "
                "first or push with force=True — no ref was updated")
        updates.append((ref, current, head))
    for tag, digest in tag_digests.items():
        ref = _TAG_PREFIX + tag
        try:
            current = remote.get_ref(ref)
        except RefNotFound:
            current = None
        if current == digest:
            continue
        if current is not None and not force:
            raise SyncError(
                f"push tag {tag!r}: already exists on the remote at "
                f"{current[:12]} (tags are immutable; use force=True to "
                "clobber) — no ref was updated")
        updates.append((ref, current, digest))

    # capture the remote's GC generation token BEFORE the first byte moves:
    # validated inside the final cas_refs batch, it proves no sweep started
    # (and so no mark could have missed these uploads) while we transferred
    guard = ensure_generation(remote) if updates else None
    attempt = 0
    while True:
        engine = _TransferEngine(local, remote, report, jobs=jobs,
                                 compress_wire=compress_wire,
                                 delta_frames=delta_frames)
        engine.run([(engine._COMMIT, h) for h in heads.values()]
                   + [(engine._COMMIT, d) for d in tag_digests.values()])
        if cache_entries:
            _sync_cache(local, remote, engine, closure, report)
        if runs:
            _sync_runs(local, remote, engine, closure, set(heads), report)
        if not updates:
            break
        try:
            report.ref_update_mode = _cas_refs(
                remote,
                list(updates) + [(GC_GENERATION_REF, guard, guard)])
            report.updated_refs = [name for name, _e, _n in updates]
            break
        except RefConflict as e:
            if GC_GENERATION_REF not in str(e):
                raise SyncError(
                    f"push: ref update conflicted ({e}); every ref was "
                    "left unchanged — pull and retry") from e
            # a remote GC sweep raced this push: some uploads may be gone.
            # Nothing was published (the guard failed the whole batch) —
            # re-capture the token and re-transfer with a FRESH engine (the
            # old done-set can no longer be trusted), then try again.
            attempt += 1
            if attempt > _GC_RETRIES:
                raise SyncError(
                    "push: a concurrent remote GC sweep kept interrupting "
                    f"the ref update ({_GC_RETRIES} retries); every ref "
                    "was left unchanged — re-run the push") from e
            report.gc_retries += 1
            guard = ensure_generation(remote)
    for branch, head in heads.items():
        local.set_ref(remote_tracking_ref(remote_name, branch), head)
    for tag, digest in tag_digests.items():
        local.set_ref(remote_tracking_tag_ref(remote_name, tag), digest)
    return report


def pull_refs(local: StoreBackend, remote: StoreBackend,
              branches: Sequence[str], *, tags: Sequence[str] = (),
              remote_name: str = "origin", force: bool = False,
              cache_entries: bool = True, runs: bool = True,
              jobs: Optional[int] = None, compress_wire: bool = True,
              _shared_done: Optional[Set[str]] = None) -> MultiSyncReport:
    """Atomic multi-ref pull: fetch the closures of several remote branches
    and tags in one concurrent transfer, then fast-forward every local ref
    with one all-or-nothing ``cas_refs``.

    Remote-tracking refs (``remote/<name>/branch=<b>``, ``.../tag=<t>``) are
    written as soon as the closure has landed — before the local branch
    update, so even a refused fast-forward leaves the fetched history
    GC-rooted and resolvable as ``<name>/<ref>``.
    """
    branch_names = _match_refs(remote, _BRANCH_PREFIX, branches)
    tag_names = _match_refs(remote, _TAG_PREFIX, tags)
    if not branch_names and not tag_names:
        raise SyncError("pull: no branches or tags matched")

    heads: Dict[str, str] = {}
    for branch in branch_names:
        try:
            heads[branch] = remote.get_ref(_BRANCH_PREFIX + branch)
        except RefNotFound:
            raise SyncError(
                f"pull {branch!r}: remote has no such branch") from None
    tag_digests: Dict[str, str] = {}
    for tag in tag_names:
        try:
            tag_digests[tag] = remote.get_ref(_TAG_PREFIX + tag)
        except RefNotFound:
            raise SyncError(
                f"pull tag {tag!r}: remote has no such tag") from None

    report = MultiSyncReport("pull", dict(heads), dict(tag_digests))
    # same GC-generation guard as push, but against the LOCAL store: a
    # local `repro gc` racing this pull would otherwise sweep fetched
    # blobs between transfer and the local ref update
    guard = ensure_generation(local)
    attempt = 0
    while True:
        engine = _TransferEngine(remote, local, report, jobs=jobs,
                                 compress_wire=compress_wire)
        if _shared_done is not None and attempt == 0:
            # clone threads one dedup set through its per-branch pulls, so
            # a closure shared by many branches is checked against the
            # destination once, not once per branch.  After a raced sweep
            # the shared set lies — retries start from an empty one.
            engine.done = _shared_done
        engine.run([(engine._COMMIT, h) for h in heads.values()]
                   + [(engine._COMMIT, d) for d in tag_digests.values()])

        # everything is local now — closures walk the local store
        closures = {b: commit_closure(local, h) for b, h in heads.items()}
        closure: Set[str] = set().union(
            *closures.values(),
            *(commit_closure(local, d) for d in tag_digests.values())) \
            if (closures or tag_digests) else set()
        for branch, head in heads.items():
            local.set_ref(remote_tracking_ref(remote_name, branch), head)
        for tag, digest in tag_digests.items():
            local.set_ref(remote_tracking_tag_ref(remote_name, tag), digest)

        updates: List[Tuple[str, Optional[str], str]] = []
        for branch, head in heads.items():
            ref = _BRANCH_PREFIX + branch
            try:
                current: Optional[str] = local.get_ref(ref)
            except RefNotFound:
                current = None
            if current == head:
                continue
            if (current is not None and current not in closures[branch]
                    and not force and not _is_empty_root(local, current)):
                raise SyncError(
                    f"pull {branch!r}: local head {current[:12]} has "
                    "diverged from the remote (non-fast-forward); push "
                    "first or pull with force=True — no local ref was "
                    "updated")
            updates.append((ref, current, head))
        for tag, digest in tag_digests.items():
            ref = _TAG_PREFIX + tag
            try:
                current = local.get_ref(ref)
            except RefNotFound:
                current = None
            if current == digest:
                continue
            if current is not None and not force:
                raise SyncError(
                    f"pull tag {tag!r}: exists locally at {current[:12]} "
                    "with a different target (tags are immutable; use "
                    "force=True to clobber) — no local ref was updated")
            updates.append((ref, current, digest))
        if not updates:
            break
        try:
            report.ref_update_mode = _cas_refs(
                local, list(updates) + [(GC_GENERATION_REF, guard, guard)])
            report.updated_refs = [name for name, _e, _n in updates]
            break
        except RefConflict as e:
            if GC_GENERATION_REF not in str(e):
                raise SyncError(
                    f"pull: ref update conflicted ({e}); every local ref "
                    "was left unchanged") from e
            attempt += 1
            if attempt > _GC_RETRIES:
                raise SyncError(
                    "pull: a concurrent local GC sweep kept interrupting "
                    f"the ref update ({_GC_RETRIES} retries); every local "
                    "ref was left unchanged — re-run the pull") from e
            report.gc_retries += 1
            guard = ensure_generation(local)
    if _shared_done is not None and attempt > 0:
        # rebuild the clone's shared dedup set from the last (verified)
        # transfer — everything in it was re-checked after the sweep
        _shared_done.clear()
        _shared_done.update(engine.done)

    if cache_entries:
        _sync_cache(remote, local, engine, closure, report)
    if runs:
        _sync_runs(remote, local, engine, closure, set(heads), report)
    return report


def _single_report(multi: MultiSyncReport, direction: str,
                   branch: str) -> SyncReport:
    return SyncReport(
        direction, branch, multi.branches[branch],
        objects_sent=multi.objects_sent,
        objects_skipped=multi.objects_skipped,
        objects_touched=multi.objects_touched,
        bytes_sent=multi.bytes_sent,
        bytes_wire=multi.bytes_wire,
        bytes_delta_saved=multi.bytes_delta_saved,
        cache_entries=multi.cache_entries,
        runs=multi.runs,
        ref_updated=(_BRANCH_PREFIX + branch) in multi.updated_refs,
        ref_update_mode=multi.ref_update_mode,
        gc_retries=multi.gc_retries)


def push(local: StoreBackend, remote: StoreBackend, branch: str, *,
         remote_name: str = "origin", force: bool = False,
         cache_entries: bool = True, runs: bool = True,
         tags: Sequence[str] = (), jobs: Optional[int] = None,
         compress_wire: bool = True,
         delta_frames: bool = True) -> SyncReport:
    """Publish one branch (plus optional tags): closure transfer, then a
    CAS-guarded ref update.  Refuses non-fast-forward updates (the remote
    head must be an ancestor of the pushed head) unless ``force``."""
    multi = push_refs(local, remote, [branch], tags=tags,
                      remote_name=remote_name, force=force,
                      cache_entries=cache_entries, runs=runs, jobs=jobs,
                      compress_wire=compress_wire,
                      delta_frames=delta_frames)
    return _single_report(multi, "push", branch)


class _SourceCache:
    """Read-through memo over a fan-out push's shared fetch side.

    ``push_fanout`` runs one :func:`push_refs` per destination off the SAME
    local store; without this wrapper every destination would re-read the
    full closure (walk + leaf fetches) from disk.  Reads memoize by digest
    — safe because the store is content-addressed, so a digest's bytes can
    never change — while every write and every ref operation passes
    straight through.  Lives for one fan-out call, so the memo's size is
    bounded by the pushed closure."""

    def __init__(self, store: StoreBackend):
        self._store = store
        self._blobs: Dict[str, bytes] = {}
        self._payloads: Dict[str, bytes] = {}
        self._lock = threading.Lock()

    def get(self, digest: str) -> bytes:
        with self._lock:
            if digest in self._blobs:
                return self._blobs[digest]
        data = self._store.get(digest)
        with self._lock:
            self._blobs[digest] = data
        return data

    def get_many(self, digests: Sequence[str]) -> Dict[str, bytes]:
        digests = list(digests)
        with self._lock:
            out = {d: self._blobs[d] for d in digests if d in self._blobs}
        rest = [d for d in digests if d not in out]
        if rest:
            fetched = self._store.get_many(rest)
            with self._lock:
                self._blobs.update(fetched)
            out.update(fetched)
        return out

    def get_encoded(self, digest: str) -> bytes:
        with self._lock:
            if digest in self._payloads:
                return self._payloads[digest]
        payload = self._store.get_encoded(digest)
        with self._lock:
            self._payloads[digest] = payload
        return payload

    def get_many_encoded(self, digests: Sequence[str]) -> Dict[str, bytes]:
        digests = list(digests)
        with self._lock:
            out = {d: self._payloads[d] for d in digests
                   if d in self._payloads}
        rest = [d for d in digests if d not in out]
        if rest:
            fetched = self._store.get_many_encoded(rest)
            with self._lock:
                self._payloads.update(fetched)
            out.update(fetched)
        return out

    def __getattr__(self, name: str):
        # refs, puts, has_many, iteration, capability probes — everything
        # else is the store itself
        return getattr(self._store, name)


def push_fanout(local: StoreBackend,
                remotes: Sequence[Tuple[str, StoreBackend]],
                branches: Sequence[str], *, tags: Sequence[str] = (),
                force: bool = False, cache_entries: bool = True,
                runs: bool = True, jobs: Optional[int] = None,
                compress_wire: bool = True, delta_frames: bool = True
                ) -> List[Tuple[str, MultiSyncReport]]:
    """Push the same branches/tags to several remotes — one shared fetch
    side, N destination engines (``repro push --remote a --remote b``).

    Each destination still gets the full :func:`push_refs` treatment
    (preflight, deps-first transfer, GC guard, atomic ``cas_refs``,
    tracking refs under its own remote name), but closure reads hit a
    shared memo, so the local store pays the walk and the leaf fetches
    once, not once per remote.  Destinations are pushed in order and
    independently: a conflict on one raises after earlier remotes already
    landed — like a loop of ``git push``, not a cross-remote transaction
    (remotes don't share a CAS domain to be atomic over)."""
    if not remotes:
        raise SyncError("push_fanout: no remotes given")
    source = _SourceCache(local)
    reports: List[Tuple[str, MultiSyncReport]] = []
    for name, remote in remotes:
        reports.append((name, push_refs(
            source, remote, branches, tags=tags, remote_name=name,
            force=force, cache_entries=cache_entries, runs=runs, jobs=jobs,
            compress_wire=compress_wire, delta_frames=delta_frames)))
    return reports


def pull(local: StoreBackend, remote: StoreBackend, branch: str, *,
         remote_name: str = "origin", force: bool = False,
         cache_entries: bool = True, runs: bool = True,
         tags: Sequence[str] = (), jobs: Optional[int] = None,
         compress_wire: bool = True) -> SyncReport:
    """Fetch one branch's closure (plus optional tags) and fast-forward the
    local branch to it.

    The remote-tracking ref (``remote/<name>/branch=<b>``) is updated as
    soon as the closure has landed — it is the GC root that keeps fetched
    history alive even when the local branch diverges or is deleted.
    """
    multi = pull_refs(local, remote, [branch], tags=tags,
                      remote_name=remote_name, force=force,
                      cache_entries=cache_entries, runs=runs, jobs=jobs,
                      compress_wire=compress_wire)
    return _single_report(multi, "pull", branch)


def clone(remote: StoreBackend, dest_root, *, branch: Optional[str] = None,
          remote_name: str = "origin", cache_entries: bool = True,
          runs: bool = True, tags: Sequence[str] = ("*",),
          jobs: Optional[int] = None) -> Tuple[ObjectStore, List[SyncReport]]:
    """Materialize a fresh local store from a remote: pull one branch, or
    every remote branch when ``branch`` is None.  Remote tags ride along by
    default (``tags=("*",)``; pass ``()`` to skip them) — their closures
    dedup against the branch pulls, so they are usually ref-only writes."""
    local = ObjectStore(dest_root)
    if branch is not None:
        branches: Sequence[str] = [branch]
    else:
        names = _list_ref_names(remote, _BRANCH_PREFIX)
        if not names:
            raise SyncError("clone: remote has no branches")
        branches = sorted(names)
    done: Set[str] = set()  # dedup shared closures across the branch pulls
    reports = []
    for b in branches:
        multi = pull_refs(local, remote, [b], remote_name=remote_name,
                          cache_entries=cache_entries, runs=runs, jobs=jobs,
                          _shared_done=done)
        reports.append(_single_report(multi, "pull", b))
    tag_names = _match_refs(remote, _TAG_PREFIX, tags)
    if tag_names:
        pull_refs(local, remote, [], tags=tag_names,
                  remote_name=remote_name, cache_entries=False,
                  runs=False, jobs=jobs, _shared_done=done)
    return local, reports
