"""Git-remote semantics for the catalog: ``push`` / ``pull`` / ``clone``.

What moves when a branch syncs (the paper's "full pipeline reproducibility
with a few CLI commands", made multi-host):

1. the branch's **commit closure** — every ancestor commit, every table
   snapshot those commits reference, every tensorfile those snapshots
   manifest;
2. the branch's **run-cache closure** — cache entries whose input snapshot
   digests are satisfied by the commit closure (computed to a fixpoint so a
   chain of hits through unmaterialized intermediates transfers whole), plus
   the output snapshots those entries point at;
3. the branch's **run manifests** — ledger entries recorded on the branch
   whose data/result commits are inside the closure, grafted onto the
   destination's own chain under their original run ids (so
   ``repro run --id`` replays cross-host).

Transfer rules that make this safe over a flaky wire:

* objects are copied **dependencies-first**, so any object present on the
  destination has its full closure present — an interrupted transfer leaves
  orphans at worst, never a torn closure, and a rerun resumes by skipping
  completed subtrees (dedup via batched ``has_many``);
* refs move **last** and only via compare-and-set: the destination branch
  head either still points at fully-transferred history or the push/pull
  fails with a conflict — readers never observe a head without its objects;
* non-fast-forward updates are refused unless ``force`` (the freshly
  initialized empty root commit every new catalog starts with is exempt,
  so cloning/pulling ``main`` into a new lake just works).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence, Set, Tuple

import msgpack

from .catalog import _BRANCH_PREFIX, remote_tracking_ref
from .errors import ObjectNotFound, RefNotFound, SyncError
from .ledger import RunLedger
from .runcache import RunCache
from .store import ObjectStore, StoreBackend

_HAS_CHUNK = 256  # digests per batched-exists request


def _pack(obj) -> bytes:
    return msgpack.packb(obj, use_bin_type=True)


def _unpack(blob: bytes):
    return msgpack.unpackb(blob, raw=False)


# ------------------------------------------------------------------ transfer
@dataclass
class SyncReport:
    direction: str  # "push" | "pull"
    branch: str
    head: str
    objects_sent: int = 0
    objects_skipped: int = 0
    bytes_sent: int = 0
    cache_entries: int = 0
    runs: int = 0
    ref_updated: bool = False

    def summary(self) -> str:
        return (f"{self.direction} {self.branch}: head={self.head[:12]} "
                f"objects={self.objects_sent} (+{self.objects_skipped} "
                f"deduped) bytes={self.bytes_sent} "
                f"cache_entries={self.cache_entries} runs={self.runs} "
                f"ref_updated={self.ref_updated}")


class _ClosureTransfer:
    """Copies dependency closures src → dst, deps-first.

    Invariant: a blob is written to dst only after everything it references
    is on dst.  ``done`` holds digests known to be on dst (either just
    written or discovered via batched exists) — anything in it is pruned
    together with its entire sub-closure, which is what makes a re-run of an
    interrupted transfer resume instead of restart.
    """

    _COMMIT, _SNAPSHOT, _BLOB = "c", "s", "b"

    def __init__(self, src: StoreBackend, dst: StoreBackend,
                 report: SyncReport):
        self.src = src
        self.dst = dst
        self.report = report
        self.done: Set[str] = set()
        self._visited: Set[str] = set()

    def _prime(self, digests: Iterable[str]) -> None:
        """Batched exists against dst; present digests become prune points."""
        unknown = [d for d in dict.fromkeys(digests) if d not in self.done]
        for i in range(0, len(unknown), _HAS_CHUNK):
            present = self.dst.has_many(unknown[i:i + _HAS_CHUNK])
            self.report.objects_skipped += len(present)
            self.done.update(present)

    def _put(self, digest: str, blob: bytes) -> None:
        written = self.dst.put(blob)
        if written != digest:  # defensive: src handed us corrupt bytes
            raise SyncError(f"transfer of {digest} produced {written}")
        self.report.objects_sent += 1
        self.report.bytes_sent += len(blob)
        self.done.add(digest)

    def transfer_commit(self, digest: str) -> None:
        self._walk(self._COMMIT, digest)

    def transfer_snapshot(self, digest: str) -> None:
        self._walk(self._SNAPSHOT, digest)

    def _children(self, kind: str, blob: bytes) -> List[Tuple[str, str]]:
        if kind == self._COMMIT:
            obj = _unpack(blob)
            return ([(self._COMMIT, p) for p in obj.get("parents", [])]
                    + [(self._SNAPSHOT, s)
                       for s in sorted(obj.get("tables", {}).values())])
        if kind == self._SNAPSHOT:
            obj = _unpack(blob)
            out = [(self._BLOB, entry[0])
                   for entry in obj.get("manifest", [])]
            if obj.get("parent"):
                out.append((self._SNAPSHOT, obj["parent"]))
            return out
        return []  # leaf tensorfile

    def _walk(self, kind: str, root: str) -> None:
        # Iterative post-order: a (digest, blob) frame is re-pushed as
        # "expanded" and only written once every child frame has drained —
        # metadata blobs ride the stack, leaf tensorfiles never do.
        self._prime([root])
        stack: List[Tuple[str, str, bool, Optional[bytes]]] = \
            [(kind, root, False, None)]
        while stack:
            k, digest, expanded, blob = stack.pop()
            if expanded:
                self._put(digest, blob)
                continue
            if digest in self.done or digest in self._visited:
                continue
            self._visited.add(digest)
            blob = self.src.get(digest)
            children = self._children(k, blob)
            self._prime(d for _k, d in children)
            stack.append((k, digest, True, blob))
            stack.extend((ck, cd, False, None) for ck, cd in children
                         if cd not in self.done)


# ------------------------------------------------------------------ closures
def commit_closure(store: StoreBackend, head: str) -> Set[str]:
    """Every digest reachable from ``head``: commits, snapshots,
    tensorfiles.  Walks ``store`` directly, so call it on the side that has
    the objects locally (push: before transfer; pull: after)."""
    closure: Set[str] = set()
    stack: List[Tuple[str, str]] = [("c", head)]
    while stack:
        kind, digest = stack.pop()
        if digest in closure:
            continue
        closure.add(digest)
        if kind == "b":
            continue
        obj = _unpack(store.get(digest))
        if kind == "c":
            stack.extend(("c", p) for p in obj.get("parents", []))
            stack.extend(("s", s) for s in obj.get("tables", {}).values())
        else:  # snapshot
            stack.extend(("b", e[0]) for e in obj.get("manifest", []))
            if obj.get("parent"):
                stack.append(("s", obj["parent"]))
    return closure


def _is_empty_root(store: StoreBackend, digest: str) -> bool:
    """The parentless zero-table commit a fresh catalog initializes ``main``
    with.  Histories on two hosts always diverge at this commit (it embeds a
    timestamp), so fast-forward checks treat it as replaceable."""
    try:
        obj = _unpack(store.get(digest))
    except ObjectNotFound:
        return False
    return not obj.get("parents") and not obj.get("tables")


def _select_cache_entries(
    cache: RunCache, store: StoreBackend, closure: Set[str]
) -> List[Tuple[str, str, bytes, Optional[str]]]:
    """Cache entries shippable with a branch: entries whose input digests
    are all inside the branch closure — iterated to a fixpoint so an entry
    keyed on another entry's (possibly unmaterialized) output snapshot
    qualifies once that entry is selected.  Returns
    ``(key, entry_digest, entry_blob, output_snapshot)`` tuples."""
    entries = []
    for key, entry_digest in cache.entry_refs():
        try:
            blob = store.get(entry_digest)
        except ObjectNotFound:  # dangling ref (concurrent GC)
            continue
        entries.append((key, entry_digest, blob, _unpack(blob)))
    available = set(closure)
    selected: List[Tuple[str, str, bytes, Optional[str]]] = []
    picked: Set[str] = set()
    changed = True
    while changed:
        changed = False
        for key, entry_digest, blob, entry in entries:
            if key in picked:
                continue
            inputs = [d for _name, d in entry.get("inputs", [])]
            if all(d in available for d in inputs):
                snapshot = entry.get("snapshot")
                selected.append((key, entry_digest, blob, snapshot))
                picked.add(key)
                if snapshot:
                    available.add(snapshot)
                changed = True
    return selected


def _sync_cache(src: StoreBackend, dst: StoreBackend,
                xfer: _ClosureTransfer, closure: Set[str],
                report: SyncReport) -> None:
    src_cache, dst_cache = RunCache(src), RunCache(dst)
    selected = _select_cache_entries(src_cache, src, closure)
    xfer._prime(entry_digest for _k, entry_digest, _b, _s in selected)
    for key, entry_digest, blob, snapshot in selected:
        if snapshot:  # output closure first: an adopted ref must be warm
            xfer.transfer_snapshot(snapshot)
        if entry_digest not in xfer.done:
            xfer._put(entry_digest, blob)
        if dst_cache.adopt(key, entry_digest):
            report.cache_entries += 1


def _sync_runs(src: StoreBackend, dst: StoreBackend,
               xfer: _ClosureTransfer, closure: Set[str], branch: str,
               report: SyncReport) -> None:
    src_ledger, dst_ledger = RunLedger(src), RunLedger(dst)
    have = set(dst_ledger.runs())
    picked = []
    for link in src_ledger.links():
        run_id, manifest_digest = link["run_id"], link["manifest"]
        if run_id in have:
            continue
        try:
            blob = src.get(manifest_digest)
        except ObjectNotFound:
            continue
        manifest = _unpack(blob)
        # only runs recorded on this branch whose pinned commits made the
        # trip — a manifest must never reference objects the destination
        # cannot resolve
        if manifest.get("branch") != branch:
            continue
        if manifest.get("data_commit") not in closure:
            continue
        if manifest.get("result_commit") not in closure:
            continue
        picked.append((run_id, manifest_digest, blob))
    xfer._prime(digest for _r, digest, _b in picked)
    for run_id, manifest_digest, blob in reversed(picked):  # oldest first
        if manifest_digest not in xfer.done:
            xfer._put(manifest_digest, blob)
        dst_ledger.graft(run_id, manifest_digest)
        report.runs += 1


# ----------------------------------------------------------------- push/pull
def push(local: StoreBackend, remote: StoreBackend, branch: str, *,
         remote_name: str = "origin", force: bool = False,
         cache_entries: bool = True, runs: bool = True) -> SyncReport:
    """Publish a branch: closure transfer, then a CAS-guarded ref update.

    Refuses non-fast-forward updates (the remote head must be an ancestor
    of the pushed head) unless ``force``.
    """
    branch_ref = _BRANCH_PREFIX + branch
    try:
        head = local.get_ref(branch_ref)
    except RefNotFound:
        raise SyncError(f"local branch {branch!r} does not exist") from None
    try:
        remote_head: Optional[str] = remote.get_ref(branch_ref)
    except RefNotFound:
        remote_head = None

    report = SyncReport("push", branch, head)
    closure = commit_closure(local, head)
    if (remote_head is not None and remote_head != head
            and remote_head not in closure and not force
            and not _is_empty_root(remote, remote_head)):
        raise SyncError(
            f"push {branch!r}: remote head {remote_head[:12]} is not an "
            "ancestor of the pushed head (non-fast-forward); pull first "
            "or push with force=True")

    xfer = _ClosureTransfer(local, remote, report)
    xfer.transfer_commit(head)
    if cache_entries:
        _sync_cache(local, remote, xfer, closure, report)
    if runs:
        _sync_runs(local, remote, xfer, closure, branch, report)

    if remote_head != head:
        remote.cas_ref(branch_ref, remote_head, head)
        report.ref_updated = True
    local.set_ref(remote_tracking_ref(remote_name, branch), head)
    return report


def pull(local: StoreBackend, remote: StoreBackend, branch: str, *,
         remote_name: str = "origin", force: bool = False,
         cache_entries: bool = True, runs: bool = True) -> SyncReport:
    """Fetch a branch's closure and fast-forward the local branch to it.

    The remote-tracking ref (``remote/<name>/branch=<b>``) is updated as
    soon as the closure has landed — it is the GC root that keeps fetched
    history alive even when the local branch diverges or is deleted.
    """
    branch_ref = _BRANCH_PREFIX + branch
    try:
        remote_head = remote.get_ref(branch_ref)
    except RefNotFound:
        raise SyncError(
            f"pull {branch!r}: remote has no such branch") from None

    report = SyncReport("pull", branch, remote_head)
    xfer = _ClosureTransfer(remote, local, report)
    xfer.transfer_commit(remote_head)
    closure = commit_closure(local, remote_head)  # everything is local now
    local.set_ref(remote_tracking_ref(remote_name, branch), remote_head)

    try:
        local_head: Optional[str] = local.get_ref(branch_ref)
    except RefNotFound:
        local_head = None
    if local_head != remote_head:
        if (local_head is not None and local_head not in closure
                and not force and not _is_empty_root(local, local_head)):
            raise SyncError(
                f"pull {branch!r}: local head {local_head[:12]} has "
                "diverged from the remote (non-fast-forward); push first "
                "or pull with force=True")
        local.cas_ref(branch_ref, local_head, remote_head)
        report.ref_updated = True

    if cache_entries:
        _sync_cache(remote, local, xfer, closure, report)
    if runs:
        _sync_runs(remote, local, xfer, closure, branch, report)
    return report


def clone(remote: StoreBackend, dest_root, *, branch: Optional[str] = None,
          remote_name: str = "origin", cache_entries: bool = True,
          runs: bool = True) -> Tuple[ObjectStore, List[SyncReport]]:
    """Materialize a fresh local store from a remote: pull one branch, or
    every remote branch when ``branch`` is None."""
    local = ObjectStore(dest_root)
    if branch is not None:
        branches: Sequence[str] = [branch]
    else:
        names: List[str] = []
        token: Optional[str] = None
        while True:
            page, token = remote.list_refs(_BRANCH_PREFIX, page_token=token)
            names.extend(name[len(_BRANCH_PREFIX):] for name, _d in page)
            if token is None:
                break
        if not names:
            raise SyncError("clone: remote has no branches")
        branches = sorted(names)
    reports = [pull(local, remote, b, remote_name=remote_name,
                    cache_entries=cache_entries, runs=runs)
               for b in branches]
    return local, reports
