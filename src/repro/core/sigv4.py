"""AWS Signature Version 4 for the S3 backend — sign *and* verify.

Pure stdlib (``hmac``/``hashlib``): the lake must authenticate against real
S3/GCS/MinIO endpoints without growing a dependency.  Two halves:

* :class:`SigV4Signer` — client side.  Builds the canonical request,
  derives the signing key, and returns the headers (``Authorization``,
  ``x-amz-date``, ``x-amz-content-sha256``, optionally
  ``x-amz-security-token``) that :class:`~repro.core.s3.S3Backend`
  attaches to every request when credentials are present.
* :func:`verify` — server side, used by the s3 stub's opt-in verification
  mode.  Re-derives the signature from the *received* request and compares
  with ``hmac.compare_digest``, so CI proves the canonical-request math
  end-to-end with no network access: if the client canonicalizes a query
  string or percent-encodes a key differently than the spec, the stub
  rejects the request and the conformance leg fails.

Canonicalization notes (the parts people get wrong):

* S3 canonical URIs are **single-encoded**: the path is canonicalized as
  sent, percent-escapes preserved.  ``S3Backend`` and the signer share one
  encoder (:func:`canonical_quote`) so the signed string always matches the
  wire bytes.
* Query canonicalization sorts by encoded name, then encoded value, and
  encodes with the unreserved set ``A-Za-z0-9-._~`` (no ``quote_plus``
  space-to-``+``).
* ``x-amz-date`` is formatted with explicit digits, never ``strftime``
  month names — locale-proof by construction (a regression test pins this
  under a non-C locale).
"""

from __future__ import annotations

import hashlib
import hmac
import os
from dataclasses import dataclass, field
from datetime import datetime, timezone
from typing import (Callable, Dict, Iterable, List, Mapping, Optional,
                    Sequence, Tuple)
from urllib.parse import quote, unquote

ALGORITHM = "AWS4-HMAC-SHA256"
EMPTY_SHA256 = hashlib.sha256(b"").hexdigest()

#: unreserved characters per RFC 3986 — SigV4 escapes everything else
_UNRESERVED = "-._~"


def canonical_quote(text: str, *, safe: str = "") -> str:
    """Percent-encode with the SigV4 unreserved set.  ``safe="/"`` for
    URI paths (slashes are structure), ``safe=""`` for query parts."""
    return quote(text, safe=_UNRESERVED + safe)


def canonical_query(params: Sequence[Tuple[str, str]]) -> str:
    """Sorted, canonically-encoded query string (also the wire form the
    backend sends, so signature and request can never drift apart)."""
    encoded = sorted((canonical_quote(k), canonical_quote(v))
                     for k, v in params)
    return "&".join(f"{k}={v}" for k, v in encoded)


def sha256_hex(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def _hmac(key: bytes, message: str) -> bytes:
    return hmac.new(key, message.encode("utf-8"), hashlib.sha256).digest()


def _amz_date(now: datetime) -> str:
    """``YYYYMMDDTHHMMSSZ`` from explicit fields — no strftime names, so
    the output is identical under every locale."""
    return (f"{now.year:04d}{now.month:02d}{now.day:02d}T"
            f"{now.hour:02d}{now.minute:02d}{now.second:02d}Z")


@dataclass(frozen=True)
class Credentials:
    """An access key pair (plus optional STS session token)."""

    access_key: str
    secret_key: str
    session_token: Optional[str] = None

    @classmethod
    def from_env(cls, environ: Optional[Mapping[str, str]] = None
                 ) -> Optional["Credentials"]:
        """Standard AWS variables; returns ``None`` when not configured so
        the backend can fall back to unsigned requests (the stub's default
        mode)."""
        env = os.environ if environ is None else environ
        access = env.get("AWS_ACCESS_KEY_ID", "")
        secret = env.get("AWS_SECRET_ACCESS_KEY", "")
        if not access or not secret:
            return None
        return cls(access_key=access, secret_key=secret,
                   session_token=env.get("AWS_SESSION_TOKEN") or None)


@dataclass
class SigV4Signer:
    credentials: Credentials
    region: str = "us-east-1"
    service: str = "s3"
    #: injectable clock for deterministic tests
    clock: Callable[[], datetime] = field(
        default=lambda: datetime.now(timezone.utc))

    def signing_key(self, date: str) -> bytes:
        """Derive the per-day signing key: the HMAC chain over
        date/region/service/terminator."""
        key = _hmac(b"AWS4" + self.credentials.secret_key.encode("utf-8"),
                    date)
        key = _hmac(key, self.region)
        key = _hmac(key, self.service)
        return _hmac(key, "aws4_request")

    def sign(self, method: str, host: str, path: str,
             query: Sequence[Tuple[str, str]], payload: bytes,
             *, extra_headers: Optional[Mapping[str, str]] = None
             ) -> Dict[str, str]:
        """Headers for one request.  ``path`` must be the already-encoded
        URI path as it goes on the wire; ``query`` the raw (unencoded)
        name/value pairs."""
        now = self.clock()
        amz_date = _amz_date(now)
        scope_date = amz_date[:8]
        payload_hash = sha256_hex(payload)

        headers: Dict[str, str] = {
            "host": host,
            "x-amz-content-sha256": payload_hash,
            "x-amz-date": amz_date,
        }
        if self.credentials.session_token:
            headers["x-amz-security-token"] = self.credentials.session_token
        if extra_headers:
            for name, value in extra_headers.items():
                headers[name.lower()] = value

        signed_names = sorted(headers)
        canonical_headers = "".join(
            f"{name}:{headers[name].strip()}\n" for name in signed_names)
        signed_headers = ";".join(signed_names)
        canonical_request = "\n".join([
            method.upper(), path, canonical_query(query),
            canonical_headers, signed_headers, payload_hash,
        ])
        scope = f"{scope_date}/{self.region}/{self.service}/aws4_request"
        string_to_sign = "\n".join([
            ALGORITHM, amz_date, scope,
            sha256_hex(canonical_request.encode("utf-8")),
        ])
        signature = hmac.new(self.signing_key(scope_date),
                             string_to_sign.encode("utf-8"),
                             hashlib.sha256).hexdigest()
        authorization = (
            f"{ALGORITHM} Credential={self.credentials.access_key}/{scope}, "
            f"SignedHeaders={signed_headers}, Signature={signature}")
        out = {name: headers[name] for name in signed_names if name != "host"}
        out["Authorization"] = authorization
        return out


# ------------------------------------------------------------- verification
class SignatureError(ValueError):
    """A request failed SigV4 verification (stub replies 403)."""


def _parse_authorization(header: str) -> Tuple[str, str, List[str], str]:
    """-> (access_key, scope, signed_header_names, signature)."""
    if not header.startswith(ALGORITHM + " "):
        raise SignatureError(f"unsupported algorithm in {header!r}")
    fields: Dict[str, str] = {}
    for part in header[len(ALGORITHM) + 1:].split(","):
        part = part.strip()
        if "=" not in part:
            raise SignatureError(f"malformed Authorization field {part!r}")
        name, _, value = part.partition("=")
        fields[name] = value
    try:
        credential = fields["Credential"]
        signed_headers = fields["SignedHeaders"]
        signature = fields["Signature"]
    except KeyError as exc:
        raise SignatureError(f"Authorization missing {exc}") from None
    access_key, _, scope = credential.partition("/")
    if not access_key or not scope:
        raise SignatureError(f"malformed Credential {credential!r}")
    return access_key, scope, signed_headers.split(";"), signature


def verify(method: str, path_qs: str, headers: Mapping[str, str],
           payload: bytes,
           secret_for: Callable[[str], Optional[str]]) -> str:
    """Verify a received request's SigV4 signature; returns the access key
    on success, raises :class:`SignatureError` otherwise.

    ``path_qs`` is the request target as received (encoded path, optional
    query string); ``secret_for`` maps access key → secret (``None`` =
    unknown key).  The canonical request is rebuilt from exactly what came
    over the wire, so any client/spec disagreement shows up as a 403 in
    the signed conformance leg rather than passing silently."""
    recv = {k.lower(): v for k, v in headers.items()}
    auth = recv.get("authorization")
    if not auth:
        raise SignatureError("request is unsigned")
    access_key, scope, signed_names, claimed_sig = _parse_authorization(auth)

    scope_parts = scope.split("/")
    if len(scope_parts) != 4 or scope_parts[3] != "aws4_request":
        raise SignatureError(f"malformed credential scope {scope!r}")
    scope_date, region, service = scope_parts[0], scope_parts[1], scope_parts[2]

    amz_date = recv.get("x-amz-date", "")
    if not amz_date.startswith(scope_date):
        raise SignatureError("x-amz-date does not match credential scope")

    claimed_payload_hash = recv.get("x-amz-content-sha256", "")
    if claimed_payload_hash != sha256_hex(payload):
        raise SignatureError("x-amz-content-sha256 does not match body")

    secret = secret_for(access_key)
    if secret is None:
        raise SignatureError(f"unknown access key {access_key!r}")

    path, _, qs = path_qs.partition("?")
    params = []
    if qs:
        for item in qs.split("&"):
            name, _, value = item.partition("=")
            params.append((unquote(name), unquote(value)))

    missing = [n for n in ("host", "x-amz-date", "x-amz-content-sha256")
               if n not in signed_names]
    if missing:
        raise SignatureError(f"required headers not signed: {missing}")
    try:
        canonical_headers = "".join(
            f"{name}:{recv[name].strip()}\n" for name in signed_names)
    except KeyError as exc:
        raise SignatureError(f"signed header absent from request: {exc}")
    canonical_request = "\n".join([
        method.upper(), path, canonical_query(params),
        canonical_headers, ";".join(signed_names), claimed_payload_hash,
    ])
    string_to_sign = "\n".join([
        ALGORITHM, amz_date, scope,
        sha256_hex(canonical_request.encode("utf-8")),
    ])
    signer = SigV4Signer(Credentials(access_key, secret),
                         region=region, service=service)
    expected = hmac.new(signer.signing_key(scope_date),
                        string_to_sign.encode("utf-8"),
                        hashlib.sha256).hexdigest()
    if not hmac.compare_digest(expected, claimed_sig):
        raise SignatureError("signature mismatch")
    return access_key
