"""Background compaction for high-churn tables (ROADMAP item 5).

Micro-batch ingestion (``TableIO.append_stream``) buys flat ingest cost by
landing every batch as its own manifest of small tensorfile fragments —
and pays for it on the read side: scans touch one blob per fragment.
Compaction is the other half of the bargain: rewrite the small fragments
into ``target_rows_per_file``-sized files as a NEW snapshot — the old one
stays immutable and time-travelable until snapshot expiry (the PR-5 GC
grace window) collects it.

The refactor's invariant, enforced at runtime, is **provable
losslessness**: a compacted snapshot's :meth:`~.table.TableIO.logical_digest`
— schema + per-column row bytes in row order, independent of file
boundaries — must equal the source's exactly, or :func:`compact_snapshot`
raises and nothing is published.  Entries already at or above the target
size are reused *verbatim* (same blob digest — zero data read or written
for them), so steady-state compaction cost is proportional to the small
tail, not the table.

:func:`compact_table` runs the snapshot rewrite inside an optimistic
transaction (``core/txn.py``).  Ingestion keeps winning under contention:
a concurrent append moves the table, the compactor's commit conflicts
(an append/compact race is NOT append/append, so the manifest-diff merge
correctly refuses it), and the compactor retries from the new head —
never the other way around.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from .errors import ReproError, TransactionConflict
from .table import ManifestEntry, Snapshot, TableIO, inline_manifest
from . import tensorfile


class CompactionError(ReproError):
    """Compaction produced (or would publish) different logical contents —
    the losslessness proof failed.  Nothing was published."""


@dataclass(frozen=True)
class CompactionReport:
    table: Optional[str]  # None for bare compact_snapshot runs
    old_snapshot: str
    new_snapshot: str
    files_before: int
    files_after: int
    rows: int
    #: bytes of fragment data decoded + re-encoded; right-sized files are
    #: reused verbatim and cost zero here, so write amplification =
    #: bytes_written / ingested bytes stays bounded by the small tail
    bytes_read: int
    bytes_written: int
    logical_digest: str

    def summary(self) -> str:
        name = f"{self.table}: " if self.table else ""
        return (f"compact {name}{self.files_before} -> {self.files_after} "
                f"files, {self.rows} rows, rewrote {self.bytes_written} "
                f"bytes (digest {self.logical_digest[:12]} verified)")


def compact_snapshot(io: TableIO, digest: str, *,
                     target_rows_per_file: Optional[int] = None,
                     keep_history: bool = True) -> CompactionReport:
    """Rewrite ``digest``'s small fragments into target-sized files as a
    new snapshot; returns a report carrying the new digest.

    Row order is preserved exactly (it is part of the logical contents):
    entries are walked in scan order, runs of under-sized fragments are
    buffered and re-chunked, and any entry already holding >=
    ``target_rows_per_file`` rows is carried over by digest.  With
    ``keep_history`` the new snapshot keeps ``digest`` as parent (op
    ``"compact"`` in the lineage); otherwise it starts a fresh chain and
    the old history becomes GC-collectable once nothing references it."""
    target = target_rows_per_file or io.target_rows_per_file
    snap = io.load_snapshot(digest)
    before_digest = io.logical_digest(digest)

    entries: List[ManifestEntry] = []
    buffered: List[dict] = []
    buffered_rows = 0
    bytes_read = 0
    bytes_written = 0

    def flush(final: bool) -> None:
        """Re-chunk the buffered fragment run into target-sized files.
        Mid-stream, hold back a partial tail chunk — the next fragment may
        top it up; at the end everything goes out."""
        nonlocal buffered, buffered_rows, bytes_written
        if not buffered:
            return
        if not final and buffered_rows < target:
            return
        cols = tensorfile.concat(buffered)
        n = buffered_rows
        emit_until = n if final else (n // target) * target
        for start in range(0, emit_until, target):
            stop = min(start + target, emit_until)
            blob, meta = tensorfile.encode(
                {k: v[start:stop] for k, v in cols.items()})
            bytes_written += meta["nbytes"]
            entries.append(ManifestEntry(io.store.put(blob), meta["nrows"],
                                         meta["nbytes"], meta["stats"]))
        if emit_until < n:
            buffered = [{k: v[emit_until:] for k, v in cols.items()}]
            buffered_rows = n - emit_until
        else:
            buffered = []
            buffered_rows = 0

    files_before = 0
    for mf in snap.manifests:
        for entry in io.manifest_entries(mf):
            files_before += 1
            if entry.nrows >= target and not buffered:
                # right-sized and on a clean boundary: reuse verbatim —
                # no decode, no re-encode, no new blob
                entries.append(entry)
                continue
            buffered.append(tensorfile.decode(io.store.get(entry.digest)))
            bytes_read += entry.nbytes
            buffered_rows += entry.nrows
            flush(final=False)
    flush(final=True)

    new_snap = Snapshot(
        schema=snap.schema,
        manifests=(inline_manifest(tuple(entries)),),
        parent=digest if keep_history else None,
        op="compact",
        seq=snap.seq + 1,
    )
    new_digest = io.store_snapshot(new_snap)
    after_digest = io.logical_digest(new_digest)
    if after_digest != before_digest:
        raise CompactionError(
            f"compaction of {digest[:12]} changed logical contents "
            f"({before_digest[:12]} -> {after_digest[:12]}); refusing to "
            "publish")
    return CompactionReport(
        table=None,
        old_snapshot=digest,
        new_snapshot=new_digest,
        files_before=files_before,
        files_after=len(entries),
        rows=snap.nrows,
        bytes_read=bytes_read,
        bytes_written=bytes_written,
        logical_digest=after_digest,
    )


def compact_table(catalog, table: str, *, branch: str = "main",
                  author: str = "compactor",
                  target_rows_per_file: Optional[int] = None,
                  keep_history: bool = True,
                  max_attempts: int = 4,
                  _wap_token: bool = False) -> CompactionReport:
    """Compact ``table`` on ``branch`` through a transaction.

    Each attempt compacts the CURRENT head snapshot; if ingestion lands
    mid-compaction the commit conflicts (append/compact is a genuine
    conflict by design) and the compactor retries against the new head —
    streaming writers never see the compactor, only the compactor yields.
    Raises :class:`~.errors.TransactionConflict` after ``max_attempts``
    losing races (call again later — churn that hot means the table is
    being rewritten anyway)."""
    last: Optional[TransactionConflict] = None
    for _ in range(max_attempts):
        txn = catalog.transaction(branch, author=author)
        report = compact_snapshot(
            txn.io, txn.snapshot_of(table),
            target_rows_per_file=target_rows_per_file,
            keep_history=keep_history)
        txn.write_snapshot(table, report.new_snapshot)
        try:
            txn.commit(f"compact {table}: {report.files_before} -> "
                       f"{report.files_after} files",
                       _wap_token=_wap_token)
        except TransactionConflict as e:
            last = e  # ingestion won the race: retry from the new head
            continue
        return CompactionReport(table=table, old_snapshot=report.old_snapshot,
                                new_snapshot=report.new_snapshot,
                                files_before=report.files_before,
                                files_after=report.files_after,
                                rows=report.rows, bytes_read=report.bytes_read,
                                bytes_written=report.bytes_written,
                                logical_digest=report.logical_digest)
    assert last is not None
    raise last
