"""In-process stub S3 server: the dialect :class:`~repro.core.s3.S3Backend`
speaks, served from the stdlib with zero new dependencies.

One bucket, backed by a plain directory whose layout is EXACTLY the
filesystem :class:`~repro.core.store.ObjectStore` tree (``objects/ab/…``,
``refs/…``) — so the tree a stub serves is simultaneously readable as a
local store, which is what lets the sync conformance harness use a direct
``ObjectStore`` over the same directory as the ground-truth oracle for the
``s3`` leg.

Dialect (the subset of the S3 REST API the backend needs):

    GET    /<bucket>/<key>                     200 body + ETag +
                                               Last-Modified   | 404
           Range: bytes=a-b                    206 + Content-Range | 416
    HEAD   /<bucket>/<key>                     200 headers     | 404
    PUT    /<bucket>/<key>                     200 + ETag
           If-Match: <etag>                    412 unless the current
                                               version matches
           If-None-Match: *                    412 unless the key is absent
    PUT    /<bucket>/<key>?uploadId&partNumber 200 + part ETag
    POST   /<bucket>/<key>?uploads             InitiateMultipartUpload XML
    POST   /<bucket>/<key>?uploadId=U          complete: assemble + store
    DELETE /<bucket>/<key>?uploadId=U          abort: drop buffered parts
    DELETE /<bucket>/<key>                     204 | 404
           If-Match: <etag>                    412 unless the current
                                               version matches
    GET    /<bucket>?list-type=2&prefix=P      ListObjectsV2-style XML:
           [&start-after=K][&max-keys=N]       sorted keys, IsTruncated

Version tokens (ETags) are the sha-256 of the stored bytes — fine for CAS
because ref semantics compare *values* (ABA on equal content is, by
definition, not a conflict).  Conditional evaluation and the write/delete
it guards happen under one server-side lock, which is what makes the
backend's read-compare-conditional-write loop linearizable per key.

Two opt-in test affordances:

* ``credentials=`` turns on **SigV4 verification**: every request must carry
  a valid ``Authorization`` header (verified via :func:`repro.core.sigv4.verify`
  against the received bytes) or it is refused with 403 — CI proves the
  client's canonical-request math without network access.  The returned URL
  embeds the credentials so ``connect(url)`` signs transparently.
* ``httpd.inject_faults(n, status=503)`` arms a **fault plan**: the next
  ``n`` matching requests are answered with a retryable error (``SlowDown``
  body, like real S3 throttling) before service resumes — the hook the
  503-retry regression tests use.

In-flight multipart uploads are buffered in memory and exposed as
``httpd.uploads`` so tests can assert the abort path leaves no orphans.
"""

from __future__ import annotations

import hashlib
import os
import re
import tempfile
import threading
from pathlib import Path
from typing import Dict, List, Optional, Tuple
from urllib.parse import quote
from xml.sax.saxutils import escape

from . import sigv4

_MAX_KEYS_CAP = 1000

_SLOWDOWN_BODY = (
    b'<?xml version="1.0" encoding="UTF-8"?>'
    b"<Error><Code>SlowDown</Code>"
    b"<Message>Please reduce your request rate.</Message></Error>")

_RANGE_RE = re.compile(r"bytes=(\d+)-(\d*)$")


def _etag(data: bytes) -> str:
    return '"' + hashlib.sha256(data).hexdigest() + '"'


class _BucketTree:
    """Key → file mapping over one directory, with atomic writes and
    lock-guarded conditional mutations."""

    def __init__(self, root):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.lock = threading.Lock()

    def _path(self, key: str) -> Path:
        parts = key.split("/")
        for part in parts:
            if not part or part.startswith(".") or part == "..":
                raise ValueError(f"bad key {key!r}")
        return self.root.joinpath(*parts)

    def read(self, key: str) -> Optional[bytes]:
        try:
            return self._path(key).read_bytes()
        except (FileNotFoundError, ValueError):
            return None

    def mtime(self, key: str) -> Optional[float]:
        """Backing-file mtime — served as ``Last-Modified`` so clients
        can apply the GC upload-age grace window, exactly like real S3."""
        try:
            return self._path(key).stat().st_mtime
        except (FileNotFoundError, ValueError):
            return None

    def write(self, key: str, data: bytes) -> None:
        path = self._path(key)
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=".tmp-")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def delete(self, key: str) -> bool:
        try:
            self._path(key).unlink()
            return True
        except (FileNotFoundError, ValueError):
            return False

    def keys(self, prefix: str) -> List[str]:
        """All keys under ``prefix``, sorted (dotfiles — tmp writes, the
        oracle store's ``.cas-lock`` — are invisible)."""
        out: List[str] = []
        for dirpath, dirnames, filenames in os.walk(self.root):
            dirnames[:] = sorted(d for d in dirnames if not d.startswith("."))
            rel = Path(dirpath).relative_to(self.root)
            for fn in filenames:
                if fn.startswith("."):
                    continue
                key = fn if rel == Path(".") else (rel / fn).as_posix()
                if key.startswith(prefix):
                    out.append(key)
        return sorted(out)


def _list_xml(bucket: str, prefix: str, keys: List[str],
              truncated: bool) -> bytes:
    contents = "".join(
        f"<Contents><Key>{escape(k)}</Key></Contents>" for k in keys)
    return (
        '<?xml version="1.0" encoding="UTF-8"?>'
        f"<ListBucketResult><Name>{escape(bucket)}</Name>"
        f"<Prefix>{escape(prefix)}</Prefix>"
        f"<KeyCount>{len(keys)}</KeyCount>"
        f"<IsTruncated>{'true' if truncated else 'false'}</IsTruncated>"
        f"{contents}</ListBucketResult>").encode()


class _FaultPlan:
    """Armed via ``httpd.inject_faults``: answer the next ``n`` matching
    requests with an error status before returning to normal service."""

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: List[dict] = []
        self.served = 0  # total faults actually injected (for assertions)

    def arm(self, count: int, *, status: int = 503,
            method: Optional[str] = None,
            key_contains: Optional[str] = None) -> None:
        with self._lock:
            self._entries.append({"count": count, "status": status,
                                  "method": method,
                                  "key_contains": key_contains})

    def take(self, method: str, key: str) -> Optional[int]:
        """Status to inject for this request, or None to serve normally."""
        with self._lock:
            for entry in self._entries:
                if entry["method"] and entry["method"] != method:
                    continue
                if entry["key_contains"] and entry["key_contains"] not in key:
                    continue
                if entry["count"] > 0:
                    entry["count"] -= 1
                    self.served += 1
                    return entry["status"]
        return None


def serve_s3(root, *, host: str = "127.0.0.1", port: int = 0,
             bucket: str = "lake",
             credentials: Optional["sigv4.Credentials"] = None,
             region: str = "us-east-1",
             max_keys_cap: Optional[int] = None) -> Tuple[object, str]:
    """Serve ``root`` as one S3-dialect bucket on a daemon thread.

    Returns ``(httpd, url)`` where ``url`` is the ``s3://host:port/bucket``
    spelling :func:`repro.core.remote.connect` (and therefore
    ``repro remote add``/``clone``) accepts directly.  ``port=0`` picks a
    free port; call ``httpd.shutdown()`` to stop.

    With ``credentials=`` the stub verifies SigV4 signatures on every
    request (403 on failure) and the returned URL embeds the key pair so
    clients built from it sign automatically.  ``max_keys_cap`` lowers the
    server-side listing page cap (pagination stress tests).
    """
    import email.utils
    import http.server
    import urllib.parse

    tree = _BucketTree(root)
    faults = _FaultPlan()
    # in-flight multipart uploads: id -> {"key": str, "parts": {n: bytes}};
    # in memory on purpose — an aborted upload must leave zero residue in
    # the bucket tree, and tests assert this dict drains
    uploads: Dict[str, dict] = {}
    uploads_lock = threading.Lock()
    upload_seq = [0]

    def _object_headers(key: str, data: bytes) -> dict:
        headers = {"ETag": _etag(data)}
        mtime = tree.mtime(key)
        if mtime is not None:
            # IMF-fixdate, always GMT and always English month names —
            # never strftime, whose %b depends on the process locale
            headers["Last-Modified"] = email.utils.formatdate(
                mtime, usegmt=True)
        return headers

    class Handler(http.server.BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"

        # ------------------------------------------------------ plumbing
        def _reply(self, status: int, body: bytes = b"",
                   headers: Optional[dict] = None) -> None:
            self.send_response(status)
            for name, value in (headers or {}).items():
                self.send_header(name, value)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            if self.command != "HEAD":
                self.wfile.write(body)

        def _key(self) -> Optional[str]:
            """The object key, or None (bad bucket / bucket-level path)."""
            path = urllib.parse.urlsplit(self.path).path
            parts = path.lstrip("/").split("/", 1)
            if not parts or parts[0] != bucket:
                return None
            return urllib.parse.unquote(parts[1]) if len(parts) == 2 else ""

        def _query(self) -> Dict[str, str]:
            # keep_blank_values: "?uploads" (no value) marks multipart
            # initiation and must survive parsing
            return dict(urllib.parse.parse_qsl(
                urllib.parse.urlsplit(self.path).query,
                keep_blank_values=True))

        def _read_body(self) -> bytes:
            length = int(self.headers.get("Content-Length", 0))
            return self.rfile.read(length) if length else b""

        def _gate(self, body: bytes) -> bool:
            """Fault plan + signature verification.  Returns True when the
            request was already answered (fault or 403)."""
            key = self._key() or ""
            status = faults.take(self.command, key)
            if status is not None:
                self._reply(status, _SLOWDOWN_BODY,
                            {"Content-Type": "application/xml"})
                return True
            if credentials is not None:
                try:
                    sigv4.verify(self.command, self.path, dict(self.headers),
                                 body, lambda access: credentials.secret_key
                                 if access == credentials.access_key else None)
                except sigv4.SignatureError as exc:
                    self._reply(403, (
                        '<?xml version="1.0" encoding="UTF-8"?>'
                        "<Error><Code>SignatureDoesNotMatch</Code>"
                        f"<Message>{escape(str(exc))}</Message></Error>"
                    ).encode(), {"Content-Type": "application/xml"})
                    return True
            return False

        # ------------------------------------------------------- listing
        def _list(self) -> None:
            query = self._query()
            cap = max_keys_cap if max_keys_cap is not None else _MAX_KEYS_CAP
            prefix = query.get("prefix", "")
            start_after = query.get("start-after", "")
            limit = min(int(query.get("max-keys", cap) or 1), cap)
            keys = [k for k in tree.keys(prefix)
                    if not start_after or k > start_after]
            page, truncated = keys[:limit], len(keys) > limit
            self._reply(200, _list_xml(bucket, prefix, page, truncated),
                        {"Content-Type": "application/xml"})

        # ----------------------------------------------------- multipart
        def _initiate_upload(self, key: str) -> None:
            with uploads_lock:
                upload_seq[0] += 1
                upload_id = f"upload-{upload_seq[0]:06d}"
                uploads[upload_id] = {"key": key, "parts": {}}
            self._reply(200, (
                '<?xml version="1.0" encoding="UTF-8"?>'
                "<InitiateMultipartUploadResult>"
                f"<Bucket>{escape(bucket)}</Bucket><Key>{escape(key)}</Key>"
                f"<UploadId>{upload_id}</UploadId>"
                "</InitiateMultipartUploadResult>").encode(),
                {"Content-Type": "application/xml"})

        def _put_part(self, key: str, query: Dict[str, str],
                      body: bytes) -> None:
            upload_id = query["uploadId"]
            part_number = int(query["partNumber"])
            with uploads_lock:
                upload = uploads.get(upload_id)
                if upload is None or upload["key"] != key:
                    self._reply(404)
                    return
                upload["parts"][part_number] = body
            self._reply(200, b"", {"ETag": _etag(body)})

        def _complete_upload(self, key: str, upload_id: str) -> None:
            with uploads_lock:
                upload = uploads.get(upload_id)
                if upload is None or upload["key"] != key:
                    self._reply(404)
                    return
                parts = upload["parts"]
                data = b"".join(parts[n] for n in sorted(parts))
                del uploads[upload_id]
            try:
                with tree.lock:
                    tree.write(key, data)
            except ValueError:
                self._reply(400)
                return
            self._reply(200, (
                '<?xml version="1.0" encoding="UTF-8"?>'
                "<CompleteMultipartUploadResult>"
                f"<Key>{escape(key)}</Key><ETag>{escape(_etag(data))}</ETag>"
                "</CompleteMultipartUploadResult>").encode(),
                {"Content-Type": "application/xml"})

        def _abort_upload(self, key: str, upload_id: str) -> None:
            with uploads_lock:
                upload = uploads.get(upload_id)
                if upload is None or upload["key"] != key:
                    self._reply(404)
                    return
                del uploads[upload_id]
            self._reply(204)

        # ------------------------------------------------------- methods
        def do_GET(self):  # noqa: N802 - stdlib naming
            if self._gate(b""):
                return
            key = self._key()
            if key is None:
                self._reply(404)
                return
            if key == "":
                self._list()
                return
            data = tree.read(key)
            if data is None:
                self._reply(404)
                return
            headers = _object_headers(key, data)
            headers["Content-Type"] = "application/octet-stream"
            range_header = self.headers.get("Range")
            if range_header:
                match = _RANGE_RE.match(range_header.strip())
                if match:
                    start = int(match.group(1))
                    end = int(match.group(2)) if match.group(2) else (
                        len(data) - 1)
                    if start >= len(data):
                        self._reply(416, b"", {
                            "Content-Range": f"bytes */{len(data)}"})
                        return
                    end = min(end, len(data) - 1)
                    headers["Content-Range"] = (
                        f"bytes {start}-{end}/{len(data)}")
                    self._reply(206, data[start:end + 1], headers)
                    return
            self._reply(200, data, headers)

        def do_HEAD(self):  # noqa: N802
            if self._gate(b""):
                return
            key = self._key()
            data = tree.read(key) if key else None
            if data is None:
                self._reply(404)
                return
            self._reply(200, data, _object_headers(key, data))

        def do_POST(self):  # noqa: N802
            body = self._read_body()
            if self._gate(body):
                return
            key = self._key()
            if not key:
                self._reply(404)
                return
            query = self._query()
            if "uploads" in query:
                self._initiate_upload(key)
            elif "uploadId" in query:
                self._complete_upload(key, query["uploadId"])
            else:
                self._reply(400)

        def do_PUT(self):  # noqa: N802
            body = self._read_body()
            if self._gate(body):
                return
            key = self._key()
            if not key:
                self._reply(404)
                return
            query = self._query()
            if "uploadId" in query and "partNumber" in query:
                self._put_part(key, query, body)
                return
            if_match = self.headers.get("If-Match")
            if_none = self.headers.get("If-None-Match")
            # conditional evaluation + write are one critical section:
            # this lock is what makes client-side ref CAS linearizable
            with tree.lock:
                if if_match is not None or if_none is not None:
                    current = tree.read(key)
                    if if_none == "*" and current is not None:
                        self._reply(412)
                        return
                    if if_match is not None and (
                            current is None or _etag(current) != if_match):
                        self._reply(412)
                        return
                try:
                    tree.write(key, body)
                except ValueError:
                    self._reply(400)
                    return
            self._reply(200, b"", {"ETag": _etag(body)})

        def do_DELETE(self):  # noqa: N802
            if self._gate(b""):
                return
            key = self._key()
            if not key:
                self._reply(404)
                return
            query = self._query()
            if "uploadId" in query:
                self._abort_upload(key, query["uploadId"])
                return
            if_match = self.headers.get("If-Match")
            with tree.lock:
                if if_match is not None:
                    current = tree.read(key)
                    if current is None:
                        self._reply(404)
                        return
                    if _etag(current) != if_match:
                        self._reply(412)
                        return
                deleted = tree.delete(key)
            self._reply(204 if deleted else 404)

        def log_message(self, *args):  # quiet: tests hammer the endpoint
            pass

    httpd = http.server.ThreadingHTTPServer((host, port), Handler)
    httpd.daemon_threads = True
    httpd.uploads = uploads        # in-flight multipart (orphan assertions)
    httpd.faults = faults
    httpd.inject_faults = faults.arm
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()
    auth = ""
    if credentials is not None:
        auth = (f"{quote(credentials.access_key, safe='')}:"
                f"{quote(credentials.secret_key, safe='')}@")
    suffix = "" if region == "us-east-1" else f"?region={quote(region)}"
    url = (f"s3://{auth}{httpd.server_address[0]}:{httpd.server_address[1]}"
           f"/{bucket}{suffix}")
    return httpd, url
