"""In-process stub S3 server: the dialect :class:`~repro.core.s3.S3Backend`
speaks, served from the stdlib with zero new dependencies.

One bucket, backed by a plain directory whose layout is EXACTLY the
filesystem :class:`~repro.core.store.ObjectStore` tree (``objects/ab/…``,
``refs/…``) — so the tree a stub serves is simultaneously readable as a
local store, which is what lets the sync conformance harness use a direct
``ObjectStore`` over the same directory as the ground-truth oracle for the
``s3`` leg.

Dialect (the subset of the S3 REST API the backend needs):

    GET    /<bucket>/<key>                     200 body + ETag +
                                               Last-Modified   | 404
    HEAD   /<bucket>/<key>                     200 headers     | 404
    PUT    /<bucket>/<key>                     200 + ETag
           If-Match: <etag>                    412 unless the current
                                               version matches
           If-None-Match: *                    412 unless the key is absent
    DELETE /<bucket>/<key>                     204 | 404
           If-Match: <etag>                    412 unless the current
                                               version matches
    GET    /<bucket>?list-type=2&prefix=P      ListObjectsV2-style XML:
           [&start-after=K][&max-keys=N]       sorted keys, IsTruncated

Version tokens (ETags) are the sha-256 of the stored bytes — fine for CAS
because ref semantics compare *values* (ABA on equal content is, by
definition, not a conflict).  Conditional evaluation and the write/delete
it guards happen under one server-side lock, which is what makes the
backend's read-compare-conditional-write loop linearizable per key.
"""

from __future__ import annotations

import hashlib
import os
import tempfile
import threading
from pathlib import Path
from typing import List, Optional, Tuple
from xml.sax.saxutils import escape

_MAX_KEYS_CAP = 1000


def _etag(data: bytes) -> str:
    return '"' + hashlib.sha256(data).hexdigest() + '"'


class _BucketTree:
    """Key → file mapping over one directory, with atomic writes and
    lock-guarded conditional mutations."""

    def __init__(self, root):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.lock = threading.Lock()

    def _path(self, key: str) -> Path:
        parts = key.split("/")
        for part in parts:
            if not part or part.startswith(".") or part == "..":
                raise ValueError(f"bad key {key!r}")
        return self.root.joinpath(*parts)

    def read(self, key: str) -> Optional[bytes]:
        try:
            return self._path(key).read_bytes()
        except (FileNotFoundError, ValueError):
            return None

    def mtime(self, key: str) -> Optional[float]:
        """Backing-file mtime — served as ``Last-Modified`` so clients
        can apply the GC upload-age grace window, exactly like real S3."""
        try:
            return self._path(key).stat().st_mtime
        except (FileNotFoundError, ValueError):
            return None

    def write(self, key: str, data: bytes) -> None:
        path = self._path(key)
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=".tmp-")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def delete(self, key: str) -> bool:
        try:
            self._path(key).unlink()
            return True
        except (FileNotFoundError, ValueError):
            return False

    def keys(self, prefix: str) -> List[str]:
        """All keys under ``prefix``, sorted (dotfiles — tmp writes, the
        oracle store's ``.cas-lock`` — are invisible)."""
        out: List[str] = []
        for dirpath, dirnames, filenames in os.walk(self.root):
            dirnames[:] = sorted(d for d in dirnames if not d.startswith("."))
            rel = Path(dirpath).relative_to(self.root)
            for fn in filenames:
                if fn.startswith("."):
                    continue
                key = fn if rel == Path(".") else (rel / fn).as_posix()
                if key.startswith(prefix):
                    out.append(key)
        return sorted(out)


def _list_xml(bucket: str, prefix: str, keys: List[str],
              truncated: bool) -> bytes:
    contents = "".join(
        f"<Contents><Key>{escape(k)}</Key></Contents>" for k in keys)
    return (
        '<?xml version="1.0" encoding="UTF-8"?>'
        f"<ListBucketResult><Name>{escape(bucket)}</Name>"
        f"<Prefix>{escape(prefix)}</Prefix>"
        f"<KeyCount>{len(keys)}</KeyCount>"
        f"<IsTruncated>{'true' if truncated else 'false'}</IsTruncated>"
        f"{contents}</ListBucketResult>").encode()


def serve_s3(root, *, host: str = "127.0.0.1", port: int = 0,
             bucket: str = "lake") -> Tuple[object, str]:
    """Serve ``root`` as one S3-dialect bucket on a daemon thread.

    Returns ``(httpd, url)`` where ``url`` is the ``s3://host:port/bucket``
    spelling :func:`repro.core.remote.connect` (and therefore
    ``repro remote add``/``clone``) accepts directly.  ``port=0`` picks a
    free port; call ``httpd.shutdown()`` to stop.
    """
    import email.utils
    import http.server
    import urllib.parse

    tree = _BucketTree(root)

    def _object_headers(key: str, data: bytes) -> dict:
        headers = {"ETag": _etag(data)}
        mtime = tree.mtime(key)
        if mtime is not None:
            headers["Last-Modified"] = email.utils.formatdate(
                mtime, usegmt=True)
        return headers

    class Handler(http.server.BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"

        # ------------------------------------------------------ plumbing
        def _reply(self, status: int, body: bytes = b"",
                   headers: Optional[dict] = None) -> None:
            self.send_response(status)
            for name, value in (headers or {}).items():
                self.send_header(name, value)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            if self.command != "HEAD":
                self.wfile.write(body)

        def _key(self) -> Optional[str]:
            """The object key, or None (bad bucket / bucket-level path)."""
            path = urllib.parse.urlsplit(self.path).path
            parts = path.lstrip("/").split("/", 1)
            if not parts or parts[0] != bucket:
                return None
            return urllib.parse.unquote(parts[1]) if len(parts) == 2 else ""

        # ------------------------------------------------------- listing
        def _list(self) -> None:
            query = dict(urllib.parse.parse_qsl(
                urllib.parse.urlsplit(self.path).query))
            prefix = query.get("prefix", "")
            start_after = query.get("start-after", "")
            limit = min(int(query.get("max-keys", _MAX_KEYS_CAP) or 1),
                        _MAX_KEYS_CAP)
            keys = [k for k in tree.keys(prefix)
                    if not start_after or k > start_after]
            page, truncated = keys[:limit], len(keys) > limit
            self._reply(200, _list_xml(bucket, prefix, page, truncated),
                        {"Content-Type": "application/xml"})

        # ------------------------------------------------------- methods
        def do_GET(self):  # noqa: N802 - stdlib naming
            key = self._key()
            if key is None:
                self._reply(404)
                return
            if key == "":
                self._list()
                return
            data = tree.read(key)
            if data is None:
                self._reply(404)
                return
            headers = _object_headers(key, data)
            headers["Content-Type"] = "application/octet-stream"
            self._reply(200, data, headers)

        def do_HEAD(self):  # noqa: N802
            key = self._key()
            data = tree.read(key) if key else None
            if data is None:
                self._reply(404)
                return
            self._reply(200, data, _object_headers(key, data))

        def do_PUT(self):  # noqa: N802
            key = self._key()
            if not key:
                self._reply(404)
                return
            length = int(self.headers.get("Content-Length", 0))
            body = self.rfile.read(length)
            if_match = self.headers.get("If-Match")
            if_none = self.headers.get("If-None-Match")
            # conditional evaluation + write are one critical section:
            # this lock is what makes client-side ref CAS linearizable
            with tree.lock:
                if if_match is not None or if_none is not None:
                    current = tree.read(key)
                    if if_none == "*" and current is not None:
                        self._reply(412)
                        return
                    if if_match is not None and (
                            current is None or _etag(current) != if_match):
                        self._reply(412)
                        return
                try:
                    tree.write(key, body)
                except ValueError:
                    self._reply(400)
                    return
            self._reply(200, b"", {"ETag": _etag(body)})

        def do_DELETE(self):  # noqa: N802
            key = self._key()
            if not key:
                self._reply(404)
                return
            if_match = self.headers.get("If-Match")
            with tree.lock:
                if if_match is not None:
                    current = tree.read(key)
                    if current is None:
                        self._reply(404)
                        return
                    if _etag(current) != if_match:
                        self._reply(412)
                        return
                deleted = tree.delete(key)
            self._reply(204 if deleted else 404)

        def log_message(self, *args):  # quiet: tests hammer the endpoint
            pass

    httpd = http.server.ThreadingHTTPServer((host, port), Handler)
    httpd.daemon_threads = True
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()
    url = (f"s3://{httpd.server_address[0]}:{httpd.server_address[1]}"
           f"/{bucket}")
    return httpd, url
