"""S3-compatible object-store backend: the paper's claim made literal.

The filesystem :class:`~repro.core.store.ObjectStore` mirrors an S3 key
scheme precisely so a real object-store backend is a drop-in replacement —
this module is that replacement.  :class:`S3Backend` implements the full
:class:`~repro.core.store.StoreBackend` contract over an S3-style REST
dialect, so ``push``/``pull``/``clone``, the run-cache closure transfer,
tiered reads and remote-side GC all run against commodity object storage
with no catalog service in between:

    keyspace        ``<bucket>/objects/<d0d1>/<d2...>``  framed blob payloads
                    ``<bucket>/refs/<name>``             tiny digest pointers

    GET / HEAD / PUT / DELETE <key>        object + ref bytes
    GET ?list-type=2&prefix=&start-after=  ListObjectsV2-style paged listing
    PUT + If-Match / If-None-Match         conditional writes → ref CAS

Blobs are stored in the same framed (magic + codec byte) form the
filesystem store uses at rest, so an S3 bucket and a store directory are
byte-compatible mirrors of each other, and encoded wire transfers
(``get_encoded``/``put_encoded``) pass payloads straight through without
recompressing.

Ref atomicity over plain conditional writes:

* ``cas_ref`` is a read-compare-conditional-write loop: the version token
  (ETag) captured at read time guards the write, so a racing writer makes
  the conditional PUT fail with 412 instead of silently losing an update —
  the loop re-reads and either retries (value still matches ``expected``)
  or raises :class:`~repro.core.errors.RefConflict`.
* ``cas_refs`` preflights EVERY expectation (capturing version tokens)
  before writing anything — a stale expectation updates nothing — then
  applies token-guarded conditional writes; a mid-batch 412 (concurrent
  racer) rolls the already-applied refs back.  Unlike the server-side
  ``cas_refs`` of :class:`~repro.core.remote.RemoteServer` the
  conflict-then-rollback window is briefly visible to concurrent readers
  (S3 has no multi-key transaction), which is the same contract as the
  sync layer's per-ref fallback — and what the conformance matrix pins.

A transport fault *during* a conditional write raises
:class:`~repro.core.errors.AmbiguousRefUpdate` (the write may have landed;
see docs/remote_store.md), never a plain failure.

Real-endpoint readiness (docs/remote_store.md "Wire speed"):

* **SigV4 signing** — when credentials are present (keyword, URL userinfo,
  or ``AWS_ACCESS_KEY_ID``/``AWS_SECRET_ACCESS_KEY``), every request
  carries an ``Authorization`` header computed by
  :class:`~repro.core.sigv4.SigV4Signer`; the stub's verification mode
  proves the canonical-request math in CI.
* **Retryable 5xx** — 500/502/503/504 (S3 ``SlowDown`` throttling) retry
  with capped jittered backoff, but ONLY for idempotent requests: a
  conditional write is never blindly replayed, preserving the
  ``AmbiguousRefUpdate`` contract.
* **Multipart + ranged transfer** — payloads at or above
  ``multipart_threshold`` upload via initiate/part/complete (part-level
  retry for free since part PUTs are idempotent; any failure aborts the
  upload server-side so no orphaned parts accrue) and download via ranged
  GETs (a ``Range``-first probe: a 200 means the server ignored the header
  and sent everything — the clean downgrade path).

``tests/``'s :mod:`repro.core.s3stub` serves the same dialect from the
stdlib so the whole stack is testable with zero new dependencies.
"""

from __future__ import annotations

import random
import threading
import time
import urllib.parse
import xml.etree.ElementTree as ET
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

from . import sigv4
from .errors import (AmbiguousRefUpdate, ObjectNotFound, RefConflict,
                     RefNotFound, RemoteError)
from .store import decode_frame, encode_frame, sha256_hex

_OBJ_PREFIX = "objects/"
_REF_PREFIX = "refs/"
_CAS_ATTEMPTS = 4  # re-read/retry rounds before a contended CAS gives up

#: response statuses worth retrying (transient server-side): 500 internal,
#: 502/504 gateway, 503 SlowDown — S3's throttling signal
_RETRYABLE_STATUS = frozenset({500, 502, 503, 504})
_BACKOFF_CAP = 2.0  # seconds; per-sleep ceiling for the jittered backoff


def _object_key(digest: str) -> str:
    return f"{_OBJ_PREFIX}{digest[:2]}/{digest[2:]}"


def _digest_of_key(key: str) -> str:
    return key[len(_OBJ_PREFIX):].replace("/", "", 1)


def _ref_key(name: str) -> str:
    for part in name.split("/"):
        if not part or part.startswith("."):
            raise ValueError(f"bad ref name {name!r}")
    return _REF_PREFIX + name


def _local_name(tag: str) -> str:
    """XML tag without its namespace (real S3 responses are namespaced,
    the stub's are not — match both)."""
    return tag.rsplit("}", 1)[-1]


class S3Backend:
    """``StoreBackend`` over an S3-compatible REST endpoint.

    >>> remote = S3Backend("http://127.0.0.1:9000", "lake")
    >>> remote.put(b"blob")            # PUT objects/…, framed + compressed
    >>> remote.cas_ref("branch=main", None, digest)   # If-None-Match: *

    ``pool`` bounds the HEAD/GET/PUT fan-out used to batch ``has_many`` /
    ``get_many`` / ``put_many`` — the S3 dialect has no server-side batch
    ops, so batching is client-side concurrency over per-thread
    connections.
    """

    def __init__(self, endpoint: str, bucket: str, *, timeout: float = 30.0,
                 retries: int = 2, pool: int = 8, codec: str = "auto",
                 level: int = 3,
                 credentials: Optional[sigv4.Credentials] = None,
                 region: str = "us-east-1", style: str = "path",
                 multipart_threshold: int = 8 << 20,
                 part_size: int = 8 << 20,
                 backoff: float = 0.1):
        parsed = urllib.parse.urlsplit(endpoint)
        if parsed.scheme not in ("http", "https"):
            raise ValueError(f"unsupported endpoint scheme {parsed.scheme!r}")
        if not bucket or "/" in bucket:
            raise ValueError(f"bad bucket name {bucket!r}")
        if style not in ("path", "virtual"):
            raise ValueError(f"addressing style must be 'path' or "
                             f"'virtual', got {style!r}")
        self.endpoint = endpoint.rstrip("/")
        self.bucket = bucket
        self.scheme = parsed.scheme
        endpoint_host = parsed.hostname or "127.0.0.1"
        self.style = style
        # virtual-host addressing (real S3 default): the bucket rides the
        # hostname and drops out of the path; path style keeps /bucket/key
        # (MinIO/stub spelling)
        self.host = (f"{bucket}.{endpoint_host}" if style == "virtual"
                     else endpoint_host)
        self.port = parsed.port or (443 if parsed.scheme == "https" else 80)
        self.timeout = timeout
        self.retries = retries
        self.pool = max(1, pool)
        self.codec = codec
        self.level = level
        self.region = region
        self.multipart_threshold = max(1, multipart_threshold)
        self.part_size = max(1, part_size)
        self.backoff = backoff
        if credentials is None:
            credentials = sigv4.Credentials.from_env()
        self.credentials = credentials
        self._signer = (sigv4.SigV4Signer(credentials, region=region)
                        if credentials is not None else None)
        # what http.client will put in the Host header (port elided when
        # default for the scheme) — the signer must sign the exact bytes
        default_port = 443 if self.scheme == "https" else 80
        self._host_header = (self.host if self.port == default_port
                             else f"{self.host}:{self.port}")
        self._local = threading.local()
        self._executor: Optional[ThreadPoolExecutor] = None
        self._executor_lock = threading.Lock()

    @classmethod
    def from_url(cls, url: str, **kw) -> "S3Backend":
        """``s3://[key:secret@]host[:port]/bucket[?region=R&style=S&secure=1]``
        → a configured backend.

        Credential precedence: explicit ``credentials=`` keyword, then URL
        userinfo, then the standard ``AWS_*`` environment variables (the
        constructor's fallback); no credentials anywhere → unsigned
        requests (the stub's default mode).  ``secure=1`` selects HTTPS
        (real endpoints); default is plain HTTP (stub/MinIO-in-CI)."""
        parsed = urllib.parse.urlsplit(url)
        if parsed.scheme != "s3":
            raise ValueError(f"not an s3 URL: {url!r}")
        bucket = parsed.path.strip("/")
        if not bucket:
            raise ValueError(f"s3 URL missing a bucket: {url!r}")
        host = parsed.hostname or "127.0.0.1"
        port = f":{parsed.port}" if parsed.port else ""
        params = dict(urllib.parse.parse_qsl(parsed.query,
                                             keep_blank_values=True))
        if "region" in params:
            kw.setdefault("region", params["region"])
        if "style" in params:
            kw.setdefault("style", params["style"])
        if kw.get("credentials") is None and parsed.username:
            kw["credentials"] = sigv4.Credentials(
                access_key=urllib.parse.unquote(parsed.username),
                secret_key=urllib.parse.unquote(parsed.password or ""))
        scheme = ("https" if params.get("secure", "").lower()
                  in ("1", "true", "yes") else "http")
        return cls(f"{scheme}://{host}{port}", bucket, **kw)

    # ----------------------------------------------------------- plumbing
    def _conn(self):
        conn = getattr(self._local, "conn", None)
        if conn is None:
            import http.client

            cls = (http.client.HTTPSConnection if self.scheme == "https"
                   else http.client.HTTPConnection)
            conn = cls(self.host, self.port, timeout=self.timeout)
            self._local.conn = conn
        return conn

    def _drop_conn(self) -> None:
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            try:
                conn.close()
            finally:
                self._local.conn = None

    def _sleep_backoff(self, attempt: int) -> None:
        """Capped exponential backoff with full jitter — the polite
        response to a throttling 503 (a synchronized immediate retry from
        a whole fan-out pool is exactly what SlowDown asks us to stop)."""
        delay = min(_BACKOFF_CAP, self.backoff * (2 ** attempt))
        time.sleep(delay * random.random())

    def _request(self, method: str, key: str, *, body: Optional[bytes] = None,
                 headers: Optional[Dict[str, str]] = None,
                 query: Optional[Dict[str, str]] = None,
                 idempotent: bool = True):
        """One REST round-trip → ``(status, headers, body)``.

        Idempotent requests (everything except conditional writes) retry
        on transport faults AND on retryable 5xx responses (500/502/503/504
        — S3 throttling serves ``503 SlowDown``) with capped jittered
        backoff.  A conditional write is never blindly replayed: a
        transport fault mid-flight raises :class:`AmbiguousRefUpdate`
        because the server may have applied it, and a 5xx *response*
        (the server answered — the write was not applied) surfaces to the
        caller unretried."""
        # percent-encode the key (the server decodes): ref names may carry
        # spaces/%/?/# — sent raw they would break http.client, truncate at
        # the query separator, or alias with their decoded spelling.  The
        # SigV4 canonical-URI rule is "single-encode, sign what you send",
        # so the signer sees this exact string.
        key_path = "/" + sigv4.canonical_quote(key, safe="/") if key else ""
        if self.style == "virtual":
            path = key_path or "/"
        else:
            path = "/" + self.bucket + key_path
        query_pairs = sorted((query or {}).items())
        # canonical query encoding on the wire == what gets signed; also
        # round-trips continuation tokens with spaces/%/# intact (urlencode
        # would spell a space '+', which SigV4 never does)
        query_string = sigv4.canonical_query(query_pairs)
        target = path + ("?" + query_string if query_string else "")
        attempts = 1 + (self.retries if idempotent else 0)
        last: Optional[Exception] = None
        last_status: Optional[int] = None
        result = None
        for attempt in range(attempts):
            result = None
            send_headers = dict(headers or {})
            if self._signer is not None:
                # re-signed per attempt: x-amz-date stays fresh across
                # backoff sleeps
                send_headers.update(self._signer.sign(
                    method, self._host_header, path, query_pairs,
                    body or b""))
            conn = self._conn()
            try:
                conn.request(method, target, body=body,
                             headers=send_headers)
                resp = conn.getresponse()
                data = resp.read()
                # normalize header names: servers spell ETag/Etag/etag
                # differently, and a missed version token would break CAS
                result = (resp.status,
                          {k.lower(): v for k, v in resp.getheaders()}, data)
            except Exception as e:  # noqa: BLE001 - socket/http.client zoo
                self._drop_conn()
                last = e
                continue
            if (result[0] in _RETRYABLE_STATUS and idempotent
                    and attempt + 1 < attempts):
                last_status = result[0]
                self._sleep_backoff(attempt)
                continue
            return result
        if result is not None:
            return result  # final attempt still 5xx: caller raises
        if not idempotent:
            raise AmbiguousRefUpdate(
                f"{method} {key}: transport failed after a conditional "
                f"write may have been delivered ({last!r}); ref state is "
                "unknown — re-read to resolve") from last
        detail = (f"HTTP {last_status}" if last_status is not None
                  else repr(last))
        raise RemoteError(f"{method} {key}: transport failed after "
                          f"{attempts} attempts ({detail})") from last

    def close(self) -> None:
        self._drop_conn()
        with self._executor_lock:
            executor, self._executor = self._executor, None
        if executor is not None:
            executor.shutdown(wait=True)

    # ------------------------------------------------------------ objects
    def _encode(self, data: bytes) -> bytes:
        return encode_frame(data, codec=self.codec, level=self.level)

    def put(self, data: bytes) -> str:
        digest = sha256_hex(data)
        self._upload(_object_key(digest), self._encode(data), digest)
        return digest

    def get(self, digest: str) -> bytes:
        data = decode_frame(self.get_encoded(digest),
                            what=f"object {digest}")
        if sha256_hex(data) != digest:  # never trust the wire
            raise ObjectNotFound(f"digest mismatch for {digest} from s3")
        return data

    def has(self, digest: str) -> bool:
        status, _h, _b = self._request("HEAD", _object_key(digest))
        if status == 200:
            return True
        if status == 404:
            return False
        # anything else (503 throttle, 403) must NOT read as "absent":
        # the GC mark phase trusts has(), and a swallowed server error
        # would let the sweep delete live objects
        raise RemoteError(f"head {digest}: HTTP {status}")

    def _fan_out(self, fn, items):
        """Run ``fn`` over ``items`` on a bounded pool (order-preserving).
        The pool is persistent per backend so worker threads keep their
        per-thread connections alive across calls (a sync moves many small
        chunks — a fresh pool per chunk would pay a TCP connect per worker
        per chunk and leak the old sockets to the GC)."""
        if len(items) <= 1:
            return [fn(x) for x in items]
        with self._executor_lock:
            if self._executor is None:
                self._executor = ThreadPoolExecutor(max_workers=self.pool)
            pool = self._executor
        return list(pool.map(fn, items))

    def has_many(self, digests: Iterable[str]) -> Set[str]:
        digests = list(digests)
        present = self._fan_out(self.has, digests)
        return {d for d, ok in zip(digests, present) if ok}

    def get_many(self, digests: Sequence[str]) -> Dict[str, bytes]:
        digests = list(digests)
        return dict(zip(digests, self._fan_out(self.get, digests)))

    def put_many(self, blobs: Sequence[bytes]) -> List[str]:
        return self._fan_out(self.put, list(blobs))

    def size(self, digest: str) -> int:
        """Stored (framed/compressed) size, same semantics as the
        filesystem store's on-disk size."""
        status, headers, _b = self._request("HEAD", _object_key(digest))
        if status != 200:
            raise ObjectNotFound(digest)
        return int(headers.get("content-length", 0))

    def mtime(self, digest: str) -> float:
        """Upload time from the ``Last-Modified`` response header — the
        age source for the GC grace window over S3.  A server that omits
        the header reads as *just uploaded* (never sweepable inside the
        window): the failure mode of missing age data must be "kept a
        garbage blob another hour", never "deleted an in-flight upload"."""
        return self.stat(digest)[1]

    def stat(self, digest: str) -> Tuple[int, float]:
        """``(stored size, Last-Modified)`` from ONE HEAD request — the
        per-candidate cost of a grace-window sweep over the dialect."""
        import email.utils
        import time as _time

        status, headers, _b = self._request("HEAD", _object_key(digest))
        if status == 404:
            raise ObjectNotFound(digest)
        if status != 200:
            raise RemoteError(f"head {digest}: HTTP {status}")
        size = int(headers.get("content-length", 0))
        stamp = headers.get("last-modified")
        if not stamp:
            return size, _time.time()
        try:
            return size, email.utils.parsedate_to_datetime(
                stamp).timestamp()
        except (TypeError, ValueError):
            return size, _time.time()

    def touch_many(self, digests: Sequence[str]) -> int:
        """S3 has no cheap mtime refresh (a self-copy per object would
        cost a mutating request each) — report 0 touched; pushes that
        dedup against an S3 remote stay protected by the GC generation
        token's retry path instead."""
        return 0

    def delete_object(self, digest: str) -> bool:
        """Remote-side GC sweep primitive.  Idempotent: missing → False."""
        status, _h, _b = self._request("DELETE", _object_key(digest))
        if status in (200, 204):
            return True
        if status == 404:
            return False
        raise RemoteError(f"delete {digest}: HTTP {status}")

    # ------------------------------------------------- large-blob transfer
    def _upload(self, key: str, payload: bytes, what: str) -> None:
        """Simple PUT below the multipart threshold, initiate/part/complete
        at or above it."""
        if len(payload) >= self.multipart_threshold:
            self._put_multipart(key, payload, what)
            return
        status, _h, _b = self._request("PUT", key, body=payload)
        if status not in (200, 201, 204):
            raise RemoteError(f"put {what}: HTTP {status}")

    def _put_multipart(self, key: str, payload: bytes, what: str) -> None:
        """Multipart upload with abort-on-failure.

        Part PUTs are idempotent (same bytes to the same part number), so
        they ride ``_request``'s retry loop for free.  ANY failure after
        initiation aborts the upload server-side — a crashed push must not
        leave orphaned parts accruing storage charges."""
        status, _h, body = self._request("POST", key, body=b"",
                                         query={"uploads": ""})
        if status != 200:
            raise RemoteError(f"multipart initiate {what}: HTTP {status}")
        upload_id = None
        try:
            root = ET.fromstring(body)
        except ET.ParseError as e:
            raise RemoteError(
                f"multipart initiate {what}: malformed XML ({e})") from e
        for el in root.iter():
            if _local_name(el.tag) == "UploadId" and el.text:
                upload_id = el.text.strip()
                break
        if not upload_id:
            raise RemoteError(f"multipart initiate {what}: no UploadId")
        try:
            part_numbers: List[int] = []
            for off in range(0, len(payload), self.part_size):
                number = off // self.part_size + 1
                status, _h, _b = self._request(
                    "PUT", key, body=payload[off:off + self.part_size],
                    query={"uploadId": upload_id,
                           "partNumber": str(number)})
                if status not in (200, 201, 204):
                    raise RemoteError(
                        f"multipart part {number} of {what}: HTTP {status}")
                part_numbers.append(number)
            complete = ("<CompleteMultipartUpload>" + "".join(
                f"<Part><PartNumber>{n}</PartNumber></Part>"
                for n in part_numbers) +
                "</CompleteMultipartUpload>").encode()
            status, _h, _b = self._request(
                "POST", key, body=complete, query={"uploadId": upload_id})
            if status != 200:
                raise RemoteError(
                    f"multipart complete {what}: HTTP {status}")
        except BaseException:
            try:  # best-effort abort: no orphaned parts
                self._request("DELETE", key,
                              query={"uploadId": upload_id})
            except Exception:  # noqa: BLE001 - original error wins
                pass
            raise

    @staticmethod
    def _content_range_total(value: Optional[str]) -> Optional[int]:
        """``bytes 0-99/1234`` → 1234 (None when absent/opaque)."""
        if not value or "/" not in value:
            return None
        total = value.rsplit("/", 1)[1].strip()
        return int(total) if total.isdigit() else None

    # -------------------------------------------------- encoded payloads
    def get_encoded(self, digest: str) -> bytes:
        """Framed payload fetch via ranged GET.

        The first request carries ``Range: bytes=0-(part_size-1)`` as a
        probe: a 200 means the server ignored the header and sent the
        whole object (the downgrade path — old stubs, simple proxies); a
        206 carries ``Content-Range`` naming the total, and the remainder
        streams in sequential ``part_size`` ranges (each idempotent, so a
        dropped connection re-fetches one range, not the whole blob).
        Ranges are fetched on the calling thread — ``get_many_encoded``
        already fans out per blob and nesting pools would deadlock."""
        key = _object_key(digest)
        status, headers, body = self._request(
            "GET", key, headers={"Range": f"bytes=0-{self.part_size - 1}"})
        if status == 404:
            raise ObjectNotFound(digest)
        if status == 200:
            return body  # server ignored Range: whole object in one go
        if status != 206:
            raise RemoteError(f"get {digest}: HTTP {status}")
        total = self._content_range_total(headers.get("content-range"))
        if total is None or total <= len(body):
            return body
        parts = [body]
        got = len(body)
        while got < total:
            end = min(got + self.part_size, total) - 1
            status, _h, chunk = self._request(
                "GET", key, headers={"Range": f"bytes={got}-{end}"})
            if status == 200:
                return chunk  # downgraded mid-flight: full body came back
            if status != 206 or not chunk:
                raise RemoteError(
                    f"get {digest}: ranged fetch at {got} → HTTP {status}")
            parts.append(chunk)
            got += len(chunk)
        return b"".join(parts)

    def put_encoded(self, payload: bytes) -> str:
        # decode to learn + verify the digest, upload the ORIGINAL payload:
        # compression paid at the source is never re-paid here
        digest = sha256_hex(decode_frame(payload, what="encoded payload"))
        self._upload(_object_key(digest), payload, digest)
        return digest

    def get_many_encoded(self, digests: Sequence[str]) -> Dict[str, bytes]:
        digests = list(digests)
        return dict(zip(digests, self._fan_out(self.get_encoded, digests)))

    def put_many_encoded(self, payloads: Sequence[bytes],
                         digests: Optional[Sequence[str]] = None
                         ) -> List[str]:
        # the digest hint is ignored: the S3 dialect has no server-side
        # verification, so the client-side decode here is the only check
        # standing between a corrupt payload and the bucket
        return self._fan_out(self.put_encoded, list(payloads))

    # ------------------------------------------------------------ listing
    def _list_keys(self, prefix: str, *, start_after: Optional[str],
                   limit: int) -> Tuple[List[str], bool]:
        """One ListObjectsV2-style page: ``(sorted keys, truncated)``.

        Truncation comes from the response's ``IsTruncated`` field, never
        from comparing the page size to ``limit`` — servers cap max-keys
        (S3: 1000), so a short page can still have more behind it."""
        query = {"list-type": "2", "prefix": prefix,
                 "max-keys": str(max(1, limit))}
        if start_after:
            query["start-after"] = start_after
        status, _h, body = self._request("GET", "", query=query)
        if status != 200:
            raise RemoteError(f"list {prefix!r}: HTTP {status}")
        try:
            root = ET.fromstring(body)
        except ET.ParseError as e:
            raise RemoteError(f"list {prefix!r}: malformed XML ({e})") from e
        keys: List[str] = []
        truncated = False
        for el in root.iter():
            name = _local_name(el.tag)
            if name == "Contents":
                for child in el:
                    if _local_name(child.tag) == "Key":
                        keys.append(child.text or "")
            elif name == "IsTruncated":
                truncated = (el.text or "").strip().lower() == "true"
        return keys, truncated

    def list_objects(self, *, page_token: Optional[str] = None,
                     limit: int = 1000) -> Tuple[List[str], Optional[str]]:
        limit = max(1, limit)
        start = _object_key(page_token) if page_token else None
        keys, truncated = self._list_keys(_OBJ_PREFIX, start_after=start,
                                          limit=limit)
        page = [_digest_of_key(k) for k in keys]
        return page, (page[-1] if page and truncated else None)

    def iter_objects(self) -> Iterator[str]:
        token: Optional[str] = None
        while True:
            page, token = self.list_objects(page_token=token)
            yield from page
            if token is None:
                return

    # --------------------------------------------------------------- refs
    def _read_ref(self, name: str) -> Tuple[Optional[str], Optional[str]]:
        """Current ``(value, version_token)`` of a ref; (None, None) when
        it does not exist.  The token guards conditional writes."""
        status, headers, body = self._request("GET", _ref_key(name))
        if status == 404:
            return None, None
        if status != 200:
            raise RemoteError(f"get_ref {name}: HTTP {status}")
        return body.decode().strip(), headers.get("etag")

    def get_ref(self, name: str) -> str:
        value, _etag = self._read_ref(name)
        if value is None:
            raise RefNotFound(name)
        return value

    def set_ref(self, name: str, digest: str) -> None:
        status, _h, _b = self._request(
            "PUT", _ref_key(name), body=digest.encode())
        if status not in (200, 201, 204):
            raise RemoteError(f"set_ref {name}: HTTP {status}")

    def delete_ref(self, name: str) -> None:
        status, _h, _b = self._request("DELETE", _ref_key(name))
        if status == 404:
            raise RefNotFound(name)
        if status not in (200, 204):
            raise RemoteError(f"delete_ref {name}: HTTP {status}")

    def _conditional_put(self, name: str, digest: str,
                         etag: Optional[str]) -> Tuple[bool, Optional[str]]:
        """Token-guarded ref write: ``If-Match`` against the captured
        version, ``If-None-Match: *`` for create-only.  Returns
        ``(applied, new_etag)``; False means 412 (a racer moved the ref
        between our read and this write)."""
        headers = ({"If-Match": etag} if etag is not None
                   else {"If-None-Match": "*"})
        status, resp_headers, _b = self._request(
            "PUT", _ref_key(name), body=digest.encode(), headers=headers,
            idempotent=False)
        if status == 412:
            return False, None
        if status not in (200, 201, 204):
            raise RemoteError(f"cas_ref {name}: HTTP {status}")
        return True, resp_headers.get("etag")

    def _conditional_delete(self, name: str, etag: str) -> None:
        """Token-guarded ref delete (rollback of a create): 412 means a
        racer moved the ref since our write — their update stays."""
        status, _h, _b = self._request(
            "DELETE", _ref_key(name), headers={"If-Match": etag},
            idempotent=False)
        if status not in (200, 204, 404, 412):
            raise RemoteError(f"conditional delete {name}: HTTP {status}")

    def cas_ref(self, name: str, expected: Optional[str], new: str) -> None:
        """Compare-and-set via conditional write.

        Value semantics match :meth:`ObjectStore.cas_ref` exactly: the
        *current value* is compared against ``expected``; the version
        token only makes the read-compare-write atomic (a 412 from a
        concurrent writer re-reads instead of clobbering)."""
        for _ in range(_CAS_ATTEMPTS):
            current, etag = self._read_ref(name)
            if current != expected:
                raise RefConflict(
                    f"ref {name}: expected {expected!r}, found {current!r}")
            applied, _new_etag = self._conditional_put(name, new, etag)
            if applied:
                return
        raise RefConflict(
            f"ref {name}: conditional write kept losing races "
            f"({_CAS_ATTEMPTS} attempts)")

    def cas_refs(self, updates: Sequence[Tuple[str, Optional[str], str]]
                 ) -> None:
        """Multi-ref CAS over conditional writes.

        Every expectation is validated (and its version token captured)
        before ANY write — one stale expectation updates nothing.  The
        token-guarded writes then apply in order; a mid-batch 412 from a
        concurrent racer rolls the applied prefix back.  See the module
        docstring for how this differs from a server-side transactional
        ``cas_refs``."""
        tokens: List[Optional[str]] = []
        for name, expected, _new in updates:
            current, etag = self._read_ref(name)
            if current != expected:
                raise RefConflict(
                    f"ref {name}: expected {expected!r}, found {current!r} "
                    "(no ref in this batch was updated)")
            tokens.append(etag)
        applied: List[Tuple[str, Optional[str], Optional[str]]] = []
        for (name, expected, new), etag in zip(updates, tokens):
            try:
                ok, new_etag = self._conditional_put(name, new, etag)
            except AmbiguousRefUpdate:
                # the write may have landed before the fault: resolve by
                # re-read so a mid-batch fault can never leave the prefix
                # torn behind an "unknown" diagnosis
                try:
                    current, cur_etag = self._read_ref(name)
                except RemoteError:
                    self._rollback(applied)
                    raise
                if current == new:
                    ok, new_etag = True, cur_etag  # it DID apply: continue
                else:
                    self._rollback(applied)
                    raise RemoteError(
                        f"ref {name}: transport fault during conditional "
                        "write; the ref was re-read and verified unchanged "
                        "— applied refs were rolled back") from None
            except RemoteError:
                self._rollback(applied)
                raise
            if not ok:
                self._rollback(applied)
                raise RefConflict(
                    f"ref {name}: lost a race mid-batch; already-applied "
                    "refs were rolled back")
            applied.append((name, expected, new_etag))

    def _rollback(self, applied) -> None:
        """Best-effort restore of already-applied conditional writes."""
        for name, expected, new_etag in reversed(applied):
            try:
                if expected is None:
                    # we created it: undo is a delete — guarded by OUR
                    # write's token, so a racer who CASed the ref onward
                    # since keeps their committed update (412, not clobber)
                    if new_etag is not None:
                        self._conditional_delete(name, new_etag)
                    else:
                        self.delete_ref(name)
                else:
                    # guarded by OUR write's token: if a racer moved the
                    # ref since, the 412 leaves their update in place
                    self._conditional_put(name, expected, new_etag)
            except (RemoteError, RefConflict, RefNotFound):
                pass  # best effort: the racer's update wins

    def iter_refs(self, prefix: str = "") -> Iterator[str]:
        token: Optional[str] = None
        while True:
            page, token = self.list_refs(prefix, page_token=token)
            for name, _digest in page:
                yield name
            if token is None:
                return

    def list_refs(self, prefix: str = "", *,
                  page_token: Optional[str] = None, limit: int = 1000
                  ) -> Tuple[List[Tuple[str, str]], Optional[str]]:
        limit = max(1, limit)
        start = _REF_PREFIX + page_token if page_token else None
        keys, truncated = self._list_keys(_REF_PREFIX + prefix,
                                          start_after=start, limit=limit)
        names = [k[len(_REF_PREFIX):] for k in keys]
        values = self._fan_out(lambda n: self._read_ref(n)[0], names)
        page = [(n, v) for n, v in zip(names, values) if v is not None]
        return page, (names[-1] if names and truncated else None)
